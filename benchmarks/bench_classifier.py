"""Paper Table 2 + Fig. 1: LEAR classifier precision/recall and feature
importance (sentinel features vs original q-d features)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_experiment
from repro.core.lear import augment_features, build_continue_labels
from repro.metrics.classification import precision_recall


def run(exp_name: str, sentinel_idx: int = 0, threshold: float = 0.5) -> dict:
    exp = get_experiment(exp_name)
    s = exp.spec.sentinels[sentinel_idx]
    clf = exp.classifiers[s]
    ds = exp.splits["test"]
    per_tree = exp.scores("test")
    partial = per_tree[..., :s].sum(-1) + exp.ranker.base_score
    full = per_tree.sum(-1) + exp.ranker.base_score
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)

    aug = augment_features(jnp.asarray(ds.X), partial, mask)
    cont_true = build_continue_labels(full, labels, mask, k=15)
    cont_pred = clf.continue_mask(aug, mask, threshold=threshold)
    pr = precision_recall(cont_pred, cont_true, mask)
    pr["sentinel"] = s
    pr["dataset"] = exp_name

    # Fig. 1 analogue: split-frequency importance of the 4 sentinel features
    # (score, rank, minmax score, n_candidates) vs original features.
    F = ds.X.shape[-1]
    feats = np.asarray(clf.forest.feature).reshape(-1)
    thr = np.asarray(clf.forest.threshold).reshape(-1)
    used = feats[np.isfinite(thr)]
    counts = np.bincount(used, minlength=F + 4)
    names = ["partial_score", "sentinel_rank", "minmax_score", "n_candidates"]
    top = np.argsort(-counts)[:10]
    pr["top_features"] = [
        (names[i - F] if i >= F else f"qd_feat_{i}", int(counts[i]))
        for i in top if counts[i] > 0
    ]
    pr["sentinel_feature_rank"] = int(
        min((list(np.argsort(-counts)).index(F + j) for j in range(4)))
    ) + 1
    return pr


def main(csv: bool = True):
    out = []
    for name in ("msn1", "istella"):
        pr = run(name)
        out.append(pr)
        if csv:
            print(
                f"table2_{name},exit_precision={pr['exit_precision']:.2f},"
                f"exit_recall={pr['exit_recall']:.2f},"
                f"continue_precision={pr['continue_precision']:.2f},"
                f"continue_recall={pr['continue_recall']:.2f}"
            )
            print(f"fig1_{name}_top_features,{pr['top_features'][:5]}")
    return out


if __name__ == "__main__":
    main()
