"""Kernel & cascade micro-benchmarks (QuickScorer-adapted forest scoring).

CPU wall times are NOT TPU predictions; the derived columns (bytes and
FLOPs per doc·tree from the kernel's own cost model) are the
hardware-independent part. ``cascade_compacted`` vs ``cascade_full``
demonstrates the batch-compaction speedup mechanism end to end.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeRanker
from repro.core.strategies import ert_continue
from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import score_bitvector, score_level
from repro.kernels.ops import forest_score


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def main(csv: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    for n_docs, n_trees, n_feat in ((512, 256, 136), (2048, 512, 136)):
        ens = random_ensemble(0, n_trees=n_trees, depth=6, n_features=n_feat)
        X = jnp.asarray(rng.normal(size=(n_docs, n_feat)).astype(np.float32))
        t_bv = _time(jax.jit(lambda x: score_bitvector(ens, x)), X)
        t_lv = _time(jax.jit(lambda x: score_level(ens, x)), X)
        t_pk = _time(lambda x: forest_score(ens, x, interpret=True), X, iters=2)
        # Cost model per doc·tree: 63 compares + 126 u32 ANDs + 2 popcnt +
        # leaf contraction ≈ 200 VPU ops; node tables ≈ 63·18B VMEM-resident.
        per_dt = n_docs * n_trees
        rows.append((f"score_bitvector_{n_docs}x{n_trees}", t_bv,
                     f"ops_per_doctree=200,n={per_dt}"))
        rows.append((f"score_level_{n_docs}x{n_trees}", t_lv,
                     f"gather_steps=6,n={per_dt}"))
        rows.append((f"pallas_interpret_{n_docs}x{n_trees}", t_pk,
                     "validates_kernel_path"))

    # Cascade: compacted vs full at a 10% continue rate.
    ens = random_ensemble(1, n_trees=256, depth=6, n_features=64)
    Q, D, F = 64, 64, 64
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = CascadeRanker(
        ensemble=ens, sentinel=25,
        strategy=lambda p, m: ert_continue(p, m, k_s=6),
    )
    ref = cascade.rank(X, mask)
    cap = int(ref.continue_mask.sum()) + 64
    t_full = _time(lambda x: score_bitvector(ens, x.reshape(Q * D, F)), X)
    t_comp = _time(
        lambda x: cascade.rank_compacted(x, mask, capacity=cap).scores, X,
        iters=2,
    )
    rows.append(("cascade_full_scoring", t_full, "trees=256,all_docs"))
    rows.append((
        "cascade_compacted", t_comp,
        f"trees_traversed_speedup={ref.speedup:.2f}",
    ))

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
