"""Kernel & cascade micro-benchmarks (QuickScorer-adapted forest scoring).

CPU wall times are NOT TPU predictions; the derived columns (bytes and
FLOPs per doc·tree from the kernel's own cost model) are the
hardware-independent part. ``cascade_compacted`` vs ``cascade_full``
demonstrates the batch-compaction speedup mechanism end to end; the
``multi_sentinel`` section measures the progressive engine against the
seed's per-stage execution (1 segmented launch vs S launches, cumsum vs
argsort compaction, cached vs per-call re-padded buffers); the
``fused_vs_staged`` section sweeps the jit-fused progressive engine's two
execution modes across continue rates and records the crossover the
serving cost model should sit near; the ``leaf_gather`` section sweeps the
kernel's three leaf-value resolution paths (one-hot / select tree / MXU
contraction) across leaf counts; the ``blocked_rank`` section sweeps the
direct vs blocked sort-free per-query ranking across candidate counts;
the ``hybrid`` section runs the dense-stage-0 cascade (distilled proxy
gate) against the all-trees cascade at matched NDCG@10 and records the
trees-traversed reduction.

Besides the CSV on stdout, results are written machine-readable to
``BENCH_kernels.json`` at the repo root so the perf trajectory is tracked
across PRs. ``main(smoke=True, json_path=...)`` runs a minutes-scale tiny
configuration of every section for CI (``benchmarks/check_bench.py``)
without clobbering the tracked numbers.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.stage import EngineConfig
from repro.core.compaction import compact_indices_argsort, compact_indices_cumsum
from repro.core.features import (
    RANK_BLOCKED_MIN_D,
    query_ranks_blocked,
    query_ranks_direct,
)
from repro.core.strategies import ert_continue
from repro.forest.ensemble import random_ensemble, slice_trees
from repro.forest.scoring import score_bitvector, score_level
from repro.kernels.forest_score import LEAF_GATHERS
from repro.kernels.ops import resolve_leaf_gather
from repro.kernels.ops import (
    ENGINE_BLOCK_B,
    forest_score,
    forest_score_range,
    forest_score_segments,
    padded_forest,
)
from repro.metrics.ranking import rank_from_scores
from repro.metrics.speedup import speedup_vs_full

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")


def _time(fn, *args, iters: int = 5) -> float:
    """Min-of-N wall time in µs (min is robust to scheduler/GC noise)."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # µs


def _time_group(fns, *args, iters: int = 5) -> list[float]:
    """Min-of-N for several functions with INTERLEAVED iterations.

    Background load on a shared box drifts over seconds; timing candidates
    back-to-back within each iteration keeps comparisons order-unbiased.
    """
    for fn in fns:
        fn(*args)  # compile
    best = [float("inf")] * len(fns)
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]  # µs


def _seed_cascade_compacted(ens, sentinel, X, mask, capacity, k_s):
    """The seed PR's production path, reproduced for comparison: per-call
    ensemble re-slice (⇒ per-call kernel re-pad, fresh cache), O(n log n)
    argsort compaction, and the hidden ``int(overflow)`` device sync."""
    Q, D, F = X.shape
    head = slice_trees(ens, 0, sentinel)          # fresh objects: no cache
    tail = slice_trees(ens, sentinel, ens.n_trees)
    partial = forest_score(head, X.reshape(Q * D, F)).reshape(Q, D)
    cont = ert_continue(partial, mask, k_s=k_s)
    sel, n_cont = compact_indices_argsort(cont.reshape(Q * D), capacity)
    x_sel = X.reshape(Q * D, F)[sel]
    tail_sel = forest_score(tail, x_sel)
    valid = jnp.arange(capacity) < n_cont
    deltas = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
        jnp.where(valid, tail_sel, 0.0)
    )
    scores = partial + deltas.reshape(Q, D)
    overflow = int(jnp.maximum(n_cont - capacity, 0))  # the seed's hidden sync
    sp = speedup_vs_full(cont, mask, sentinel, ens.n_trees)  # per-call stats
    return scores, overflow, sp


def _bench_scoring(rows, smoke=False):
    rng = np.random.default_rng(0)
    sizes = ((64, 32, 24),) if smoke else ((512, 256, 136), (2048, 512, 136))
    for n_docs, n_trees, n_feat in sizes:
        ens = random_ensemble(0, n_trees=n_trees, depth=6, n_features=n_feat)
        X = jnp.asarray(rng.normal(size=(n_docs, n_feat)).astype(np.float32))
        t_bv = _time(jax.jit(lambda x: score_bitvector(ens, x)), X)
        t_lv = _time(jax.jit(lambda x: score_level(ens, x)), X)
        t_pk = _time(lambda x: forest_score(ens, x, interpret=True), X, iters=2)
        # Cost model per doc·tree: 63 compares + 126 u32 ANDs + 2 popcnt +
        # leaf contraction ≈ 200 VPU ops; node tables ≈ 63·18B VMEM-resident.
        per_dt = n_docs * n_trees
        rows.append((f"score_bitvector_{n_docs}x{n_trees}", t_bv,
                     f"ops_per_doctree=200,n={per_dt}"))
        rows.append((f"score_level_{n_docs}x{n_trees}", t_lv,
                     f"gather_steps=6,n={per_dt}"))
        rows.append((f"pallas_interpret_{n_docs}x{n_trees}", t_pk,
                     "validates_kernel_path"))


def _bench_cascade(rows, smoke=False):
    # Cascade at a ~10% continue rate: seed path vs the new engine, at a
    # throughput batch (kernel-bound: paths should tie — the engine's wins
    # are launches/HBM, invisible to CPU interpret) and a latency batch
    # (overhead-bound: re-pad + argsort + sync elimination shows directly).
    rng = np.random.default_rng(1)
    n_trees = 64 if smoke else 256
    ens = random_ensemble(1, n_trees=n_trees, depth=6, n_features=64)
    sentinel, k_s = 25, 6                      # 6/64 ≈ 9.4% continue
    cascade = CascadeRanker(
        ensemble=ens, sentinel=sentinel,
        strategy=lambda p, m: ert_continue(p, m, k_s=k_s),
    )
    batches = (
        (("batch8x64", 8, 64, 64),) if smoke
        else (("batch64x64", 64, 64, 64), ("batch8x64", 8, 64, 64))
    )
    for tag, Q, D, F in batches:
        X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
        mask = jnp.ones((Q, D), bool)
        ref = cascade.rank(X, mask)
        cap = int(ref.continue_mask.sum()) + 64

        if tag == "batch64x64":
            t_full = _time(
                lambda x, n=Q * D, f=F: score_bitvector(ens, x.reshape(n, f)), X
            )
            rows.append(("cascade_full_scoring", t_full, "trees=256,all_docs"))
        t_seed, t_comp, t_prog = _time_group(
            [
                lambda x, m=mask, c=cap: _seed_cascade_compacted(
                    ens, sentinel, x, m, c, k_s
                )[0],
                lambda x, m=mask, c=cap: cascade.rank_compacted(
                    x, m, capacity=c
                ).scores,
                lambda x, m=mask, c=cap: cascade.rank_progressive(
                    x, m, EngineConfig.trees([sentinel], capacities=c)
                ).scores,
            ],
            X, iters=2 if smoke else 16,
        )
        rows.append((f"cascade_compacted_seed_equiv_{tag}", t_seed,
                     "argsort+reslice+sync,continue_rate=0.094"))
        rows.append((f"cascade_compacted_{tag}", t_comp,
                     f"trees_traversed_speedup={ref.speedup:.2f},"
                     f"vs_seed={t_seed / max(t_comp, 1e-9):.2f}x"))
        rows.append((f"cascade_progressive_s1_{tag}", t_prog,
                     f"vs_seed_speedup={t_seed / max(t_prog, 1e-9):.2f}x"))


def _bench_multi_sentinel(rows, smoke=False):
    # S=3 head: one segmented launch vs S per-stage launches over the same
    # trees, plus the progressive engine end to end.
    rng = np.random.default_rng(2)
    ens = random_ensemble(2, n_trees=128 if smoke else 256, depth=6, n_features=64)
    Q, D, F = (8, 64, 64) if smoke else (32, 64, 64)
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    flat = X.reshape(Q * D, F)
    mask = jnp.ones((Q, D), bool)
    sentinels = (16, 32, 64)
    pf = padded_forest(ens, boundaries=(*sentinels, ens.n_trees))

    t_one, t_s = _time_group(
        [
            lambda x: forest_score_segments(pf, x, n_segments=3),
            lambda x: [
                forest_score_range(pf, x, seg_lo=k, seg_hi=k + 1)
                for k in range(3)
            ][-1],
        ],
        flat, iters=2 if smoke else 16,
    )
    rows.append(("head_segmented_1_launch", t_one,
                 f"S=3,trees=64,docs={Q * D}"))
    rows.append(("head_per_stage_3_launches", t_s,
                 f"vs_segmented={t_s / max(t_one, 1e-9):.2f}x"))

    cascade = CascadeRanker(
        ensemble=ens, sentinel=sentinels[0],
        strategy=lambda p, m: ert_continue(p, m, k_s=6),
    )
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (26, 13, 6)
    ]
    cfg_s3 = EngineConfig.trees(
        sentinels, tuple(strategies), capacities=512
    )
    t_prog3 = _time(
        lambda x: cascade.rank_progressive(x, mask, cfg_s3).scores,
        X, iters=2 if smoke else 5,
    )
    rows.append(("cascade_progressive_s3", t_prog3,
                 "launches=1_segmented+1_tail,continue_rate=0.094"))

    # Compaction primitive: O(n) cumsum vs O(n log n) argsort.
    it = 10 if smoke else 200
    cont = jnp.asarray(rng.random(Q * D) < 0.1)
    t_cum = _time(lambda c: compact_indices_cumsum(c, 256)[0], cont, iters=it)
    t_arg = _time(lambda c: compact_indices_argsort(c, 256)[0], cont, iters=it)
    rows.append(("compaction_cumsum", t_cum, f"n={Q * D},capacity=256"))
    rows.append(("compaction_argsort", t_arg,
                 f"vs_cumsum={t_arg / max(t_cum, 1e-9):.2f}x"))


def _bench_fused_vs_staged(rows, extra, smoke=False):
    """Jit-fused progressive engine: fused head vs per-stage tails, across
    continue rates. Staged scores segment k only on stage-(k-1) compacted
    survivors — it wins when survivors shrink fast (head work saved dwarfs
    the extra launches); fused wins when survivors stay large. The recorded
    crossover is what RankingService's cost model should reproduce.

    Also runs the combined mode="auto" program at every swept rate with the
    rate injected as the survivor estimate, recording (a) the branch the
    ON-DEVICE pick took, (b) the branch the host cost model picks at the
    bench-calibrated launch overhead, and (c) that the combined program's
    scores are bit-exact with the picked branch's dedicated run — the
    acceptance contract, measured where the crossover is."""
    from repro.metrics.speedup import progressive_cost_model
    from repro.serve.calibration import (
        calibrate_launch_overhead_trees,
        last_calibration,
    )

    rng = np.random.default_rng(3)
    n_trees = 128 if smoke else 192
    ens = random_ensemble(3, n_trees=n_trees, depth=6, n_features=64)
    Q, D, F = (4, 64, 64) if smoke else (16, 64, 64)
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    sentinels = [32, 64, 96]
    cascade = CascadeRanker(
        ensemble=ens, sentinel=sentinels[0],
        strategy=lambda p, m: ert_continue(p, m, k_s=8),
    )
    # The calibration report lands in the payload (main() rewrites the
    # JSON wholesale, so merging into the file here would be clobbered).
    loh = calibrate_launch_overhead_trees()
    extra["launch_calibration"] = {
        **(last_calibration() or {}), "launch_overhead_trees": round(loh, 1),
    }
    sweep = []
    rates = (0.05, 0.5) if smoke else (0.05, 0.15, 0.3, 0.5, 0.8)
    for rate in rates:
        k_s = max(1, int(rate * D))
        strategies = [
            (lambda p, m, k=k_s: ert_continue(p, m, k_s=k)) for _ in sentinels
        ]
        cap = bucket_capacity(int(Q * k_s * 1.25), Q * D)
        def cfg(mode, loh=0.0, s=tuple(strategies), c=cap):
            return EngineConfig.trees(
                sentinels, s, capacities=c, mode=mode,
                launch_overhead_trees=loh,
            )

        t_fused, t_staged = _time_group(
            [
                lambda x, c=cfg(mode): cascade.rank_progressive(
                    x, mask, c
                ).scores
                for mode in ("fused", "staged")
            ],
            X, iters=2 if smoke else 8,
        )
        # Combined program at this rate: device pick vs host reference,
        # and bit-exactness with the picked branch's dedicated run.
        ema = [rate * Q * D] * len(sentinels)
        auto = cascade.rank_progressive(
            X, mask, cfg("auto", loh),
            stage_ema=jnp.asarray(ema, jnp.float32),
        )
        device_pick = "staged" if bool(auto.picked_staged) else "fused"
        # block_b must match what the in-program pick was traced with
        # (ENGINE_BLOCK_B) or pick_agrees would compare different models.
        cost = {
            m: progressive_cost_model(
                Q * D, ema, sentinels, ens.n_trees, m,
                launch_overhead_trees=loh,
                stage_capacities=[cap] * len(sentinels),
                block_b=ENGINE_BLOCK_B,
            )
            for m in ("fused", "staged")
        }
        host_pick = "staged" if cost["staged"] < cost["fused"] else "fused"
        picked_ref = cascade.rank_progressive(X, mask, cfg(device_pick))
        exact = bool(
            (np.asarray(auto.scores) == np.asarray(picked_ref.scores)).all()
        )
        sweep.append(
            {
                "continue_rate": rate,
                "fused_us": round(t_fused, 1),
                "staged_us": round(t_staged, 1),
                "staged_vs_fused": round(t_fused / max(t_staged, 1e-9), 2),
                "device_pick": device_pick,
                "host_model_pick": host_pick,
                "pick_agrees": device_pick == host_pick,
                "auto_bitexact_with_picked_branch": exact,
            }
        )
        rows.append((f"cascade_s3_fused_r{rate:.2f}", t_fused,
                     f"trees={n_trees},docs={Q * D},capacity={cap}"))
        rows.append((f"cascade_s3_staged_r{rate:.2f}", t_staged,
                     f"vs_fused={t_fused / max(t_staged, 1e-9):.2f}x"))

    # Crossover: the first swept rate at which fused stops losing.
    crossover = next(
        (p["continue_rate"] for p in sweep if p["staged_vs_fused"] <= 1.0),
        None,
    )
    extra["fused_vs_staged"] = {
        "sentinels": sentinels,
        "n_trees": n_trees,
        "docs": Q * D,
        "launch_overhead_trees_calibrated": round(loh, 1),
        "sweep": sweep,
        "crossover_continue_rate": crossover,
        "note": ("staged faster below the crossover rate, fused at/above; "
                 "null crossover = staged won the whole sweep; device_pick "
                 "is the in-program lax.cond choice at the calibrated "
                 "launch overhead"),
    }


def _bench_leaf_gather(rows, extra, smoke=False):
    """Kernel leaf-value resolution: one-hot vs select tree vs MXU
    contraction, across leaf counts. All three move the same f32 values
    (asserted per point) — the sweep records which one is cheapest and
    that the auto-resolved path is no slower than the one-hot baseline at
    the serving-default L=64."""
    rng = np.random.default_rng(4)
    n_docs, n_trees, n_feat = (256, 32, 32) if smoke else (2048, 128, 64)
    depths = (6,) if smoke else (3, 5, 6)   # L = 8, 32, 64
    iters = 2 if smoke else 8
    sweep = []
    for depth in depths:
        L = 1 << depth
        ens = random_ensemble(40 + depth, n_trees=n_trees, depth=depth,
                              n_features=n_feat)
        X = jnp.asarray(rng.normal(size=(n_docs, n_feat)).astype(np.float32))
        pfs = {
            lg: padded_forest(ens, leaf_gather=lg) for lg in LEAF_GATHERS
        }
        times = dict(zip(LEAF_GATHERS, _time_group(
            [
                (lambda x, pf=pfs[lg]: forest_score_range(pf, x))
                for lg in LEAF_GATHERS
            ],
            X, iters=iters,
        )))
        outs = {
            lg: np.asarray(forest_score_range(pfs[lg], X))
            for lg in LEAF_GATHERS
        }
        bitexact = all(
            (outs[lg] == outs["onehot"]).all() for lg in LEAF_GATHERS
        )
        auto = resolve_leaf_gather(L)
        point = {
            "n_leaves": L,
            "auto_pick": auto,
            **{f"{lg}_us": round(times[lg], 1) for lg in LEAF_GATHERS},
            "auto_vs_onehot": round(
                times["onehot"] / max(times[auto], 1e-9), 2
            ),
            "bitexact": bool(bitexact),
        }
        sweep.append(point)
        for lg in LEAF_GATHERS:
            rows.append((f"leaf_gather_{lg}_L{L}", times[lg],
                         f"docs={n_docs},trees={n_trees},"
                         f"vs_onehot={times['onehot'] / max(times[lg], 1e-9):.2f}x"))
    extra["leaf_gather"] = {
        "docs": n_docs,
        "n_trees": n_trees,
        "sweep": sweep,
        "note": ("auto_vs_onehot > 1 means the auto-resolved path beats the "
                 "one-hot baseline; bitexact asserts all three paths "
                 "returned identical f32 scores on the swept batch"),
    }


def _bench_blocked_rank(rows, extra, smoke=False):
    """Sort-free per-query ranking: direct [Q, D, D] pairwise count vs the
    [block_d, block_d]-tiled blocked count, across candidate counts. The
    counts are bit-identical (asserted against the argsort oracle per
    point); the sweep records where tiling starts paying."""
    rng = np.random.default_rng(5)
    Ds = (128, 512) if smoke else (128, 256, 512, 1024)
    iters = 2 if smoke else 8
    direct_j = jax.jit(query_ranks_direct)
    blocked_j = jax.jit(query_ranks_blocked)
    sweep = []
    for D in Ds:
        Q = 2 if smoke else 4
        # Tie-heavy scores: small integer grid, the worst case for any
        # ranking that cuts corners on tie semantics.
        s = jnp.asarray(
            rng.integers(0, 32, size=(Q, D)).astype(np.float32)
        )
        m = jnp.asarray(rng.random((Q, D)) < 0.9)
        t_direct, t_blocked = _time_group(
            [lambda a, b: direct_j(a, b), lambda a, b: blocked_j(a, b)],
            s, m, iters=iters,
        )
        oracle = np.asarray(rank_from_scores(s, m))
        matches = bool(
            (np.asarray(direct_j(s, m)) == oracle).all()
            and (np.asarray(blocked_j(s, m)) == oracle).all()
        )
        sweep.append(
            {
                "n_docs": D,
                "auto_pick": "blocked" if D > RANK_BLOCKED_MIN_D else "direct",
                "direct_us": round(t_direct, 1),
                "blocked_us": round(t_blocked, 1),
                "blocked_vs_direct": round(
                    t_direct / max(t_blocked, 1e-9), 2
                ),
                "matches_argsort": matches,
            }
        )
        rows.append((f"rank_direct_D{D}", t_direct, f"queries={Q}"))
        rows.append((f"rank_blocked_D{D}", t_blocked,
                     f"vs_direct={t_direct / max(t_blocked, 1e-9):.2f}x"))
    crossover = next(
        (p["n_docs"] for p in sweep if p["blocked_vs_direct"] >= 1.0), None
    )
    extra["blocked_rank"] = {
        "cutoff_n_docs": RANK_BLOCKED_MIN_D,
        "sweep": sweep,
        "crossover_n_docs": crossover,
        "note": ("blocked_vs_direct > 1 means tiling wins; auto uses "
                 "blocked above cutoff_n_docs candidates"),
    }


def _bench_tradeoff(rows, extra, smoke=False):
    """Query-level exit + learned tree reordering vs document-only LEAR.

    Self-contained miniature of the paper pipeline: a random ranker whose
    (noised) full-ensemble ranking defines graded labels, ragged per-query
    candidate lists, and real LEAR classifiers trained per sentinel. Four
    configurations run through the SAME progressive engine — document-only
    LEAR, +query-level exit, +greedy tree reorder (classifiers retrained
    on the permuted prefixes), and both combined. Query-exit (margin,
    from_stage) pairs, per-config LEAR thresholds, and the reorder are
    adopted only where NDCG@10 stays within
    ``ndcg_bar_pct`` of the LEAR operating point, and the margin sweep
    always contains ``inf`` (exact mode, bit-identical scores), so every
    recorded config matches LEAR's quality bar and its trees-traversed
    ratio vs LEAR is ≤ 1 by construction — the measured reduction is the
    tradeoff headline ``check_bench.py`` validates."""
    from repro.core.lear import augment_features, train_lear
    from repro.core.strategies import QueryExitConfig
    from repro.forest.reorder import reordered_ensemble
    from repro.metrics.ranking import mean_ndcg
    from repro.metrics.speedup import trees_traversed_progressive

    rng = np.random.default_rng(6)
    Q, D, F = (10, 32, 16) if smoke else (24, 64, 24)
    QT = 24 if smoke else 64                  # classifier-train queries
    n_trees = 64 if smoke else 160
    sentinels = [8, 16] if smoke else [16, 40, 80]
    bar_pct = 1.0 if smoke else 0.5           # tiny eval sets are noisy
    thresholds = (0.1, 0.2, 0.3, 0.5)
    margins = (
        (float("inf"), 0.3, 0.1) if smoke
        else (float("inf"), 0.5, 0.3, 0.1, 0.05)
    )
    iters = 2 if smoke else 8
    ens = random_ensemble(6, n_trees=n_trees, depth=4, n_features=F)

    def make_batch(q):
        X = rng.normal(size=(q, D, F)).astype(np.float32)
        n_docs = rng.integers(4, D + 1, size=q)   # ragged candidate lists
        mask = np.arange(D)[None, :] < n_docs[:, None]
        full = np.asarray(
            forest_score(ens, jnp.asarray(X.reshape(q * D, F)))
        ).reshape(q, D)
        noisy = (full + 0.5 * full.std() * rng.normal(size=full.shape))
        ranks = np.asarray(rank_from_scores(
            jnp.asarray(noisy.astype(np.float32)), jnp.asarray(mask)
        ))
        labels = (np.clip(4 - ranks // 4, 0, 4) * mask).astype(np.float32)
        return X, labels, mask, full

    Xt, yt, mt, _ = make_batch(QT)
    X, labels, mask, full = make_batch(Q)
    Xj, mj, yj = jnp.asarray(X), jnp.asarray(mask), jnp.asarray(labels)
    ndcg_full = float(mean_ndcg(jnp.asarray(full), yj, mj, 10))
    full_trees = float(mask.sum()) * n_trees

    def train_all(ranker):
        return {
            s: train_lear(Xt, yt, mt, ranker, sentinel=s, k=10)
            for s in sentinels
        }

    def lear_strategy(clf, thr):
        def strat(partial, alive):
            aug = augment_features(Xj, partial, alive)
            return clf.continue_mask(aug, alive, threshold=thr)
        return strat

    def evaluate(ranker, classifiers, thr, qe, tag):
        cascade = CascadeRanker(
            ensemble=ranker, sentinel=sentinels[0],
            strategy=lear_strategy(classifiers[sentinels[0]], thr),
        )
        strategies = [lear_strategy(classifiers[s], thr) for s in sentinels]

        config = EngineConfig.trees(
            sentinels, tuple(strategies), capacities=Q * D,
            mode="fused", query_exit=qe,
        )

        def call():
            return cascade.rank_progressive(Xj, mj, config)

        res = call()
        exited = (
            int(res.query_exited.sum()) if res.query_exited is not None else 0
        )
        return {
            "tag": tag,
            "threshold": thr,
            "margin": None if qe is None else qe.margin,
            "from_stage": None if qe is None else qe.from_stage,
            "ndcg": float(mean_ndcg(res.scores, yj, mj, 10)),
            "trees": float(trees_traversed_progressive(
                mj, res.stage_masks, sentinels, n_trees,
                classifier_trees=[classifiers[s].n_trees for s in sentinels],
            )),
            "exited": exited,
            "call": lambda: call().scores,
        }

    clfs = train_all(ens)
    # Document-only LEAR operating point: cheapest threshold whose NDCG
    # matches the full ensemble within the bar (most conservative
    # threshold as fallback) — every other config is held to ITS quality.
    cands = [evaluate(ens, clfs, t, None, "identity") for t in thresholds]
    ok = [c for c in cands if c["ndcg"] >= ndcg_full * (1 - bar_pct / 100)]
    base = min(ok or cands[:1], key=lambda c: c["trees"])
    bar = base["ndcg"] * (1 - bar_pct / 100)
    thr = base["threshold"]

    def best(candidates):
        ok = [c for c in candidates if c["ndcg"] >= bar]
        return min(ok, key=lambda c: c["trees"])  # inf-margin ⇒ non-empty

    # Checking convergence only from a later stage (from_stage) lets short
    # ragged queries see a deeper prefix before they may exit — at stage 0
    # the vacuous n_alive<=k rule fires on 10%-of-ensemble scores and the
    # NDCG loss blows the bar.
    from_stages = tuple(
        fs for fs in ((0, 1) if smoke else (0, 1, 2))
        if fs < len(sentinels)
    )

    def qe_sweep(ranker, classifiers, t, tag):
        # inf = exact mode (scores bit-identical to the no-exit run at the
        # same threshold/order), so the candidate set can never lose to it.
        cands = [evaluate(ranker, classifiers, t,
                          QueryExitConfig(k=10, margin=float("inf")), tag)]
        for m in margins:
            if m == float("inf"):
                continue
            for fs in from_stages:
                cands.append(evaluate(
                    ranker, classifiers, t,
                    QueryExitConfig(k=10, margin=m, from_stage=fs), tag,
                ))
        return cands

    # +query-exit: (margin x from_stage) sweep on the identity order.
    qe_best = best(qe_sweep(ens, clfs, thr, "identity"))
    # +reorder: greedy order learned on the classifier split, classifiers
    # retrained against the permuted prefixes. The permuted prefixes shift
    # the classifiers' operating points, so the reorder gets its own
    # threshold sweep (matched NDCG, not matched threshold); identity
    # baseline stays in the candidate set as the structural fallback.
    permuted, _ = reordered_ensemble(
        ens, Xt.reshape(QT * D, F), method="greedy"
    )
    clfs_p = train_all(permuted)
    re_best = best([base] + [
        evaluate(permuted, clfs_p, t, None, "greedy") for t in thresholds
    ])
    # both: (margin x from_stage) sweep on whichever order/threshold the
    # reorder config adopted.
    both_ens, both_clfs = (
        (permuted, clfs_p) if re_best["tag"] == "greedy" else (ens, clfs)
    )
    both_best = best(
        qe_sweep(both_ens, both_clfs, re_best["threshold"], re_best["tag"])
    )

    configs = []
    for name, cand in (
        ("lear", base),
        ("lear+query_exit", qe_best),
        ("lear+reorder", re_best),
        ("lear+query_exit+reorder", both_best),
    ):
        wall = _time(cand["call"], iters=iters)
        margin = cand["margin"]
        configs.append({
            "name": name,
            "threshold": cand["threshold"],
            "order": cand["tag"],
            "query_exit_margin": (
                "inf" if margin == float("inf") else margin
            ),
            "query_exit_from_stage": cand["from_stage"],
            "queries_exited": cand["exited"],
            "ndcg10": round(cand["ndcg"], 4),
            "delta_pct_vs_full": round(
                100 * (cand["ndcg"] - ndcg_full) / ndcg_full, 3
            ),
            "trees_traversed": cand["trees"],
            "trees_vs_full": round(cand["trees"] / full_trees, 4),
            "trees_vs_lear": round(cand["trees"] / base["trees"], 4),
            "wall_us": round(wall, 1),
            "meets_ndcg_bar": bool(cand["ndcg"] >= bar - 1e-12),
        })
        rows.append((f"tradeoff_{name}", wall,
                     f"ndcg10={cand['ndcg']:.4f},"
                     f"trees_vs_lear={cand['trees'] / base['trees']:.3f}"))

    extra["tradeoff"] = {
        "queries": Q,
        "docs": int(mask.sum()),
        "n_trees": n_trees,
        "sentinels": sentinels,
        "classifier_trees_per_stage": clfs[sentinels[0]].n_trees,
        "ndcg_full": round(ndcg_full, 4),
        "lear_threshold": thr,
        "ndcg_bar_pct": bar_pct,
        "margins_swept": [
            "inf" if m == float("inf") else m for m in margins
        ],
        "from_stages_swept": list(from_stages),
        "configs": configs,
        "trees_reduction_pct_vs_lear": round(
            100 * (1 - min(c["trees_vs_lear"] for c in configs)), 2
        ),
        "note": ("every config matches the document-only LEAR operating "
                 "point's NDCG@10 within ndcg_bar_pct; margin sweeps "
                 "include inf (exact query exit) and the reorder falls "
                 "back to identity, so trees_vs_lear <= 1 is structural "
                 "and the reduction is measured, not assumed"),
    }


def _bench_hybrid(rows, extra, smoke=False):
    """Hybrid dense-stage-0 cascade vs the all-trees cascade, matched NDCG.

    Distills the dense proxy from the bench ensemble itself
    (:func:`repro.train.distill.distill_dense_scorer`), gates with
    ``dense_keep_fraction`` at swept keep fractions, and runs BOTH
    configurations through the same progressive engine with identical
    tree-stage strategies. The recorded config is the cheapest keep
    fraction whose NDCG@10 stays within ``ndcg_bar_pct`` of the all-trees
    run; its trees-traversed ratio (dense evaluations charged at
    ``DenseStage.cost_trees`` tree-equivalents per doc) must come in
    below 1 — that reduction is the hybrid headline ``check_bench.py``
    validates."""
    import functools

    from repro.core.stage import DenseStage, EngineConfig
    from repro.core.strategies import dense_keep_fraction
    from repro.metrics.ranking import mean_ndcg
    from repro.metrics.speedup import trees_traversed_progressive
    from repro.train.distill import distill_dense_scorer

    rng = np.random.default_rng(7)
    Q, D, F = (8, 32, 16) if smoke else (24, 64, 24)
    QT = 16 if smoke else 48                  # distillation queries
    n_trees = 64 if smoke else 160
    sentinels = [16, 32] if smoke else [40, 80]
    steps = 120 if smoke else 400
    bar_pct = 1.0 if smoke else 0.5           # tiny eval sets are noisy
    keep_fracs = (0.9, 0.75, 0.5, 0.35)
    iters = 2 if smoke else 8
    ens = random_ensemble(7, n_trees=n_trees, depth=4, n_features=F)

    def make_batch(q):
        X = rng.normal(size=(q, D, F)).astype(np.float32)
        n_docs = rng.integers(8, D + 1, size=q)   # ragged candidate lists
        mask = np.arange(D)[None, :] < n_docs[:, None]
        full = np.asarray(
            forest_score(ens, jnp.asarray(X.reshape(q * D, F)))
        ).reshape(q, D)
        noisy = full + 0.5 * full.std() * rng.normal(size=full.shape)
        ranks = np.asarray(rank_from_scores(
            jnp.asarray(noisy.astype(np.float32)), jnp.asarray(mask)
        ))
        labels = (np.clip(4 - ranks // 4, 0, 4) * mask).astype(np.float32)
        return X, labels, mask

    Xt, _, mt = make_batch(QT)
    X, labels, mask = make_batch(Q)
    Xj, mj, yj = jnp.asarray(X), jnp.asarray(mask), jnp.asarray(labels)

    distilled = distill_dense_scorer(
        ens, Xt, mt, steps=steps, lr=3e-3, seed=7, log_every=0
    )

    # Identical tree-stage strategies on both sides: rank-threshold exits
    # keeping the top ~40% / ~20% of candidates per stage.
    strategies = tuple(
        (lambda p, m, k=max(1, int(f * D)): ert_continue(p, m, k_s=k))
        for f in (0.4, 0.2)[: len(sentinels)]
    )
    cascade = CascadeRanker(
        ensemble=ens, sentinel=sentinels[0], strategy=strategies[0]
    )
    cfg_all = EngineConfig.trees(
        sentinels, strategies, capacities=Q * D, mode="fused"
    )

    def run(cfg):
        res = cascade.rank_progressive(Xj, mj, cfg)
        acct_sents: tuple = tuple(sentinels)
        acct_costs: tuple = (0.0,) * len(sentinels)
        if cfg.dense is not None:
            acct_sents = (0, *acct_sents)
            acct_costs = (float(cfg.dense.cost_trees), *acct_costs)
        trees = float(trees_traversed_progressive(
            mj, res.stage_masks, acct_sents, n_trees, list(acct_costs)
        ))
        ndcg = float(mean_ndcg(res.scores, yj, mj, 10))
        return res, trees, ndcg

    _, trees_all, ndcg_all = run(cfg_all)
    bar = ndcg_all * (1 - bar_pct / 100)

    sweep, picked = [], None
    for kf in keep_fracs:
        stage = DenseStage(
            scorer=distilled.scorer,
            policy=functools.partial(dense_keep_fraction, keep_frac=kf),
        )
        cfg = EngineConfig.hybrid(
            stage, sentinels, strategies, capacities=Q * D, mode="fused"
        )
        _, trees, ndcg = run(cfg)
        point = {
            "keep_frac": kf,
            "ndcg10": round(ndcg, 4),
            "trees_traversed": trees,
            "trees_vs_all_trees": round(trees / trees_all, 4),
            "meets_ndcg_bar": bool(ndcg >= bar - 1e-12),
        }
        sweep.append(point)
        if point["meets_ndcg_bar"] and (
            picked is None or trees < picked[1]
        ):
            picked = (cfg, trees, point)
    assert picked is not None, (
        "no keep fraction met the matched-NDCG bar", sweep
    )
    cfg_hyb, _, point = picked

    t_all, t_hyb = _time_group(
        [
            lambda x, c=cfg_all: cascade.rank_progressive(x, mj, c).scores,
            lambda x, c=cfg_hyb: cascade.rank_progressive(x, mj, c).scores,
        ],
        Xj, iters=iters,
    )
    rows.append(("hybrid_all_trees", t_all,
                 f"trees={n_trees},docs={int(mask.sum())},"
                 f"ndcg10={ndcg_all:.4f}"))
    rows.append(("hybrid_dense_stage0", t_hyb,
                 f"keep_frac={point['keep_frac']},"
                 f"trees_vs_all_trees={point['trees_vs_all_trees']:.3f},"
                 f"vs_all_trees_wall={t_all / max(t_hyb, 1e-9):.2f}x"))

    extra["hybrid"] = {
        "queries": Q,
        "docs": int(mask.sum()),
        "n_trees": n_trees,
        "sentinels": sentinels,
        "dense_cost_trees": float(cfg_hyb.dense.cost_trees),
        "ndcg_bar_pct": bar_pct,
        "distill": {
            "steps": steps,
            "teacher_rmse": round(distilled.teacher_rmse, 4),
            "pair_accuracy": round(distilled.pair_accuracy, 4),
        },
        "all_trees": {
            "ndcg10": round(ndcg_all, 4),
            "trees_traversed": trees_all,
            "wall_us": round(t_all, 1),
        },
        "dense_stage0": {
            **point,
            "delta_pct_vs_all_trees": round(
                100 * (point["ndcg10"] - ndcg_all) / ndcg_all, 3
            ),
            "wall_us": round(t_hyb, 1),
        },
        "sweep": sweep,
        "note": ("dense_stage0 is the cheapest swept keep fraction whose "
                 "NDCG@10 stays within ndcg_bar_pct of the all-trees run; "
                 "trees_vs_all_trees < 1 means the dense gate (charged at "
                 "dense_cost_trees tree-equivalents per candidate) pays "
                 "for itself in pruned tree traversals"),
    }


def main(csv: bool = True, json_path: str = JSON_PATH, smoke: bool = False):
    rows = []
    extra = {}
    _bench_scoring(rows, smoke)
    _bench_cascade(rows, smoke)
    _bench_multi_sentinel(rows, smoke)
    _bench_fused_vs_staged(rows, extra, smoke)
    _bench_leaf_gather(rows, extra, smoke)
    _bench_blocked_rank(rows, extra, smoke)
    _bench_tradeoff(rows, extra, smoke)
    _bench_hybrid(rows, extra, smoke)

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")

    payload = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "smoke": smoke,
        "rows": [
            {"name": name, "us_per_call": round(us, 1), "derived": derived}
            for name, us, derived in rows
        ],
        **extra,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


if __name__ == "__main__":
    main()
