"""Roofline table: aggregates artifacts/dryrun/*.json into the §Roofline
report (one row per arch × shape × mesh)."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def main(csv: bool = True):
    records = load_records()
    rows = []
    for r in records:
        tag = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if "skipped" in r:
            rows.append((tag, "SKIP", r["skipped"][:60]))
            continue
        if "error" in r:
            rows.append((tag, "FAIL", r["error"][:60]))
            continue
        roof = r["roofline"]
        rows.append((
            tag,
            f"{roof['bound_s']:.3e}",
            f"dominant={roof['dominant']},compute={roof['compute_s']:.2e},"
            f"memory={roof['memory_s']:.2e},coll={roof['collective_s']:.2e},"
            f"useful={roof['useful_ratio']:.2f},"
            f"mem_gib={r['memory'].get('per_device_total_gib', -1)}",
        ))
    if csv:
        for tag, v, detail in rows:
            print(f"roofline_{tag},{v},{detail}")
    return rows


if __name__ == "__main__":
    main()
