"""Load-generator benchmark for the continuously-batched serving tier.

Drives :class:`repro.serve.tier.ServingTier` with closed-loop client
threads at 1×/8×/64× concurrency and measures what the tentpole claims:

- **QPS and latency under concurrency** — per-request submit→result wall
  times (p50/p99) and aggregate throughput per stream level. The payoff of
  continuous batching is the 64× row: many concurrent single-query clients
  get packed into full engine blocks, so QPS must beat the serial
  baseline by ≥2× (the acceptance bar for the committed full run).
- **Serial baseline** — the same queries submitted one at a time through a
  warmed service (each padded to the same ``(1, D)`` bucket the tier would
  use): what a deployment without the batcher pays.
- **Warm start** — AOT warmup seconds, and the FIRST real request's
  latency vs steady-state p50 (must be ≤2×: no compile hides behind
  request 1).
- **Zero cold-start overflow** — warmup seeds every bucket's capacities at
  the physical max, so the service must report 0 overflow docs across the
  whole run.
- **Bit-exactness** — a sample of batched responses replayed through a
  fresh single-query service must match score-for-score, index-for-index.
- **Degraded mode** (the fault-tolerance tentpole) — an overload run
  against a tier with a degradation ladder and a bounded queue: shed rate
  and deadline-miss rate stay finite fractions (admission control, not
  queue growth), the rung ladder steps under load and recovers after it,
  and a per-rung quality sweep records NDCG@10 against full-ensemble
  teacher labels (monotone: each cheaper rung may only trade quality
  DOWN, and stepping rungs after warmup triggers ZERO jit lowerings).

CPU wall times are NOT TPU predictions (the kernel runs in interpret mode
here); the *ratios* — batched vs serial QPS, first-request vs steady p50 —
are the portable part. Results go to ``BENCH_serve.json`` at the repo root
(full run committed for the perf trajectory); ``main(smoke=True,
json_path=...)`` is the tiny CI profile used by ``check_bench.py``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np

import jax.numpy as jnp
import jax._src.test_util as jtu

from repro.core.lear import LearClassifier
from repro.core.strategies import QueryExitConfig
from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import score_numpy_oracle
from repro.metrics.ranking import mean_ndcg
from repro.serve.batching import BucketPolicy
from repro.serve.degradation import DegradationPolicy, ExitRung
from repro.serve.errors import Overloaded
from repro.serve.ranking_service import RankingService, ServiceConfig
from repro.serve.tier import ServingTier, TierConfig
from repro.serve.warmup import warmup_service

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

N_FEATURES = 12
SENTINELS = (8, 28)
CONCURRENCY = (1, 8, 64)


def _make_service(n_trees: int, seed: int = 0) -> RankingService:
    ens = random_ensemble(seed, n_trees=n_trees, depth=4,
                          n_features=N_FEATURES)
    clfs = [
        LearClassifier(
            forest=random_ensemble(
                100 + i, n_trees=10, depth=3, n_features=N_FEATURES + 4
            ),
            sentinel=s,
        )
        for i, s in enumerate(SENTINELS)
    ]
    return RankingService(
        ens, clfs[0],
        ServiceConfig(
            threshold=0.4, execution_mode="auto",
            launch_overhead_trees="auto",
        ),
        extra_classifiers=clfs[1:],
    )


def _make_queries(
    rng: np.random.Generator, n: int, lo: int, hi: int
) -> list[np.ndarray]:
    return [
        rng.normal(size=(int(rng.integers(lo, hi + 1)), N_FEATURES))
        .astype(np.float32)
        for _ in range(n)
    ]


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q))


def _lat_section(lat_s: list[float], wall_s: float) -> dict:
    return {
        "n_queries": len(lat_s),
        "qps": round(len(lat_s) / wall_s, 2),
        "p50_ms": round(_pct(lat_s, 50) * 1e3, 3),
        "p99_ms": round(_pct(lat_s, 99) * 1e3, 3),
    }


def run_serial(
    n_trees: int, queries: list[np.ndarray], doc_bucket: int
) -> dict:
    """One query at a time through a warmed service — the no-batcher
    deployment, padded to the same (1, D) shape the tier would use."""
    svc = _make_service(n_trees)
    warmup_service(svc, N_FEATURES, [(1, doc_bucket)])
    lat = []
    t_wall = time.perf_counter()
    for q in queries:
        X = np.zeros((1, doc_bucket, N_FEATURES), np.float32)
        m = np.zeros((1, doc_bucket), bool)
        X[0, : len(q)] = q
        m[0, : len(q)] = True
        t0 = time.perf_counter()
        svc.rank_batch(jnp.asarray(X), jnp.asarray(m))
        lat.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t_wall
    out = _lat_section(lat, wall)
    out["overflow_docs"] = svc.stats.overflow_docs
    return out


def run_stream(
    tier: ServingTier, queries: list[np.ndarray], concurrency: int
) -> dict:
    """Closed-loop clients: each thread submits its share sequentially and
    waits for every result before the next submit."""
    chunks = [queries[i::concurrency] for i in range(concurrency)]
    lats: list[list[float]] = [[] for _ in range(concurrency)]
    b0 = dict(
        flushes_full=tier.batcher.stats.flushes_full,
        flushes_deadline=tier.batcher.stats.flushes_deadline,
        batches=tier.service.stats.batches,
        queries=tier.service.stats.queries,
    )

    def client(ci: int) -> None:
        for q in chunks[ci]:
            t0 = time.perf_counter()
            tier.submit(q).result(timeout=600)
            lats[ci].append(time.perf_counter() - t0)

    threads = [
        threading.Thread(target=client, args=(ci,), daemon=True)
        for ci in range(concurrency)
    ]
    t_wall = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_wall

    out = _lat_section([x for per_client in lats for x in per_client], wall)
    out["concurrency"] = concurrency
    d_batches = tier.service.stats.batches - b0["batches"]
    d_queries = tier.service.stats.queries - b0["queries"]
    out["engine_batches"] = d_batches
    out["mean_queries_per_batch"] = round(d_queries / max(d_batches, 1), 2)
    out["flushes_full"] = (
        tier.batcher.stats.flushes_full - b0["flushes_full"]
    )
    out["flushes_deadline"] = (
        tier.batcher.stats.flushes_deadline - b0["flushes_deadline"]
    )
    return out


def check_bitexact(
    tier_results: list[tuple[np.ndarray, np.ndarray]],
    queries: list[np.ndarray],
    n_trees: int,
) -> dict:
    """Replay a sample of batched responses through a fresh single-query
    service: scores and top-k must match exactly."""
    ref = _make_service(n_trees)
    identical = True
    for q, (top, scores) in zip(queries, tier_results):
        t_ref, s_ref = ref.rank_batch(
            jnp.asarray(q[None]), jnp.ones((1, len(q)), bool)
        )
        k = min(ref.top_k, len(q))
        if not (
            np.array_equal(scores, np.asarray(s_ref)[0])
            and np.array_equal(top, np.asarray(t_ref)[0][:k])
        ):
            identical = False
            break
    return {"checked": len(tier_results), "identical": identical}


#: The degradation ladder the bench exercises: level 0 is the baseline
#: (threshold 0.4), each rung trades NDCG for latency via the paper's own
#: exit knobs — tighter document threshold, then tighter still plus a
#: finite query-exit margin.
DEGRADE_RUNGS = (
    ExitRung("tight", threshold=0.6),
    ExitRung(
        "tightest", threshold=0.8,
        query_exit=QueryExitConfig(k=10, margin=2.0),
    ),
)


def _teacher_labels(svc: RankingService, q: np.ndarray) -> np.ndarray:
    """Graded 0..4 relevance from the FULL ensemble's ranking of ``q`` —
    the quality reference every rung is scored against (the paper's
    NDCG@10 setup, with the exact scorer as its own teacher)."""
    teacher = score_numpy_oracle(svc.ensemble, q)
    order = np.argsort(-teacher, kind="stable")
    rank = np.empty(len(q), np.int64)
    rank[order] = np.arange(len(q))
    labels = np.zeros(len(q), np.float32)
    for grade, lo_r, hi_r in ((4, 0, 1), (3, 1, 4), (2, 4, 8), (1, 8, 16)):
        labels[(rank >= lo_r) & (rank < hi_r)] = grade
    return labels


def run_degraded_quality(n_trees: int, smoke: bool) -> tuple[list[dict], int]:
    """NDCG@10 of every rung on a fixed eval block, plus the jit-lowering
    count while STEPPING rungs post-warmup (the AOT ladder guarantee)."""
    n_eval = 4 if smoke else 16
    n_docs = 64
    rng = np.random.default_rng(7)
    X = rng.normal(size=(n_eval, n_docs, N_FEATURES)).astype(np.float32)
    mask = np.ones((n_eval, n_docs), bool)

    svc = _make_service(n_trees, seed=1)
    labels = np.stack([_teacher_labels(svc, x) for x in X])
    svc.install_rungs(DEGRADE_RUNGS)
    warmup_service(svc, N_FEATURES, [(n_eval, n_docs)])

    Xj, mj = jnp.asarray(X), jnp.asarray(mask)
    per_level: list[np.ndarray] = []
    with jtu.count_jit_and_pmap_lowerings() as count:
        for level in range(svc.n_rungs):
            svc.set_rung(level)
            _top, scores = svc.rank_batch(Xj, mj)
            per_level.append(np.asarray(scores))
    lowerings = int(count[0])

    names = ["baseline"] + [r.name for r in DEGRADE_RUNGS]
    rungs = [
        {
            "level": level,
            "name": names[level],
            "ndcg10": round(float(mean_ndcg(
                jnp.asarray(scores), jnp.asarray(labels), mj, k=10
            )), 4),
        }
        for level, scores in enumerate(per_level)
    ]
    return rungs, lowerings


def run_overload(n_trees: int, smoke: bool) -> dict:
    """Spike a degradation-enabled tier with a bounded queue far past its
    capacity, then trickle until it recovers: the shed/miss/degrade/recover
    numbers the fault-tolerance tentpole commits to."""
    offered = 96 if smoke else 384
    policy = BucketPolicy(
        max_queries=8, max_wait_ms=2.0, min_docs=8, max_queue_depth=64
    )
    # Band placement: a full queue (64 deep, 8 per flush) backs requests
    # up for several flush times (≫ 15 ms), so overload degrades; the
    # recovery threshold must clear max_wait_ms, because trickle
    # traffic's queue delay IS the deadline-flush wait — recovering
    # below the flush window would be unreachable by construction.
    dpolicy = DegradationPolicy(
        rungs=DEGRADE_RUNGS,
        degrade_above_ms=15.0,
        recover_below_ms=6.0,
        ema_alpha=0.5,
        dwell_flushes=2,
    )
    svc = _make_service(n_trees)
    tier = ServingTier(
        svc, N_FEATURES,
        TierConfig(
            doc_counts=(64,), warmup=True, persistent_cache=True,
            degradation=dpolicy,
        ),
        policy=policy,
    )
    tier.start()
    rng = np.random.default_rng(11)
    queries = _make_queries(rng, 32, 33, 64)

    futs = []
    max_level = 0
    for i in range(offered):
        try:
            futs.append(tier.submit(queries[i % len(queries)],
                                    deadline_ms=500.0))
        except Overloaded:
            pass  # counted in BatcherStats.shed_overload
        max_level = max(max_level, tier.degradation.level)
    for f in futs:
        try:
            f.result(timeout=600)
        except Exception:
            pass  # misses/crashes are counted typed in the stats
        max_level = max(max_level, tier.degradation.level)

    # Calm trickle until the ladder walks back to the baseline (bounded:
    # a tier that cannot recover is itself a finding in the JSON).
    recover_budget = time.monotonic() + (10.0 if smoke else 60.0)
    while (
        tier.degradation.level != 0 and time.monotonic() < recover_budget
    ):
        tier.rank(queries[0])
    snap = tier.degradation.snapshot()
    health = tier.health()
    tier.stop()

    s = tier.batcher.stats
    return {
        "offered": offered,
        "completed": s.completed,
        "shed_overload": s.shed_overload,
        "deadline_missed": s.shed_deadline + s.expired_deadline,
        "shed_rate": round(s.shed_rate, 4),
        "deadline_miss_rate": round(s.deadline_miss_rate, 4),
        "queue_depth_limit": policy.max_queue_depth,
        "max_queue_depth_observed": s.max_queue_depth,
        "max_level": max_level,
        "final_level": snap["level"],
        "recovered": snap["level"] == 0,
        "degrade_steps": snap["degrade_steps"],
        "recover_steps": snap["recover_steps"],
        "worker_crashes": s.worker_crashes,
        "health_state": health["state"],
    }


def run_degraded(n_trees: int, smoke: bool) -> dict:
    rungs, lowerings = run_degraded_quality(n_trees, smoke)
    return {
        "overload": run_overload(n_trees, smoke),
        "rungs": rungs,
        "post_warmup_lowerings": lowerings,
    }


def main(json_path: str = JSON_PATH, smoke: bool = False) -> dict:
    n_trees = 32 if smoke else 64
    n_queries = 64 if smoke else 512
    n_bitexact = 4 if smoke else 16
    lo, hi = (33, 64)
    policy = BucketPolicy(max_queries=8, max_wait_ms=2.0, min_docs=8)
    rng = np.random.default_rng(0)
    queries = _make_queries(rng, n_queries, lo, hi)
    doc_bucket = policy.doc_bucket(hi)

    svc = _make_service(n_trees)
    tier = ServingTier(
        svc, N_FEATURES,
        TierConfig(doc_counts=(hi,), warmup=True, persistent_cache=True),
        policy=policy,
    )
    t0 = time.perf_counter()
    tier.start()
    start_seconds = time.perf_counter() - t0

    # The first REAL request after warmup: any compile hiding here shows
    # up as first_ms >> steady p50.
    t0 = time.perf_counter()
    first_result = tier.rank(queries[0])
    first_ms = (time.perf_counter() - t0) * 1e3

    streams = [run_stream(tier, queries, c) for c in CONCURRENCY]

    bitexact_sample = queries[:n_bitexact]
    sample_results = [first_result] + [
        tier.rank(q) for q in bitexact_sample[1:]
    ]
    tier.stop()

    serial = run_serial(n_trees, queries, doc_bucket)
    bitexact = check_bitexact(sample_results, bitexact_sample, n_trees)
    degraded = run_degraded(n_trees, smoke)

    steady_p50 = streams[0]["p50_ms"]
    payload = {
        "config": {
            "smoke": smoke,
            "n_trees": n_trees,
            "n_features": N_FEATURES,
            "sentinels": list(SENTINELS),
            "n_queries": n_queries,
            "doc_range": [lo, hi],
            "max_queries": policy.max_queries,
            "max_wait_ms": policy.max_wait_ms,
            "n_devices": tier.placement.n_devices,
        },
        "serial": serial,
        "streams": streams,
        "speedup": {
            "qps_max_concurrency_vs_serial": round(
                streams[-1]["qps"] / serial["qps"], 2
            ),
        },
        "warmup": {
            "start_seconds": round(start_seconds, 2),
            "warmup_seconds": round(tier.warmup_report.total_seconds, 2),
            "buckets": [list(b) for b in tier.warmup_report.buckets],
            "cache_dir": tier.warmup_report.cache_dir,
            "warm_first_request_ms": round(first_ms, 3),
            "first_to_steady_p50_ratio": round(
                first_ms / max(steady_p50, 1e-9), 3
            ),
        },
        "cold_start_overflow_docs": svc.stats.overflow_docs,
        "bitexact": bitexact,
        "degraded": degraded,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    print(f"serial        qps={serial['qps']:>8}  p50={serial['p50_ms']}ms"
          f"  p99={serial['p99_ms']}ms")
    for s in streams:
        print(f"stream {s['concurrency']:>3}x   qps={s['qps']:>8}"
              f"  p50={s['p50_ms']}ms  p99={s['p99_ms']}ms"
              f"  q/batch={s['mean_queries_per_batch']}")
    print(f"speedup {payload['speedup']['qps_max_concurrency_vs_serial']}x"
          f"  overflow={payload['cold_start_overflow_docs']}"
          f"  first/p50={payload['warmup']['first_to_steady_p50_ratio']}"
          f"  bitexact={bitexact['identical']}")
    _print_degraded(degraded)
    return payload


def _print_degraded(degraded: dict) -> None:
    ov = degraded["overload"]
    print(f"overload      shed={ov['shed_rate']}"
          f"  miss={ov['deadline_miss_rate']}"
          f"  level max={ov['max_level']} final={ov['final_level']}"
          f"  recovered={ov['recovered']}  depth<= {ov['queue_depth_limit']}")
    rungs = "  ".join(
        f"{r['name']}={r['ndcg10']}" for r in degraded["rungs"]
    )
    print(f"rung ndcg@10  {rungs}"
          f"  lowerings={degraded['post_warmup_lowerings']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI profile (do not commit its numbers)")
    ap.add_argument("--overload-smoke", action="store_true",
                    help="run ONLY the degraded/overload section, tiny — "
                         "the nightly chaos lane's live fire exercise")
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    if args.overload_smoke:
        degraded = run_degraded(n_trees=32, smoke=True)
        _print_degraded(degraded)
        ov = degraded["overload"]
        ok = (
            ov["worker_crashes"] == 0
            and ov["health_state"] in ("running", "stopped")
            and ov["max_queue_depth_observed"] <= ov["queue_depth_limit"]
            and degraded["post_warmup_lowerings"] == 0
        )
        print(f"overload smoke {'OK' if ok else 'FAILED'}")
        sys.exit(0 if ok else 1)
    main(json_path=args.json, smoke=args.smoke)
