"""Paper Table 1: Full vs EE_ideal vs ERT vs EPT on MSN-1' (test split).

Reports NDCG@10, ΔNDCG vs Full, trees-traversed speedup, and the oracle's
per-query cut statistics (k_s^μ, k_s^σ) — the paper's exact table layout.

This bench has NO smoke-scale mode: it needs the fully trained
experiment (λ-MART teacher + LEAR classifiers via
``benchmarks.common.get_experiment``), so ``check_bench.py`` never runs
it and :func:`smoke` raises ``NotImplementedError`` explicitly. The gap
is pinned by ``tests/test_bench_smoke.py``, which skips on the raise and
starts validating the Table-1 row schema the day a tiny-configuration
path exists.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Experiment, get_experiment
from repro.core.strategies import ept_continue, ert_continue, ideal_continue
from repro.metrics.ranking import mean_ndcg
from repro.metrics.speedup import speedup_vs_full


def evaluate_strategy(exp: Experiment, sentinel: int, cont, classifier_trees=0):
    ds = exp.splits["test"]
    per_tree = exp.scores("test")
    partial = per_tree[..., :sentinel].sum(-1) + exp.ranker.base_score
    full = per_tree.sum(-1) + exp.ranker.base_score
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)
    scores = jnp.where(cont, full, partial)
    ndcg = float(mean_ndcg(scores, labels, mask, 10))
    sp = speedup_vs_full(cont, mask, sentinel, exp.ranker.n_trees,
                         classifier_trees)
    return ndcg, sp


def run(exp_name: str = "msn1", sentinel_idx: int = 0) -> list[dict]:
    exp = get_experiment(exp_name)
    s = exp.spec.sentinels[sentinel_idx]
    ds = exp.splits["test"]
    per_tree = exp.scores("test")
    partial = per_tree[..., :s].sum(-1) + exp.ranker.base_score
    full = per_tree.sum(-1) + exp.ranker.base_score
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)

    rows = []
    ndcg_full = float(mean_ndcg(full, labels, mask, 10))
    rows.append({"method": "Full", "ndcg@10": ndcg_full, "delta_pct": 0.0,
                 "speedup": 1.0})

    cont, cut = ideal_continue(partial, full, labels, mask, k=10)
    ndcg, sp = evaluate_strategy(exp, s, cont)
    cut_np = np.asarray(cut, dtype=np.float64)
    rows.append({
        "method": "EE_ideal", "ndcg@10": ndcg,
        "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full, "speedup": sp,
        "ks_mean": float(cut_np.mean()), "ks_std": float(cut_np.std()),
    })

    for k_s in (15, 20):
        cont = ert_continue(partial, mask, k_s=k_s)
        ndcg, sp = evaluate_strategy(exp, s, cont)
        rows.append({
            "method": f"ERT(k_s={k_s})", "ndcg@10": ndcg,
            "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full, "speedup": sp,
        })

    for p in (0.2, 0.5):
        cont = ept_continue(partial, mask, k_s=15, p=p)
        ndcg, sp = evaluate_strategy(exp, s, cont)
        n_kept = np.asarray((cont & mask).sum(axis=1), np.float64)
        rows.append({
            "method": f"EPT(k_s=15,p={p})", "ndcg@10": ndcg,
            "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full, "speedup": sp,
            "ks_mean": float(n_kept.mean()), "ks_std": float(n_kept.std()),
        })
    return rows


def smoke() -> list[dict]:
    """Tiny-configuration entry point for the CI bench smoke lane.

    Explicitly not implemented: Table 1 is only meaningful against the
    trained teacher + LEAR classifiers (minutes of training the smoke
    lane cannot absorb), and a random-forest stand-in would produce
    garbage NDCG columns that validate nothing. When a cached-artifact
    tiny experiment exists, implement this to return :func:`run`-schema
    rows; ``tests/test_bench_smoke.py`` will then enforce the schema
    instead of skipping.
    """
    raise NotImplementedError(
        "bench_table1 has no smoke-scale mode: it requires the fully "
        "trained experiment (lambda-MART teacher + LEAR classifiers); "
        "run `python -m benchmarks.bench_table1` for the real table"
    )


def main(csv: bool = True):
    rows = run()
    if csv:
        print("table1_method,ndcg@10,delta_pct,speedup,ks_mean,ks_std")
        for r in rows:
            print(
                f"{r['method']},{r['ndcg@10']:.4f},{r['delta_pct']:+.2f},"
                f"{r['speedup']:.2f},{r.get('ks_mean', float('nan')):.2f},"
                f"{r.get('ks_std', float('nan')):.2f}"
            )
    return rows


if __name__ == "__main__":
    main()
