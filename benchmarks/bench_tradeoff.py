"""Paper Figures 2 & 3: efficiency/effectiveness trade-off curves.

Fig. 2: for each sentinel, sweep the LEAR confidence threshold (0.1–0.7)
and the EPT proximity threshold (0.3–0.8); report (speedup, ΔNDCG@10).
Fig. 3: best-sentinel LEAR vs best-sentinel EPT on both datasets, plus the
dominance check (LEAR ≥ EPT speedup at matched quality).

:func:`tradeoff_configs` extends the figures with the strategy-composition
table: {LEAR, LEAR+query-exit, LEAR+reorder, both} run through the real
progressive engine at matched NDCG@10, recording trees traversed and wall
clock per configuration (the experiment-scale counterpart of the
self-contained ``tradeoff`` section in ``bench_kernels.py``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Experiment, get_experiment
from repro.core.cascade import CascadeRanker
from repro.core.lear import augment_features, train_lear
from repro.core.stage import EngineConfig
from repro.core.strategies import QueryExitConfig, ept_continue
from repro.forest.reorder import reordered_ensemble
from repro.metrics.ranking import mean_ndcg
from repro.metrics.speedup import (
    speedup_vs_full,
    trees_traversed_progressive,
)

LEAR_THRESHOLDS = (0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7)
EPT_PS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8)
QUERY_EXIT_MARGINS = (float("inf"), 0.5, 0.25, 0.1)
# Stages before which query convergence is never checked: later stages see
# deeper prefixes, so short queries can't exit vacuously on early scores.
QUERY_EXIT_FROM_STAGES = (0, 1, 2)
# The permuted prefixes shift the retrained classifiers' operating points,
# so the reorder config sweeps its own threshold (matched NDCG, not
# matched threshold).
REORDER_THRESHOLDS = (0.1, 0.2, 0.3, 0.5)


def sweep(exp: Experiment, split: str = "test"):
    ds = exp.splits[split]
    per_tree = exp.scores(split)
    full = per_tree.sum(-1) + exp.ranker.base_score
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)
    ndcg_full = float(mean_ndcg(full, labels, mask, 10))
    T = exp.ranker.n_trees

    curves = {"lear": {}, "ept": {}}
    for s in exp.spec.sentinels:
        partial = per_tree[..., :s].sum(-1) + exp.ranker.base_score
        aug = augment_features(jnp.asarray(ds.X), partial, mask)
        clf = exp.classifiers[s]
        pts = []
        for t in LEAR_THRESHOLDS:
            cont = clf.continue_mask(aug, mask, threshold=t)
            scores = jnp.where(cont, full, partial)
            ndcg = float(mean_ndcg(scores, labels, mask, 10))
            sp = speedup_vs_full(cont, mask, s, T, clf.n_trees)
            pts.append({"threshold": t, "speedup": sp,
                        "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full})
        curves["lear"][s] = pts

        pts = []
        for p in EPT_PS:
            cont = ept_continue(partial, mask, k_s=15, p=p)
            scores = jnp.where(cont, full, partial)
            ndcg = float(mean_ndcg(scores, labels, mask, 10))
            sp = speedup_vs_full(cont, mask, s, T)
            pts.append({"p": p, "speedup": sp,
                        "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full})
        curves["ept"][s] = pts
    return curves, ndcg_full


def best_at_quality(curve_pts, max_loss_pct: float = 0.05):
    ok = [p for p in curve_pts if p["delta_pct"] >= -max_loss_pct]
    if not ok:
        return None
    return max(ok, key=lambda p: p["speedup"])


def _lear_strategy(clf, X, threshold):
    """Per-stage engine strategy closing over the batch features."""
    def strat(partial, alive):
        aug = augment_features(X, partial, alive)
        return clf.continue_mask(aug, alive, threshold=threshold)
    return strat


def tradeoff_configs(exp: Experiment, split: str = "test",
                     threshold: float = 0.3, max_loss_pct: float = 0.25,
                     iters: int = 5):
    """Strategy-composition table at matched NDCG@10, on the real engine.

    Runs {LEAR, LEAR+query-exit, LEAR+reorder, both} through
    ``rank_progressive`` with the experiment's trained classifiers (the
    reorder configs retrain them against the permuted prefixes) and
    reports NDCG@10, trees traversed, and wall clock. Query-exit sweeps
    (margin, from_stage) pairs whose margins always include ``inf``
    (exact mode, scores bit-identical to the document-only run), the
    reorder sweeps its own LEAR threshold, and both fall back to the
    identity order, so every returned config matches the LEAR operating
    point within ``max_loss_pct`` and never traverses more trees than it.
    """
    ds = exp.splits[split]
    sentinels = list(exp.spec.sentinels)
    T = exp.ranker.n_trees
    X = jnp.asarray(ds.X)
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)
    Q, D, _F = ds.X.shape
    cls_split = exp.splits["classifier"]

    def retrained(ranker):
        return {
            s: train_lear(cls_split.X, cls_split.labels, cls_split.mask,
                          ranker, sentinel=s, k=15)
            for s in sentinels
        }

    def run(ranker, clfs, qe, tag, thr=threshold):
        cascade = CascadeRanker(
            ensemble=ranker, sentinel=sentinels[0],
            strategy=_lear_strategy(clfs[sentinels[0]], X, thr),
        )
        strategies = [
            _lear_strategy(clfs[s], X, thr) for s in sentinels
        ]

        config = EngineConfig.trees(
            sentinels, tuple(strategies), capacities=Q * D,
            mode="fused", query_exit=qe,
        )

        def call():
            return cascade.rank_progressive(X, mask, config)

        res = call()
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(call().scores)
            best = min(best, time.perf_counter() - t0)
        return {
            "order": tag,
            "threshold": thr,
            "query_exit_margin": None if qe is None else qe.margin,
            "query_exit_from_stage": None if qe is None else qe.from_stage,
            "ndcg@10": float(mean_ndcg(res.scores, labels, mask, 10)),
            "trees": float(trees_traversed_progressive(
                mask, res.stage_masks, sentinels, T,
                classifier_trees=[clfs[s].n_trees for s in sentinels],
            )),
            "queries_exited": (
                int(res.query_exited.sum())
                if res.query_exited is not None else 0
            ),
            "wall_us": best * 1e6,
        }

    base = run(exp.ranker, exp.classifiers, None, "identity")
    bar = base["ndcg@10"] * (1 - max_loss_pct / 100)

    def best_of(cands):
        ok = [c for c in cands if c["ndcg@10"] >= bar]
        return min(ok, key=lambda c: c["trees"])   # inf margin ⇒ non-empty

    from_stages = tuple(
        fs for fs in QUERY_EXIT_FROM_STAGES if fs < len(sentinels)
    )

    def qe_sweep(ranker, clfs, tag, thr):
        cands = [run(ranker, clfs,
                     QueryExitConfig(k=10, margin=float("inf")), tag, thr)]
        for m in QUERY_EXIT_MARGINS:
            if m == float("inf"):
                continue
            for fs in from_stages:
                cands.append(run(
                    ranker, clfs,
                    QueryExitConfig(k=10, margin=m, from_stage=fs),
                    tag, thr,
                ))
        return cands

    qe_best = best_of(
        qe_sweep(exp.ranker, exp.classifiers, "identity", threshold)
    )
    QD = cls_split.X.shape[0] * cls_split.X.shape[1]
    permuted, _order = reordered_ensemble(
        exp.ranker, jnp.asarray(cls_split.X.reshape(QD, -1)),
        method="greedy",
    )
    clfs_p = retrained(permuted)
    re_best = best_of([base] + [
        run(permuted, clfs_p, None, "greedy", t) for t in REORDER_THRESHOLDS
    ])
    both_ens, both_clfs = (
        (permuted, clfs_p) if re_best["order"] == "greedy"
        else (exp.ranker, exp.classifiers)
    )
    both_best = best_of(
        qe_sweep(both_ens, both_clfs, re_best["order"], re_best["threshold"])
    )

    table = {}
    for name, cand in (
        ("lear", base), ("lear+query_exit", qe_best),
        ("lear+reorder", re_best), ("lear+query_exit+reorder", both_best),
    ):
        table[name] = {
            **cand,
            "delta_pct": 100 * (cand["ndcg@10"] - base["ndcg@10"])
            / base["ndcg@10"],
            "trees_vs_lear": cand["trees"] / base["trees"],
        }
    return table


def main(csv: bool = True):
    results = {}
    for name in ("msn1", "istella"):
        exp = get_experiment(name)
        curves, ndcg_full = sweep(exp)
        results[name] = curves
        if not csv:
            continue
        for method in ("lear", "ept"):
            for s, pts in curves[method].items():
                for p in pts:
                    knob = p.get("threshold", p.get("p"))
                    print(
                        f"fig2_{name}_{method}_s{s},knob={knob},"
                        f"speedup={p['speedup']:.2f},"
                        f"delta_pct={p['delta_pct']:+.3f}"
                    )
        # Fig. 3: best sentinel per method at the paper's ≤0.05% bar and at
        # a reduced-scale-appropriate ≤0.25% bar (test split is ~100× smaller
        # than the paper's, so per-point NDCG noise is ~±0.1%).
        for bar in (0.05, 0.25):
            for method in ("lear", "ept"):
                best = None
                for s, pts in curves[method].items():
                    cand = best_at_quality(pts, max_loss_pct=bar)
                    if cand and (best is None or
                                 cand["speedup"] > best[1]["speedup"]):
                        best = (s, cand)
                if best:
                    print(
                        f"fig3_{name}_{method}_best@{bar},sentinel={best[0]},"
                        f"speedup={best[1]['speedup']:.2f},"
                        f"delta_pct={best[1]['delta_pct']:+.3f}"
                    )
        # Fig. 3 dominance: for every EPT operating point, does some LEAR
        # point match-or-beat it on BOTH axes?
        lear_all = [p for pts in curves["lear"].values() for p in pts]
        ept_all = [p for pts in curves["ept"].values() for p in pts]
        dominated = sum(
            any(lp["speedup"] >= ep["speedup"] - 1e-9
                and lp["delta_pct"] >= ep["delta_pct"] - 1e-9
                for lp in lear_all)
            for ep in ept_all
        )
        print(f"fig3_{name}_lear_dominates,{dominated}/{len(ept_all)},"
              f"EPT operating points matched-or-beaten by LEAR on both axes")
        # Strategy composition: {LEAR, +query-exit, +reorder, both} on the
        # progressive engine at matched NDCG (see tradeoff_configs).
        table = tradeoff_configs(exp)
        results[name + "_configs"] = table
        if csv:
            for cfg, row in table.items():
                print(
                    f"tradeoff_{name}_{cfg},order={row['order']},"
                    f"margin={row['query_exit_margin']},"
                    f"ndcg@10={row['ndcg@10']:.4f},"
                    f"delta_pct={row['delta_pct']:+.3f},"
                    f"trees_vs_lear={row['trees_vs_lear']:.3f},"
                    f"wall_us={row['wall_us']:.0f}"
                )
    return results


if __name__ == "__main__":
    main()
