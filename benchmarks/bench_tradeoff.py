"""Paper Figures 2 & 3: efficiency/effectiveness trade-off curves.

Fig. 2: for each sentinel, sweep the LEAR confidence threshold (0.1–0.7)
and the EPT proximity threshold (0.3–0.8); report (speedup, ΔNDCG@10).
Fig. 3: best-sentinel LEAR vs best-sentinel EPT on both datasets, plus the
dominance check (LEAR ≥ EPT speedup at matched quality).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Experiment, get_experiment
from repro.core.lear import augment_features
from repro.core.strategies import ept_continue
from repro.metrics.ranking import mean_ndcg
from repro.metrics.speedup import speedup_vs_full

LEAR_THRESHOLDS = (0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7)
EPT_PS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8)


def sweep(exp: Experiment, split: str = "test"):
    ds = exp.splits[split]
    per_tree = exp.scores(split)
    full = per_tree.sum(-1) + exp.ranker.base_score
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)
    ndcg_full = float(mean_ndcg(full, labels, mask, 10))
    T = exp.ranker.n_trees

    curves = {"lear": {}, "ept": {}}
    for s in exp.spec.sentinels:
        partial = per_tree[..., :s].sum(-1) + exp.ranker.base_score
        aug = augment_features(jnp.asarray(ds.X), partial, mask)
        clf = exp.classifiers[s]
        pts = []
        for t in LEAR_THRESHOLDS:
            cont = clf.continue_mask(aug, mask, threshold=t)
            scores = jnp.where(cont, full, partial)
            ndcg = float(mean_ndcg(scores, labels, mask, 10))
            sp = speedup_vs_full(cont, mask, s, T, clf.n_trees)
            pts.append({"threshold": t, "speedup": sp,
                        "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full})
        curves["lear"][s] = pts

        pts = []
        for p in EPT_PS:
            cont = ept_continue(partial, mask, k_s=15, p=p)
            scores = jnp.where(cont, full, partial)
            ndcg = float(mean_ndcg(scores, labels, mask, 10))
            sp = speedup_vs_full(cont, mask, s, T)
            pts.append({"p": p, "speedup": sp,
                        "delta_pct": 100 * (ndcg - ndcg_full) / ndcg_full})
        curves["ept"][s] = pts
    return curves, ndcg_full


def best_at_quality(curve_pts, max_loss_pct: float = 0.05):
    ok = [p for p in curve_pts if p["delta_pct"] >= -max_loss_pct]
    if not ok:
        return None
    return max(ok, key=lambda p: p["speedup"])


def main(csv: bool = True):
    results = {}
    for name in ("msn1", "istella"):
        exp = get_experiment(name)
        curves, ndcg_full = sweep(exp)
        results[name] = curves
        if not csv:
            continue
        for method in ("lear", "ept"):
            for s, pts in curves[method].items():
                for p in pts:
                    knob = p.get("threshold", p.get("p"))
                    print(
                        f"fig2_{name}_{method}_s{s},knob={knob},"
                        f"speedup={p['speedup']:.2f},"
                        f"delta_pct={p['delta_pct']:+.3f}"
                    )
        # Fig. 3: best sentinel per method at the paper's ≤0.05% bar and at
        # a reduced-scale-appropriate ≤0.25% bar (test split is ~100× smaller
        # than the paper's, so per-point NDCG noise is ~±0.1%).
        for bar in (0.05, 0.25):
            for method in ("lear", "ept"):
                best = None
                for s, pts in curves[method].items():
                    cand = best_at_quality(pts, max_loss_pct=bar)
                    if cand and (best is None or
                                 cand["speedup"] > best[1]["speedup"]):
                        best = (s, cand)
                if best:
                    print(
                        f"fig3_{name}_{method}_best@{bar},sentinel={best[0]},"
                        f"speedup={best[1]['speedup']:.2f},"
                        f"delta_pct={best[1]['delta_pct']:+.3f}"
                    )
        # Fig. 3 dominance: for every EPT operating point, does some LEAR
        # point match-or-beat it on BOTH axes?
        lear_all = [p for pts in curves["lear"].values() for p in pts]
        ept_all = [p for pts in curves["ept"].values() for p in pts]
        dominated = sum(
            any(lp["speedup"] >= ep["speedup"] - 1e-9
                and lp["delta_pct"] >= ep["delta_pct"] - 1e-9
                for lp in lear_all)
            for ep in ept_all
        )
        print(f"fig3_{name}_lear_dominates,{dominated}/{len(ept_all)},"
              f"EPT operating points matched-or-beaten by LEAR on both axes")
    return results


if __name__ == "__main__":
    main()
