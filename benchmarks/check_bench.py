"""CI bench smoke: run the kernel bench tiny, validate the JSON schema.

Runs ``bench_kernels.main(smoke=True)`` against a temp file (NEVER the
tracked ``BENCH_kernels.json`` — the repo copy records the full-size
numbers) and then checks the contract the serving stack and the perf
trajectory depend on:

- every sweep section is present (``fused_vs_staged``, ``leaf_gather``,
  ``blocked_rank``, ``launch_calibration``);
- every timing is a positive finite number (a NaN/zero timing means the
  bench measured nothing and the trajectory row is garbage);
- the mode-pick contract holds (``pick_agrees`` and
  ``auto_bitexact_with_picked_branch`` true at every swept rate);
- the kernel paths' exactness flags hold (``bitexact`` per leaf-gather
  point, ``matches_argsort`` per blocked-rank point).

Exit code 0 on success, 1 with a findings list on violation — CI-friendly,
no pytest dependency.
"""

from __future__ import annotations

import json
import math
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

REQUIRED_SECTIONS = (
    "rows", "fused_vs_staged", "leaf_gather", "blocked_rank",
    "launch_calibration",
)


def _positive_finite(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def validate(payload: dict) -> list[str]:
    """Schema findings for a bench payload; empty list = valid."""
    problems = []
    for section in REQUIRED_SECTIONS:
        if section not in payload:
            problems.append(f"missing section: {section}")
    if problems:
        return problems

    for row in payload["rows"]:
        if not _positive_finite(row.get("us_per_call")):
            problems.append(
                f"row {row.get('name')!r}: bad timing {row.get('us_per_call')!r}"
            )

    fvs = payload["fused_vs_staged"]
    if not fvs.get("sweep"):
        problems.append("fused_vs_staged.sweep is empty")
    for point in fvs.get("sweep", []):
        rate = point.get("continue_rate")
        if not point.get("pick_agrees"):
            problems.append(f"fused_vs_staged r={rate}: device pick != host pick")
        if not point.get("auto_bitexact_with_picked_branch"):
            problems.append(f"fused_vs_staged r={rate}: auto not bit-exact")
        for key in ("fused_us", "staged_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"fused_vs_staged r={rate}: bad {key}")

    lg = payload["leaf_gather"]
    if not lg.get("sweep"):
        problems.append("leaf_gather.sweep is empty")
    for point in lg.get("sweep", []):
        L = point.get("n_leaves")
        if not point.get("bitexact"):
            problems.append(f"leaf_gather L={L}: paths not bit-exact")
        for key in ("onehot_us", "select_us", "mxu_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"leaf_gather L={L}: bad {key}")

    br = payload["blocked_rank"]
    if not br.get("sweep"):
        problems.append("blocked_rank.sweep is empty")
    for point in br.get("sweep", []):
        D = point.get("n_docs")
        if not point.get("matches_argsort"):
            problems.append(f"blocked_rank D={D}: ranks != argsort oracle")
        for key in ("direct_us", "blocked_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"blocked_rank D={D}: bad {key}")

    # 0.0 is a legitimate calibration (launch latency fully explained by
    # tree work on a fast runner — the probe floors at 0); only NaN or a
    # negative value means the probe is broken.
    loh = payload["launch_calibration"].get("launch_overhead_trees")
    if not (isinstance(loh, (int, float)) and math.isfinite(loh) and loh >= 0):
        problems.append("launch_calibration: bad launch_overhead_trees")
    return problems


def main() -> int:
    import bench_kernels

    with tempfile.TemporaryDirectory() as tmp:
        json_path = os.path.join(tmp, "BENCH_kernels.json")
        bench_kernels.main(csv=False, json_path=json_path, smoke=True)
        with open(json_path) as f:
            payload = json.load(f)

    problems = validate(payload)
    if problems:
        print("bench smoke FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_rows = len(payload["rows"])
    print(f"bench smoke OK: {n_rows} rows, all sweep sections valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
