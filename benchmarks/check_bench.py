"""CI bench smoke: run both benches tiny, validate the JSON schemas.

Runs ``bench_kernels.main(smoke=True)`` and ``bench_serve.main(smoke=True)``
against temp files (NEVER the tracked ``BENCH_*.json`` — the repo copies
record the full-size numbers) and then checks the contracts the serving
stack and the perf trajectory depend on.

Kernel bench (:func:`validate`):

- every sweep section is present (``fused_vs_staged``, ``leaf_gather``,
  ``blocked_rank``, ``launch_calibration``);
- every timing is a positive finite number (a NaN/zero timing means the
  bench measured nothing and the trajectory row is garbage);
- the mode-pick contract holds (``pick_agrees`` and
  ``auto_bitexact_with_picked_branch`` true at every swept rate);
- the kernel paths' exactness flags hold (``bitexact`` per leaf-gather
  point, ``matches_argsort`` per blocked-rank point);
- the ``tradeoff`` section (:func:`validate_tradeoff`) carries all four
  configurations ({LEAR, +query-exit, +reorder, both}), each meeting the
  matched-NDCG bar with positive finite trees/wall numbers, and no
  enhanced config traverses MORE trees than document-only LEAR (the
  margin sweep contains the exact ``inf`` mode and the reorder falls
  back to identity, so ``trees_vs_lear ≤ 1`` must hold structurally);
- the ``hybrid`` section (:func:`validate_hybrid`) compares the
  dense-stage-0 cascade against the all-trees cascade: the recorded
  config meets the matched-NDCG bar, its trees-traversed ratio is
  strictly below 1 (the distilled gate pays for itself), both timings
  are positive and finite, and the distillation actually fit
  (pair accuracy above chance).

Serve bench (:func:`validate_serve`):

- every section is present (``serial``, ``streams``, ``speedup``,
  ``warmup``, ``bitexact``, ``degraded``) with non-zero QPS and
  ``p99 ≥ p50`` per row;
- zero cold-start overflow docs (AOT warmup's no-overflow guarantee);
- batched responses bit-exact with single-query serving;
- the ``degraded`` section (:func:`validate_degraded`) holds the
  fault-tolerance contracts: shed/deadline-miss rates are finite
  fractions, the observed queue depth never exceeded the admission
  bound, zero worker crashes and a live supervisor after the overload
  run, per-rung NDCG@10 monotone non-increasing down the ladder (small
  tolerance for eval-set noise), ZERO jit lowerings while stepping
  warmed rungs, and — full runs only — recovery to the baseline rung
  once load subsides;
- for a FULL run additionally the acceptance ratios: ≥2× QPS at max
  concurrency vs serial, first-request latency ≤2× steady p50 (smoke
  skips only the ratio bars — tiny runs on a loaded CI box are too noisy
  to gate on, while the structural/exactness contracts always hold).

Exit code 0 on success, 1 with a findings list on violation — CI-friendly,
no pytest dependency.
"""

from __future__ import annotations

import json
import math
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

REQUIRED_SECTIONS = (
    "rows", "fused_vs_staged", "leaf_gather", "blocked_rank",
    "launch_calibration", "tradeoff", "hybrid",
)

TRADEOFF_CONFIGS = (
    "lear", "lear+query_exit", "lear+reorder", "lear+query_exit+reorder",
)


def _positive_finite(x: object) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def validate_tradeoff(td: dict) -> list[str]:
    """Contract findings for the query-exit/reorder tradeoff section."""
    problems: list[str] = []
    configs = {c.get("name"): c for c in td.get("configs", [])}
    for name in TRADEOFF_CONFIGS:
        if name not in configs:
            problems.append(f"tradeoff: missing config {name!r}")
            continue
        c = configs[name]
        if not _positive_finite(c.get("wall_us")):
            problems.append(f"tradeoff {name}: bad wall_us {c.get('wall_us')!r}")
        if not _positive_finite(c.get("trees_traversed")):
            problems.append(
                f"tradeoff {name}: bad trees_traversed "
                f"{c.get('trees_traversed')!r}"
            )
        ndcg = c.get("ndcg10")
        if not (_positive_finite(ndcg) and ndcg <= 1.0):
            problems.append(f"tradeoff {name}: bad ndcg10 {ndcg!r}")
        if not c.get("meets_ndcg_bar"):
            problems.append(f"tradeoff {name}: fails the matched-NDCG bar")
        ratio = c.get("trees_vs_lear")
        if not (_positive_finite(ratio) and ratio <= 1.0 + 1e-9):
            problems.append(
                f"tradeoff {name}: trees_vs_lear {ratio!r} not in (0, 1] — "
                "an enhanced config must never traverse more than "
                "document-only LEAR"
            )
    return problems


def validate_hybrid(hy: dict) -> list[str]:
    """Contract findings for the hybrid dense-stage-0 section."""
    problems: list[str] = []
    for side in ("all_trees", "dense_stage0"):
        c = hy.get(side)
        if not isinstance(c, dict):
            problems.append(f"hybrid: missing config {side!r}")
            continue
        if not _positive_finite(c.get("wall_us")):
            problems.append(f"hybrid {side}: bad wall_us {c.get('wall_us')!r}")
        if not _positive_finite(c.get("trees_traversed")):
            problems.append(
                f"hybrid {side}: bad trees_traversed "
                f"{c.get('trees_traversed')!r}"
            )
        ndcg = c.get("ndcg10")
        if not (_positive_finite(ndcg) and ndcg <= 1.0):
            problems.append(f"hybrid {side}: bad ndcg10 {ndcg!r}")
    ds = hy.get("dense_stage0", {})
    if isinstance(ds, dict):
        if not ds.get("meets_ndcg_bar"):
            problems.append("hybrid dense_stage0: fails the matched-NDCG bar")
        ratio = ds.get("trees_vs_all_trees")
        if not (_positive_finite(ratio) and ratio < 1.0):
            problems.append(
                f"hybrid dense_stage0: trees_vs_all_trees {ratio!r} not in "
                "(0, 1) — the dense gate must traverse strictly fewer "
                "tree-equivalents than the all-trees cascade"
            )
    acc = hy.get("distill", {}).get("pair_accuracy")
    if not (_positive_finite(acc) and 0.5 < acc <= 1.0):
        problems.append(
            f"hybrid distill: pair_accuracy {acc!r} not in (0.5, 1] — "
            "the distilled proxy did not learn the teacher's order"
        )
    return problems


def validate(payload: dict) -> list[str]:
    """Schema findings for a bench payload; empty list = valid."""
    problems = []
    for section in REQUIRED_SECTIONS:
        if section not in payload:
            problems.append(f"missing section: {section}")
    if problems:
        return problems

    for row in payload["rows"]:
        if not _positive_finite(row.get("us_per_call")):
            problems.append(
                f"row {row.get('name')!r}: bad timing {row.get('us_per_call')!r}"
            )

    fvs = payload["fused_vs_staged"]
    if not fvs.get("sweep"):
        problems.append("fused_vs_staged.sweep is empty")
    for point in fvs.get("sweep", []):
        rate = point.get("continue_rate")
        if not point.get("pick_agrees"):
            problems.append(f"fused_vs_staged r={rate}: device pick != host pick")
        if not point.get("auto_bitexact_with_picked_branch"):
            problems.append(f"fused_vs_staged r={rate}: auto not bit-exact")
        for key in ("fused_us", "staged_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"fused_vs_staged r={rate}: bad {key}")

    lg = payload["leaf_gather"]
    if not lg.get("sweep"):
        problems.append("leaf_gather.sweep is empty")
    for point in lg.get("sweep", []):
        L = point.get("n_leaves")
        if not point.get("bitexact"):
            problems.append(f"leaf_gather L={L}: paths not bit-exact")
        for key in ("onehot_us", "select_us", "mxu_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"leaf_gather L={L}: bad {key}")

    br = payload["blocked_rank"]
    if not br.get("sweep"):
        problems.append("blocked_rank.sweep is empty")
    for point in br.get("sweep", []):
        D = point.get("n_docs")
        if not point.get("matches_argsort"):
            problems.append(f"blocked_rank D={D}: ranks != argsort oracle")
        for key in ("direct_us", "blocked_us"):
            if not _positive_finite(point.get(key)):
                problems.append(f"blocked_rank D={D}: bad {key}")

    # 0.0 is a legitimate calibration (launch latency fully explained by
    # tree work on a fast runner — the probe floors at 0); only NaN or a
    # negative value means the probe is broken.
    loh = payload["launch_calibration"].get("launch_overhead_trees")
    if not (isinstance(loh, (int, float)) and math.isfinite(loh) and loh >= 0):
        problems.append("launch_calibration: bad launch_overhead_trees")

    problems += validate_tradeoff(payload["tradeoff"])
    problems += validate_hybrid(payload["hybrid"])
    return problems


REQUIRED_SERVE_SECTIONS = (
    "config", "serial", "streams", "speedup", "warmup",
    "cold_start_overflow_docs", "bitexact", "degraded",
)

#: NDCG reversal allowed between adjacent rungs before the ladder is
#: declared non-monotone — early exit freezes sentinel partial scores, so
#: tiny lucky reversals on a finite eval set are noise, big ones a bug.
NDCG_MONOTONE_TOL = 0.02


def _rate(x: object) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and 0.0 <= x <= 1.0


def validate_degraded(dg: dict, smoke: bool) -> list[str]:
    """Contract findings for the fault-tolerance (degraded-mode) section."""
    problems: list[str] = []
    ov = dg.get("overload")
    if not isinstance(ov, dict):
        return ["degraded: missing overload run"]
    for key in ("shed_rate", "deadline_miss_rate"):
        if not _rate(ov.get(key)):
            problems.append(
                f"degraded overload: {key} {ov.get(key)!r} not a finite "
                "fraction in [0, 1]"
            )
    limit = ov.get("queue_depth_limit")
    depth = ov.get("max_queue_depth_observed")
    if isinstance(limit, int) and isinstance(depth, int) and depth > limit:
        problems.append(
            f"degraded overload: observed queue depth {depth} exceeded the "
            f"admission bound {limit} — backpressure did not hold"
        )
    if ov.get("worker_crashes", 0) != 0:
        problems.append(
            f"degraded overload: {ov['worker_crashes']} worker crashes "
            "during a crash-free load test"
        )
    if ov.get("health_state") not in ("running", "stopped"):
        problems.append(
            f"degraded overload: tier ended {ov.get('health_state')!r} "
            "(supervision must survive an overload run)"
        )

    rungs = dg.get("rungs")
    if not rungs:
        problems.append("degraded: rungs sweep is empty")
        return problems
    prev = None
    for r in rungs:
        ndcg = r.get("ndcg10")
        name = r.get("name")
        if not (_positive_finite(ndcg) and ndcg <= 1.0):
            problems.append(f"degraded rung {name}: bad ndcg10 {ndcg!r}")
            continue
        if prev is not None and ndcg > prev + NDCG_MONOTONE_TOL:
            problems.append(
                f"degraded rung {name}: ndcg10 {ndcg} exceeds the previous "
                f"rung's {prev} — a CHEAPER rung cannot rank better (the "
                "ladder is mis-ordered)"
            )
        prev = ndcg

    low = dg.get("post_warmup_lowerings")
    if low != 0:
        problems.append(
            f"degraded: {low!r} jit lowerings while stepping warmed rungs "
            "(degrading under load must never compile)"
        )
    if not smoke and not ov.get("recovered"):
        problems.append(
            "degraded overload: tier did not recover to the baseline rung "
            "after load subsided (full run)"
        )
    return problems


def validate_serve(payload: dict) -> list[str]:
    """Schema + contract findings for a serve-bench payload."""
    problems = []
    for section in REQUIRED_SERVE_SECTIONS:
        if section not in payload:
            problems.append(f"missing section: {section}")
    if problems:
        return problems

    def check_lat(row: dict, name: str) -> None:
        if not _positive_finite(row.get("qps")):
            problems.append(f"{name}: bad qps {row.get('qps')!r}")
        p50, p99 = row.get("p50_ms"), row.get("p99_ms")
        if not (_positive_finite(p50) and _positive_finite(p99)):
            problems.append(f"{name}: bad latency p50={p50!r} p99={p99!r}")
        elif p99 < p50:
            problems.append(f"{name}: p99 {p99} < p50 {p50}")

    check_lat(payload["serial"], "serial")
    streams = payload["streams"]
    if not streams:
        problems.append("streams is empty")
    for row in streams:
        check_lat(row, f"stream {row.get('concurrency')}x")

    if payload["cold_start_overflow_docs"] != 0:
        problems.append(
            f"cold-start overflow: {payload['cold_start_overflow_docs']} "
            "docs (warmup must make overflow impossible)"
        )
    bx = payload["bitexact"]
    if not (bx.get("identical") and bx.get("checked", 0) > 0):
        problems.append(f"batched serving not bit-exact: {bx}")

    ratio = payload["speedup"].get("qps_max_concurrency_vs_serial")
    if not _positive_finite(ratio):
        problems.append(f"speedup: bad ratio {ratio!r}")
    first = payload["warmup"].get("first_to_steady_p50_ratio")
    if not _positive_finite(first):
        problems.append(f"warmup: bad first-request ratio {first!r}")
    problems += validate_degraded(
        payload["degraded"], bool(payload["config"].get("smoke"))
    )
    if problems or payload["config"].get("smoke"):
        return problems
    # Full-run acceptance bars (the committed BENCH_serve.json).
    if ratio < 2.0:
        problems.append(
            f"batched QPS only {ratio}x serial at max concurrency (need >=2)"
        )
    if first > 2.0:
        problems.append(
            f"warm first request {first}x steady p50 (need <=2: AOT warmup "
            "must leave no compile behind request 1)"
        )
    return problems


def main() -> int:
    import bench_kernels
    import bench_serve

    problems = []
    with tempfile.TemporaryDirectory() as tmp:
        json_path = os.path.join(tmp, "BENCH_kernels.json")
        bench_kernels.main(csv=False, json_path=json_path, smoke=True)
        with open(json_path) as f:
            kernels = json.load(f)
        problems += [f"kernels: {p}" for p in validate(kernels)]

        serve_path = os.path.join(tmp, "BENCH_serve.json")
        serve = bench_serve.main(json_path=serve_path, smoke=True)
        problems += [f"serve: {p}" for p in validate_serve(serve)]

    # The COMMITTED full-run serve numbers must hold the acceptance bars
    # (≥2× QPS, warm first request, zero overflow) — a regenerated file
    # that regressed them fails CI here, not in a reviewer's head.
    tracked = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_serve.json"
    )
    if os.path.exists(tracked):
        with open(tracked) as f:
            problems += [
                f"tracked BENCH_serve.json: {p}"
                for p in validate_serve(json.load(f))
            ]

    if problems:
        print("bench smoke FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_rows = len(kernels["rows"])
    print(f"bench smoke OK: {n_rows} kernel rows, "
          f"{len(serve['streams'])} serve stream levels, all sections valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
