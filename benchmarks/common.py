"""Shared experiment context for the paper-reproduction benchmarks.

Trains (once, cached under ``artifacts/repro/<dataset>/``) the λ-MART
teacher and the LEAR classifiers for each sentinel, on synthetic MSN-1' /
Istella' (see repro.data.synthetic). Ensemble sizes are scaled down from
the paper's 1,047/1,469 trees (CPU budget); sentinel positions keep the
paper's *fractional* placement (≈5%/10%/20% of the ensemble).
"""

from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

from repro.core.lear import LearClassifier, train_lear
from repro.data.synthetic import LetorDataset, make_letor_dataset
from repro.forest.ensemble import TreeEnsemble, from_complete_arrays
from repro.forest.gbdt import GBDTParams, train_lambdamart
from repro.forest.scoring import score_bitvector

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "repro")


@dataclasses.dataclass
class DatasetSpec:
    preset: str
    n_queries: int
    docs_scale: float
    n_trees: int
    sentinels: tuple[int, ...]
    depth: int = 6
    lr: float = 0.1
    seed: int = 0


SPECS = {
    "msn1": DatasetSpec(
        preset="msn1", n_queries=1000, docs_scale=0.5, n_trees=300,
        sentinels=(15, 30, 60),
    ),
    "istella": DatasetSpec(
        preset="istella", n_queries=600, docs_scale=0.35, n_trees=400,
        sentinels=(20, 40, 80),
    ),
}


def _save_ensemble(path: str, ens: TreeEnsemble) -> None:
    np.savez(
        path,
        feature=np.asarray(ens.feature),
        threshold=np.asarray(ens.threshold),
        leaf_value=np.asarray(ens.leaf_value),
        base_score=np.asarray(ens.base_score),
    )


def _load_ensemble(path: str) -> TreeEnsemble:
    d = np.load(path)
    return from_complete_arrays(
        d["feature"], d["threshold"], d["leaf_value"],
        base_score=float(d["base_score"]),
    )


@dataclasses.dataclass
class Experiment:
    name: str
    spec: DatasetSpec
    data: LetorDataset
    splits: dict
    ranker: TreeEnsemble
    classifiers: dict[int, LearClassifier]   # sentinel -> classifier

    def scores(self, split: str):
        ds = self.splits[split]
        Q, D, F = ds.X.shape
        _, per_tree = score_bitvector(
            self.ranker, jnp.asarray(ds.X.reshape(Q * D, F)),
            return_per_tree=True,
        )
        return per_tree.reshape(Q, D, -1)  # [Q, D, T]


def get_experiment(name: str, verbose: bool = True) -> Experiment:
    spec = SPECS[name]
    art = os.path.join(ART, name)
    os.makedirs(art, exist_ok=True)
    data = make_letor_dataset(
        spec.preset, n_queries=spec.n_queries, docs_scale=spec.docs_scale,
        seed=spec.seed,
    )
    splits = data.splits()

    ranker_path = os.path.join(art, "ranker.npz")
    if os.path.exists(ranker_path):
        ranker = _load_ensemble(ranker_path)
    else:
        if verbose:
            print(f"[{name}] training λ-MART teacher ({spec.n_trees} trees)...",
                  flush=True)
        tr = splits["train"]
        params = GBDTParams(
            n_trees=spec.n_trees, depth=spec.depth, learning_rate=spec.lr
        )
        ranker = train_lambdamart(
            tr.X, tr.labels.astype(np.float32), tr.mask, params, k=10
        )
        _save_ensemble(ranker_path, ranker)

    classifiers = {}
    cls_split = splits["classifier"]
    for s in spec.sentinels:
        cpath = os.path.join(art, f"lear_s{s}.npz")
        if os.path.exists(cpath):
            classifiers[s] = LearClassifier(
                forest=_load_ensemble(cpath), sentinel=s
            )
        else:
            if verbose:
                print(f"[{name}] training LEAR classifier @ sentinel {s}...",
                      flush=True)
            clf = train_lear(
                cls_split.X, cls_split.labels, cls_split.mask, ranker,
                sentinel=s, k=15,
            )
            _save_ensemble(cpath, clf.forest)
            classifiers[s] = clf

    return Experiment(
        name=name, spec=spec, data=data, splits=splits, ranker=ranker,
        classifiers=classifiers,
    )
