"""Benchmark entry point: one section per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--only SECTION]

Prints ``name,us_per_call_or_value,derived`` CSV lines per section.
"""

from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("table1", "classifier", "tradeoff", "kernels", "roofline")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", choices=SECTIONS, default=None)
    args = p.parse_args()

    sections = [args.only] if args.only else list(SECTIONS)
    for section in sections:
        print(f"# === {section} ===", flush=True)
        t0 = time.time()
        try:
            if section == "table1":
                from benchmarks import bench_table1
                bench_table1.main()
            elif section == "classifier":
                from benchmarks import bench_classifier
                bench_classifier.main()
            elif section == "tradeoff":
                from benchmarks import bench_tradeoff
                bench_tradeoff.main()
            elif section == "kernels":
                from benchmarks import bench_kernels
                bench_kernels.main()
            elif section == "roofline":
                from benchmarks import bench_roofline
                bench_roofline.main()
        except Exception as e:  # noqa: BLE001
            print(f"{section}_ERROR,{type(e).__name__},{e}", file=sys.stderr)
            raise
        print(f"# {section} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
