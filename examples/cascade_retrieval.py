"""Beyond-paper: the LEAR cascade generalized to recsys retrieval.

Scores 100k candidates for one user with a DLRM-family model in two stages:
a cheap sentinel scorer (embedding dot product) filters candidates, the
full model scores the survivors — the paper's document-level early exit
transplanted onto a neural ranking stack (see DESIGN.md
§Arch-applicability).

    PYTHONPATH=src python examples/cascade_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import RecSysConfig
from repro.models import recsys as rec
from repro.serve.ranking_service import TwoStageCascade


def main():
    cfg: RecSysConfig = get_smoke_config("dlrm-rm2")
    params = rec.dlrm_init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    C = 100_000
    user = {
        "dense": jnp.asarray(rng.normal(size=(1, cfg.n_dense)).astype(np.float32)),
        "sparse": jnp.asarray(
            np.stack([rng.integers(0, v, size=(1, cfg.multi_hot))
                      for v in cfg.vocab_sizes[:-1]], axis=1).astype(np.int32)
        ),
    }
    cand_ids = jnp.asarray(
        rng.integers(0, cfg.vocab_sizes[-1], size=C).astype(np.int32)
    )

    # Full scorer: complete DLRM interaction per candidate.
    @jax.jit
    def full_fn(ids):
        return rec.dlrm_score_candidates(cfg, params, {**user, "cand_ids": ids})

    # Sentinel: dot(candidate embedding, user bottom-MLP vector) — the cheap
    # first stage (one gather + one matvec per candidate).
    bot = rec._mlp(user["dense"], params["bot"], jax.nn.relu)[0]

    @jax.jit
    def sentinel_fn(ids):
        cand_vec = jnp.take(params["tables"][f"t{len(cfg.vocab_sizes) - 1}"],
                            ids, axis=0)
        return cand_vec @ bot

    # Ground truth = full scoring of everything.
    t0 = time.perf_counter()
    full_all = np.asarray(full_fn(cand_ids))
    t_full = time.perf_counter() - t0
    true_top100 = set(np.argsort(-full_all)[:100].tolist())

    for keep in (0.01, 0.05, 0.2):
        cascade = TwoStageCascade(sentinel_fn, full_fn, keep_fraction=keep)
        t0 = time.perf_counter()
        survivors, scores, cheap = cascade.score(cand_ids)
        t_casc = time.perf_counter() - t0
        # Survivor *positions* in cand_ids (the cascade keeps top sentinel
        # scores); recall = how many of the true top-100 survive the filter.
        surv_pos = set(
            np.asarray(jax.lax.top_k(cheap, max(1, int(C * keep)))[1]).tolist()
        )
        recall = len(true_top100 & surv_pos) / 100
        print(
            f"keep={keep:.0%}: sentinel+full over {int(C * keep)} survivors, "
            f"top-100 recall={recall:.2f}, "
            f"wall {t_casc:.2f}s vs full {t_full:.2f}s"
        )


if __name__ == "__main__":
    main()
