"""Quickstart: train a λ-MART ranker, attach LEAR early exit, measure the
efficiency/effectiveness trade-off — the paper's pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.lear import augment_features, train_lear
from repro.data.synthetic import make_letor_dataset
from repro.forest.gbdt import GBDTParams, train_lambdamart
from repro.forest.scoring import score_bitvector
from repro.metrics.ranking import mean_ndcg
from repro.metrics.speedup import speedup_vs_full


def main():
    # 1. A small MSN-1-like dataset (136 features, graded labels 0-4).
    data = make_letor_dataset("msn1", n_queries=200, n_features=64,
                              docs_scale=0.3, seed=0)
    splits = data.splits()
    train, cls_split, test = splits["train"], splits["classifier"], splits["test"]

    # 2. λ-MART teacher (NDCG@10 lambda gradients).
    print("training λ-MART (80 trees)...")
    ranker = train_lambdamart(
        train.X, train.labels.astype(np.float32), train.mask,
        GBDTParams(n_trees=80, depth=5, learning_rate=0.15), k=10,
    )

    # 3. LEAR classifier at sentinel 8 (≈10% of the ensemble).
    sentinel = 8
    print("training LEAR classifier...")
    clf = train_lear(cls_split.X, cls_split.labels, cls_split.mask, ranker,
                     sentinel=sentinel, k=15)

    # 4. Evaluate the cascade on the test split.
    Q, D, F = test.X.shape
    flat = jnp.asarray(test.X.reshape(Q * D, F))
    _, per_tree = score_bitvector(ranker, flat, return_per_tree=True)
    per_tree = per_tree.reshape(Q, D, -1)
    partial = per_tree[..., :sentinel].sum(-1)
    full = per_tree.sum(-1)
    mask, labels = jnp.asarray(test.mask), jnp.asarray(test.labels)

    ndcg_full = float(mean_ndcg(full, labels, mask, 10))
    print(f"\nFull ensemble: NDCG@10 = {ndcg_full:.4f}, speedup 1.00x")
    aug = augment_features(jnp.asarray(test.X), partial, mask)
    for threshold in (0.1, 0.3, 0.5, 0.7):
        cont = clf.continue_mask(aug, mask, threshold=threshold)
        scores = jnp.where(cont, full, partial)
        ndcg = float(mean_ndcg(scores, labels, mask, 10))
        sp = speedup_vs_full(cont, mask, sentinel, ranker.n_trees, clf.n_trees)
        print(
            f"LEAR(threshold={threshold:.1f}): NDCG@10 = {ndcg:.4f} "
            f"({100 * (ndcg - ndcg_full) / ndcg_full:+.2f}%), "
            f"speedup {sp:.2f}x"
        )


if __name__ == "__main__":
    main()
