"""Progressive-engine serving walkthrough: the device-resident hot path.

Companion to ``docs/serving.md`` — demonstrates every tuning knob of
:class:`repro.serve.ranking_service.RankingService` on the multi-sentinel
progressive engine:

1. calibrate ``launch_overhead_trees`` from a measured timing probe;
2. build a two-stage LEAR cascade (two classifiers, two sentinels) whose
   augmented features are built on device inside the compiled step;
3. serve traffic whose continue rate SHIFTS mid-stream and watch the
   on-device ``lax.cond`` mode pick follow it (staged on sparse traffic,
   fused on dense) with zero host round trips in the decision loop;
4. read the capacity ratchet and the service stats.

    PYTHONPATH=src python examples/serve_progressive.py           # full
    PYTHONPATH=src python examples/serve_progressive.py --smoke   # tiny/CI
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.lear import train_lear
from repro.data.synthetic import make_letor_dataset
from repro.forest.gbdt import GBDTParams, train_lambdamart
from repro.serve.calibration import calibrate_launch_overhead_trees
from repro.serve.ranking_service import RankingService, ServiceConfig


def _shifted_batches(ds, rng, batch_queries, n_batches, sparse_first):
    """Yield query batches; the first half resamples toward queries with
    few relevant docs (sparse survivors), the second half toward many."""
    rel_per_q = (ds.labels > 0).sum(axis=1)
    order = np.argsort(rel_per_q)
    half = n_batches // 2
    for b in range(n_batches):
        pool = order[: len(order) // 2] if (b < half) == sparse_first \
            else order[len(order) // 2:]
        idx = rng.choice(pool, size=batch_queries, replace=True)
        yield (
            jnp.asarray(ds.X[idx]),
            jnp.asarray(ds.mask[idx]),
        )


def main(smoke: bool = False):
    if smoke:
        n_queries, n_feat, n_trees, batches, bq = 40, 16, 32, 4, 2
        sentinels = (4, 12)
    else:
        n_queries, n_feat, n_trees, batches, bq = 160, 48, 64, 10, 8
        sentinels = (6, 20)

    # 1. Calibrate the cost model's launch price from measurement. The
    # service default launch_overhead_trees="auto" does exactly this
    # (cached per process); we call it explicitly to show the number.
    overhead = calibrate_launch_overhead_trees()
    print(f"calibrated launch_overhead_trees ≈ {overhead:.0f} doc·trees")
    if overhead > 4096:
        # CPU interpret mode: kernel dispatch goes through the Pallas
        # interpreter, so a launch is worth a LOT of tree work and the
        # pick will lean fused. On a compiled TPU backend the measured
        # overhead is orders of magnitude smaller and sparse traffic
        # flips the pick to staged (see docs/serving.md for the bench
        # crossover).
        print("  (interpret-mode dispatch is expensive → expect fused picks"
              " on this backend)")

    print(f"training λ-MART ({n_trees} trees) + 2 LEAR classifiers...")
    data = make_letor_dataset("msn1", n_queries=n_queries,
                              n_features=n_feat, docs_scale=0.25, seed=3)
    splits = data.splits()
    train, cls_split, test = (
        splits["train"], splits["classifier"], splits["test"]
    )
    ranker = train_lambdamart(
        train.X, train.labels.astype(np.float32), train.mask,
        GBDTParams(n_trees=n_trees, depth=4, learning_rate=0.15), k=10,
    )
    clf_a, clf_b = (
        train_lear(cls_split.X, cls_split.labels, cls_split.mask, ranker,
                   sentinel=s, k=15)
        for s in sentinels
    )

    # 2. The service: auto execution mode = on-device fused/staged pick.
    service = RankingService(
        ranker, clf_a,
        ServiceConfig(
            threshold=0.3, execution_mode="auto",
            launch_overhead_trees=overhead, capacity_headroom=1.25,
            survivor_ema=0.5, top_k=10,
        ),
        extra_classifiers=[clf_b],
    )

    # 3. Shifting traffic: sparse-survivor batches first, dense after.
    rng = np.random.default_rng(0)
    print(f"serving {batches} batches of {bq} queries "
          "(sparse → dense traffic shift)...")
    for b, (X, mask) in enumerate(
        _shifted_batches(test, rng, bq, batches, sparse_first=True)
    ):
        fused0, staged0 = (
            service.stats.batches_fused, service.stats.batches_staged
        )
        service.rank_batch(X, mask)
        picked = (
            "staged" if service.stats.batches_staged > staged0 else "fused"
        )
        ema = [f"{e:.0f}" for e in service._stage_ema]
        print(f"  batch {b}: picked={picked:<6} survivor_ema={ema} "
              f"capacities={service._pick_capacities(X.shape[0] * X.shape[1])}")

    # 4. Service-level accounting (trees traversed — the paper's metric).
    s = service.stats
    print(f"\nstats after {s.batches} batches "
          f"({s.batches_fused} fused / {s.batches_staged} staged):")
    print(f"  queries        : {s.queries}")
    print(f"  docs scored    : {s.docs}")
    print(f"  continue rate  : {s.continue_rate:.1%}")
    print(f"  overflow docs  : {s.overflow_docs}")
    print(f"  speedup (trees): {s.speedup:.2f}x vs full ensemble")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (the docs-lane test runs this)")
    main(**vars(ap.parse_args()))
