"""End-to-end driver: a batched ranking SERVICE with LEAR early exit.

Trains the full stack (λ-MART teacher + LEAR classifier), then serves
streams of query batches through :class:`repro.serve.RankingService` —
compacted tail execution via the Pallas kernel path, capacity adaptation,
checkpointed service state, and final service-level stats.

    PYTHONPATH=src python examples/serve_ranking.py
"""

import os

import jax.numpy as jnp
import numpy as np

from repro.core.lear import train_lear
from repro.data.pipeline import QueryBatcher
from repro.data.synthetic import make_letor_dataset
from repro.forest.gbdt import GBDTParams, train_lambdamart
from repro.metrics.ranking import mean_ndcg
from repro.serve.ranking_service import RankingService, ServiceConfig

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "serve_demo")


def main():
    data = make_letor_dataset("msn1", n_queries=160, n_features=48,
                              docs_scale=0.25, seed=3)
    splits = data.splits()
    train, cls_split, test = splits["train"], splits["classifier"], splits["test"]

    print("training λ-MART (64 trees) + LEAR...")
    ranker = train_lambdamart(
        train.X, train.labels.astype(np.float32), train.mask,
        GBDTParams(n_trees=64, depth=5, learning_rate=0.15), k=10,
    )
    clf = train_lear(cls_split.X, cls_split.labels, cls_split.mask, ranker,
                     sentinel=6, k=15)

    service = RankingService(ranker, clf, ServiceConfig(threshold=0.3))
    batcher = QueryBatcher(n_queries=test.n_queries, batch_queries=8)

    print("serving 6 batches of 8 queries...")
    ndcgs = []
    for _ in range(6):
        idx = batcher.next_indices()
        X = jnp.asarray(test.X[idx])
        mask = jnp.asarray(test.mask[idx])
        top_idx, scores = service.rank_batch(X, mask)
        ndcgs.append(float(mean_ndcg(
            jnp.asarray(scores), jnp.asarray(test.labels[idx]), mask, 10
        )))

    s = service.stats
    print(f"\nservice stats after {s.batches} batches:")
    print(f"  queries        : {s.queries}")
    print(f"  docs scored    : {s.docs}")
    print(f"  continue rate  : {s.continue_rate:.1%}")
    print(f"  overflow docs  : {s.overflow_docs}")
    print(f"  speedup (trees): {s.speedup:.2f}x vs full ensemble")
    print(f"  NDCG@10 (mean) : {np.mean(ndcgs):.4f}")
    # Resumable service state (fault-tolerance contract).
    print(f"  batcher cursor : {batcher.state()}")


if __name__ == "__main__":
    main()
