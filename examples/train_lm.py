"""Train a ~100M-parameter dense LM for a few hundred steps on CPU, with
checkpoint/restart mid-run (the fault-tolerance path, exercised for real).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os

import jax
import numpy as np

from repro.configs.base import TransformerConfig
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as tfm
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.optimizer import adamw
from repro.train.trainer import init_state, make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "lm_demo")


def small_lm() -> TransformerConfig:
    # ~103M params: 10 layers × d640 (62M body) + 32k vocab (41M embeddings).
    return TransformerConfig(
        name="demo-100m", n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
        d_head=64, d_ff=2560, vocab_size=32000, rope_theta=10000.0,
        attn_q_block=128, attn_kv_block=128,
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-every", type=int, default=100)
    args = p.parse_args()

    cfg = small_lm()
    n_params = sum(x.size for x in jax.tree.leaves(tfm.abstract_params(cfg)))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch_size=args.batch,
                         seq_len=args.seq, seed=0)
    opt = adamw(lr=3e-4)
    step_fn = jax.jit(make_train_step(
        lambda params, batch: tfm.loss_fn(cfg, params, batch), opt
    ))

    # Restart-aware: resume from the latest checkpoint if one exists.
    state = init_state(tfm.init(cfg, jax.random.key(0)), opt)
    start = 0
    if latest_step(ART) is not None:
        state, extra = restore_checkpoint(ART, state)
        pipe.restore(extra["pipeline"])
        start = int(extra["step"])
        print(f"restored checkpoint at step {start}; pipeline cursor "
              f"{pipe.cursor}")

    losses = []
    for i in range(start, args.steps):
        batch = pipe.next_batch()
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % 20 == 0:
            print(f"step {i + 1:4d}  loss {np.mean(losses[-20:]):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")
        if (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(
                ART, i + 1, state,
                extra={"step": i + 1, "pipeline": pipe.state()},
            )
            print(f"checkpoint → {os.path.basename(path)}")

    print(f"\nfirst-20 mean loss {np.mean(losses[:20]):.4f} → "
          f"last-20 mean loss {np.mean(losses[-20:]):.4f}")
    if len(losses) >= 40:  # loss-drop check needs disjoint windows
        assert np.mean(losses[-20:]) < np.mean(losses[:20]), "loss did not drop"


if __name__ == "__main__":
    main()
