"""Repo-specific static analysis: tracer-safety and engine-contract
rules over the serving hot path.

Run as ``python -m repro.analysis [paths...]`` (or through
``tools/check_invariants.py``); the default target is ``src/repro``.
Rules, error codes, and the suppression syntax are documented in
``docs/static-analysis.md``.
"""

from __future__ import annotations

from repro.analysis.engine import Finding, format_findings, run_paths

__all__ = ["Finding", "format_findings", "run_paths", "main"]


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the process exit code (0 = clean)."""
    import argparse
    from pathlib import Path

    from repro.analysis.rules import all_rules

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "tracer-safety & invariant linter for the LEAR serving engine"
        ),
    )
    parser.add_argument(
        "paths", nargs="*", default=["src/repro"],
        help="files or directories to analyze (default: src/repro)",
    )
    parser.add_argument(
        "--select", default=None,
        help="comma-separated rule codes to run (default: all)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule table and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.code}  {rule.name}")
            print(f"       fix: {rule.hint}")
        return 0

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        parser.error(f"no such path(s): {', '.join(missing)}")
    codes = (
        [c.strip() for c in args.select.split(",")] if args.select else None
    )
    findings = run_paths(args.paths, codes=codes)
    print(format_findings(findings, fmt=args.fmt))
    return 1 if findings else 0
