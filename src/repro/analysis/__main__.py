"""``python -m repro.analysis`` — see the package docstring."""

from __future__ import annotations

import sys

from repro.analysis import main

if __name__ == "__main__":
    sys.exit(main())
