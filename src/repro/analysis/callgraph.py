"""Project model for the tracer-safety rules: modules, functions, a
resolved call graph, jit/kernel scope, and a light taint analysis.

Everything here is pure ``ast`` — the analyzed tree is never imported,
so the pass is safe to run on broken or heavyweight code and needs no
JAX at analysis time.

The model answers four questions the rules ask:

1. **Which functions are jit roots?**  ``@jax.jit`` (bare or through
   ``functools.partial``), ``jax.jit(fn)`` call sites, and kernel bodies
   handed to ``pl.pallas_call`` (directly, through an inline
   ``functools.partial``, or through a local variable bound to one).
   Declared traced roots (closures the graph cannot see) come from
   :mod:`repro.analysis.config`.
2. **What does a function reach?**  Call edges plus *reference* edges —
   a bare ``Name`` load that resolves to a project function (covers
   ``lax.cond(p, f, g)``, ``fori_loop(0, n, body)``, dict/tuple
   dispatch through module-level containers, and ``partial(f, ...)``).
   Code under ``with jax.ensure_compile_time_eval():`` runs at trace
   time, so its edges are kept separately and excluded from jit scope.
3. **Which values are tracers?**  Parameters are tainted unless their
   annotation is static-like (``int``/``str``/config objects/...);
   array-ish annotations (``jax.Array``, ``jaxtyping.Float32[...]``)
   and *missing* annotations taint.  Shape/dtype attribute access,
   ``len()``/``isinstance()`` and ``is``/``is not`` comparisons break
   taint — those are trace-time Python values.
4. **What is this call, canonically?**  Import aliases are followed so
   ``np.asarray`` names ``numpy.asarray`` while ``jnp.asarray`` names
   ``jax.numpy.asarray`` — the rules match canonical dotted names, not
   surface spellings.
"""

from __future__ import annotations

import ast
from collections.abc import Iterable, Iterator
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis import config

JIT_CANONICAL = {"jax.jit", "jax.pjit"}
PALLAS_CALL_CANONICAL = {"jax.experimental.pallas.pallas_call"}
PARTIAL_CANONICAL = {"functools.partial", "jax.tree_util.Partial"}
EAGER_CONTEXT_CANONICAL = {"jax.ensure_compile_time_eval"}

# Attribute reads that yield trace-time Python values even on tracers.
SHAPE_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "itemsize", "sharding"})

# Calls whose result is a host value regardless of argument taint.
# int()/float()/bool() either concretize at trace time or raise — TS001
# owns flagging them; for control-flow purposes their result is host.
UNTAINT_CALLS = frozenset(
    {"len", "isinstance", "issubclass", "range", "enumerate", "type",
     "repr", "str", "hash", "id", "int", "float", "bool", "callable"}
)

# Annotation roots that mark a parameter as carrying device values.
ARRAY_ANNOTATION_ROOTS = frozenset(
    {"Array", "ndarray", "ArrayLike", "Float", "Float32", "Float64",
     "Int", "Int8", "Int32", "Int64", "UInt32", "UInt64", "Bool",
     "Shaped", "Num", "Inexact", "Key", "Scalar", "Ref"}
)

# Attribute method calls never resolved to project methods — ubiquitous
# names on dicts/arrays/stdlib objects that would mis-link the graph.
ATTR_FALLBACK_SKIP = frozenset(
    {"get", "put", "pop", "append", "extend", "add", "update", "copy",
     "items", "keys", "values", "join", "split", "read", "write",
     "close", "sum", "mean", "max", "min", "astype", "reshape", "result",
     "submit", "start", "stop", "set", "setdefault", "format", "index"}
)


def _attr_chain(expr: ast.expr) -> tuple[list[str], ast.expr]:
    """Peel ``a.b.c`` into ([\"b\", \"c\"], Name(\"a\"))-style parts."""
    parts: list[str] = []
    cur = expr
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    parts.reverse()
    return parts, cur


def annotation_is_arrayish(ann: ast.expr | None) -> bool:
    """True when an annotation says \"this is (or may be) a device array\"."""
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return any(root in ann.value for root in ARRAY_ANNOTATION_ROOTS)
    if isinstance(ann, ast.Name):
        return ann.id in ARRAY_ANNOTATION_ROOTS
    if isinstance(ann, ast.Attribute):
        return ann.attr in ARRAY_ANNOTATION_ROOTS
    if isinstance(ann, ast.Subscript):
        return annotation_is_arrayish(ann.value) or annotation_is_arrayish(ann.slice)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return annotation_is_arrayish(ann.left) or annotation_is_arrayish(ann.right)
    if isinstance(ann, ast.Tuple):
        return any(annotation_is_arrayish(e) for e in ann.elts)
    return False


@dataclass
class FunctionInfo:
    """One function (or jitted lambda) in the analyzed tree."""

    qualname: str  # dotted within the module, e.g. "CascadeRanker.rank"
    module: str
    path: Path
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
    class_name: str | None = None
    is_jit_root: bool = False
    is_kernel_body: bool = False
    calls: set[str] = field(default_factory=set)  # resolved full ids
    eager_calls: set[str] = field(default_factory=set)
    eager_ranges: list[tuple[int, int]] = field(default_factory=list)
    _taint: set[str] | None = None

    @property
    def full_id(self) -> str:
        return f"{self.module}:{self.qualname}"

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def in_eager_range(self, lineno: int) -> bool:
        return any(lo <= lineno <= hi for lo, hi in self.eager_ranges)


@dataclass
class ModuleInfo:
    name: str
    path: Path
    tree: ast.Module
    source_lines: list[str]
    aliases: dict[str, str] = field(default_factory=dict)  # name -> dotted
    top_level_defs: dict[str, str] = field(default_factory=dict)  # name -> qualname
    containers: dict[str, set[str]] = field(default_factory=dict)  # name -> full ids


def module_name_for(path: Path) -> str:
    """Derive a dotted module name; falls back to the file stem for
    fixture files analyzed outside a package tree."""
    parts = list(path.with_suffix("").parts)
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)
    return path.stem


class ProjectIndex:
    """Parsed modules + resolved call graph + scope/taint queries."""

    def __init__(self, paths: Iterable[Path]) -> None:
        self.modules: dict[str, ModuleInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.canonical_to_id: dict[str, str] = {}
        self.methods_by_name: dict[str, list[str]] = {}
        self.errors: list[tuple[Path, str]] = []
        for path in paths:
            self._parse(Path(path))
        for mod in self.modules.values():
            self._index_module(mod)
        for mod in self.modules.values():
            self._collect_containers(mod)
        for func in list(self.functions.values()):
            self._collect_edges(func)
        self._jit_scope: set[str] | None = None
        self._kernel_scope: set[str] | None = None

    # -- parsing --------------------------------------------------------

    def _parse(self, path: Path) -> None:
        try:
            text = path.read_text()
            tree = ast.parse(text, filename=str(path))
        except (OSError, SyntaxError) as exc:
            self.errors.append((path, str(exc)))
            return
        name = module_name_for(path)
        self.modules[name] = ModuleInfo(
            name=name, path=path, tree=tree, source_lines=text.splitlines()
        )

    def _index_module(self, mod: ModuleInfo) -> None:
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    mod.aliases[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else alias.name.split(".")[0]
                    )
            elif isinstance(stmt, ast.ImportFrom) and stmt.module and stmt.level == 0:
                for alias in stmt.names:
                    mod.aliases[alias.asname or alias.name] = (
                        f"{stmt.module}.{alias.name}"
                    )
        self._index_scope(mod, mod.tree.body, prefix="", class_name=None)

    def _index_scope(
        self,
        mod: ModuleInfo,
        body: list[ast.stmt],
        prefix: str,
        class_name: str | None,
    ) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qualname = f"{prefix}{stmt.name}"
                info = FunctionInfo(
                    qualname=qualname,
                    module=mod.name,
                    path=mod.path,
                    node=stmt,
                    class_name=class_name,
                )
                info.is_jit_root = self._has_jit_decorator(mod, stmt)
                self._register(mod, info)
                self._index_scope(
                    mod, stmt.body, prefix=f"{qualname}.", class_name=class_name
                )
            elif isinstance(stmt, ast.ClassDef):
                qualname = f"{prefix}{stmt.name}"
                self._index_scope(
                    mod, stmt.body, prefix=f"{qualname}.", class_name=stmt.name
                )
            elif isinstance(stmt, (ast.If, ast.Try, ast.With)):
                # conditional defs (TYPE_CHECKING guards, try/except imports)
                for child in ast.iter_child_nodes(stmt):
                    if isinstance(child, ast.stmt):
                        self._index_scope(mod, [child], prefix, class_name)

    def _register(self, mod: ModuleInfo, info: FunctionInfo) -> None:
        self.functions[info.full_id] = info
        self.canonical_to_id[f"{mod.name}.{info.qualname}"] = info.full_id
        if "." not in info.qualname:
            mod.top_level_defs[info.qualname] = info.qualname
        if info.class_name is not None and info.qualname.count(".") == 1:
            self.methods_by_name.setdefault(info.name, []).append(info.full_id)

    # -- canonical names ------------------------------------------------

    def canonical(self, mod: ModuleInfo, expr: ast.expr) -> str | None:
        """Canonical dotted name for a Name/Attribute chain, following
        import aliases (``np.asarray`` → ``numpy.asarray``)."""
        parts, base = _attr_chain(expr)
        if not isinstance(base, ast.Name):
            return None
        root = mod.aliases.get(base.id)
        if root is None:
            if base.id in mod.top_level_defs:
                root = f"{mod.name}.{base.id}"
            else:
                root = base.id
        return ".".join([root, *parts])

    def resolve_name_in_scope(
        self, func: FunctionInfo, name: str
    ) -> str | None:
        """Resolve a bare name lexically: sibling/parent nested scopes
        first (``step`` calling ``fused_body``), then module level."""
        parts = func.qualname.split(".")
        for i in range(len(parts), -1, -1):
            prefix = ".".join([func.module, *parts[:i], name])
            if prefix in self.canonical_to_id:
                return self.canonical_to_id[prefix]
        return None

    def resolve_canonical(self, canon: str, depth: int = 0) -> str | None:
        """Map a canonical dotted name to a project function id,
        following re-export chains (``from x import f``) across modules."""
        if depth > 8 or canon is None:
            return None
        if canon in self.canonical_to_id:
            return self.canonical_to_id[canon]
        if "." not in canon:
            return None
        owner, leaf = canon.rsplit(".", 1)
        mod = self.modules.get(owner)
        if mod is not None and leaf in mod.aliases:
            return self.resolve_canonical(mod.aliases[leaf], depth + 1)
        return None

    # -- edges ----------------------------------------------------------

    def _collect_containers(self, mod: ModuleInfo) -> None:
        """Module-level assignments whose value references functions —
        the dispatch tables (``_LEAF_VALUE_FNS``, ``COMPACTORS``)."""
        for stmt in mod.tree.body:
            targets: list[ast.expr] = []
            value: ast.expr | None = None
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            if value is None:
                continue
            # a function CALLED to compute the constant is not a stored
            # reference — only names in value position count
            call_positions = {
                id(node.func)
                for node in ast.walk(value)
                if isinstance(node, ast.Call)
            }
            refs = set()
            for node in ast.walk(value):
                if (
                    isinstance(node, (ast.Name, ast.Attribute))
                    and id(node) not in call_positions
                ):
                    canon = self.canonical(mod, node)
                    target = self.resolve_canonical(canon) if canon else None
                    if target is not None:
                        refs.add(target)
            if not refs:
                continue
            for tgt in targets:
                if isinstance(tgt, ast.Name):
                    mod.containers[tgt.id] = refs

    def _has_jit_decorator(
        self, mod: ModuleInfo, node: ast.FunctionDef | ast.AsyncFunctionDef
    ) -> bool:
        for deco in node.decorator_list:
            expr = deco
            if isinstance(expr, ast.Call):
                canon = self.canonical(mod, expr.func)
                if canon in JIT_CANONICAL:
                    return True
                if canon in PARTIAL_CANONICAL and expr.args:
                    inner = self.canonical(mod, expr.args[0])
                    if inner in JIT_CANONICAL:
                        return True
            else:
                if self.canonical(mod, expr) in JIT_CANONICAL:
                    return True
        return False

    def _resolve_call_target(
        self,
        mod: ModuleInfo,
        func: FunctionInfo,
        call: ast.Call,
    ) -> set[str]:
        """Project function ids a call may dispatch to."""
        out: set[str] = set()
        target = call.func
        if isinstance(target, ast.Subscript) and isinstance(target.value, ast.Name):
            out |= mod.containers.get(target.value.id, set())
            return out
        if isinstance(target, ast.Name):
            scoped = self.resolve_name_in_scope(func, target.id)
            if scoped is not None:
                return {scoped}
        canon = self.canonical(mod, target)
        if canon is not None:
            resolved = self.resolve_canonical(canon)
            if resolved is not None:
                out.add(resolved)
                return out
        if isinstance(target, ast.Attribute):
            parts, base = _attr_chain(target)
            leaf = parts[-1]
            if (
                isinstance(base, ast.Name)
                and base.id == "self"
                and func.class_name is not None
            ):
                own = self.canonical_to_id.get(
                    f"{func.module}.{func.class_name}.{leaf}"
                )
                if own is not None:
                    out.add(own)
                    return out
            if leaf not in ATTR_FALLBACK_SKIP:
                candidates = self.methods_by_name.get(leaf, [])
                if len(candidates) == 1:
                    out.add(candidates[0])
        return out

    def _collect_edges(self, func: FunctionInfo) -> None:
        mod = self.modules[func.module]
        body = (
            [func.node.body]
            if isinstance(func.node, ast.Lambda)
            else func.node.body
        )
        index = self

        class EdgeVisitor(ast.NodeVisitor):
            def __init__(self) -> None:
                self.eager_depth = 0

            def _add(self, targets: set[str]) -> None:
                sink = func.eager_calls if self.eager_depth else func.calls
                sink.update(targets)

            def visit_With(self, node: ast.With) -> None:
                is_eager = any(
                    isinstance(item.context_expr, ast.Call)
                    and index.canonical(mod, item.context_expr.func)
                    in EAGER_CONTEXT_CANONICAL
                    for item in node.items
                )
                if is_eager:
                    func.eager_ranges.append(
                        (node.lineno, node.end_lineno or node.lineno)
                    )
                    self.eager_depth += 1
                    self.generic_visit(node)
                    self.eager_depth -= 1
                else:
                    self.generic_visit(node)

            def visit_Call(self, node: ast.Call) -> None:
                self._add(index._resolve_call_target(mod, func, node))
                canon = index.canonical(mod, node.func)
                if canon in JIT_CANONICAL and node.args:
                    index._mark_jit_argument(mod, func, node.args[0])
                if canon in PALLAS_CALL_CANONICAL and node.args:
                    index._mark_kernel_argument(mod, func, node.args[0])
                self.generic_visit(node)

            def visit_Name(self, node: ast.Name) -> None:
                if isinstance(node.ctx, ast.Load):
                    scoped = index.resolve_name_in_scope(func, node.id)
                    if scoped is not None:
                        self._add({scoped})
                        return
                    canon = index.canonical(mod, node)
                    resolved = (
                        index.resolve_canonical(canon) if canon else None
                    )
                    if resolved is not None:
                        self._add({resolved})
                    elif node.id in mod.containers:
                        self._add(mod.containers[node.id])

            def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
                pass  # nested defs are their own FunctionInfo

            visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

            def visit_Lambda(self, node: ast.Lambda) -> None:
                # lambdas have no FunctionInfo of their own (unless
                # jitted) — their references belong to the enclosing
                # function (lax.cond branch thunks)
                self.generic_visit(node)

        visitor = EdgeVisitor()
        for stmt in body:
            visitor.visit(stmt)

    def _mark_jit_argument(
        self, mod: ModuleInfo, func: FunctionInfo, arg: ast.expr
    ) -> None:
        """``jax.jit(target)``: mark the target (or a synthetic lambda)."""
        if isinstance(arg, ast.Lambda):
            qualname = f"{func.qualname}.<lambda:{arg.lineno}>"
            info = FunctionInfo(
                qualname=qualname,
                module=func.module,
                path=func.path,
                node=arg,
                class_name=func.class_name,
                is_jit_root=True,
            )
            self.functions[info.full_id] = info
            self._collect_edges(info)
            return
        canon = self.canonical(mod, arg)
        resolved = self.resolve_canonical(canon) if canon else None
        if resolved is not None:
            self.functions[resolved].is_jit_root = True

    def _mark_kernel_argument(
        self, mod: ModuleInfo, func: FunctionInfo, arg: ast.expr
    ) -> None:
        """First positional arg of ``pl.pallas_call`` is the kernel body:
        a Name, an inline ``functools.partial(body, ...)``, or a local
        variable previously bound to either."""
        if isinstance(arg, ast.Call):
            canon = self.canonical(mod, arg.func)
            if canon in PARTIAL_CANONICAL and arg.args:
                arg = arg.args[0]
        if isinstance(arg, ast.Name):
            bound = self._local_binding(func, arg.id)
            if bound is not None:
                arg = bound
                if isinstance(arg, ast.Call):
                    canon = self.canonical(mod, arg.func)
                    if canon in PARTIAL_CANONICAL and arg.args:
                        arg = arg.args[0]
        canon = self.canonical(mod, arg) if not isinstance(arg, ast.Call) else None
        resolved = self.resolve_canonical(canon) if canon else None
        if resolved is not None:
            self.functions[resolved].is_kernel_body = True

    def _local_binding(self, func: FunctionInfo, name: str) -> ast.expr | None:
        if isinstance(func.node, ast.Lambda):
            return None
        for stmt in ast.walk(func.node):
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        return stmt.value
        return None

    # -- scopes ---------------------------------------------------------

    def _declared_traced_roots(self) -> set[str]:
        roots = set()
        for fid in self.functions:
            if any(fid.endswith(sfx) for sfx in config.TRACED_ROOT_SUFFIXES):
                roots.add(fid)
        return roots

    def reachable_from(self, roots: set[str], include_eager: bool = False) -> set[str]:
        seen = set(roots)
        frontier = list(roots)
        while frontier:
            fid = frontier.pop()
            func = self.functions.get(fid)
            if func is None:
                continue
            edges = func.calls | (func.eager_calls if include_eager else set())
            for nxt in edges:
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        return seen

    @property
    def jit_roots(self) -> set[str]:
        return {
            fid
            for fid, f in self.functions.items()
            if f.is_jit_root or f.is_kernel_body
        } | self._declared_traced_roots()

    @property
    def jit_scope(self) -> set[str]:
        if self._jit_scope is None:
            self._jit_scope = self.reachable_from(self.jit_roots)
        return self._jit_scope

    @property
    def kernel_scope(self) -> set[str]:
        if self._kernel_scope is None:
            roots = {
                fid for fid, f in self.functions.items() if f.is_kernel_body
            }
            self._kernel_scope = self.reachable_from(roots)
        return self._kernel_scope

    def functions_in(self, scope: set[str]) -> Iterator[FunctionInfo]:
        for fid in sorted(scope):
            func = self.functions.get(fid)
            if func is not None:
                yield func

    # -- taint ----------------------------------------------------------

    def taint(self, func: FunctionInfo) -> set[str]:
        """Names in ``func`` holding (possibly) traced values."""
        if func._taint is None:
            func._taint = _compute_taint(func)
        return func._taint

    def expr_tainted(self, func: FunctionInfo, expr: ast.expr) -> bool:
        return _expr_tainted(expr, self.taint(func))


def _params_of(
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda,
) -> list[ast.arg]:
    args = node.args
    return [
        *args.posonlyargs, *args.args, *args.kwonlyargs,
        *([args.vararg] if args.vararg else []),
        *([args.kwarg] if args.kwarg else []),
    ]


def _expr_tainted(expr: ast.expr, tainted: set[str]) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Constant):
        return False
    if isinstance(expr, ast.Attribute):
        if expr.attr in SHAPE_ATTRS:
            return False
        return _expr_tainted(expr.value, tainted)
    if isinstance(expr, ast.Subscript):
        return _expr_tainted(expr.value, tainted) or _expr_tainted(
            expr.slice, tainted
        )
    if isinstance(expr, ast.Call):
        if isinstance(expr.func, ast.Name) and expr.func.id in UNTAINT_CALLS:
            return False
        if _expr_tainted(expr.func, tainted):
            return True
        return any(
            _expr_tainted(a, tainted)
            for a in [*expr.args, *[kw.value for kw in expr.keywords]]
        )
    if isinstance(expr, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return False
        # `"key" in pytree` is a structure check — static under trace
        if (
            all(isinstance(op, (ast.In, ast.NotIn)) for op in expr.ops)
            and isinstance(expr.left, ast.Constant)
        ):
            return False
        return _expr_tainted(expr.left, tainted) or any(
            _expr_tainted(c, tainted) for c in expr.comparators
        )
    if isinstance(expr, ast.BoolOp):
        return any(_expr_tainted(v, tainted) for v in expr.values)
    if isinstance(expr, ast.BinOp):
        return _expr_tainted(expr.left, tainted) or _expr_tainted(
            expr.right, tainted
        )
    if isinstance(expr, ast.UnaryOp):
        return _expr_tainted(expr.operand, tainted)
    if isinstance(expr, ast.IfExp):
        return any(
            _expr_tainted(e, tainted) for e in (expr.test, expr.body, expr.orelse)
        )
    if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
        return any(_expr_tainted(e, tainted) for e in expr.elts)
    if isinstance(expr, ast.Dict):
        return any(
            _expr_tainted(e, tainted)
            for e in [*expr.keys, *expr.values]
            if e is not None
        )
    if isinstance(expr, ast.Starred):
        return _expr_tainted(expr.value, tainted)
    if isinstance(expr, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
        return any(_expr_tainted(g.iter, tainted) for g in expr.generators)
    if isinstance(expr, ast.DictComp):
        return any(_expr_tainted(g.iter, tainted) for g in expr.generators)
    return False


def _assign_names(target: ast.expr) -> Iterator[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _assign_names(elt)
    elif isinstance(target, ast.Starred):
        yield from _assign_names(target.value)


def _compute_taint(func: FunctionInfo) -> set[str]:
    tainted: set[str] = set()
    for param in _params_of(func.node):
        if param.arg in ("self", "cls"):
            continue
        ann = getattr(param, "annotation", None)
        if ann is None or annotation_is_arrayish(ann):
            tainted.add(param.arg)
    if isinstance(func.node, ast.Lambda):
        return tainted

    def walk(stmts: list[ast.stmt]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            if isinstance(stmt, ast.Assign):
                if _expr_tainted(stmt.value, tainted):
                    for tgt in stmt.targets:
                        tainted.update(_assign_names(tgt))
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                if isinstance(stmt.target, ast.Name) and (
                    _expr_tainted(stmt.value, tainted)
                    or annotation_is_arrayish(stmt.annotation)
                ):
                    tainted.add(stmt.target.id)
            elif isinstance(stmt, ast.AugAssign):
                if _expr_tainted(stmt.value, tainted):
                    tainted.update(_assign_names(stmt.target))
            elif isinstance(stmt, ast.For):
                if _expr_tainted(stmt.iter, tainted):
                    tainted.update(_assign_names(stmt.target))
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.While):
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.If):
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.With):
                is_eager = any(
                    isinstance(item.context_expr, ast.Call)
                    and isinstance(item.context_expr.func, ast.Attribute)
                    and item.context_expr.func.attr == "ensure_compile_time_eval"
                    for item in stmt.items
                )
                if not is_eager:
                    walk(stmt.body)
            elif isinstance(stmt, ast.Try):
                walk(stmt.body)
                for handler in stmt.handlers:
                    walk(handler.body)
                walk(stmt.orelse)
                walk(stmt.finalbody)

    # two passes: a name assigned late then used earlier inside a loop
    walk(func.node.body)
    walk(func.node.body)
    return tainted
