"""Repo-contract knobs for the static-analysis pass.

Every rule that encodes a *project* decision (rather than a generic JAX
fact) reads its names from here, so the contracts stay greppable in one
place and the rules stay reusable.  The contracts themselves:

- the fused serving step (``core/cascade.py``) and both Pallas kernel
  entry points (``kernels/forest_score.py``) are jit roots — anything
  they reach must never sync to host (PR 2/PR 3);
- tree-axis reductions in kernel bodies go through
  ``_pairwise_tree_sum`` so the three leaf-gather paths stay bit-exact
  (PR 4);
- the engine is owned by the batcher's worker thread; only the worker
  run loop (and the post-join drain) may call into it (PR 5);
- ``RankingService.rank_batch`` performs exactly ONE ``jax.device_get``
  per batch (PR 3).
"""

from __future__ import annotations

# --- trace-scope seeds -------------------------------------------------
# Functions that are traced even though no decorator says so: they are
# passed INTO the jitted step as closures or looked up through dict /
# tuple dispatch the call-graph cannot see.  Matched as suffixes of the
# analyzer's fully-qualified ids (``module:Qual.Name``).
TRACED_ROOT_SUFFIXES: tuple[str, ...] = (
    # the per-stage continue strategy closed over by the fused step
    "RankingService._make_strategy.strategy",
    # strategy family — dispatched via the ``strategies`` tuple operand
    ":ert_continue",
    ":ept_continue",
    ":ideal_continue",
    # LEAR classifier evaluation inside the step
    "LearClassifier.prob_continue",
    "LearClassifier.continue_mask",
)

# --- TS003: sanctioned tree-axis reducers ------------------------------
# Functions allowed to reduce over the tree axis inside kernel scope.
# ``_pairwise_tree_sum`` is THE sanctioned reduction (fixed-shape
# pairwise halving → bit-exact across leaf-gather paths).
TREE_SUM_ALLOWED: tuple[str, ...] = ("_pairwise_tree_sum",)

# TS003 checks kernel scope PLUS everything reachable from these roots.
# The tree-reordering path (``forest/reorder.py``) lives outside kernel
# bodies but carries the same contract: a permuted ensemble is bit-exact
# with identity ordering only while every tree-axis total between the
# per-tree leaf values and a score goes through ``_pairwise_tree_sum``
# (host-side float64 order *learning* is exempt by construction — it
# never touches scores).  Matched as suffixes of the analyzer's
# fully-qualified ids, same idiom as ``TRACED_ROOT_SUFFIXES``.
TREE_SUM_EXTRA_ROOT_SUFFIXES: tuple[str, ...] = (
    ":per_tree_contributions",
    ":prefix_residual",
    ":reorder_trees",
)

# --- TS005: thread discipline ------------------------------------------
# serve/ classes whose methods face client threads, mapped to the ONLY
# methods allowed to call into the engine.  ``ContinuousBatcher._run``
# is the worker loop; ``_flush`` is called from the loop and once more
# from ``stop()`` after the worker has been joined (drain — single
# threaded by construction).  ``ServingTier.start`` runs AOT warmup
# before the worker exists.
SERVE_CLASS_ALLOWED_METHODS: dict[str, frozenset[str]] = {
    "ContinuousBatcher": frozenset({"_run", "_flush"}),
    "ServingTier": frozenset({"start"}),
}

# Engine entry points: calling any of these hands work to the engine and
# is only legal from the allowlisted methods above.
ENGINE_METHOD_NAMES: frozenset[str] = frozenset(
    {"rank_batch", "rank", "rank_progressive", "rank_compacted"}
)
ENGINE_FUNCTION_SUFFIXES: tuple[str, ...] = (":warmup_service",)

# --- TS007: bounded serving loops --------------------------------------
# serve/ classes that own (or supervise) the worker loop.  Inside these
# classes the robustness contract holds: no unbounded buffer growth (a
# ``deque`` without ``maxlen``, a ``Queue`` without ``maxsize``, a
# ``self.*.append/extend`` inside a ``while True`` loop — the shapes that
# turn overload into OOM instead of typed shedding) and no blind
# ``except:`` / ``except BaseException`` (the shape that swallows worker
# death instead of letting the supervisor see it) without an explicit
# ``# repro: noqa(TS007) -- why`` justification.
WORKER_LOOP_CLASSES: frozenset[str] = frozenset(
    {"ContinuousBatcher", "WorkerSupervisor"}
)

# --- TS006: the single-transfer contract -------------------------------
# Host walk starts here; at most ONE explicit device→host transfer site
# may be reachable per call.
SINGLE_TRANSFER_ROOT_SUFFIXES: tuple[str, ...] = (
    "RankingService.rank_batch",
)
