"""Analysis driver: file discovery, suppression parsing, rule running,
and finding formatting.

Suppression syntax (checked per physical line)::

    risky_call()  # repro: noqa(TS001)
    other()       # repro: noqa(TS001,TS003) -- why this is safe

A suppressed finding is dropped; rules that COUNT sites (TS006) consult
suppression themselves so a waived site does not poison the count.
"""

from __future__ import annotations

import json
import re
from collections.abc import Iterable, Sequence
from dataclasses import dataclass
from pathlib import Path

from repro.analysis.callgraph import ProjectIndex

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\(\s*([A-Z0-9_,\s]+?)\s*\)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    code: str
    path: str
    line: int
    col: int
    message: str
    hint: str

    def format(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"
            f"\n    hint: {self.hint}"
        )

    def as_dict(self) -> dict[str, object]:
        return {
            "code": self.code, "path": self.path, "line": self.line,
            "col": self.col, "message": self.message, "hint": self.hint,
        }


class Suppressions:
    """Per-file map of line → suppressed rule codes."""

    def __init__(self) -> None:
        self._by_file: dict[str, dict[int, set[str]]] = {}

    def load(self, path: Path, lines: Sequence[str]) -> None:
        per_line: dict[int, set[str]] = {}
        for i, text in enumerate(lines, start=1):
            match = NOQA_RE.search(text)
            if not match:
                continue
            codes = {c.strip() for c in match.group(1).split(",") if c.strip()}
            target = i
            if text.lstrip().startswith("#"):
                # a comment-only noqa (usually followed by justification
                # comment lines) waives the next CODE line
                for j in range(i + 1, len(lines) + 1):
                    stripped = lines[j - 1].strip()
                    if stripped and not stripped.startswith("#"):
                        target = j
                        break
            per_line.setdefault(target, set()).update(codes)
        self._by_file[str(path)] = per_line

    def is_suppressed(self, path: str | Path, line: int, code: str) -> bool:
        return code in self._by_file.get(str(path), {}).get(line, set())


def discover(paths: Iterable[str | Path]) -> list[Path]:
    """Expand files/directories into the sorted .py file set."""
    out: list[Path] = []
    for raw in paths:
        path = Path(raw)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def run_paths(
    paths: Iterable[str | Path],
    codes: Iterable[str] | None = None,
) -> list[Finding]:
    """Run all (or selected) rules over the given files/directories."""
    from repro.analysis.rules import all_rules

    files = discover(paths)
    project = ProjectIndex(files)
    suppressions = Suppressions()
    for mod in project.modules.values():
        suppressions.load(mod.path, mod.source_lines)

    wanted = set(codes) if codes is not None else None
    findings: list[Finding] = []
    for rule in all_rules():
        if wanted is not None and rule.code not in wanted:
            continue
        findings.extend(rule.check(project, suppressions))
    findings = [
        f
        for f in findings
        if not suppressions.is_suppressed(f.path, f.line, f.code)
    ]
    for path, err in project.errors:
        findings.append(
            Finding(
                code="TS000", path=str(path), line=1, col=0,
                message=f"file could not be parsed: {err}",
                hint="fix the syntax error; the analyzer needs a parseable tree",
            )
        )
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings


def format_findings(findings: list[Finding], fmt: str = "text") -> str:
    if fmt == "json":
        return json.dumps([f.as_dict() for f in findings], indent=2)
    if not findings:
        return "repro.analysis: no findings"
    lines = [f.format() for f in findings]
    lines.append(f"repro.analysis: {len(findings)} finding(s)")
    return "\n".join(lines)
