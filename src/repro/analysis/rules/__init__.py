"""Rule registry for the tracer-safety analyzer.

A rule is an object with a ``code`` (``TS00x``), a ``name``, a ``hint``
(the one-line fix shown under every finding), and a
``check(project, suppressions) -> Iterator[Finding]`` method.  To add a
rule: create ``tsNNN_short_name.py`` beside the existing six, subclass
nothing (duck typing), and append an instance to :func:`all_rules` —
see ``docs/static-analysis.md`` for the walkthrough and the fixture
conventions a new rule must ship with.
"""

from __future__ import annotations

from repro.analysis.rules.ts001_host_sync import HostSyncRule
from repro.analysis.rules.ts002_control_flow import TracerControlFlowRule
from repro.analysis.rules.ts003_reassociation import ReassociationRule
from repro.analysis.rules.ts004_trace_constants import TraceTimeConstantRule
from repro.analysis.rules.ts005_thread_discipline import ThreadDisciplineRule
from repro.analysis.rules.ts006_single_device_get import SingleDeviceGetRule
from repro.analysis.rules.ts007_bounded_serving import BoundedServingRule


def all_rules() -> list:
    """The active rule set, in error-code order."""
    return [
        HostSyncRule(),
        TracerControlFlowRule(),
        ReassociationRule(),
        TraceTimeConstantRule(),
        ThreadDisciplineRule(),
        SingleDeviceGetRule(),
        BoundedServingRule(),
    ]


__all__ = [
    "BoundedServingRule",
    "HostSyncRule",
    "TracerControlFlowRule",
    "ReassociationRule",
    "TraceTimeConstantRule",
    "ThreadDisciplineRule",
    "SingleDeviceGetRule",
    "all_rules",
]
