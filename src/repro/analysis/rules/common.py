"""Shared helpers for the rule modules."""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis.callgraph import (
    EAGER_CONTEXT_CANONICAL,
    FunctionInfo,
    ModuleInfo,
    ProjectIndex,
)


def body_nodes(
    project: ProjectIndex, func: FunctionInfo
) -> Iterator[ast.AST]:
    """All AST nodes in a function's OWN body: nested function/lambda
    subtrees are skipped (they are analyzed as their own functions), and
    so is code under ``with jax.ensure_compile_time_eval():`` — that
    runs at trace time, where host access is legal."""
    mod = project.modules[func.module]
    if isinstance(func.node, ast.Lambda):
        roots: list[ast.AST] = [func.node.body]
    else:
        roots = list(func.node.body)

    def walk(node: ast.AST) -> Iterator[ast.AST]:
        if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            return
        if isinstance(node, ast.With) and any(
            isinstance(item.context_expr, ast.Call)
            and project.canonical(mod, item.context_expr.func)
            in EAGER_CONTEXT_CANONICAL
            for item in node.items
        ):
            return
        yield node
        for child in ast.iter_child_nodes(node):
            yield from walk(child)

    for root in roots:
        yield from walk(root)


def classify_transfer(
    project: ProjectIndex, mod: ModuleInfo, call: ast.Call
) -> str | None:
    """Name the device→host transfer a call performs, or None.

    Covers the explicit sync surface: ``jax.device_get``,
    ``block_until_ready`` (function or method), ``.item()``, and
    ``numpy.asarray``/``numpy.array`` on device values (``jnp.*`` is
    resolved through import aliases and does NOT match).
    """
    canon = project.canonical(mod, call.func)
    if canon is not None:
        if canon.endswith("jax.device_get") or canon == "jax.device_get":
            return "jax.device_get"
        if canon == "jax.block_until_ready":
            return "jax.block_until_ready"
        root, _, leaf = canon.rpartition(".")
        if root == "numpy" and leaf in ("asarray", "array"):
            return f"numpy.{leaf}"
    if isinstance(call.func, ast.Attribute):
        if call.func.attr == "block_until_ready":
            return ".block_until_ready()"
        if call.func.attr == "item" and not call.args and not call.keywords:
            return ".item()"
    return None
