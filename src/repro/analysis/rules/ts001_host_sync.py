"""TS001 — host sync reachable from jit/kernel scope.

A ``jax.device_get``, ``block_until_ready``, ``.item()``, or
``numpy.asarray`` inside code reachable from a jitted function either
fails at trace time or silently forces a device→host round trip on
every step — the exact regression the fused serving step exists to
prevent.  ``float()``/``bool()`` are flagged only when applied to a
tracer-tainted value (on static Python ints they are trace-time
arithmetic and fine).
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis.callgraph import FunctionInfo, ProjectIndex
from repro.analysis.engine import Finding, Suppressions
from repro.analysis.rules.common import body_nodes, classify_transfer

HINT = (
    "hoist the sync out of traced code (host side of the step), or keep the "
    "value lazy on device; trace-time work belongs under "
    "jax.ensure_compile_time_eval()"
)


class HostSyncRule:
    code = "TS001"
    name = "host-sync-in-jit"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        for func in project.functions_in(project.jit_scope):
            mod = project.modules[func.module]
            for node in body_nodes(project, func):
                if not isinstance(node, ast.Call):
                    continue
                transfer = classify_transfer(project, mod, node)
                if transfer is not None:
                    yield self._finding(func, node, transfer)
                    continue
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "bool")
                    and node.args
                    and project.expr_tainted(func, node.args[0])
                ):
                    yield self._finding(
                        func, node, f"{node.func.id}() on a traced value"
                    )

    def _finding(
        self, func: FunctionInfo, node: ast.Call, what: str
    ) -> Finding:
        return Finding(
            code=self.code,
            path=str(func.path),
            line=node.lineno,
            col=node.col_offset,
            message=(
                f"{what} in `{func.qualname}`, which is reachable from "
                "jit/kernel scope"
            ),
            hint=self.hint,
        )
