"""TS002 — Python control flow on tracer values in jit scope.

A bare ``if``/``while`` on a value derived from traced inputs raises
``TracerBoolConversionError`` at trace time (or, worse, bakes one
branch into the compiled program if the value happens to be concrete).
Branching on shapes, dtypes, static (annotated ``int``/``str``/config)
parameters, or ``is None`` checks is trace-time Python and fine.
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis.callgraph import ProjectIndex
from repro.analysis.engine import Finding, Suppressions
from repro.analysis.rules.common import body_nodes

HINT = (
    "use jnp.where / jax.lax.cond / jax.lax.while_loop for data-dependent "
    "control flow; if the value is really static, annotate the parameter "
    "with its host type (int, str, ...)"
)


class TracerControlFlowRule:
    code = "TS002"
    name = "python-control-flow-on-tracer"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        for func in project.functions_in(project.jit_scope):
            for node in body_nodes(project, func):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                if (
                    isinstance(node, ast.While)
                    and isinstance(node.test, ast.Constant)
                ):
                    continue
                if project.expr_tainted(func, node.test):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        code=self.code,
                        path=str(func.path),
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"`{kind}` on a traced value in "
                            f"`{func.qualname}` (jit scope)"
                        ),
                        hint=self.hint,
                    )
