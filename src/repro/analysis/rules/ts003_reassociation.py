"""TS003 — reassociation hazard in kernel bodies.

The three leaf-gather paths (select/MXU/one-hot) are bit-exact with
each other ONLY because every tree-axis reduction goes through
``_pairwise_tree_sum`` — a fixed-shape pairwise halving whose float
association order does not depend on tree count or padding.  A bare
``jnp.sum``/``.sum()`` or a ``+=`` accumulation loop inside kernel
scope reduces in a different order and silently breaks the
bit-exactness contract the parity tests pin.

The same discipline covers the tree-reordering path: a permuted
ensemble (``forest/reorder.py``) scores bit-exactly with identity
ordering only while every tree-axis total it reaches goes through the
sanctioned reducer, so the reorder entry points named by
``config.TREE_SUM_EXTRA_ROOT_SUFFIXES`` (and everything they call)
join the checked scope.

Reductions that are provably order-free (one-hot row selection, integer
adds) may be waived with ``# repro: noqa(TS003) -- <why>``.
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis import config
from repro.analysis.callgraph import FunctionInfo, ProjectIndex
from repro.analysis.engine import Finding, Suppressions
from repro.analysis.rules.common import body_nodes

HINT = (
    "reduce through _pairwise_tree_sum (kernels/forest_score.py) so the "
    "association order stays fixed; waive with `# repro: noqa(TS003)` only "
    "for provably order-free reductions"
)


class ReassociationRule:
    code = "TS003"
    name = "reassociation-hazard-in-kernel"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        # Scope = kernel bodies plus the tree-reordering path: the extra
        # roots (config.TREE_SUM_EXTRA_ROOT_SUFFIXES) are matched by
        # fully-qualified-id suffix and expanded through the call graph,
        # so helpers a reorder entry point reaches are held to the same
        # reduction discipline as kernel helpers.
        extra_roots = {
            fid
            for fid in project.functions
            if any(
                fid.endswith(sfx)
                for sfx in config.TREE_SUM_EXTRA_ROOT_SUFFIXES
            )
        }
        scope = project.kernel_scope | project.reachable_from(extra_roots)
        for func in project.functions_in(scope):
            if func.name in config.TREE_SUM_ALLOWED:
                continue
            mod = project.modules[func.module]
            loop_depth_nodes = _nodes_inside_loops(project, func)
            for node in body_nodes(project, func):
                if isinstance(node, ast.Call):
                    canon = project.canonical(mod, node.func)
                    is_jnp_sum = canon is not None and canon in (
                        "jax.numpy.sum", "numpy.sum"
                    )
                    is_method_sum = (
                        isinstance(node.func, ast.Attribute)
                        and node.func.attr == "sum"
                    )
                    if is_jnp_sum or is_method_sum:
                        yield self._finding(func, node, "bare sum()")
                elif (
                    isinstance(node, ast.AugAssign)
                    and isinstance(node.op, ast.Add)
                    and id(node) in loop_depth_nodes
                ):
                    yield self._finding(
                        func, node, "`+=` accumulation inside a loop"
                    )

    def _finding(
        self, func: FunctionInfo, node: ast.AST, what: str
    ) -> Finding:
        return Finding(
            code=self.code,
            path=str(func.path),
            line=node.lineno,
            col=node.col_offset,
            message=(
                f"{what} in kernel scope (`{func.qualname}`) bypasses "
                "_pairwise_tree_sum"
            ),
            hint=self.hint,
        )


def _nodes_inside_loops(project: ProjectIndex, func: FunctionInfo) -> set[int]:
    """ids of body nodes that sit inside a for/while loop."""
    inside: set[int] = set()
    loops = [
        n
        for n in body_nodes(project, func)
        if isinstance(n, (ast.For, ast.While))
    ]
    for loop in loops:
        for node in ast.walk(loop):
            if node is not loop:
                inside.add(id(node))
    return inside
