"""TS004 — environment reads inside jitted or kernel bodies.

Engine tunables (``PADDED_CACHE_MAX``, ``LEAF_SELECT_MAX``, ...) are
read ONCE at import through ``env_int`` so a compiled computation can
never disagree with the environment it was traced under.  An
``env_int``/``os.environ``/``os.getenv`` read inside jit scope would be
baked in at trace time at best — and at worst make two traces of the
same config diverge.
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis.callgraph import ProjectIndex
from repro.analysis.engine import Finding, Suppressions
from repro.analysis.rules.common import body_nodes

HINT = (
    "read the environment once at module scope (see env_int in "
    "kernels/ops.py) and close over the value; traced code must only see "
    "trace-time constants"
)


class TraceTimeConstantRule:
    code = "TS004"
    name = "env-read-in-traced-scope"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        scope = project.jit_scope | project.kernel_scope
        for func in project.functions_in(scope):
            mod = project.modules[func.module]
            for node in body_nodes(project, func):
                what = None
                if isinstance(node, ast.Call):
                    canon = project.canonical(mod, node.func)
                    resolved = (
                        project.resolve_canonical(canon) if canon else None
                    )
                    if resolved is not None and resolved.endswith(":env_int"):
                        what = "env_int()"
                    elif canon in ("os.getenv", "os.environ.get"):
                        what = canon + "()"
                elif isinstance(node, ast.Subscript):
                    canon = project.canonical(mod, node.value)
                    if canon == "os.environ":
                        what = "os.environ[...]"
                if what is not None:
                    yield Finding(
                        code=self.code,
                        path=str(func.path),
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"{what} read inside `{func.qualname}`, which "
                            "is traced (jit/kernel scope)"
                        ),
                        hint=self.hint,
                    )
