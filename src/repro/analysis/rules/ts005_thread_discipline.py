"""TS005 — engine calls from client-facing serving methods.

One worker thread owns the engine: ``ContinuousBatcher._run`` (and the
post-join drain ``_flush``) plus ``ServingTier.start`` (AOT warmup runs
before the worker exists).  Every other method of those classes runs on
CLIENT threads — an engine call there races the worker on the jit
cache, the capacity ratchet, and the per-bucket adaptive state.

The rule flags direct call sites of engine entry points
(``rank_batch``/``rank``/``rank_progressive``/``rank_compacted`` and
``warmup_service``) in non-allowlisted methods of the configured
classes (:data:`repro.analysis.config.SERVE_CLASS_ALLOWED_METHODS`).
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis import config
from repro.analysis.callgraph import ProjectIndex
from repro.analysis.engine import Finding, Suppressions

HINT = (
    "route the work through the batcher queue (submit -> worker _run -> "
    "_flush); only the worker loop may touch the engine"
)


class ThreadDisciplineRule:
    code = "TS005"
    name = "engine-call-off-worker-thread"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        for func in project.functions.values():
            allowed = config.SERVE_CLASS_ALLOWED_METHODS.get(func.class_name or "")
            if allowed is None:
                continue
            method = func.qualname.split(".", 1)[-1].split(".", 1)[0]
            if method in allowed:
                continue
            mod = project.modules[func.module]
            if isinstance(func.node, ast.Lambda):
                continue
            for node in ast.walk(func.node):
                if not isinstance(node, ast.Call):
                    continue
                what = None
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in config.ENGINE_METHOD_NAMES
                ):
                    what = f".{node.func.attr}()"
                else:
                    canon = project.canonical(mod, node.func)
                    resolved = (
                        project.resolve_canonical(canon) if canon else None
                    )
                    target = resolved or canon
                    if target is not None and any(
                        target.endswith(sfx.lstrip(":"))
                        for sfx in config.ENGINE_FUNCTION_SUFFIXES
                    ):
                        what = target.rsplit(".", 1)[-1] + "()"
                if what is not None:
                    yield Finding(
                        code=self.code,
                        path=str(func.path),
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"engine entry {what} called from "
                            f"`{func.qualname}` — only "
                            f"{sorted(allowed)} of {func.class_name} may "
                            "touch the engine"
                        ),
                        hint=self.hint,
                    )
