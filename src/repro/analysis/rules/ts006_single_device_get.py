"""TS006 — the single-transfer contract on the serving hot path.

``RankingService.rank_batch`` fetches its whole result — top-k, scores,
survivors, traversed, overflow, docs, picked mode — through exactly ONE
fused ``jax.device_get``.  A second transfer site reachable from it is
a second device round trip per batch (PR 3's headline win undone).

The walk is HOST-side: it starts at the configured roots and does not
descend into jit roots or kernel bodies (transfers there are TS001's
problem and do not execute per call).  Every explicit transfer site
reachable per root is counted; sites beyond the first are flagged.  A
``# repro: noqa(TS006)`` on a site line removes it from the count
(waived, e.g. a debug-only branch).
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis import config
from repro.analysis.callgraph import ProjectIndex
from repro.analysis.engine import Finding, Suppressions
from repro.analysis.rules.common import body_nodes, classify_transfer

HINT = (
    "fold the value into the existing fused device_get tuple in "
    "rank_batch instead of adding a second transfer"
)


class SingleDeviceGetRule:
    code = "TS006"
    name = "single-device-get-contract"
    hint = HINT

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        roots = {
            fid
            for fid in project.functions
            if any(
                fid.endswith(sfx)
                for sfx in config.SINGLE_TRANSFER_ROOT_SUFFIXES
            )
        }
        for root in sorted(roots):
            yield from self._check_root(project, suppressions, root)

    def _check_root(
        self, project: ProjectIndex, suppressions: Suppressions, root: str
    ) -> Iterator[Finding]:
        reached = self._host_reachable(project, root)
        sites: list[tuple[str, int, int, str, str]] = []
        for fid in sorted(reached):
            func = project.functions[fid]
            mod = project.modules[func.module]
            for node in body_nodes(project, func):
                if not isinstance(node, ast.Call):
                    continue
                transfer = classify_transfer(project, mod, node)
                if transfer is None:
                    continue
                if suppressions.is_suppressed(
                    str(func.path), node.lineno, self.code
                ):
                    continue
                sites.append(
                    (
                        str(func.path), node.lineno, node.col_offset,
                        transfer, func.qualname,
                    )
                )
        if len(sites) <= 1:
            return
        sites.sort(key=lambda s: (s[0], s[1]))
        root_name = root.split(":", 1)[-1]
        for idx, (path, line, col, transfer, qualname) in enumerate(sites):
            if idx == 0:
                continue  # the sanctioned single transfer
            yield Finding(
                code=self.code,
                path=path,
                line=line,
                col=col,
                message=(
                    f"{transfer} in `{qualname}` is transfer site "
                    f"{idx + 1} of {len(sites)} reachable from "
                    f"`{root_name}` (contract: exactly one)"
                ),
                hint=self.hint,
            )

    def _host_reachable(self, project: ProjectIndex, root: str) -> set[str]:
        """BFS over host code only: stop at jit roots, kernel bodies,
        and declared traced roots — transfers inside traced code do not
        execute per call (and are TS001 findings anyway)."""
        traced = project.jit_roots
        seen = {root}
        frontier = [root]
        while frontier:
            fid = frontier.pop()
            func = project.functions.get(fid)
            if func is None:
                continue
            for nxt in func.calls | func.eager_calls:
                if nxt in seen or nxt in traced:
                    continue
                seen.add(nxt)
                frontier.append(nxt)
        return seen
