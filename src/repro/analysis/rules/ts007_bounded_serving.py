"""TS007 — unbounded growth / blind excepts in serving worker loops.

The serving tier's overload behavior is DEFINED (admission control sheds,
deadlines expire, the supervisor restarts) only while two disciplines
hold inside the worker-loop classes
(:data:`repro.analysis.config.WORKER_LOOP_CLASSES`):

1. **Every buffer is bounded.** A ``collections.deque()`` without
   ``maxlen``, a ``queue.Queue()`` without ``maxsize`` (or a
   ``SimpleQueue``, which cannot be bounded), or a ``self.*.append`` /
   ``extend`` inside a ``while True`` loop grows without limit under
   overload — the failure mode the admission-control layer exists to
   prevent, reintroduced by the implementation.
2. **No blind exception handlers.** A bare ``except:`` or
   ``except BaseException`` inside these classes swallows worker death
   (KeyboardInterrupt, injected kills, MemoryError) that the supervisor
   must observe to restart the worker and fail in-flight futures.

Deliberate catch-alls (the supervisor's own guard is one — it exists to
BE the catch-all) carry a ``# repro: noqa(TS007) -- why`` justification.
"""

from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis import config
from repro.analysis.callgraph import FunctionInfo, ProjectIndex
from repro.analysis.engine import Finding, Suppressions

HINT = (
    "bound the buffer (deque(maxlen=…), Queue(maxsize=…), admission-"
    "checked dict/list) or catch a typed exception; a deliberate "
    "catch-all needs `# repro: noqa(TS007) -- why`"
)

_GROW_METHODS = frozenset({"append", "appendleft", "extend", "extendleft"})
_QUEUE_TYPES = frozenset({"Queue", "LifoQueue", "PriorityQueue"})


def _last_name(node: ast.expr) -> str | None:
    """Rightmost identifier of a Name/Attribute chain (``queue.Queue`` →
    ``Queue``), or None for anything else."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _rooted_at_self(node: ast.expr) -> bool:
    """True when an attribute/subscript chain bottoms out at ``self``."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return isinstance(node, ast.Name) and node.id == "self"


class BoundedServingRule:
    code = "TS007"
    name = "unbounded-growth-or-blind-except-in-worker-loop"
    hint = HINT

    @staticmethod
    def _blind_handler(node: ast.ExceptHandler) -> str | None:
        if node.type is None:
            return "bare `except:`"
        if _last_name(node.type) == "BaseException":
            return "`except BaseException`"
        return None

    def check(
        self, project: ProjectIndex, suppressions: Suppressions
    ) -> Iterator[Finding]:
        for func in project.functions.values():
            if (func.class_name or "") not in config.WORKER_LOOP_CLASSES:
                continue
            if isinstance(func.node, ast.Lambda):
                continue
            for node in ast.walk(func.node):
                if isinstance(node, ast.ExceptHandler):
                    what = self._blind_handler(node)
                    if what is not None:
                        yield Finding(
                            code=self.code,
                            path=str(func.path),
                            line=node.lineno,
                            col=node.col_offset,
                            message=(
                                f"{what} in `{func.qualname}` swallows "
                                "worker death the supervisor must observe"
                            ),
                            hint=self.hint,
                        )
                elif isinstance(node, ast.Call):
                    ctor = _last_name(node.func)
                    kwargs = {kw.arg for kw in node.keywords}
                    if (
                        ctor == "deque"
                        and len(node.args) < 2
                        and "maxlen" not in kwargs
                    ):
                        yield self._unbounded(func, node, "deque without maxlen")
                    elif ctor == "SimpleQueue":
                        yield self._unbounded(
                            func, node, "SimpleQueue (cannot be bounded)"
                        )
                    elif (
                        ctor in _QUEUE_TYPES
                        and not node.args
                        and "maxsize" not in kwargs
                    ):
                        yield self._unbounded(
                            func, node, f"{ctor} without maxsize"
                        )
                elif (
                    isinstance(node, ast.While)
                    and isinstance(node.test, ast.Constant)
                    and node.test.value is True
                ):
                    for sub in ast.walk(node):
                        if (
                            isinstance(sub, ast.Call)
                            and isinstance(sub.func, ast.Attribute)
                            and sub.func.attr in _GROW_METHODS
                            and _rooted_at_self(sub.func.value)
                        ):
                            yield self._unbounded(
                                func, sub,
                                f"self-state .{sub.func.attr}() inside "
                                "`while True`",
                            )

    def _unbounded(
        self, func: FunctionInfo, node: ast.AST, what: str
    ) -> Finding:
        return Finding(
            code=self.code,
            path=str(func.path),
            line=node.lineno,
            col=node.col_offset,
            message=(
                f"unbounded growth in `{func.qualname}`: {what} — "
                "overload becomes OOM instead of typed shedding"
            ),
            hint=self.hint,
        )
