"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

10 assigned architectures + the paper's own λ-MART/LEAR forest config.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    ArchConfig,
    ForestConfig,
    NequIPConfig,
    RecSysConfig,
    ShapeSpec,
    TransformerConfig,
)

_MODULES = {
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "nequip": "repro.configs.nequip",
    "bert4rec": "repro.configs.bert4rec",
    "din": "repro.configs.din",
    "deepfm": "repro.configs.deepfm",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "lear-msn1": "repro.configs.lear_msn1",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "lear-msn1")


def list_archs() -> tuple[str, ...]:
    return tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    return importlib.import_module(_MODULES[name]).config()


def get_smoke_config(name: str) -> ArchConfig:
    return importlib.import_module(_MODULES[name]).smoke_config()


__all__ = [
    "ArchConfig",
    "ForestConfig",
    "NequIPConfig",
    "RecSysConfig",
    "ShapeSpec",
    "TransformerConfig",
    "ASSIGNED_ARCHS",
    "list_archs",
    "get_config",
    "get_smoke_config",
]
