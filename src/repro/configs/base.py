"""Config dataclasses for every assigned architecture family.

Each ``src/repro/configs/<arch>.py`` exposes ``config()`` (the exact
published configuration) and ``smoke_config()`` (a reduced same-family
config for CPU smoke tests). Shapes are per-arch (the assignment pairs each
arch with its own shape set); ``kind`` selects which step a shape lowers
(``train_step`` vs ``serve_step``).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

ShapeKind = Literal["train", "prefill", "decode", "serve"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: ShapeKind
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # GNN shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    graph_batch: int = 0
    # RecSys shapes
    batch: int = 0
    n_candidates: int = 0
    # Execution hints
    microbatch: int = 0        # grad-accumulation microbatch (0 = whole batch)
    skip_reason: str = ""      # non-empty → cell is skipped (e.g. long_500k)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    causal: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-MoE style)
    dense_d_ff: int = 0            # FFN width of those dense layers
    capacity_factor: float = 1.25
    # Numerics / perf
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # "nothing" | "dots" (§Perf knob)
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    embed_onehot: bool = False     # one-hot-matmul embedding lookup (§Perf)
    causal_skip: bool = False      # unrolled q-blocks skip masked kv blocks
    seq_parallel: bool = False     # Megatron-SP residual stream (AR→RS+AG)
    optimizer: str = "adamw"       # "adamw" | "adafactor"
    shapes: tuple[ShapeSpec, ...] = ()

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.is_moe else 0


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 32             # multiplicity per irrep l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    avg_degree: float = 20.0
    radial_mlp: tuple[int, ...] = (64, 64)
    dtype: str = "float32"         # equivariance is precision-sensitive
    # §Perf: apply the per-path channel mix BEFORE the edge→node
    # segment-sum (legal by linearity) — shrinks the cross-shard
    # all-reduce payload from (Σ_l paths_l·mul·d_l) to (Σ_l mul·d_l)
    # floats per node (3.9× for l_max=2) at the cost of per-edge mixing
    # FLOPs, which the collective-bound cells have abundant headroom for.
    premix_messages: bool = False
    optimizer: str = "adamw"
    shapes: tuple[ShapeSpec, ...] = ()


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    family: Literal["dlrm", "deepfm", "din", "bert4rec"] = "dlrm"
    embed_dim: int = 64
    n_dense: int = 0
    n_sparse: int = 0
    vocab_sizes: tuple[int, ...] = ()
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    mlp: tuple[int, ...] = ()
    attn_mlp: tuple[int, ...] = ()
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    item_vocab: int = 0
    multi_hot: int = 1             # ids per sparse field (embedding-bag size)
    dtype: str = "float32"
    optimizer: str = "adamw"
    shapes: tuple[ShapeSpec, ...] = ()


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """The paper's own architecture: λ-MART ensemble + LEAR cascade."""

    name: str
    n_trees: int = 1047
    depth: int = 6
    n_features: int = 136
    sentinel: int = 50
    classifier_trees: int = 10
    max_docs: int = 256
    # §Perf knobs: 0 → reference path (score everything, masked combine).
    # capacity_frac > 0 → compacted execution: only the per-query top
    # ⌈frac·D⌉ survivors run the tail trees (the paper's speedup realized
    # structurally). sentinel2 > 0 adds a second (beyond-paper) sentinel.
    capacity_frac: float = 0.0
    sentinel2: int = 0
    capacity2_frac: float = 0.0
    dtype: str = "float32"
    optimizer: str = "none"
    shapes: tuple[ShapeSpec, ...] = ()


ArchConfig = TransformerConfig | NequIPConfig | RecSysConfig | ForestConfig


# Shared LM shape sets (assignment: 4 shapes per LM arch).
def lm_shapes(full_attention: bool = True) -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256,
                  microbatch=32),
        ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
        ShapeSpec(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
        ShapeSpec(
            name="long_500k", kind="decode", seq_len=524288, global_batch=1,
            skip_reason=(
                "pure full-attention arch: 500k-token decode requires "
                "sub-quadratic attention (spec: skip and note in DESIGN.md)"
            ) if full_attention else "",
        ),
    )


def recsys_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec(name="train_batch", kind="train", batch=65536),
        ShapeSpec(name="serve_p99", kind="serve", batch=512),
        ShapeSpec(name="serve_bulk", kind="serve", batch=262144),
        ShapeSpec(name="retrieval_cand", kind="serve", batch=1, n_candidates=1_000_000),
    )


def gnn_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec(name="full_graph_sm", kind="train", n_nodes=2708, n_edges=10556,
                  d_feat=1433),
        # minibatch_lg: sampled block from reddit-scale graph (232,965 nodes /
        # 114.6M edges), batch_nodes=1024, fanout 15-10 → block sizes below.
        ShapeSpec(name="minibatch_lg", kind="train", n_nodes=170_000, n_edges=169_000,
                  d_feat=602, graph_batch=1024),
        ShapeSpec(name="ogb_products", kind="train", n_nodes=2_449_029,
                  n_edges=61_859_140, d_feat=100),
        ShapeSpec(name="molecule", kind="train", n_nodes=30, n_edges=64,
                  graph_batch=128),
    )


def forest_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec(name="rank_xl", kind="serve", batch=4096),   # queries per step
        ShapeSpec(name="rank_online", kind="serve", batch=64),
    )
