"""BERT4Rec on ML-20M-scale item vocabulary. [arXiv:1904.06690; paper]"""

from repro.configs.base import RecSysConfig, recsys_shapes


def config() -> RecSysConfig:
    return RecSysConfig(
        name="bert4rec",
        family="bert4rec",
        embed_dim=64,
        n_blocks=2,
        n_heads=2,
        seq_len=200,
        item_vocab=26744,       # ML-20M items (paper's largest dataset)
        shapes=recsys_shapes(),
    )


def smoke_config() -> RecSysConfig:
    return RecSysConfig(
        name="bert4rec-smoke",
        family="bert4rec",
        embed_dim=16,
        n_blocks=2,
        n_heads=2,
        seq_len=20,
        item_vocab=200,
        shapes=(),
    )
