"""DeepFM on Criteo-style 39 sparse fields. [arXiv:1703.04247; paper]

One concatenated embedding table (global ids = field offsets + local ids),
row-sharded over the model axis. Field vocabularies follow Criteo-Kaggle
magnitudes (13 integer-bucket fields + 26 categorical).
"""

from repro.configs.base import RecSysConfig, recsys_shapes

# 39 field vocab sizes, Criteo-Kaggle-like magnitudes.
_VOCABS = tuple(
    [64] * 13  # bucketized integer features
    + [
        1_460, 584, 10_131_227, 2_202_608, 306, 24, 12_518, 634, 4, 93_146,
        5_684, 8_351_593, 3_195, 28, 14_992, 5_461_306, 11, 5_653, 2_173,
        4, 7_046_547, 18, 16, 286_181, 105, 142_572,
    ]
)


def config() -> RecSysConfig:
    return RecSysConfig(
        name="deepfm",
        family="deepfm",
        embed_dim=10,
        n_sparse=39,
        vocab_sizes=_VOCABS,
        mlp=(400, 400, 400),
        shapes=recsys_shapes(),
    )


def smoke_config() -> RecSysConfig:
    return RecSysConfig(
        name="deepfm-smoke",
        family="deepfm",
        embed_dim=4,
        n_sparse=6,
        vocab_sizes=(16, 32, 64, 16, 8, 128),
        mlp=(32, 32),
        shapes=(),
    )
