"""DeepSeek-MoE-16B: fine-grained MoE, 2 shared + 64 routed top-6, first
layer dense. [arXiv:2401.06066; hf]"""

from repro.configs.base import TransformerConfig, lm_shapes


def config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab_size=102400,
        rope_theta=10_000.0,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
        n_dense_layers=1,
        dense_d_ff=10944,
        shapes=lm_shapes(full_attention=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-moe-16b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=32,
        vocab_size=512,
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        d_ff_expert=32,
        n_dense_layers=1,
        dense_d_ff=128,
        attn_q_block=16,
        attn_kv_block=16,
        shapes=(),
    )
