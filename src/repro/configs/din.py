"""DIN: target attention over user behavior. [arXiv:1706.06978; paper]

Item vocabulary sized to Amazon-Books (the paper's public benchmark).
"""

from repro.configs.base import RecSysConfig, recsys_shapes


def config() -> RecSysConfig:
    return RecSysConfig(
        name="din",
        family="din",
        embed_dim=18,
        seq_len=100,
        attn_mlp=(80, 40),
        mlp=(200, 80),
        item_vocab=367_984,     # Amazon-Books goods count
        shapes=recsys_shapes(),
    )


def smoke_config() -> RecSysConfig:
    return RecSysConfig(
        name="din-smoke",
        family="din",
        embed_dim=8,
        seq_len=12,
        attn_mlp=(16, 8),
        mlp=(24, 12),
        item_vocab=500,
        shapes=(),
    )
