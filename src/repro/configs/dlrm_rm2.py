"""DLRM RM2: dot interaction, MLPerf/Criteo-TB table sizes.
[arXiv:1906.00091; paper]

Table row counts are the published Criteo-Terabyte cardinalities used by
MLPerf DLRM — ~188M rows total × dim 64, the "huge sparse embedding"
regime; rows are model-axis sharded and trained with row-wise Adagrad
(the production DLRM optimizer — full-state optimizers triple table
memory for no accuracy gain at this scale).
"""

from repro.configs.base import RecSysConfig, recsys_shapes

_CRITEO_TB_VOCABS = (
    45_833_188, 36_746, 17_245, 7_413, 20_243, 3, 7_114, 1_441, 62,
    29_275_261, 1_572_176, 345_138, 10, 2_209, 11_267, 128, 4, 974, 14,
    48_937_457, 11_316_796, 40_094_537, 452_104, 12_606, 104, 35,
)


def config() -> RecSysConfig:
    return RecSysConfig(
        name="dlrm-rm2",
        family="dlrm",
        embed_dim=64,
        n_dense=13,
        n_sparse=26,
        vocab_sizes=_CRITEO_TB_VOCABS,
        bot_mlp=(512, 256, 64),
        top_mlp=(512, 512, 256, 1),
        optimizer="adagrad_rowwise",
        shapes=recsys_shapes(),
    )


def smoke_config() -> RecSysConfig:
    return RecSysConfig(
        name="dlrm-rm2-smoke",
        family="dlrm",
        embed_dim=8,
        n_dense=13,
        n_sparse=4,
        vocab_sizes=(64, 128, 32, 256),
        bot_mlp=(32, 16, 8),
        top_mlp=(32, 16, 1),
        optimizer="adagrad_rowwise",
        shapes=(),
    )
