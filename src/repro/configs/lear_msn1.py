"""The paper's own architecture: λ-MART ensemble (MSN-1 scale) + LEAR
cascade. 1,047 trees / 64 leaves / 136 features, sentinel 50, 10-tree
Continue/Exit classifier — exactly Table 1's setting."""

from repro.configs.base import ForestConfig, forest_shapes


def config() -> ForestConfig:
    return ForestConfig(
        name="lear-msn1",
        n_trees=1047,
        depth=6,
        n_features=136,
        sentinel=50,
        classifier_trees=10,
        max_docs=256,
        shapes=forest_shapes(),
    )


def smoke_config() -> ForestConfig:
    return ForestConfig(
        name="lear-msn1-smoke",
        n_trees=24,
        depth=4,
        n_features=16,
        sentinel=6,
        classifier_trees=4,
        max_docs=32,
        shapes=(),
    )
