"""Llama-4 Maverick 400B-A17B: MoE 128 routed top-1 + 1 shared expert.
[hf:meta-llama/Llama-4-Maverick-17B-128E; unverified]

400B total parameters ⇒ the optimizer is Adafactor (factored second
moment): full AdamW state (12 bytes/param fp32) does not fit 256 × 16 GiB
alongside activations; Adafactor state is ~O(params/d). Noted in
EXPERIMENTS.md §Dry-run.
"""

from repro.configs.base import TransformerConfig, lm_shapes


def config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=500_000.0,
        n_experts=128,
        n_shared_experts=1,
        top_k=1,
        d_ff_expert=8192,
        optimizer="adafactor",
        shapes=lm_shapes(full_attention=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=64,
        vocab_size=512,
        n_experts=8,
        n_shared_experts=1,
        top_k=1,
        d_ff_expert=64,
        optimizer="adafactor",
        attn_q_block=16,
        attn_kv_block=16,
        shapes=(),
    )
