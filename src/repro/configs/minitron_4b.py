"""Minitron-4B: width/depth-pruned Nemotron. [arXiv:2407.14679; hf]"""

from repro.configs.base import TransformerConfig, lm_shapes


def config() -> TransformerConfig:
    return TransformerConfig(
        name="minitron-4b",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=9216,
        vocab_size=256000,
        rope_theta=10_000.0,
        shapes=lm_shapes(full_attention=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minitron-4b-smoke",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_head=16,
        d_ff=96,
        vocab_size=512,
        attn_q_block=16,
        attn_kv_block=16,
        shapes=(),
    )
