"""NequIP: E(3)-equivariant interatomic potential. [arXiv:2101.03164; paper]"""

from repro.configs.base import NequIPConfig, gnn_shapes


def config() -> NequIPConfig:
    return NequIPConfig(
        name="nequip",
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        shapes=gnn_shapes(),
    )


def smoke_config() -> NequIPConfig:
    return NequIPConfig(
        name="nequip-smoke",
        n_layers=2,
        d_hidden=8,
        l_max=2,
        n_rbf=4,
        cutoff=5.0,
        shapes=(),
    )
