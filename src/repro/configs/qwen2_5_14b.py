"""Qwen2.5-14B: dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5-14B; hf]"""

from repro.configs.base import TransformerConfig, lm_shapes


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-14b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        shapes=lm_shapes(full_attention=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-14b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        attn_q_block=16,
        attn_kv_block=16,
        shapes=(),
    )
