"""Qwen3-4B: dense GQA decoder with QK-norm. [hf:Qwen/Qwen3-4B; hf]"""

from repro.configs.base import TransformerConfig, lm_shapes


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-4b",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        shapes=lm_shapes(full_attention=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        qk_norm=True,
        attn_q_block=16,
        attn_kv_block=16,
        shapes=(),
    )
