"""LEAR — the paper's contribution: learned early-exit for additive ranking
ensembles, plus the heuristic baselines it is evaluated against.

- :mod:`repro.core.strategies` — ERT / EPT (Cambazoglu et al. 2010) and the
  per-query oracle EE_ideal, all as pure vectorized functions over padded
  ``[Q, D]`` blocks.
- :mod:`repro.core.lear` — LEAR itself: sentinel feature augmentation,
  Continue/Exit label construction, cost-sensitive weighting
  ``w_d = 2^{r_d}/f_q(l_d)``, 10-tree logistic GBDT classifier.
- :mod:`repro.core.features` — the device-resident augmented-feature
  pipeline (sort-free per-query ranking, min/max segment reductions,
  score normalization) shared by LEAR training and the compiled serving
  step.
- :mod:`repro.core.stage` — cascade stages as first-class values: the
  :class:`CascadeStage` protocol, :class:`TreeStage` /
  :class:`DenseStage` implementations, and the frozen
  :class:`EngineConfig` that configures one progressive step (and doubles
  as its jit cache key).
- :mod:`repro.core.cascade` — the execution engine: sentinel-partitioned
  ensemble traversal with batch compaction (the TPU realization of
  document-level early exit), including the multi-sentinel progressive
  engine (fused segmented-head, per-stage-tail, and the combined
  ``mode="auto"`` program with an on-device fused/staged pick) and its
  hybrid dense-stage-0 variant.
- :mod:`repro.core.compaction` — O(n) cumsum survivor compaction plus the
  O(n log n) argsort reference it replaced.
"""

from repro.core.strategies import (
    QueryExitConfig,
    dense_keep_fraction,
    ept_continue,
    ert_continue,
    ideal_continue,
    query_converged,
)
from repro.core.stage import (
    CascadeStage,
    DenseStage,
    EngineConfig,
    TreeStage,
)
from repro.core.features import augment_features
from repro.core.lear import (
    LearClassifier,
    build_continue_labels,
    instance_weights,
    train_lear,
)
from repro.core.cascade import CascadeRanker, CascadeResult, bucket_capacity
from repro.core.compaction import (
    compact_indices_argsort,
    compact_indices_cumsum,
)

__all__ = [
    "CascadeStage",
    "TreeStage",
    "DenseStage",
    "EngineConfig",
    "QueryExitConfig",
    "ert_continue",
    "ept_continue",
    "dense_keep_fraction",
    "ideal_continue",
    "query_converged",
    "LearClassifier",
    "augment_features",
    "build_continue_labels",
    "instance_weights",
    "train_lear",
    "CascadeRanker",
    "CascadeResult",
    "bucket_capacity",
    "compact_indices_cumsum",
    "compact_indices_argsort",
]
