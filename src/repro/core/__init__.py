"""LEAR — the paper's contribution: learned early-exit for additive ranking
ensembles, plus the heuristic baselines it is evaluated against.

- :mod:`repro.core.strategies` — ERT / EPT (Cambazoglu et al. 2010) and the
  per-query oracle EE_ideal, all as pure vectorized functions over padded
  ``[Q, D]`` blocks.
- :mod:`repro.core.lear` — LEAR itself: sentinel feature augmentation,
  Continue/Exit label construction, cost-sensitive weighting
  ``w_d = 2^{r_d}/f_q(l_d)``, 10-tree logistic GBDT classifier.
- :mod:`repro.core.features` — the device-resident augmented-feature
  pipeline (sort-free per-query ranking, min/max segment reductions,
  score normalization) shared by LEAR training and the compiled serving
  step.
- :mod:`repro.core.cascade` — the execution engine: sentinel-partitioned
  ensemble traversal with batch compaction (the TPU realization of
  document-level early exit), including the multi-sentinel progressive
  engine (fused segmented-head, per-stage-tail, and the combined
  ``mode="auto"`` program with an on-device fused/staged pick).
- :mod:`repro.core.compaction` — O(n) cumsum survivor compaction plus the
  O(n log n) argsort reference it replaced.
"""

from repro.core.strategies import (
    QueryExitConfig,
    ept_continue,
    ert_continue,
    ideal_continue,
    query_converged,
)
from repro.core.features import augment_features
from repro.core.lear import (
    LearClassifier,
    build_continue_labels,
    instance_weights,
    train_lear,
)
from repro.core.cascade import CascadeRanker, CascadeResult, bucket_capacity
from repro.core.compaction import (
    compact_indices_argsort,
    compact_indices_cumsum,
)

__all__ = [
    "QueryExitConfig",
    "ert_continue",
    "ept_continue",
    "ideal_continue",
    "query_converged",
    "LearClassifier",
    "augment_features",
    "build_continue_labels",
    "instance_weights",
    "train_lear",
    "CascadeRanker",
    "CascadeResult",
    "bucket_capacity",
    "compact_indices_cumsum",
    "compact_indices_argsort",
]
