"""Sentinel-partitioned cascade execution — early exit as batch compaction.

Three execution paths with identical ranking semantics:

- :meth:`CascadeRanker.rank` — *reference* path: scores every document
  through head and tail, applies the continue mask arithmetically. Used for
  quality evaluation and as the oracle for the compacted paths. Cost is
  accounted in the paper's currency (trees traversed), not saved.
- :meth:`CascadeRanker.rank_compacted` — single-sentinel *reference
  production* path: after the sentinel, surviving documents are gathered
  into a dense prefix (O(n) cumsum stable partition) and ONLY that
  compacted block runs the tail trees through the Pallas kernel.
- :meth:`CascadeRanker.rank_progressive` — the *multi-stage engine* and
  the serving hot path, configured by a single frozen
  :class:`repro.core.stage.EngineConfig` (the stage list + engine knobs;
  the config doubles as the jit-step cache key). The WHOLE step — stage
  scoring, exit decisions, cumsum compaction, tail, scatter — is built
  once per configuration and compiled into ONE end-to-end ``jax.jit``
  computation (XLA is free to fuse compact → gather → tail → scatter);
  launch accounting moved to trace time
  (:func:`repro.kernels.ops._counted_pallas`), so the launch contract
  stays testable. Two execution modes share identical ranking semantics:

  * ``mode="fused"`` (default): one sentinel-segmented Pallas launch over
    the head trees yields the prefix score of every document at EVERY
    sentinel (``[Q, D, S]``); stage decisions are pure vector work (no
    kernel, no HBM round-trip between stages), exit masks are nested
    (``alive_k = alive_{k-1} ∧ continue_k`` — a document that exits never
    re-enters), and exactly ONE tail launch runs the remaining trees on
    the cumsum-compacted survivors of the last stage: 1 segmented head
    launch + ≤1 tail launch total.
  * ``mode="staged"`` (per-stage tails): segment ``k`` is scored ONLY on
    the stage-(k−1) compacted survivors — each stage's capacity is a REAL
    kernel block bound (survivors beyond it retire with their stage-k
    prefix and are charged to ``overflow``), so kernel work shrinks with
    the survivor set at the cost of one launch plus one gather/scatter
    per stage: ≤S+1 plain launches, no segmented launch. With S == 1 the
    two modes are the same computation.
  * ``mode="auto"`` (the ON-DEVICE pick): ONE combined program contains
    both branches under a ``jax.lax.cond`` and the branch predicate is
    computed on device —
    :func:`repro.metrics.speedup.progressive_cost_model_device` prices
    both modes from a traced survivor estimate (``stage_ema``, typically
    the service's smoothed per-stage survivor counts) and the cheaper
    branch executes. No host round trip, no batch-boundary decision lag.
    Both branches are staged at trace time (launch counters account each
    exactly once); at run time exactly one branch's launches execute.

  **Hybrid cascades** (:class:`repro.core.stage.DenseStage` at position
  0): the dense scorer evaluates the ENTIRE ``[Q·D, F]`` block in one
  matmul, its policy prunes the easy majority, and the survivors are
  cumsum-compacted into a block of ``capacities[0]`` — the tree stages
  (both modes' head launches included) then run on THAT block, so no
  tree is ever traversed for a dense-exited document. Dense-exited
  documents keep the dense score as their final score (the distilled
  model stands in for the ensemble); the dense compaction is a real
  kernel block bound in both modes, with real overflow accounting. The
  dense matmul is pure XLA — it adds no Pallas launch, so the launch
  contract is unchanged with ``S`` = the number of TREE stages.

  Mode trade-off: fused scores every candidate document through the whole
  head region, trading redundant VPU work on early-exited documents for
  the elimination of S−1 launches and all intermediate gather/scatter
  traffic. Staged wins when survivors shrink fast and the head region is
  deep. :meth:`repro.serve.ranking_service.RankingService` serves
  ``auto`` by default; the host-side pick via
  :func:`repro.metrics.speedup.progressive_cost_model` remains the
  reference model. The speedup metric stays in the paper's currency
  (trees *logically* traversed under early-exit semantics).

  Strategies and dense policies must be *mask-invariant* (read
  ``partial`` only where the alive mask is set): in staged and hybrid
  execution, exited documents hold stale prefixes (or grid slots never
  scored by the trees), and all stock strategies already mask them out.

A static ``capacity`` bounds each compacted block so the step stays
jit-compatible; :func:`bucket_capacity` buckets requested capacities to
powers of two so the jit cache stays bounded. Survivors beyond capacity
keep their stage prefix score (bounded, graceful quality degradation —
never a crash), and the overflow count is a LAZY device scalar: the hot
path never blocks on it (read it in a stats path via
``int(result.overflow)``). For the same reason, ``rank_progressive``
reports ``speedup`` as a lazy device scalar too; the reference paths keep
returning host floats.

Deprecated keyword configuration (``sentinels=…, capacities=…,
strategies=…, mode=…`` and friends) still works through a shim that
builds the equivalent :class:`~repro.core.stage.EngineConfig` and emits a
``DeprecationWarning`` whose message starts with ``repro.`` — CI runs the
repo's own tests with that warning escalated to an error, proving no
in-repo caller still uses it.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compaction import (
    COMPACTORS,
    compact_indices_cumsum,
    compact_indices_cumsum_masked,
)
from repro.core.stage import DenseStage, EngineConfig
from repro.core.strategies import QueryExitConfig, query_converged
from repro.forest.ensemble import TreeEnsemble, slice_trees
from repro.forest.scoring import score_bitvector
from repro.kernels.ops import (
    ENGINE_BLOCK_B,
    PaddedForest,
    forest_score,
    forest_score_range,
    forest_score_segments,
    padded_forest,
)
from repro.metrics.speedup import (
    progressive_cost_model_device,
    speedup_progressive,
    speedup_vs_full,
)

_DEPRECATED_KWARGS_MSG = (
    "repro.core.cascade.rank_progressive: keyword configuration "
    "(sentinels=…, capacities=…, strategies=…, mode=…) is deprecated; "
    "pass an EngineConfig — e.g. rank_progressive(X, mask, "
    "EngineConfig.trees(sentinels=…, …)). The shim builds the equivalent "
    "config and will be removed in a future release."
)


def bucket_capacity(want: int, limit: int, minimum: int = 64) -> int:
    """Power-of-two capacity bucketing (bounded jit cache), clipped to limit."""
    cap = 1 << int(np.ceil(np.log2(max(want, minimum, 1))))
    return min(cap, limit)


@dataclasses.dataclass
class CascadeResult:
    scores: jax.Array          # [Q, D] final scores (exited docs keep the
    #                            score of the stage that exited them — the
    #                            dense score for dense-stage exits)
    continue_mask: jax.Array   # [Q, D] — survivors of the LAST stage
    speedup: float | jax.Array  # trees-traversed speedup vs Full (lazy scalar
    #                             on the progressive path; host float on the
    #                             reference paths)
    overflow: jax.Array | int = 0  # lazy device scalar; docs beyond capacity
    #   (fused: dense + final-stage compactions; staged: summed over stages)
    stage_masks: list | None = None   # progressive: nested alive mask per
    #   stage, dense stage first when present (len == config.n_stages)
    partials: jax.Array | None = None  # progressive: [Q, D, n_stages] — the
    #   score grid each stage's policy saw (fused all-trees: exact sentinel
    #   prefixes for every doc; staged/hybrid: docs already exited hold
    #   their exit-stage score; hybrid slice 0 is the dense score grid)
    mode: str | None = None            # progressive: "fused"|"staged"|"auto"
    picked_staged: jax.Array | None = None  # mode="auto": lazy device bool —
    #   which cond branch executed (True = staged); None for fixed modes
    query_exited: jax.Array | None = None  # query_exit enabled: [Q] lazy bool
    #   — queries whose remaining docs were removed by query-level exit
    #   (converged top-k or no alive docs); None when the knob is off


@dataclasses.dataclass
class CascadeRanker:
    ensemble: TreeEnsemble
    sentinel: int
    strategy: Callable[..., jax.Array]
    classifier_trees: int = 0   # extra per-doc cost charged for the strategy
    _ht_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )
    # End-to-end jitted progressive steps, keyed by the full static config
    # (buffers, stages, capacities, mode, …). LRU-bounded so sweeping
    # configurations cannot pin unbounded compiled computations.
    _step_cache: OrderedDict = dataclasses.field(
        default_factory=OrderedDict, init=False, repr=False, compare=False
    )

    def _head_tail(self) -> tuple[TreeEnsemble, TreeEnsemble]:
        # Sliced sub-ensembles are cached: repeated rank*() calls reuse the
        # same TreeEnsemble objects (and therefore their padded-buffer
        # caches) instead of re-slicing per call.
        if self._ht_cache is None:
            head = slice_trees(self.ensemble, 0, self.sentinel)
            tail = slice_trees(self.ensemble, self.sentinel, self.ensemble.n_trees)
            self._ht_cache = (head, tail)
        return self._ht_cache

    def rank(
        self, X: jax.Array, mask: jax.Array, **strategy_kwargs: object
    ) -> CascadeResult:
        """Reference path: full compute, masked combine."""
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        head, tail = self._head_tail()
        partial = score_bitvector(head, flat).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        tail_scores = score_bitvector(tail, flat).reshape(Q, D)
        scores = jnp.where(cont, partial + tail_scores, partial)
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(scores=scores, continue_mask=cont, speedup=sp)

    def rank_compacted(
        self,
        X: jax.Array,
        mask: jax.Array,
        capacity: int,
        compaction: str = "cumsum",
        **strategy_kwargs: object,
    ) -> CascadeResult:
        """Single-sentinel production path: tail sees only compacted survivors."""
        Q, D, F = X.shape
        head, tail = self._head_tail()
        partial = forest_score(head, X.reshape(Q * D, F)).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        scores, n_cont = _compacted_tail(X, partial, cont, tail, capacity, compaction)
        overflow = jnp.maximum(n_cont - capacity, 0)  # lazy: no device sync
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(
            scores=scores, continue_mask=cont, speedup=sp, overflow=overflow
        )

    def rank_progressive(
        self,
        X: jax.Array,
        mask: jax.Array,
        config: EngineConfig | None = None,
        sentinels: Sequence[int] | None = None,
        capacities: Sequence[int] | int | None = None,
        strategies: Sequence[Callable[..., jax.Array]] | None = None,
        *,
        classifier_trees: Sequence[int] | int | None = None,
        block_t: int | None = None,
        leaf_gather: str | None = None,
        mode: str | None = None,
        stage_ema: jax.Array | None = None,
        have_ema: jax.Array | bool = True,
        launch_overhead_trees: float | None = None,
        query_exit: QueryExitConfig | None = None,
        query_exit_rate: jax.Array | float = 0.0,
        **strategy_kwargs: object,
    ) -> CascadeResult:
        """Multi-stage engine, end-to-end jitted (one XLA computation).

        ``config`` (an :class:`repro.core.stage.EngineConfig`) is the
        whole static configuration: the ordered stage list (an optional
        :class:`repro.core.stage.DenseStage` at position 0, then
        :class:`repro.core.stage.TreeStage` entries with increasing
        sentinels) plus the engine knobs. A ``TreeStage`` with
        ``strategy=None`` / ``classifier_trees=None`` inherits the
        ranker's defaults. Per-stage survivor capacities resolve as
        stage.capacity → config.capacities entry → bucket default
        (:func:`bucket_capacity`), each clipped to ``Q·D``.

        Everything else on the signature is either a traced per-call
        operand (``stage_ema``/``have_ema``/``query_exit_rate`` for
        ``mode="auto"``, plus ``**strategy_kwargs`` whose array values
        become traced operands of the jitted step) or the DEPRECATED
        keyword configuration: passing ``sentinels=…`` (and friends)
        without a config still works — the shim builds the equivalent
        ``EngineConfig.trees(...)`` and emits a ``DeprecationWarning``
        (message prefixed ``repro.`` so CI can escalate in-repo use to an
        error). Mixing a config WITH legacy keywords is a ``TypeError``.

        ``mode="auto"`` compiles BOTH modes into one program and picks the
        branch on device with a ``lax.cond``: ``stage_ema`` (``[n_stages]``
        f32, required — dense stage included for hybrid configs) is the
        traced per-stage survivor estimate priced by
        :func:`repro.metrics.speedup.progressive_cost_model_device`;
        ``have_ema`` (traced bool) gates the pick — ``False`` forces the
        fused branch (the safe cold-start floor). The executed branch is
        reported as the lazy ``picked_staged`` device bool on the result.
        Requires ≥ 2 TREE stages (with one the modes are the same
        computation).

        ``config.query_exit`` (a
        :class:`repro.core.strategies.QueryExitConfig`) enables
        query-level early exit: after each stage's document decision,
        :func:`repro.core.strategies.query_converged` folds a per-query
        "top-k stabilized" predicate into the alive mask (stage indices
        count ALL stages — the dense stage is stage 0 of a hybrid
        config) — a converged query's remaining documents skip every
        later stage and the tail, and the tail launch itself moves under
        a ``lax.cond`` on the survivor count (counted as ``gated`` by the
        launch counters). With ``margin=inf`` the transform is
        score-preserving. ``query_exit_rate`` (traced scalar,
        ``mode="auto"`` only) is the tail-skip estimate the in-program
        mode pick prices launches with.
        """
        if config is not None and not isinstance(config, EngineConfig):
            # Legacy POSITIONAL call: rank_progressive(X, mask, [10, 20], …)
            assert sentinels is None, (config, sentinels)
            config, sentinels = None, config
        legacy = {
            name: value
            for name, value in (
                ("sentinels", sentinels), ("capacities", capacities),
                ("strategies", strategies),
                ("classifier_trees", classifier_trees),
                ("block_t", block_t), ("leaf_gather", leaf_gather),
                ("mode", mode), ("launch_overhead_trees", launch_overhead_trees),
                ("query_exit", query_exit),
            )
            if value is not None
        }
        if config is None:
            assert sentinels is not None, (
                "rank_progressive needs an EngineConfig (or the deprecated "
                "sentinels=… keywords)"
            )
            warnings.warn(
                _DEPRECATED_KWARGS_MSG, DeprecationWarning, stacklevel=2
            )
            config = EngineConfig.trees(
                sentinels,
                strategies,
                classifier_trees=classifier_trees,
                capacities=capacities,
                mode=mode if mode is not None else "fused",
                leaf_gather=leaf_gather if leaf_gather is not None else "auto",
                block_t=block_t if block_t is not None else 16,
                launch_overhead_trees=(
                    launch_overhead_trees
                    if launch_overhead_trees is not None else 0.0
                ),
                query_exit=query_exit,
            )
        elif legacy:
            raise TypeError(
                "rank_progressive: pass configuration via EngineConfig OR "
                f"the deprecated keywords, not both (got {sorted(legacy)})"
            )

        Q, D, F = X.shape
        dense = config.dense
        tree_sentinels = config.sentinels
        S = len(tree_sentinels)
        S_total = config.n_stages
        T = self.ensemble.n_trees
        run_mode = config.mode
        assert 0 < tree_sentinels[0] and tree_sentinels[-1] <= T, (
            tree_sentinels, T
        )
        tree_strategies = tuple(
            st.strategy if st.strategy is not None else self.strategy
            for st in config.tree_stages
        )
        tree_classifier_trees = tuple(
            float(
                st.classifier_trees
                if st.classifier_trees is not None
                else self.classifier_trees
            )
            for st in config.tree_stages
        )

        conf_caps = config.capacities
        if conf_caps is None:
            conf_caps = (None,) * S_total
        elif isinstance(conf_caps, int):
            conf_caps = (conf_caps,) * S_total
        default_cap = bucket_capacity(Q * D, Q * D)
        resolved = tuple(
            min(
                int(
                    st.capacity
                    if st.capacity is not None
                    else (c if c is not None else default_cap)
                ),
                Q * D,
            )
            for st, c in zip(config.stages, conf_caps)
        )

        has_tail = tree_sentinels[-1] < T
        boundaries = tree_sentinels + ((T,) if has_tail else ())
        # leaf_gather picks the kernel's leaf-value resolution path (select
        # tree / MXU contraction / one-hot reference — all bit-exact); the
        # buffer set carries the matching leaf layout, so a distinct path is
        # simply a distinct cached PaddedForest (and thus a distinct step).
        pf = padded_forest(
            self.ensemble, boundaries=boundaries, block_t=config.block_t,
            leaf_gather=config.leaf_gather,
        )

        # Array-valued strategy kwargs become traced operands of the jitted
        # step; everything else (ints, floats, flags) is static config and
        # part of the cache key.
        names = tuple(sorted(strategy_kwargs))
        traced_names = tuple(
            n for n in names
            if isinstance(strategy_kwargs[n], (jax.Array, np.ndarray))
        )
        static_items = tuple(
            (n, strategy_kwargs[n]) for n in names if n not in traced_names
        )

        if run_mode == "auto":
            assert S >= 2, "mode='auto' needs ≥2 tree stages (S=1: modes equal)"
            assert stage_ema is not None, "mode='auto' requires stage_ema"
            mode_ops = (
                jnp.asarray(stage_ema, jnp.float32),
                jnp.asarray(have_ema, bool),
                jnp.asarray(query_exit_rate, jnp.float32),
            )
            assert mode_ops[0].shape == (S_total,), (
                mode_ops[0].shape, S_total
            )
        else:
            mode_ops = ()

        # Fused mode only ever reads the capacities that bound kernel blocks
        # (the dense gate and the tail); keying on the full tuple would
        # re-trace identical computations whenever the service ratchets an
        # early-stage bucket. Staged and auto read every entry (auto also
        # prices the staged branch with them).
        if run_mode != "fused":
            key_capacities = resolved
        else:
            key_capacities = (
                resolved[:1] if dense is not None else ()
            ) + resolved[-1:]
        key = (
            id(pf), config.stages, key_capacities, tree_strategies,
            tree_classifier_trees, run_mode,
            float(config.launch_overhead_trees), config.query_exit,
            traced_names, static_items,
        )
        step = self._step_cache.get(key)
        if step is None:
            step = _build_progressive_step(
                pf, dense, tree_sentinels, resolved, tree_strategies,
                tree_classifier_trees, run_mode, traced_names,
                dict(static_items), T,
                launch_overhead_trees=float(config.launch_overhead_trees),
                query_exit=config.query_exit,
            )
            self._step_cache[key] = step
            while len(self._step_cache) > _STEP_CACHE_MAX:
                self._step_cache.popitem(last=False)
        else:
            self._step_cache.move_to_end(key)

        traced_vals = tuple(strategy_kwargs[n] for n in traced_names)
        (scores, alive, stage_masks, partials, overflow, sp, picked,
         q_exited) = step(X, mask, traced_vals, mode_ops)
        return CascadeResult(
            scores=scores,
            continue_mask=alive,
            speedup=sp,
            overflow=overflow,   # lazy: no device sync
            stage_masks=list(stage_masks),
            partials=partials,
            mode=run_mode,
            picked_staged=picked,  # lazy device bool (auto), else None
            query_exited=q_exited if config.query_exit is not None else None,
        )


_STEP_CACHE_MAX = 16  # compiled progressive steps kept per ranker (LRU)


def _build_progressive_step(
    pf: PaddedForest,
    dense: DenseStage | None,
    sentinels: tuple[int, ...],
    capacities: tuple[int, ...],
    strategies: tuple,
    classifier_trees: tuple[float, ...],
    mode: str,
    traced_names: tuple[str, ...],
    static_kwargs: dict,
    n_trees: int,
    launch_overhead_trees: float = 0.0,
    query_exit: QueryExitConfig | None = None,
) -> Callable[..., tuple]:
    """Build the end-to-end jitted progressive step for one configuration.

    Everything static (buffers, stages, capacities, mode) is closed over;
    the returned callable takes ``(X, mask, traced_vals, mode_ops)`` —
    ``mode_ops`` is ``()`` for the fixed modes and ``(stage_ema, have_ema,
    query_exit_rate)`` for ``mode="auto"`` — and compiles dense gate →
    head → decisions → compaction → tail → scatter into one XLA
    computation. Launch counters fire while THIS function's body traces
    (see :func:`repro.kernels.ops._counted_pallas`), so a compiled step
    re-executing from cache stages no new launches and moves no counters;
    under ``mode="auto"`` BOTH branch bodies trace into the one program,
    so each branch's launches are accounted exactly once even though only
    one branch executes per batch.

    ``sentinels``/``strategies``/``classifier_trees`` describe the TREE
    stages; ``capacities`` covers ALL stages (``capacities[0]`` is the
    dense gate's survivor block bound when ``dense`` is set). With a
    dense stage, both modes score the tree head on the SAME
    dense-compacted survivor block, so the per-block kernel sums — and
    therefore cross-mode bit-exactness on non-overflow batches — carry
    over unchanged from the all-trees engine: both modes accumulate
    prefixes with the same left-to-right association and identical
    per-doc segment sums, which is also what makes the ``lax.cond``
    branch structures compatible.
    """
    S = len(sentinels)
    has_tail = sentinels[-1] < n_trees
    # Accounting views: the dense stage charges `cost_trees` per candidate
    # document at "sentinel 0" (no trees traversed, one dense evaluation),
    # then the first tree stage charges its sentinel depth on the dense
    # survivors — trees_traversed_progressive handles that uniformly once
    # the dense stage is spliced in as a zero-sentinel stage.
    if dense is not None:
        acct_sentinels = (0, *sentinels)
        acct_costs = (float(dense.cost_trees), *classifier_trees)
        tree_caps = capacities[1:]
    else:
        acct_sentinels = sentinels
        acct_costs = classifier_trees
        tree_caps = capacities

    def final_tail(flat, scores, alive, overflow):
        # Tail launch on the compacted survivors of the last stage. In
        # fused mode only this compaction (plus the dense gate's, for
        # hybrid configs) can drop tail scores; staged mode accumulated
        # per-stage overflow before reaching here. With query-level exit
        # enabled the launch moves under a lax.cond on the survivor count
        # (counted "gated"): a batch whose queries all converged
        # dispatches no tail kernel.
        if not has_tail:
            return scores, overflow
        cap = capacities[-1]
        sel, n_cont = compact_indices_cumsum(alive.reshape(-1), cap)
        if query_exit is None:
            x_sel = jnp.take(flat, sel, axis=0)
            tail_sel = forest_score_range(pf, x_sel, seg_lo=S)
            scores = _scatter_tail(scores, sel, tail_sel, n_cont)
        else:
            def run_tail(s):
                x_sel = jnp.take(flat, sel, axis=0)
                tail_sel = forest_score_range(
                    pf, x_sel, seg_lo=S, count_as="gated"
                )
                return _scatter_tail(s, sel, tail_sel, n_cont)

            scores = jax.lax.cond(
                n_cont > 0, run_tail, lambda s: s, scores
            )
        overflow = overflow + jnp.maximum(n_cont - cap, 0)
        return scores, overflow

    def apply_query_exit(stage_idx: int, prefix, alive, exited):
        # Fold the per-query convergence predicate into the alive mask:
        # once a query converges, none of its documents may re-enter
        # (exit flags accumulate like the nested per-doc stage masks).
        # Stage indices count ALL stages: the dense gate of a hybrid
        # config is stage 0, the first tree stage is stage 1.
        if query_exit is None or stage_idx < query_exit.from_stage:
            return alive, exited
        conv = query_converged(
            prefix, alive, k=query_exit.k, margin=query_exit.margin
        )
        exited = exited | conv
        return alive & ~exited[:, None], exited

    def dense_gate(flat, mask, skw):
        # Stage 0 of a hybrid cascade: score EVERY candidate through the
        # dense model in one matmul (pure XLA — no Pallas launch), prune
        # with the stage policy, then cumsum-compact the survivors into a
        # block of capacities[0]. The tree stages only ever see that
        # block, so a dense-exited document costs zero tree traversals.
        Q, D = mask.shape
        cap = capacities[0]
        d_scores = dense.scorer(flat).reshape(Q, D).astype(jnp.float32)
        # The dense policy sees (scores, mask) only — its knobs (and any
        # extra operands) live in the closure; **strategy_kwargs belong to
        # the tree strategies.
        alive = mask & dense.policy(d_scores, mask)
        exited = jnp.zeros((Q,), bool)
        alive, exited = apply_query_exit(0, d_scores, alive, exited)
        sel, n_cont, within = compact_indices_cumsum_masked(
            alive.reshape(Q * D), cap
        )
        overflow = jnp.maximum(n_cont - cap, 0)
        alive = alive & within.reshape(Q, D)
        x_sel = jnp.take(flat, sel, axis=0)
        valid = jnp.arange(cap) < n_cont
        return d_scores, alive, exited, overflow, sel, x_sel, valid

    def scatter_grid(vec, sel, valid, alive, fallback):
        # Compacted per-doc values back onto the [Q, D] grid: exact for
        # every alive doc (alive ⊆ within-capacity ⊆ scattered), the
        # fallback elsewhere (policies are mask-invariant, so stale slots
        # are never read where it matters).
        Q, D = fallback.shape
        grid = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
            jnp.where(valid, vec, 0.0)
        ).reshape(Q, D)
        return jnp.where(alive, grid, fallback)

    def fused_tree_prefix_vecs(x_sel):
        # One launch over the head trees: prefix score of every survivor
        # at every sentinel, as compacted [C] vectors. A single segment
        # needs no segmented accumulator — it degenerates to the plain
        # kernel (same launch count, less work).
        if S == 1:
            return [forest_score_range(pf, x_sel, 0, 1)]
        seg = forest_score_segments(pf, x_sel, n_segments=S)
        acc = seg[:, 0] + pf.base_score
        vecs = [acc]
        for k in range(1, S):
            acc = acc + seg[:, k]
            vecs.append(acc)
        return vecs

    def fused_body(flat, mask, skw):
        Q, D = mask.shape
        if dense is None:
            # All-trees fused: the head launch scores the FULL block.
            alive = mask
            exited = jnp.zeros((Q,), bool)
            stage_masks = []
            if S == 1:
                prefixes = [forest_score_range(pf, flat, 0, 1).reshape(Q, D)]
            else:
                seg = forest_score_segments(pf, flat, n_segments=S)
                seg = seg.reshape(Q, D, S)
                acc = seg[..., 0] + pf.base_score
                prefixes = [acc]
                for k in range(1, S):
                    acc = acc + seg[..., k]
                    prefixes.append(acc)

            # Stage decisions: pure vector work, nested exit masks.
            scores = prefixes[0]
            for k in range(S):
                cont = strategies[k](prefixes[k], alive, **skw)
                alive = alive & cont
                alive, exited = apply_query_exit(
                    k, prefixes[k], alive, exited
                )
                stage_masks.append(alive)
                if k + 1 < S:
                    scores = jnp.where(alive, prefixes[k + 1], scores)
            scores, overflow = final_tail(flat, scores, alive, jnp.int32(0))
            return (
                scores, alive, tuple(stage_masks),
                jnp.stack(prefixes, axis=-1), overflow, exited,
            )

        # Hybrid fused: dense gate → ONE segmented head launch on the
        # dense-compacted survivor block → vector-work stage decisions on
        # the scattered prefix grids → one compacted tail.
        d_scores, alive, exited, overflow, sel, x_sel, valid = dense_gate(
            flat, mask, skw
        )
        stage_masks = [alive]
        vecs = fused_tree_prefix_vecs(x_sel)
        scores = d_scores
        grids = [d_scores]
        prev_grid = d_scores
        for k in range(S):
            grid = scatter_grid(vecs[k], sel, valid, alive, prev_grid)
            scores = jnp.where(alive, grid, scores)
            cont = strategies[k](grid, alive, **skw)
            alive = alive & cont
            alive, exited = apply_query_exit(k + 1, grid, alive, exited)
            stage_masks.append(alive)
            grids.append(grid)
            prev_grid = grid
        scores, overflow = final_tail(flat, scores, alive, overflow)
        return (
            scores, alive, tuple(stage_masks),
            jnp.stack(grids, axis=-1), overflow, exited,
        )

    def staged_body(flat, mask, skw):
        # Per-stage tails: segment k runs only on the compacted survivors
        # of stage k-1; every capacity is a real kernel bound with real
        # overflow accounting.
        Q, D = mask.shape
        if dense is None:
            alive = mask
            exited = jnp.zeros((Q,), bool)
            overflow = jnp.int32(0)
            prefix = forest_score_range(pf, flat, 0, 1).reshape(Q, D)
            stage_offset = 0
        else:
            d_scores, alive, exited, overflow, sel0, x_sel0, valid0 = (
                dense_gate(flat, mask, skw)
            )
            # First tree segment on the dense-compacted block — the same
            # block (and therefore the same per-doc kernel sums) the
            # fused head scores, which keeps the modes bit-exact.
            seg0 = forest_score_range(pf, x_sel0, 0, 1)
            prefix = scatter_grid(seg0, sel0, valid0, alive, d_scores)
            stage_offset = 1
        stage_masks = [alive] if dense is not None else []
        prefixes = [d_scores, prefix] if dense is not None else [prefix]
        for k in range(S):
            cont = strategies[k](prefix, alive, **skw)
            alive = alive & cont
            alive, exited = apply_query_exit(
                k + stage_offset, prefix, alive, exited
            )
            if k + 1 < S:
                cap = tree_caps[k]
                sel, n_cont, within = compact_indices_cumsum_masked(
                    alive.reshape(Q * D), cap
                )
                overflow = overflow + jnp.maximum(n_cont - cap, 0)
                alive = alive & within.reshape(Q, D)
                x_sel = jnp.take(flat, sel, axis=0)
                seg_sel = forest_score_range(pf, x_sel, k + 1, k + 2)
                prefix = jnp.where(
                    alive,
                    _scatter_tail(prefix, sel, seg_sel, n_cont),
                    prefix,
                )
                prefixes.append(prefix)
            stage_masks.append(alive)
        scores, overflow = final_tail(flat, prefix, alive, overflow)
        return (
            scores, alive, tuple(stage_masks),
            jnp.stack(prefixes, axis=-1), overflow, exited,
        )

    @jax.jit
    def step(X, mask, traced_vals, mode_ops):
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        skw = {**dict(zip(traced_names, traced_vals)), **static_kwargs}

        if mode == "fused":
            out = fused_body(flat, mask, skw)
            picked = None
        elif mode == "staged":
            out = staged_body(flat, mask, skw)
            picked = None
        else:
            # On-device mode pick: price both modes from the traced
            # survivor estimate and run the cheaper branch. Both bodies
            # trace here (cond stages both); one executes per batch.
            stage_ema, have_ema, qe_rate = mode_ops
            fused_cost, staged_cost = progressive_cost_model_device(
                Q * D, stage_ema, sentinels, n_trees,
                launch_overhead_trees=launch_overhead_trees,
                stage_capacities=capacities,
                block_b=ENGINE_BLOCK_B,
                query_exit_rate=qe_rate,
                dense_cost_trees=(
                    float(dense.cost_trees) if dense is not None else 0.0
                ),
                dense_stage=dense is not None,
            )
            picked = jnp.logical_and(have_ema, staged_cost < fused_cost)
            out = jax.lax.cond(
                picked,
                lambda: staged_body(flat, mask, skw),
                lambda: fused_body(flat, mask, skw),
            )
        scores, alive, stage_masks, partials, overflow, exited = out
        sp = speedup_progressive(
            mask, list(stage_masks), acct_sentinels, n_trees,
            list(acct_costs),
        )
        return (
            scores, alive, stage_masks, partials, overflow, sp, picked,
            exited,
        )

    return step


def _compacted_tail(
    X: jax.Array,
    partial: jax.Array,
    cont: jax.Array,
    tail: TreeEnsemble,
    capacity: int,
    compaction: str = "cumsum",
) -> tuple[jax.Array, jax.Array]:
    """Gather survivors → dense block of ``capacity`` → tail kernel → scatter.

    Kept at the Python level (jitted pieces around one counted kernel call)
    so launch accounting stays truthful.
    """
    Q, D, F = X.shape
    sel, n_cont = COMPACTORS[compaction](cont.reshape(Q * D), capacity)
    x_sel = jnp.take(X.reshape(Q * D, F), sel, axis=0)         # [C, F]
    tail_sel = forest_score(tail, x_sel)                       # [C]
    return _scatter_tail(partial, sel, tail_sel, n_cont), n_cont


@jax.jit
def _scatter_tail(
    scores: jax.Array, sel: jax.Array, tail_sel: jax.Array, n_cont: jax.Array
) -> jax.Array:
    """Scatter valid compacted tail scores back onto the [Q, D] grid."""
    Q, D = scores.shape
    valid = jnp.arange(sel.shape[0]) < n_cont
    deltas = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
        jnp.where(valid, tail_sel, 0.0)
    )
    return scores + deltas.reshape(Q, D)
