"""Sentinel-partitioned cascade execution — early exit as batch compaction.

Three execution paths with identical ranking semantics:

- :meth:`CascadeRanker.rank` — *reference* path: scores every document
  through head and tail, applies the continue mask arithmetically. Used for
  quality evaluation and as the oracle for the compacted paths. Cost is
  accounted in the paper's currency (trees traversed), not saved.
- :meth:`CascadeRanker.rank_compacted` — single-sentinel *reference
  production* path: after the sentinel, surviving documents are gathered
  into a dense prefix (O(n) cumsum stable partition) and ONLY that
  compacted block runs the tail trees through the Pallas kernel.
- :meth:`CascadeRanker.rank_progressive` — the *multi-sentinel engine* and
  the serving hot path. One sentinel-segmented Pallas launch over the head
  trees yields the prefix score of every document at EVERY sentinel
  (``[Q, D, S]``); stage decisions are then pure vector work (no kernel,
  no HBM round-trip between stages), exit masks are nested
  (``alive_k = alive_{k-1} ∧ continue_k`` — a document that exits never
  re-enters), and exactly ONE tail launch runs the remaining trees on the
  cumsum-compacted survivors of the last stage. Head and tail score from
  the same cached padded buffer set (:func:`repro.kernels.ops.padded_forest`
  — pad once, score many), so an S-stage cascade costs 1 segmented head
  launch + 1 tail launch instead of S+1 launches with full re-slice/re-pad
  and an HBM round-trip each.

  Design note: for LEAR-scale ensembles the final sentinel sits at a few
  percent of the ensemble (s_S ≪ T), so scoring every document through the
  whole head region — rather than per-stage tails on shrinking survivor
  sets — trades a small amount of redundant VPU work on early-exited
  documents for the elimination of S−1 kernel launches, S−1 HBM partial
  round-trips, and all intermediate gather/scatter traffic. The speedup
  metric stays in the paper's currency (trees *logically* traversed under
  early-exit semantics), matching :func:`metrics.speedup.trees_traversed`.

A static ``capacity`` bounds each compacted block so the step stays
jit-compatible; :func:`bucket_capacity` buckets requested capacities to
powers of two so the jit cache stays bounded. Survivors beyond capacity
keep their sentinel prefix score (bounded, graceful quality degradation —
never a crash), and the overflow count is a LAZY device scalar: the hot
path never blocks on it (read it in a stats path via
``int(result.overflow)``). For the same reason, ``rank_progressive``
reports ``speedup`` as a lazy device scalar too; the reference paths keep
returning host floats.

The strategy is injected as a callable ``(partial, mask, aux) → continue
mask`` so LEAR / ERT / EPT / EE_ideal all run through the same engine.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compaction import COMPACTORS, compact_indices_cumsum
from repro.forest.ensemble import TreeEnsemble, slice_trees
from repro.forest.scoring import score_bitvector
from repro.kernels.ops import (
    forest_score,
    forest_score_range,
    forest_score_segments,
    padded_forest,
)
from repro.metrics.speedup import speedup_progressive, speedup_vs_full


def bucket_capacity(want: int, limit: int, minimum: int = 64) -> int:
    """Power-of-two capacity bucketing (bounded jit cache), clipped to limit."""
    cap = 1 << int(np.ceil(np.log2(max(want, minimum, 1))))
    return min(cap, limit)


@dataclasses.dataclass
class CascadeResult:
    scores: jax.Array          # [Q, D] final scores (exited docs keep partial)
    continue_mask: jax.Array   # [Q, D] — survivors of the LAST stage
    speedup: float | jax.Array  # trees-traversed speedup vs Full (lazy scalar
    #                             on the progressive path; host float on the
    #                             reference paths)
    overflow: jax.Array | int = 0  # lazy device scalar; docs beyond capacity
    stage_masks: list | None = None   # progressive: nested alive mask per stage
    partials: jax.Array | None = None  # progressive: [Q, D, S] sentinel prefixes


@dataclasses.dataclass
class CascadeRanker:
    ensemble: TreeEnsemble
    sentinel: int
    strategy: Callable[..., jax.Array]
    classifier_trees: int = 0   # extra per-doc cost charged for the strategy
    _ht_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def _head_tail(self):
        # Sliced sub-ensembles are cached: repeated rank*() calls reuse the
        # same TreeEnsemble objects (and therefore their padded-buffer
        # caches) instead of re-slicing per call.
        if self._ht_cache is None:
            head = slice_trees(self.ensemble, 0, self.sentinel)
            tail = slice_trees(self.ensemble, self.sentinel, self.ensemble.n_trees)
            self._ht_cache = (head, tail)
        return self._ht_cache

    def rank(self, X: jax.Array, mask: jax.Array, **strategy_kwargs) -> CascadeResult:
        """Reference path: full compute, masked combine."""
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        head, tail = self._head_tail()
        partial = score_bitvector(head, flat).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        tail_scores = score_bitvector(tail, flat).reshape(Q, D)
        scores = jnp.where(cont, partial + tail_scores, partial)
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(scores=scores, continue_mask=cont, speedup=sp)

    def rank_compacted(
        self,
        X: jax.Array,
        mask: jax.Array,
        capacity: int,
        compaction: str = "cumsum",
        **strategy_kwargs,
    ) -> CascadeResult:
        """Single-sentinel production path: tail sees only compacted survivors."""
        Q, D, F = X.shape
        head, tail = self._head_tail()
        partial = forest_score(head, X.reshape(Q * D, F)).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        scores, n_cont = _compacted_tail(X, partial, cont, tail, capacity, compaction)
        overflow = jnp.maximum(n_cont - capacity, 0)  # lazy: no device sync
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(
            scores=scores, continue_mask=cont, speedup=sp, overflow=overflow
        )

    def rank_progressive(
        self,
        X: jax.Array,
        mask: jax.Array,
        sentinels: Sequence[int],
        capacities: Sequence[int] | int | None = None,
        strategies: Sequence[Callable[..., jax.Array]] | None = None,
        *,
        classifier_trees: Sequence[int] | int | None = None,
        block_t: int = 16,
        **strategy_kwargs,
    ) -> CascadeResult:
        """Multi-sentinel engine: 1 segmented head launch + ≤1 tail launch.

        ``sentinels`` need not be tree-block aligned (segments are padded
        independently in the cached buffers). ``capacities`` bounds the
        compacted survivor block per stage (only the last stage launches a
        kernel; earlier entries bound the bookkeeping/overflow accounting);
        ``None`` derives them from :func:`bucket_capacity`. ``strategies``
        defaults to ``self.strategy`` at every stage; ``classifier_trees``
        (int or per-stage sequence) defaults to ``self.classifier_trees``
        at every stage for the cost accounting. With a single sentinel this
        path is bit-exact with :meth:`rank_compacted`, and ``speedup`` /
        ``overflow`` stay lazy device scalars — the hot path never syncs.
        """
        Q, D, F = X.shape
        sentinels = tuple(int(s) for s in sentinels)
        S = len(sentinels)
        T = self.ensemble.n_trees
        assert S >= 1 and list(sentinels) == sorted(set(sentinels))
        assert 0 < sentinels[0] and sentinels[-1] <= T, (sentinels, T)
        if strategies is None:
            strategies = [self.strategy] * S
        assert len(strategies) == S
        if capacities is None:
            capacities = [bucket_capacity(Q * D, Q * D)] * S
        elif isinstance(capacities, int):
            capacities = [capacities] * S
        capacities = [min(int(c), Q * D) for c in capacities]
        assert len(capacities) == S

        has_tail = sentinels[-1] < T
        boundaries = sentinels + ((T,) if has_tail else ())
        pf = padded_forest(self.ensemble, boundaries=boundaries, block_t=block_t)
        flat = X.reshape(Q * D, F)

        # One launch over the head trees: prefix score of every document at
        # every sentinel. A single segment needs no segmented accumulator —
        # it degenerates to the plain kernel (same launch count, less work).
        if S == 1:
            prefix = forest_score_range(pf, flat, 0, 1).reshape(Q, D, 1)
        else:
            seg_sums = forest_score_segments(pf, flat, n_segments=S)
            prefix = (jnp.cumsum(seg_sums, axis=1) + pf.base_score).reshape(Q, D, S)

        # Stage decisions: pure vector work, nested exit masks.
        alive = mask
        stage_masks = []
        scores = prefix[..., 0]
        for k in range(S):
            cont = strategies[k](prefix[..., k], alive, **strategy_kwargs)
            alive = alive & cont
            stage_masks.append(alive)
            if k + 1 < S:
                scores = jnp.where(alive, prefix[..., k + 1], scores)

        # One tail launch on the compacted survivors of the last stage.
        # Only this compaction can drop tail scores, so only it counts as
        # overflow (earlier capacities are jit-bucketing hints for future
        # per-stage tail execution; the fused head needs no block there).
        overflow = jnp.int32(0)
        if has_tail:
            capacity = capacities[-1]
            sel, n_cont = compact_indices_cumsum(alive.reshape(Q * D), capacity)
            x_sel = jnp.take(flat, sel, axis=0)
            tail_sel = forest_score_range(pf, x_sel, seg_lo=S)
            scores = _scatter_tail(scores, sel, tail_sel, n_cont)
            overflow = n_cont - capacity

        if classifier_trees is None:
            classifier_trees = self.classifier_trees
        sp = speedup_progressive(
            mask, stage_masks, sentinels, T, classifier_trees
        )
        return CascadeResult(
            scores=scores,
            continue_mask=alive,
            speedup=sp,
            overflow=jnp.maximum(overflow, 0),  # lazy: no device sync
            stage_masks=stage_masks,
            partials=prefix,
        )


def _compacted_tail(X, partial, cont, tail: TreeEnsemble, capacity: int,
                    compaction: str = "cumsum"):
    """Gather survivors → dense block of ``capacity`` → tail kernel → scatter.

    Kept at the Python level (jitted pieces around one counted kernel call)
    so launch accounting stays truthful.
    """
    Q, D, F = X.shape
    sel, n_cont = COMPACTORS[compaction](cont.reshape(Q * D), capacity)
    x_sel = jnp.take(X.reshape(Q * D, F), sel, axis=0)         # [C, F]
    tail_sel = forest_score(tail, x_sel)                       # [C]
    return _scatter_tail(partial, sel, tail_sel, n_cont), n_cont


@jax.jit
def _scatter_tail(scores, sel, tail_sel, n_cont):
    """Scatter valid compacted tail scores back onto the [Q, D] grid."""
    Q, D = scores.shape
    valid = jnp.arange(sel.shape[0]) < n_cont
    deltas = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
        jnp.where(valid, tail_sel, 0.0)
    )
    return scores + deltas.reshape(Q, D)
