"""Sentinel-partitioned cascade execution — early exit as batch compaction.

Two execution paths with identical ranking semantics:

- :meth:`CascadeRanker.rank` — *reference* path: scores every document
  through head and tail, applies the continue mask arithmetically. Used for
  quality evaluation and as the oracle for the compacted path. Cost is
  accounted in the paper's currency (trees traversed), not saved.
- :meth:`CascadeRanker.rank_compacted` — *production* path: after the
  sentinel, surviving documents are gathered into a dense prefix (one
  stable argsort over the exit mask) and ONLY that compacted block runs the
  tail trees through the Pallas kernel. This is the TPU realization of
  document-level early exit: the saved work is the reduced doc dimension of
  the dominant kernel. A static ``capacity`` bounds the compacted block so
  the step stays jit-compatible; overflow documents (beyond capacity)
  continue anyway — quality is never sacrificed silently.

The strategy is injected as a callable ``(partial, mask, aux) → continue
mask`` so LEAR / ERT / EPT / EE_ideal all run through the same engine.
"""

from __future__ import annotations

import dataclasses
from functools import partial as _partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.forest.ensemble import TreeEnsemble, slice_trees
from repro.forest.scoring import score_bitvector
from repro.kernels.ops import forest_score
from repro.metrics.speedup import speedup_vs_full


@dataclasses.dataclass
class CascadeResult:
    scores: jax.Array          # [Q, D] final scores (exited docs keep partial)
    continue_mask: jax.Array   # [Q, D]
    speedup: float             # trees-traversed speedup vs Full
    overflow: int = 0          # docs beyond compaction capacity (0 = exact)


@dataclasses.dataclass
class CascadeRanker:
    ensemble: TreeEnsemble
    sentinel: int
    strategy: Callable[..., jax.Array]
    classifier_trees: int = 0   # extra per-doc cost charged for the strategy

    def _head_tail(self):
        head = slice_trees(self.ensemble, 0, self.sentinel)
        tail = slice_trees(self.ensemble, self.sentinel, self.ensemble.n_trees)
        return head, tail

    def rank(self, X: jax.Array, mask: jax.Array, **strategy_kwargs) -> CascadeResult:
        """Reference path: full compute, masked combine."""
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        head, tail = self._head_tail()
        partial = score_bitvector(head, flat).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        tail_scores = score_bitvector(tail, flat).reshape(Q, D)
        scores = jnp.where(cont, partial + tail_scores, partial)
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(scores=scores, continue_mask=cont, speedup=sp)

    def rank_compacted(
        self,
        X: jax.Array,
        mask: jax.Array,
        capacity: int,
        **strategy_kwargs,
    ) -> CascadeResult:
        """Production path: tail trees see only the compacted survivors."""
        Q, D, F = X.shape
        head, tail = self._head_tail()
        partial = forest_score(head, X.reshape(Q * D, F)).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        scores, n_cont = _compacted_tail(
            X, partial, cont, tail, capacity
        )
        overflow = int(jnp.maximum(n_cont - capacity, 0))
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(
            scores=scores, continue_mask=cont, speedup=sp, overflow=overflow
        )


@_partial(jax.jit, static_argnames=("capacity",))
def _compacted_tail(X, partial, cont, tail: TreeEnsemble, capacity: int):
    """Gather survivors → dense block of ``capacity`` → tail kernel → scatter."""
    Q, D, F = X.shape
    flat_cont = cont.reshape(Q * D)
    n_cont = flat_cont.sum()
    # Stable partition: surviving indices first, padding (any index) after.
    order = jnp.argsort(~flat_cont, stable=True)
    sel = order[:capacity]                                     # [C]
    x_sel = X.reshape(Q * D, F)[sel]                           # [C, F]
    tail_sel = forest_score(tail, x_sel)                       # [C]
    valid = jnp.arange(capacity) < n_cont
    deltas = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
        jnp.where(valid, tail_sel, 0.0)
    )
    scores = partial + deltas.reshape(Q, D)
    return scores, n_cont
