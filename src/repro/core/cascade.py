"""Sentinel-partitioned cascade execution — early exit as batch compaction.

Three execution paths with identical ranking semantics:

- :meth:`CascadeRanker.rank` — *reference* path: scores every document
  through head and tail, applies the continue mask arithmetically. Used for
  quality evaluation and as the oracle for the compacted paths. Cost is
  accounted in the paper's currency (trees traversed), not saved.
- :meth:`CascadeRanker.rank_compacted` — single-sentinel *reference
  production* path: after the sentinel, surviving documents are gathered
  into a dense prefix (O(n) cumsum stable partition) and ONLY that
  compacted block runs the tail trees through the Pallas kernel.
- :meth:`CascadeRanker.rank_progressive` — the *multi-sentinel engine* and
  the serving hot path. The WHOLE step — head scoring, stage decisions,
  cumsum compaction, tail, scatter — is built once per configuration and
  compiled into ONE end-to-end ``jax.jit`` computation (XLA is free to fuse
  compact → gather → tail → scatter); launch accounting moved to trace
  time (:func:`repro.kernels.ops._counted_pallas`), so the launch contract
  stays testable. Two execution modes share identical ranking semantics:

  * ``mode="fused"`` (default): one sentinel-segmented Pallas launch over
    the head trees yields the prefix score of every document at EVERY
    sentinel (``[Q, D, S]``); stage decisions are pure vector work (no
    kernel, no HBM round-trip between stages), exit masks are nested
    (``alive_k = alive_{k-1} ∧ continue_k`` — a document that exits never
    re-enters), and exactly ONE tail launch runs the remaining trees on
    the cumsum-compacted survivors of the last stage: 1 segmented head
    launch + ≤1 tail launch total.
  * ``mode="staged"`` (per-stage tails): segment ``k`` is scored ONLY on
    the stage-(k−1) compacted survivors — each stage's ``capacities[k]``
    entry is a REAL kernel block bound (survivors beyond it retire with
    their stage-k prefix and are charged to ``overflow``), so kernel work
    shrinks with the survivor set at the cost of one launch plus one
    gather/scatter per stage: ≤S+1 plain launches, no segmented launch.
    With S == 1 the two modes are the same computation.

  Mode trade-off: fused scores every document through the whole head
  region, trading redundant VPU work on early-exited documents for the
  elimination of S−1 launches and all intermediate gather/scatter traffic
  — it wins when survivor sets stay large (high continue rates, nothing to
  skip) or when s_S ≪ T (LEAR-scale sentinels, the redundancy is small).
  Staged wins when survivors shrink fast and the head region is deep:
  the skipped tree work dwarfs the per-stage launch overhead.

  * ``mode="auto"`` (the ON-DEVICE pick): ONE combined program contains
    both branches under a ``jax.lax.cond`` and the branch predicate is
    computed on device —
    :func:`repro.metrics.speedup.progressive_cost_model_device` prices
    both modes from a traced survivor estimate (``stage_ema``, typically
    the service's smoothed per-stage survivor counts) and the cheaper
    branch executes. No host round trip, no batch-boundary decision lag:
    the estimate that drives the pick can be updated from the previous
    batch's fused stats read and shipped back as a tiny operand at submit
    time. Both branches are staged at trace time (launch counters account
    each exactly once — see :mod:`repro.kernels.ops`); at run time exactly
    one branch's launches execute.

  :meth:`repro.serve.ranking_service.RankingService` serves ``auto`` by
  default; the host-side pick via
  :func:`repro.metrics.speedup.progressive_cost_model` remains the
  reference model (the device pick must choose the same branch — tested on
  the ``fused_vs_staged`` bench sweep). ``benchmarks/bench_kernels.py``
  records the measured crossover. The speedup metric stays in the paper's
  currency (trees *logically* traversed under early-exit semantics),
  matching :func:`metrics.speedup.trees_traversed`.

  Strategies must be *mask-invariant* (read ``partial`` only where the
  alive mask is set): in staged mode, exited documents hold stale
  prefixes, and all stock strategies already mask them out.

A static ``capacity`` bounds each compacted block so the step stays
jit-compatible; :func:`bucket_capacity` buckets requested capacities to
powers of two so the jit cache stays bounded. Survivors beyond capacity
keep their sentinel prefix score (bounded, graceful quality degradation —
never a crash), and the overflow count is a LAZY device scalar: the hot
path never blocks on it (read it in a stats path via
``int(result.overflow)``). For the same reason, ``rank_progressive``
reports ``speedup`` as a lazy device scalar too; the reference paths keep
returning host floats.

The strategy is injected as a callable ``(partial, mask, aux) → continue
mask`` so LEAR / ERT / EPT / EE_ideal all run through the same engine.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compaction import (
    COMPACTORS,
    compact_indices_cumsum,
    compact_indices_cumsum_masked,
)
from repro.core.strategies import QueryExitConfig, query_converged
from repro.forest.ensemble import TreeEnsemble, slice_trees
from repro.forest.scoring import score_bitvector
from repro.kernels.ops import (
    ENGINE_BLOCK_B,
    PaddedForest,
    forest_score,
    forest_score_range,
    forest_score_segments,
    padded_forest,
)
from repro.metrics.speedup import (
    progressive_cost_model_device,
    speedup_progressive,
    speedup_vs_full,
)


def bucket_capacity(want: int, limit: int, minimum: int = 64) -> int:
    """Power-of-two capacity bucketing (bounded jit cache), clipped to limit."""
    cap = 1 << int(np.ceil(np.log2(max(want, minimum, 1))))
    return min(cap, limit)


@dataclasses.dataclass
class CascadeResult:
    scores: jax.Array          # [Q, D] final scores (exited docs keep partial)
    continue_mask: jax.Array   # [Q, D] — survivors of the LAST stage
    speedup: float | jax.Array  # trees-traversed speedup vs Full (lazy scalar
    #                             on the progressive path; host float on the
    #                             reference paths)
    overflow: jax.Array | int = 0  # lazy device scalar; docs beyond capacity
    #   (fused: final-stage compaction only; staged: summed over all stages)
    stage_masks: list | None = None   # progressive: nested alive mask per stage
    partials: jax.Array | None = None  # progressive: [Q, D, S] — the prefix
    #   grid each stage's strategy saw (fused: exact sentinel prefixes for
    #   every doc; staged: docs already exited hold their exit-stage prefix)
    mode: str | None = None            # progressive: "fused"|"staged"|"auto"
    picked_staged: jax.Array | None = None  # mode="auto": lazy device bool —
    #   which cond branch executed (True = staged); None for fixed modes
    query_exited: jax.Array | None = None  # query_exit enabled: [Q] lazy bool
    #   — queries whose remaining docs were removed by query-level exit
    #   (converged top-k or no alive docs); None when the knob is off


@dataclasses.dataclass
class CascadeRanker:
    ensemble: TreeEnsemble
    sentinel: int
    strategy: Callable[..., jax.Array]
    classifier_trees: int = 0   # extra per-doc cost charged for the strategy
    _ht_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )
    # End-to-end jitted progressive steps, keyed by the full static config
    # (buffers, sentinels, capacities, strategies, mode, …). LRU-bounded so
    # sweeping configurations cannot pin unbounded compiled computations.
    _step_cache: OrderedDict = dataclasses.field(
        default_factory=OrderedDict, init=False, repr=False, compare=False
    )

    def _head_tail(self) -> tuple[TreeEnsemble, TreeEnsemble]:
        # Sliced sub-ensembles are cached: repeated rank*() calls reuse the
        # same TreeEnsemble objects (and therefore their padded-buffer
        # caches) instead of re-slicing per call.
        if self._ht_cache is None:
            head = slice_trees(self.ensemble, 0, self.sentinel)
            tail = slice_trees(self.ensemble, self.sentinel, self.ensemble.n_trees)
            self._ht_cache = (head, tail)
        return self._ht_cache

    def rank(
        self, X: jax.Array, mask: jax.Array, **strategy_kwargs: object
    ) -> CascadeResult:
        """Reference path: full compute, masked combine."""
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        head, tail = self._head_tail()
        partial = score_bitvector(head, flat).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        tail_scores = score_bitvector(tail, flat).reshape(Q, D)
        scores = jnp.where(cont, partial + tail_scores, partial)
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(scores=scores, continue_mask=cont, speedup=sp)

    def rank_compacted(
        self,
        X: jax.Array,
        mask: jax.Array,
        capacity: int,
        compaction: str = "cumsum",
        **strategy_kwargs: object,
    ) -> CascadeResult:
        """Single-sentinel production path: tail sees only compacted survivors."""
        Q, D, F = X.shape
        head, tail = self._head_tail()
        partial = forest_score(head, X.reshape(Q * D, F)).reshape(Q, D)
        cont = self.strategy(partial, mask, **strategy_kwargs)
        scores, n_cont = _compacted_tail(X, partial, cont, tail, capacity, compaction)
        overflow = jnp.maximum(n_cont - capacity, 0)  # lazy: no device sync
        sp = speedup_vs_full(
            cont, mask, self.sentinel, self.ensemble.n_trees, self.classifier_trees
        )
        return CascadeResult(
            scores=scores, continue_mask=cont, speedup=sp, overflow=overflow
        )

    def rank_progressive(
        self,
        X: jax.Array,
        mask: jax.Array,
        sentinels: Sequence[int],
        capacities: Sequence[int] | int | None = None,
        strategies: Sequence[Callable[..., jax.Array]] | None = None,
        *,
        classifier_trees: Sequence[int] | int | None = None,
        block_t: int = 16,
        leaf_gather: str = "auto",
        mode: str = "fused",
        stage_ema: jax.Array | None = None,
        have_ema: jax.Array | bool = True,
        launch_overhead_trees: float = 0.0,
        query_exit: QueryExitConfig | None = None,
        query_exit_rate: jax.Array | float = 0.0,
        **strategy_kwargs: object,
    ) -> CascadeResult:
        """Multi-sentinel engine, end-to-end jitted (one XLA computation).

        ``sentinels`` need not be tree-block aligned (segments are padded
        independently in the cached buffers). ``capacities`` bounds the
        compacted survivor block per stage: in ``mode="fused"`` only the
        final entry bounds a kernel block (1 segmented head + ≤1 tail
        launch); in ``mode="staged"`` every entry is a real kernel bound —
        segment ``k`` is scored only on the stage-(k−1) compacted survivors
        (≤S+1 plain launches), and survivors beyond a stage's capacity
        retire with their stage prefix and are charged to ``overflow``.
        ``None`` derives capacities from :func:`bucket_capacity`.
        ``strategies`` defaults to ``self.strategy`` at every stage;
        ``classifier_trees`` (int or per-stage sequence) defaults to
        ``self.classifier_trees`` at every stage for the cost accounting.

        ``mode="auto"`` compiles BOTH modes into one program and picks the
        branch on device with a ``lax.cond``: ``stage_ema`` (``[S]`` f32,
        required) is the traced per-stage survivor estimate priced by
        :func:`repro.metrics.speedup.progressive_cost_model_device` with
        ``launch_overhead_trees`` (static) as the per-launch price;
        ``have_ema`` (traced bool) gates the pick — ``False`` forces the
        fused branch (the safe cold-start floor when no survivor estimate
        exists yet). The executed branch is reported as the lazy
        ``picked_staged`` device bool on the result. Requires ``S ≥ 2``
        (with one sentinel the modes are the same computation).

        The step for each static configuration (sentinels × capacities ×
        strategies × mode × …) is built once, jitted, and cached on the
        ranker; keyword arguments for the strategies are split into traced
        array operands vs static (hashable) configuration. With a single
        sentinel both modes are the same computation and bit-exact with
        :meth:`rank_compacted`; ``speedup`` / ``overflow`` stay lazy device
        scalars — the hot path never syncs.

        ``query_exit`` (a :class:`repro.core.strategies.QueryExitConfig`)
        enables query-level early exit: after each stage's document
        decision, :func:`repro.core.strategies.query_converged` folds a
        per-query "top-k stabilized" predicate into the alive mask — a
        converged query's remaining documents skip every later stage and
        the tail, and the tail launch itself moves under a ``lax.cond``
        on the survivor count (counted as ``gated`` by the launch
        counters; a batch whose queries all converged dispatches no tail
        kernel). With ``margin=inf`` (the config default) the transform
        is score-preserving and results stay bit-exact with
        ``query_exit=None``. The result reports the per-query exit flags
        as the lazy ``query_exited`` device array. ``query_exit_rate``
        (traced scalar, ``mode="auto"`` only) is the tail-skip estimate
        the in-program mode pick prices launches with — ship the
        service's smoothed all-queries-exited indicator.
        """
        Q, D, F = X.shape
        sentinels = tuple(int(s) for s in sentinels)
        S = len(sentinels)
        T = self.ensemble.n_trees
        assert mode in ("fused", "staged", "auto"), mode
        assert S >= 1 and list(sentinels) == sorted(set(sentinels))
        assert 0 < sentinels[0] and sentinels[-1] <= T, (sentinels, T)
        strategies = (
            tuple(strategies) if strategies is not None else (self.strategy,) * S
        )
        assert len(strategies) == S
        if capacities is None:
            capacities = [bucket_capacity(Q * D, Q * D)] * S
        elif isinstance(capacities, int):
            capacities = [capacities] * S
        capacities = tuple(min(int(c), Q * D) for c in capacities)
        assert len(capacities) == S
        if classifier_trees is None:
            classifier_trees = self.classifier_trees
        if isinstance(classifier_trees, int):
            classifier_trees = (classifier_trees,) * S
        classifier_trees = tuple(int(c) for c in classifier_trees)

        has_tail = sentinels[-1] < T
        boundaries = sentinels + ((T,) if has_tail else ())
        # leaf_gather picks the kernel's leaf-value resolution path (select
        # tree / MXU contraction / one-hot reference — all bit-exact); the
        # buffer set carries the matching leaf layout, so a distinct path is
        # simply a distinct cached PaddedForest (and thus a distinct step).
        pf = padded_forest(
            self.ensemble, boundaries=boundaries, block_t=block_t,
            leaf_gather=leaf_gather,
        )

        # Array-valued strategy kwargs become traced operands of the jitted
        # step; everything else (ints, floats, flags) is static config and
        # part of the cache key.
        names = tuple(sorted(strategy_kwargs))
        traced_names = tuple(
            n for n in names
            if isinstance(strategy_kwargs[n], (jax.Array, np.ndarray))
        )
        static_items = tuple(
            (n, strategy_kwargs[n]) for n in names if n not in traced_names
        )

        assert query_exit is None or isinstance(query_exit, QueryExitConfig)
        if mode == "auto":
            assert S >= 2, "mode='auto' needs ≥2 sentinels (S=1: modes equal)"
            assert stage_ema is not None, "mode='auto' requires stage_ema"
            mode_ops = (
                jnp.asarray(stage_ema, jnp.float32),
                jnp.asarray(have_ema, bool),
                jnp.asarray(query_exit_rate, jnp.float32),
            )
        else:
            mode_ops = ()

        # Fused mode only ever reads capacities[-1] (the tail block); keying
        # on the full tuple would re-trace identical computations whenever
        # the service ratchets an early-stage bucket. Staged and auto read
        # every entry (auto also prices the staged branch with them).
        key_capacities = capacities if mode != "fused" else capacities[-1:]
        key = (
            id(pf), sentinels, key_capacities, strategies, classifier_trees,
            mode, float(launch_overhead_trees), query_exit, traced_names,
            static_items,
        )
        step = self._step_cache.get(key)
        if step is None:
            step = _build_progressive_step(
                pf, sentinels, capacities, strategies, classifier_trees,
                mode, traced_names, dict(static_items), T,
                launch_overhead_trees=float(launch_overhead_trees),
                query_exit=query_exit,
            )
            self._step_cache[key] = step
            while len(self._step_cache) > _STEP_CACHE_MAX:
                self._step_cache.popitem(last=False)
        else:
            self._step_cache.move_to_end(key)

        traced_vals = tuple(strategy_kwargs[n] for n in traced_names)
        (scores, alive, stage_masks, partials, overflow, sp, picked,
         q_exited) = step(X, mask, traced_vals, mode_ops)
        return CascadeResult(
            scores=scores,
            continue_mask=alive,
            speedup=sp,
            overflow=overflow,   # lazy: no device sync
            stage_masks=list(stage_masks),
            partials=partials,
            mode=mode,
            picked_staged=picked,  # lazy device bool (auto), else None
            query_exited=q_exited if query_exit is not None else None,
        )


_STEP_CACHE_MAX = 16  # compiled progressive steps kept per ranker (LRU)


def _build_progressive_step(
    pf: PaddedForest,
    sentinels: tuple[int, ...],
    capacities: tuple[int, ...],
    strategies: tuple,
    classifier_trees: tuple[int, ...],
    mode: str,
    traced_names: tuple[str, ...],
    static_kwargs: dict,
    n_trees: int,
    launch_overhead_trees: float = 0.0,
    query_exit: QueryExitConfig | None = None,
) -> Callable[..., tuple]:
    """Build the end-to-end jitted progressive step for one configuration.

    Everything static (buffers, sentinels, capacities, strategies, mode) is
    closed over; the returned callable takes ``(X, mask, traced_vals,
    mode_ops)`` — ``mode_ops`` is ``()`` for the fixed modes and
    ``(stage_ema, have_ema, query_exit_rate)`` for ``mode="auto"`` — and
    compiles head →
    decisions → compaction → tail → scatter into one XLA computation.
    Launch counters fire while THIS function's body traces (see
    :func:`repro.kernels.ops._counted_pallas`), so a compiled step
    re-executing from cache stages no new launches and moves no counters;
    under ``mode="auto"`` BOTH branch bodies trace into the one program,
    so each branch's launches are accounted exactly once even though only
    one branch executes per batch.

    Both modes accumulate prefixes with the same left-to-right association
    (``(((base + seg_0) + seg_1) + …)``), and the per-block kernel sums are
    identical, so staged scores match fused scores bit-for-bit on batches
    where no stage overflows its capacity — which is also what makes the
    ``lax.cond`` branch structures compatible (same output shapes/dtypes,
    same semantics off overflow).
    """
    S = len(sentinels)
    has_tail = sentinels[-1] < n_trees

    def final_tail(flat, scores, alive, overflow):
        # Tail launch on the compacted survivors of the last stage. In
        # fused mode only this compaction can drop tail scores, so only it
        # counts as overflow; staged mode accumulated per-stage overflow
        # before reaching here. With query-level exit enabled the launch
        # moves under a lax.cond on the survivor count (counted "gated"):
        # a batch whose queries all converged dispatches no tail kernel.
        if not has_tail:
            return scores, overflow
        cap = capacities[-1]
        sel, n_cont = compact_indices_cumsum(alive.reshape(-1), cap)
        if query_exit is None:
            x_sel = jnp.take(flat, sel, axis=0)
            tail_sel = forest_score_range(pf, x_sel, seg_lo=S)
            scores = _scatter_tail(scores, sel, tail_sel, n_cont)
        else:
            def run_tail(s):
                x_sel = jnp.take(flat, sel, axis=0)
                tail_sel = forest_score_range(
                    pf, x_sel, seg_lo=S, count_as="gated"
                )
                return _scatter_tail(s, sel, tail_sel, n_cont)

            scores = jax.lax.cond(
                n_cont > 0, run_tail, lambda s: s, scores
            )
        overflow = overflow + jnp.maximum(n_cont - cap, 0)
        return scores, overflow

    def apply_query_exit(stage_idx: int, prefix, alive, exited):
        # Fold the per-query convergence predicate into the alive mask:
        # once a query converges, none of its documents may re-enter
        # (exit flags accumulate like the nested per-doc stage masks).
        if query_exit is None or stage_idx < query_exit.from_stage:
            return alive, exited
        conv = query_converged(
            prefix, alive, k=query_exit.k, margin=query_exit.margin
        )
        exited = exited | conv
        return alive & ~exited[:, None], exited

    def fused_body(flat, mask, skw):
        # One launch over the head trees: prefix score of every document
        # at every sentinel. A single segment needs no segmented
        # accumulator — it degenerates to the plain kernel (same launch
        # count, less work).
        Q, D = mask.shape
        alive = mask
        exited = jnp.zeros((Q,), bool)
        stage_masks = []
        if S == 1:
            prefixes = [forest_score_range(pf, flat, 0, 1).reshape(Q, D)]
        else:
            seg = forest_score_segments(pf, flat, n_segments=S)
            seg = seg.reshape(Q, D, S)
            acc = seg[..., 0] + pf.base_score
            prefixes = [acc]
            for k in range(1, S):
                acc = acc + seg[..., k]
                prefixes.append(acc)

        # Stage decisions: pure vector work, nested exit masks.
        scores = prefixes[0]
        for k in range(S):
            cont = strategies[k](prefixes[k], alive, **skw)
            alive = alive & cont
            alive, exited = apply_query_exit(k, prefixes[k], alive, exited)
            stage_masks.append(alive)
            if k + 1 < S:
                scores = jnp.where(alive, prefixes[k + 1], scores)
        scores, overflow = final_tail(flat, scores, alive, jnp.int32(0))
        return (
            scores, alive, tuple(stage_masks),
            jnp.stack(prefixes, axis=-1), overflow, exited,
        )

    def staged_body(flat, mask, skw):
        # Per-stage tails: segment k runs only on the compacted survivors
        # of stage k-1; every capacity is a real kernel bound with real
        # overflow accounting.
        Q, D = mask.shape
        alive = mask
        exited = jnp.zeros((Q,), bool)
        stage_masks = []
        overflow = jnp.int32(0)
        prefix = forest_score_range(pf, flat, 0, 1).reshape(Q, D)
        prefixes = [prefix]
        for k in range(S):
            cont = strategies[k](prefix, alive, **skw)
            alive = alive & cont
            alive, exited = apply_query_exit(k, prefix, alive, exited)
            if k + 1 < S:
                cap = capacities[k]
                sel, n_cont, within = compact_indices_cumsum_masked(
                    alive.reshape(Q * D), cap
                )
                overflow = overflow + jnp.maximum(n_cont - cap, 0)
                alive = alive & within.reshape(Q, D)
                x_sel = jnp.take(flat, sel, axis=0)
                seg_sel = forest_score_range(pf, x_sel, k + 1, k + 2)
                prefix = jnp.where(
                    alive,
                    _scatter_tail(prefix, sel, seg_sel, n_cont),
                    prefix,
                )
                prefixes.append(prefix)
            stage_masks.append(alive)
        scores, overflow = final_tail(flat, prefix, alive, overflow)
        return (
            scores, alive, tuple(stage_masks),
            jnp.stack(prefixes, axis=-1), overflow, exited,
        )

    @jax.jit
    def step(X, mask, traced_vals, mode_ops):
        Q, D, F = X.shape
        flat = X.reshape(Q * D, F)
        skw = {**dict(zip(traced_names, traced_vals)), **static_kwargs}

        if mode == "fused":
            out = fused_body(flat, mask, skw)
            picked = None
        elif mode == "staged":
            out = staged_body(flat, mask, skw)
            picked = None
        else:
            # On-device mode pick: price both modes from the traced
            # survivor estimate and run the cheaper branch. Both bodies
            # trace here (cond stages both); one executes per batch.
            stage_ema, have_ema, qe_rate = mode_ops
            fused_cost, staged_cost = progressive_cost_model_device(
                Q * D, stage_ema, sentinels, n_trees,
                launch_overhead_trees=launch_overhead_trees,
                stage_capacities=capacities,
                block_b=ENGINE_BLOCK_B,
                query_exit_rate=qe_rate,
            )
            picked = jnp.logical_and(have_ema, staged_cost < fused_cost)
            out = jax.lax.cond(
                picked,
                lambda: staged_body(flat, mask, skw),
                lambda: fused_body(flat, mask, skw),
            )
        scores, alive, stage_masks, partials, overflow, exited = out
        sp = speedup_progressive(
            mask, list(stage_masks), sentinels, n_trees,
            list(classifier_trees),
        )
        return (
            scores, alive, stage_masks, partials, overflow, sp, picked,
            exited,
        )

    return step


def _compacted_tail(
    X: jax.Array,
    partial: jax.Array,
    cont: jax.Array,
    tail: TreeEnsemble,
    capacity: int,
    compaction: str = "cumsum",
) -> tuple[jax.Array, jax.Array]:
    """Gather survivors → dense block of ``capacity`` → tail kernel → scatter.

    Kept at the Python level (jitted pieces around one counted kernel call)
    so launch accounting stays truthful.
    """
    Q, D, F = X.shape
    sel, n_cont = COMPACTORS[compaction](cont.reshape(Q * D), capacity)
    x_sel = jnp.take(X.reshape(Q * D, F), sel, axis=0)         # [C, F]
    tail_sel = forest_score(tail, x_sel)                       # [C]
    return _scatter_tail(partial, sel, tail_sel, n_cont), n_cont


@jax.jit
def _scatter_tail(
    scores: jax.Array, sel: jax.Array, tail_sel: jax.Array, n_cont: jax.Array
) -> jax.Array:
    """Scatter valid compacted tail scores back onto the [Q, D] grid."""
    Q, D = scores.shape
    valid = jnp.arange(sel.shape[0]) < n_cont
    deltas = jnp.zeros((Q * D,), jnp.float32).at[sel].add(
        jnp.where(valid, tail_sel, 0.0)
    )
    return scores + deltas.reshape(Q, D)
