"""Survivor compaction: gather continuing documents into a dense prefix.

Both implementations compute the same stable partition — the indices of the
``True`` entries of a flat continue mask, in ascending index order, written
into a fixed-size ``[capacity]`` selection buffer (jit-stable shape):

- :func:`compact_indices_cumsum` — production path. ``cumsum(cont) - 1``
  gives each survivor its output slot directly; one scatter (``mode="drop"``
  discards slots ≥ capacity) finishes the job. O(n) work, O(log n) depth.
- :func:`compact_indices_argsort` — the original stable-argsort partition,
  O(n log n). Kept as the test oracle for the cumsum path.

Selection slots beyond ``min(n_cont, capacity)`` are unspecified padding
(the cumsum path leaves index 0, the argsort path leaves exited indices);
callers MUST mask per-slot results with ``slot < n_cont`` before scattering
back. ``n_cont`` is returned as a lazy device scalar — no host sync.

Accounting note: these are pure XLA ops (no Pallas dispatch), so they move
no launch counters and are free to appear any number of times inside the
compiled progressive step — including once per ``lax.cond`` branch of the
``mode="auto"`` step. ``capacity`` is a static (trace-time) argument; the
partition itself is run-time vector work.
"""

from __future__ import annotations

from functools import partial as _partial

import jax
import jax.numpy as jnp


def _cumsum_partition(
    cont: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared body: ``(sel, n_cont, within)``; ``within`` is dead-code
    eliminated by XLA for the caller that drops it."""
    cont = cont.reshape(-1)
    n = cont.shape[0]
    pos = jnp.cumsum(cont.astype(jnp.int32)) - 1   # survivor → output slot
    n_cont = pos[-1] + 1 if n else jnp.int32(0)
    slot = jnp.where(cont, pos, capacity)          # exited / overflow → dropped
    sel = (
        jnp.zeros((capacity,), jnp.int32)
        .at[slot]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    )
    within = cont & (pos < capacity)
    return sel, n_cont, within


@_partial(jax.jit, static_argnames=("capacity",))
def compact_indices_cumsum(
    cont: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """O(n) stable partition. ``cont: [n] bool`` → ``(sel [capacity] i32,
    n_cont [] i32)``."""
    sel, n_cont, _ = _cumsum_partition(cont, capacity)
    return sel, n_cont


@_partial(jax.jit, static_argnames=("capacity",))
def compact_indices_cumsum_masked(
    cont: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`compact_indices_cumsum` plus the per-input *within-capacity*
    mask: ``within[i]`` ⇔ ``cont[i]`` and survivor ``i`` was assigned a
    selection slot ``< capacity``. The per-stage-tail cascade mode uses it
    to retire survivors that overflowed a stage's capacity bound (they keep
    their stage prefix; later stages never see them)."""
    return _cumsum_partition(cont, capacity)


@_partial(jax.jit, static_argnames=("capacity",))
def compact_indices_argsort(
    cont: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """O(n log n) reference: stable argsort puts survivors first."""
    cont = cont.reshape(-1)
    order = jnp.argsort(~cont, stable=True)
    return order[:capacity].astype(jnp.int32), cont.sum(dtype=jnp.int32)


COMPACTORS = {
    "cumsum": compact_indices_cumsum,
    "argsort": compact_indices_argsort,
}
