"""Device-resident LEAR feature pipeline: jittable sentinel-time features.

LEAR's exit decision reads four *augmented* features per document — the
partial score at the sentinel, its rank within the query, the per-query
min–max-normalized partial, and the query's candidate count (paper §2;
the query-level view of the same statistics drives the query-adaptive
exits of Lucchese et al. 2020). The serving engine evaluates the LEAR
strategy INSIDE the compiled progressive step
(:func:`repro.core.cascade._build_progressive_step`), so everything here
must trace cleanly and fuse with the segmented head launch — no host
round trip between the head kernel and the classifier forest.

Design notes:

- :func:`query_ranks` is **sort-free**: rank(i) = the number of documents
  that beat ``i`` (strictly higher score, or equal score at a lower index —
  the same deterministic tie-break as the stable-argsort ranking in
  :func:`repro.metrics.ranking.rank_from_scores`, with which it agrees
  exactly). The pairwise compare is O(D²) per query but branch-free,
  segment-local, and VPU-shaped — on an accelerator it fuses into the
  surrounding step, whereas the double argsort lowers to two sorts that
  XLA cannot fuse across. Serving blocks keep D in the tens-to-hundreds,
  where the quadratic compare is cheap; the metrics stack keeps the
  argsort path (NDCG needs the sort anyway).
- :func:`query_minmax` / :func:`normalized_partial` are plain per-query
  segment reductions (min/max over the document axis with the request
  mask applied) and an elementwise normalization.
- :func:`augment_features` is the full build; it is what
  :func:`repro.core.lear.augment_features` (training and serving both)
  delegates to, so train-time and serve-time features are computed by the
  same traced code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

N_AUG = 4   # sentinel-time features appended to the q-d vector
NEG = -1e30  # masked-document fill; ranks padding after every real doc


def query_ranks(partial: jax.Array, mask: jax.Array) -> jax.Array:
    """Sort-free per-query rank (0 = best) of each document — ``[Q, D] i32``.

    ``rank(i) = #{j : s_j > s_i  or  (s_j == s_i and j < i)}`` with masked
    documents held at ``NEG`` so they rank after all real documents.
    Identical output to the stable-argsort ranking
    (:func:`repro.metrics.ranking.rank_from_scores`); exact, because only
    integer counts of exact float comparisons are involved.
    """
    s = jnp.where(mask, partial, NEG)
    D = s.shape[-1]
    idx = jnp.arange(D, dtype=jnp.int32)
    s_i = s[..., :, None]      # the ranked document
    s_j = s[..., None, :]      # its competitors
    beats = (s_j > s_i) | ((s_j == s_i) & (idx[None, :] < idx[:, None]))
    return beats.sum(axis=-1, dtype=jnp.int32)


def query_minmax(partial: jax.Array, mask: jax.Array):
    """Per-query (segment) min/max of the partial score — ``([Q,1],[Q,1])``.

    Masked documents are excluded via ±inf fill; an all-masked query yields
    ``lo > hi`` which :func:`normalized_partial` maps to 0.
    """
    lo = jnp.where(mask, partial, jnp.inf).min(axis=-1, keepdims=True)
    hi = jnp.where(mask, partial, -jnp.inf).max(axis=-1, keepdims=True)
    return lo, hi


def normalized_partial(partial: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Min–max normalization of the partial score, clipped to [0, 1]."""
    norm = (partial - lo) / jnp.maximum(hi - lo, 1e-9)
    return jnp.clip(norm, 0.0, 1.0)


def augment_features(
    X: jax.Array,         # [Q, D, F]
    partial: jax.Array,   # [Q, D]
    mask: jax.Array,      # [Q, D]
) -> jax.Array:
    """Append the four sentinel-time features → ``[Q, D, F + 4]``.

    Fully jittable: inside the compiled progressive step this is pure
    vector work between the segmented head launch and the classifier
    forest launch — the feature build never leaves the device.
    """
    ranks = query_ranks(partial, mask).astype(jnp.float32)
    lo, hi = query_minmax(partial, mask)
    norm = normalized_partial(partial, lo, hi)
    n_cand = mask.sum(axis=-1, keepdims=True).astype(jnp.float32)
    aug = jnp.stack(
        [
            partial,
            ranks,
            norm,
            jnp.broadcast_to(n_cand, partial.shape),
        ],
        axis=-1,
    )
    aug = jnp.where(mask[..., None], aug, 0.0)
    return jnp.concatenate([X, aug], axis=-1)
