"""Device-resident LEAR feature pipeline: jittable sentinel-time features.

LEAR's exit decision reads four *augmented* features per document — the
partial score at the sentinel, its rank within the query, the per-query
min–max-normalized partial, and the query's candidate count (paper §2;
the query-level view of the same statistics drives the query-adaptive
exits of Lucchese et al. 2020). The serving engine evaluates the LEAR
strategy INSIDE the compiled progressive step
(:func:`repro.core.cascade._build_progressive_step`), so everything here
must trace cleanly and fuse with the segmented head launch — no host
round trip between the head kernel and the classifier forest.

Design notes:

- :func:`query_ranks` is **sort-free**: rank(i) = the number of documents
  that beat ``i`` (strictly higher score, or equal score at a lower index —
  the same deterministic tie-break as the stable-argsort ranking in
  :func:`repro.metrics.ranking.rank_from_scores`, with which it agrees
  exactly). The pairwise compare is O(D²) per query but branch-free,
  segment-local, and VPU-shaped — on an accelerator it fuses into the
  surrounding step, whereas the double argsort lowers to two sorts that
  XLA cannot fuse across. The metrics stack keeps the argsort path (NDCG
  needs the sort anyway).
- Two executions of the same count exist: the **direct** compare
  materializes the full ``[Q, D, D]`` predicate (cheap in the
  tens-to-hundreds of candidates the serving blocks target), and the
  **blocked** compare (:func:`query_ranks_blocked`) tiles the D×D grid
  into ``[RANK_BLOCK_D, RANK_BLOCK_D]`` chunks under ``lax.fori_loop`` —
  the working set stops growing quadratically, which is what lets the
  device-resident feature build scale past a few hundred candidates per
  query. The comparisons (and therefore the exact tie semantics) are
  identical, so the two are bit-exact; :func:`query_ranks` auto-selects
  blocked above ``RANK_BLOCKED_MIN_D``.
- :func:`query_minmax` / :func:`normalized_partial` are plain per-query
  segment reductions (min/max over the document axis with the request
  mask applied) and an elementwise normalization.
- :func:`augment_features` is the full build; it is what
  :func:`repro.core.lear.augment_features` (training and serving both)
  delegates to, so train-time and serve-time features are computed by the
  same traced code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops import env_int

N_AUG = 4   # sentinel-time features appended to the q-d vector
NEG = -1e30  # masked-document fill; ranks padding after every real doc

RANK_BLOCK_D = 128       # tile edge of the blocked pairwise-count compare
# Auto policy: direct up to this many candidates, blocked above. Set by
# the MEMORY cliff (above ~2 tiles the [Q, D, D] predicate stops fitting
# the working set the surrounding step fuses over), deliberately NOT by
# the CPU bench's wall-time crossover — interpret-mode timings measure
# XLA:CPU loop emission, not lowering on the target accelerator, and the
# sweep is noisy at small D (BENCH_kernels.json → blocked_rank records a
# crossover near D≈128 with non-monotonic ratios). Below the cutoff the
# direct form stays a single fusable elementwise+reduce, which is worth
# more inside the compiled progressive step than a small tiled win.
# Env-overridable (deployments with a measured on-target crossover).
RANK_BLOCKED_MIN_D = env_int("REPRO_RANK_BLOCKED_MIN_D", 256)


def query_ranks(
    partial: jax.Array, mask: jax.Array, *, method: str = "auto"
) -> jax.Array:
    """Sort-free per-query rank (0 = best) of each document — ``[Q, D] i32``.

    ``rank(i) = #{j : s_j > s_i  or  (s_j == s_i and j < i)}`` with masked
    documents held at ``NEG`` so they rank after all real documents.
    Identical output to the stable-argsort ranking
    (:func:`repro.metrics.ranking.rank_from_scores`); exact, because only
    integer counts of exact float comparisons are involved.

    ``method``: ``"direct"`` materializes the full pairwise predicate,
    ``"blocked"`` tiles it (:func:`query_ranks_blocked`), ``"auto"`` (the
    default) picks blocked above :data:`RANK_BLOCKED_MIN_D` candidates.
    The counted pairs are identical either way — the choice is a pure
    memory/perf knob, never a semantics knob.
    """
    if method == "auto":
        method = "blocked" if partial.shape[-1] > RANK_BLOCKED_MIN_D else "direct"
    if method == "blocked":
        return query_ranks_blocked(partial, mask)
    assert method == "direct", method
    return query_ranks_direct(partial, mask)


def query_ranks_direct(partial: jax.Array, mask: jax.Array) -> jax.Array:
    """One-shot pairwise count: materializes the ``[Q, D, D]`` predicate."""
    s = jnp.where(mask, partial, NEG)
    D = s.shape[-1]
    idx = jnp.arange(D, dtype=jnp.int32)
    s_i = s[..., :, None]      # the ranked document
    s_j = s[..., None, :]      # its competitors
    beats = (s_j > s_i) | ((s_j == s_i) & (idx[None, :] < idx[:, None]))
    return beats.sum(axis=-1, dtype=jnp.int32)


def query_ranks_blocked(
    partial: jax.Array, mask: jax.Array, block_d: int = RANK_BLOCK_D
) -> jax.Array:
    """Blocked pairwise count: same ranks as :func:`query_ranks_direct`,
    D×D compare tiled into ``[block_d, block_d]`` chunks.

    A ``lax.fori_loop`` over row tiles × a ``lax.fori_loop`` over column
    tiles accumulates each row tile's beat count; the widest live tensor
    is ``[Q, block_d, block_d]`` instead of ``[Q, D, D]``, capping the
    quadratic memory term of the device-resident feature build. The score
    axis is padded to a tile multiple with ``-inf``: a padding column
    never beats a real row (strictly below every real score incl. the
    ``NEG`` masked fill, and its tie-break index is above every real
    index), and padding rows are sliced off. Comparisons are the exact
    same float predicates as the direct path — bit-identical counts, tie
    semantics included.
    """
    s = jnp.where(mask, partial, NEG)
    D = s.shape[-1]
    lead = s.shape[:-1]
    s2 = s.reshape((-1, D))
    Q = s2.shape[0]
    n_blocks = -(-D // block_d)
    D_pad = n_blocks * block_d
    if D_pad != D:
        s2 = jnp.pad(s2, ((0, 0), (0, D_pad - D)), constant_values=-jnp.inf)
    tile = jnp.arange(block_d, dtype=jnp.int32)

    def count_cols(bj, carry):
        cnt, rows, ridx = carry
        cols = jax.lax.dynamic_slice_in_dim(s2, bj * block_d, block_d, axis=1)
        cidx = bj * block_d + tile
        beats = (cols[:, None, :] > rows[:, :, None]) | (
            (cols[:, None, :] == rows[:, :, None])
            & (cidx[None, None, :] < ridx[None, :, None])
        )
        return cnt + beats.sum(axis=-1, dtype=jnp.int32), rows, ridx

    def count_rows(bi, out):
        rows = jax.lax.dynamic_slice_in_dim(s2, bi * block_d, block_d, axis=1)
        ridx = bi * block_d + tile
        cnt = jnp.zeros((Q, block_d), jnp.int32)
        cnt, _, _ = jax.lax.fori_loop(
            0, n_blocks, count_cols, (cnt, rows, ridx)
        )
        return jax.lax.dynamic_update_slice_in_dim(out, cnt, bi * block_d, axis=1)

    out = jax.lax.fori_loop(
        0, n_blocks, count_rows, jnp.zeros((Q, D_pad), jnp.int32)
    )
    return out[:, :D].reshape(*lead, D)


def query_minmax(
    partial: jax.Array, mask: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-query (segment) min/max of the partial score — ``([Q,1],[Q,1])``.

    Masked documents are excluded via ±inf fill; an all-masked query yields
    ``lo > hi`` which :func:`normalized_partial` maps to 0.
    """
    lo = jnp.where(mask, partial, jnp.inf).min(axis=-1, keepdims=True)
    hi = jnp.where(mask, partial, -jnp.inf).max(axis=-1, keepdims=True)
    return lo, hi


def normalized_partial(partial: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Min–max normalization of the partial score, clipped to [0, 1]."""
    norm = (partial - lo) / jnp.maximum(hi - lo, 1e-9)
    return jnp.clip(norm, 0.0, 1.0)


def augment_features(
    X: jax.Array,         # [Q, D, F]
    partial: jax.Array,   # [Q, D]
    mask: jax.Array,      # [Q, D]
) -> jax.Array:
    """Append the four sentinel-time features → ``[Q, D, F + 4]``.

    Fully jittable: inside the compiled progressive step this is pure
    vector work between the segmented head launch and the classifier
    forest launch — the feature build never leaves the device.
    """
    ranks = query_ranks(partial, mask).astype(jnp.float32)
    lo, hi = query_minmax(partial, mask)
    norm = normalized_partial(partial, lo, hi)
    n_cand = mask.sum(axis=-1, keepdims=True).astype(jnp.float32)
    aug = jnp.stack(
        [
            partial,
            ranks,
            norm,
            jnp.broadcast_to(n_cand, partial.shape),
        ],
        axis=-1,
    )
    aug = jnp.where(mask[..., None], aug, 0.0)
    return jnp.concatenate([X, aug], axis=-1)
