"""LEAR: the learned early-exit classifier (the paper's §2 contribution).

Pipeline (faithful to the paper):

1. Score the classifier-training split through the FULL λ-MART ensemble and
   through the first ``s`` trees (sentinel partials).
2. **Labels** — ``Continue`` = documents that are relevant (label > 0) AND
   ranked in the full ensemble's top-``k`` (k = 15); everything else is
   ``Exit``.
3. **Augmented representation** — the original query-document features plus
   four sentinel-time signals: partial score, rank at the sentinel,
   per-query min–max-normalized partial score, and the query's candidate
   count. Built by the device-resident ops in :mod:`repro.core.features`
   (sort-free ranking, segment reductions) shared by training and the
   compiled serving step.
4. **Cost-sensitive weights** — ``w_d = 2^{r_d} / f_q(l_d)`` with ``f_q``
   the per-query frequency of the document's Continue/Exit label.
5. **Classifier** — a small 10-tree GBDT minimizing weighted logistic loss
   (same trainer family as the ranker, mirroring LightGBM-on-LightGBM).
6. At serving time, ``Continue`` ⇔ P(Continue) ≥ confidence threshold; the
   threshold sweeps the efficiency/effectiveness trade-off (paper Fig. 2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import N_AUG, augment_features
from repro.forest.ensemble import TreeEnsemble
from repro.forest.gbdt import GBDTParams, train_gbdt
from repro.forest.scoring import score_bitvector
from repro.kernels.ops import forest_score
from repro.metrics.ranking import rank_from_scores

__all__ = [
    "N_AUG",
    "augment_features",
    "build_continue_labels",
    "instance_weights",
    "LearClassifier",
    "train_lear",
]

# The augmented-feature build (sort-free per-query rank, min/max segment
# reductions, score normalization, candidate count) lives in
# :mod:`repro.core.features` as jittable device ops — the serving cascade
# traces it INTO the compiled progressive step, and training reuses the
# exact same code so the classifier never sees a train/serve feature skew.
# ``augment_features`` is re-exported here for back-compat.


def build_continue_labels(
    full_scores: jax.Array,  # [Q, D] scores of the complete ensemble
    rel_labels: jax.Array,   # [Q, D] graded relevance
    mask: jax.Array,
    k: int = 15,
) -> jax.Array:
    """Continue = relevant AND in the full ensemble's top-k (paper §2)."""
    final_rank = rank_from_scores(full_scores, mask)
    return mask & (rel_labels > 0) & (final_rank < k)


def instance_weights(
    continue_labels: jax.Array,  # [Q, D] bool
    rel_labels: jax.Array,       # [Q, D]
    mask: jax.Array,
) -> jax.Array:
    """w_d = 2^{r_d} / f_q(l_d); f_q = per-query frequency of d's class."""
    n = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(jnp.float32)
    n_cont = (continue_labels & mask).sum(axis=-1, keepdims=True).astype(jnp.float32)
    f_cont = jnp.maximum(n_cont, 1.0) / n
    f_exit = jnp.maximum(n - n_cont, 1.0) / n
    f = jnp.where(continue_labels, f_cont, f_exit)
    w = jnp.exp2(rel_labels.astype(jnp.float32)) / f
    return jnp.where(mask, w, 0.0)


@dataclasses.dataclass
class LearClassifier:
    """The trained Continue/Exit forest + its sentinel."""

    forest: TreeEnsemble
    sentinel: int

    @property
    def n_trees(self) -> int:
        return self.forest.n_trees

    def prob_continue(self, X_aug: jax.Array, use_kernel: bool = False) -> jax.Array:
        """P(Continue) for augmented features [Q, D, F+4] → [Q, D].

        ``use_kernel=True`` scores the classifier forest through the same
        Pallas path as the ranker (``kernels.ops.forest_score``), so the
        serving cascade runs all its forests through one kernel; the default
        pure-XLA bitvector path is kept for training/eval loops.
        """
        Q, D, F = X_aug.shape
        flat = X_aug.reshape(Q * D, F)
        if use_kernel:
            logits = forest_score(self.forest, flat)
        else:
            logits = score_bitvector(self.forest, flat)
        return jax.nn.sigmoid(logits).reshape(Q, D)

    def continue_mask(
        self,
        X_aug: jax.Array,
        mask: jax.Array,
        threshold: float,
        use_kernel: bool = False,
    ) -> jax.Array:
        """Continue ⇔ P(Continue) ≥ threshold. Higher = more aggressive EE."""
        return mask & (self.prob_continue(X_aug, use_kernel=use_kernel) >= threshold)


def train_lear(
    X: np.ndarray,            # [Q, D, F] classifier-train split
    rel_labels: np.ndarray,   # [Q, D]
    mask: np.ndarray,         # [Q, D]
    ranker: TreeEnsemble,
    sentinel: int,
    k: int = 15,
    params: GBDTParams | None = None,
) -> LearClassifier:
    """Train the LEAR classifier against a frozen λ-MART ranker."""
    # Depth-5 / lr-0.2 selected on the tune split (the paper fine-tunes the
    # classifier with HyperOpt): the shallower forest is better calibrated
    # on the minority Continue class at low thresholds.
    params = params or GBDTParams(
        n_trees=10, depth=5, learning_rate=0.2, reg_lambda=1.0
    )
    Q, D, F = X.shape
    flat = jnp.asarray(X.reshape(Q * D, F))
    _, per_tree = score_bitvector(ranker, flat, return_per_tree=True)
    partial = (
        per_tree[:, :sentinel].sum(axis=1) + ranker.base_score
    ).reshape(Q, D)
    full = (per_tree.sum(axis=1) + ranker.base_score).reshape(Q, D)

    mask_j = jnp.asarray(mask)
    rel_j = jnp.asarray(rel_labels)
    cont = build_continue_labels(full, rel_j, mask_j, k=k)
    w = instance_weights(cont, rel_j, mask_j)
    X_aug = augment_features(jnp.asarray(X), partial, mask_j)

    Fa = F + N_AUG
    forest = train_gbdt(
        np.asarray(X_aug).reshape(Q * D, Fa),
        np.asarray(cont).reshape(-1).astype(np.float32),
        params,
        objective="logistic",
        weights=np.asarray(w).reshape(-1),
    )
    return LearClassifier(forest=forest, sentinel=sentinel)
