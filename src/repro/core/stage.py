"""Cascade stages as first-class values: the CascadeStage/EngineConfig API.

The progressive engine used to describe a cascade as parallel keyword
sequences (``sentinels=…, strategies=…, classifier_trees=…``) threaded
through :meth:`repro.core.cascade.CascadeRanker.rank_progressive` — which
hard-wired "a stage is a tree prefix". This module makes the stage itself
the unit of configuration:

- :class:`TreeStage` — today's sentinel-segmented Pallas tree prefix,
  unchanged numerics: *scorer* = the shared segmented forest kernel up to
  ``sentinel``, *exit policy* = any strategy callable (``None`` defers to
  the ranker's default, e.g. the wrapped LEAR classifier),
  *capacity* = the compacted survivor bound.
- :class:`DenseStage` — a genuinely different scorer type: a small
  distilled dense model (one MXU matmul over the whole ``[Q·D, F]`` block,
  see :mod:`repro.models.dense_scorer`) whose policy prunes the easy
  majority before any tree is touched. Allowed only as stage 0; the tree
  stages then run on the dense-compacted survivor block.
- :class:`EngineConfig` — the frozen, hashable bundle of the stage list
  plus the engine knobs (``mode``, ``leaf_gather``, ``block_t``,
  ``capacities``, ``launch_overhead_trees``, ``query_exit``). It doubles
  as the jit-step LRU cache key: equal configs (same stage structure,
  same callables by identity) reuse the same compiled step.

Hashing contract: every stage dataclass is frozen and hashes structurally
over its fields; callable fields (strategies, scorers, policies) hash by
identity, so reusing the same callable object across calls is what keeps
the step cache hot — exactly the discipline the kwargs API already
required for ``strategies``.
"""

from __future__ import annotations

import dataclasses
import typing
from collections.abc import Callable, Sequence

import jax

from repro.core.strategies import QueryExitConfig
from repro.models.dense_scorer import DENSE_COST_TREES

#: Exit-policy signature shared by every stage: ``(partial_scores [Q, D],
#: alive [Q, D], **strategy_kwargs) -> continue mask [Q, D]``. Policies
#: must be pure, jittable, and mask-invariant (read ``partial`` only where
#: ``alive`` is set).
Strategy = Callable[..., jax.Array]

#: Dense scorer signature: ``[B, F] float32 -> [B]`` scores, pure and
#: jittable (parameters are closed over and traced as constants).
DenseScorer = Callable[[jax.Array], jax.Array]

MODES = ("fused", "staged", "auto")


@typing.runtime_checkable
class CascadeStage(typing.Protocol):
    """One stage of the progressive cascade: scorer + exit policy + capacity.

    A stage scores the documents it is given, applies its exit policy to
    decide which survive, and bounds the compacted survivor block handed
    to the next stage with ``capacity`` (``None`` defers to
    :class:`EngineConfig` / the engine's bucket default). ``stage_cost_trees``
    is the per-document accounting charge of running the stage's *policy
    or scorer* in the paper's currency (doc·tree traversals) — LEAR's
    10-tree classifier forest for a :class:`TreeStage`, the MXU-discounted
    matmul FLOPs for a :class:`DenseStage`.
    """

    capacity: int | None

    @property
    def stage_cost_trees(self) -> float:
        """Per-document accounting charge, in tree-traversal equivalents."""
        ...


@dataclasses.dataclass(frozen=True)
class TreeStage:
    """A sentinel-segmented tree-prefix stage (today's cascade stage).

    ``sentinel`` is the tree index the stage scores up to; ``strategy``
    (``None`` → the ranker's default strategy) decides which documents
    continue; ``classifier_trees`` is the per-document accounting cost of
    that decision (``None`` → the ranker's default). ``capacity`` bounds
    this stage's compacted survivor block (``None`` → the config-level
    ``capacities`` entry, else the engine's bucket default).
    """

    sentinel: int
    strategy: Strategy | None = None
    capacity: int | None = None
    classifier_trees: float | None = None

    def __post_init__(self) -> None:
        assert self.sentinel > 0, self.sentinel
        assert self.capacity is None or self.capacity > 0, self.capacity

    @property
    def stage_cost_trees(self) -> float:
        return float(self.classifier_trees or 0.0)


@dataclasses.dataclass(frozen=True)
class DenseStage:
    """A dense (non-tree) scorer stage — stage 0 of the hybrid cascade.

    ``scorer`` maps the flat ``[B, F]`` feature block to ``[B]`` scores in
    one shot (one MXU matmul for the distilled MLP of
    :mod:`repro.models.dense_scorer`); ``policy`` is the stage's exit
    policy over the resulting ``[Q, D]`` score grid (e.g.
    :func:`repro.core.strategies.dense_keep_fraction`). Unlike tree
    strategies, the policy is called as ``policy(scores, mask)`` with NO
    engine strategy kwargs — close knobs over it
    (``functools.partial(dense_keep_fraction, keep_frac=0.3)``) and keep
    ONE closure per configuration so the step cache stays hot. Documents
    the policy exits keep the dense score as their final score — the
    distilled model stands in for the ensemble on the easy majority.

    ``cost_trees`` prices one dense evaluation in doc·tree equivalents
    for the accounting and the mode-pick cost models (see
    ``REPRO_DENSE_COST_TREES`` in :mod:`repro.models.dense_scorer`: the
    matmul runs on the MXU, so it is charged far below its raw FLOP
    parity with the VPU tree kernel). ``capacity`` bounds the compacted
    survivor block the tree stages run on — in the hybrid engine it is a
    REAL kernel block bound in both execution modes.
    """

    scorer: DenseScorer
    policy: Strategy
    capacity: int | None = None
    cost_trees: float = float(DENSE_COST_TREES)

    def __post_init__(self) -> None:
        assert self.capacity is None or self.capacity > 0, self.capacity
        assert self.cost_trees >= 0.0, self.cost_trees

    @property
    def stage_cost_trees(self) -> float:
        return float(self.cost_trees)


def _as_capacities(
    capacities: Sequence[int] | int | None,
) -> tuple[int, ...] | int | None:
    if capacities is None or isinstance(capacities, int):
        return capacities
    return tuple(int(c) for c in capacities)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen, hashable configuration of one progressive-engine step.

    Collapses ``rank_progressive``'s former keyword sprawl into one value
    that (a) fully describes the computation and (b) doubles as the
    jit-step LRU cache key. ``stages`` is the ordered stage list — at
    most one :class:`DenseStage`, and only at position 0; every other
    entry a :class:`TreeStage` with strictly increasing sentinels.

    ``capacities`` (optional) is the config-level survivor-capacity
    override: an int broadcasts to every stage, a sequence must have one
    entry per stage (dense stage included). A stage's own ``capacity``
    field wins over the config entry; ``None`` everywhere derives the
    bound from :func:`repro.core.cascade.bucket_capacity`. The remaining
    fields are the engine knobs with their historical defaults.

    Traced per-call operands (``stage_ema``, ``have_ema``,
    ``query_exit_rate``, strategy kwargs) deliberately stay OUT of the
    config: they vary per batch without re-tracing.
    """

    stages: tuple[CascadeStage, ...]
    mode: str = "fused"
    leaf_gather: str = "auto"
    block_t: int = 16
    capacities: tuple[int, ...] | int | None = None
    launch_overhead_trees: float = 0.0
    query_exit: QueryExitConfig | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "stages", tuple(self.stages))
        object.__setattr__(
            self, "capacities", _as_capacities(self.capacities)
        )
        object.__setattr__(
            self, "launch_overhead_trees", float(self.launch_overhead_trees)
        )
        assert self.mode in MODES, self.mode
        assert len(self.stages) >= 1, "EngineConfig needs at least one stage"
        for i, st in enumerate(self.stages):
            if isinstance(st, DenseStage):
                assert i == 0, "DenseStage is only supported as stage 0"
            else:
                assert isinstance(st, TreeStage), (i, st)
        sents = self.sentinels
        assert len(sents) >= 1, "EngineConfig needs at least one TreeStage"
        assert list(sents) == sorted(set(sents)), sents
        if isinstance(self.capacities, tuple):
            assert len(self.capacities) == len(self.stages), (
                "capacities must have one entry per stage",
                self.capacities, len(self.stages),
            )
        assert self.query_exit is None or isinstance(
            self.query_exit, QueryExitConfig
        )

    # -- structure accessors -------------------------------------------------

    @property
    def dense(self) -> DenseStage | None:
        """The dense stage-0 gate, or ``None`` for an all-trees cascade."""
        first = self.stages[0]
        return first if isinstance(first, DenseStage) else None

    @property
    def tree_stages(self) -> tuple[TreeStage, ...]:
        return tuple(
            st for st in self.stages if isinstance(st, TreeStage)
        )

    @property
    def sentinels(self) -> tuple[int, ...]:
        return tuple(st.sentinel for st in self.tree_stages)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    # -- constructors --------------------------------------------------------

    @classmethod
    def trees(
        cls,
        sentinels: Sequence[int],
        strategies: Sequence[Strategy | None] | Strategy | None = None,
        *,
        classifier_trees: Sequence[float] | float | None = None,
        capacities: Sequence[int] | int | None = None,
        mode: str = "fused",
        leaf_gather: str = "auto",
        block_t: int = 16,
        launch_overhead_trees: float = 0.0,
        query_exit: QueryExitConfig | None = None,
    ) -> EngineConfig:
        """All-trees cascade from parallel sequences (the migration path
        from the deprecated kwargs API: same arguments, one config out)."""
        sents = tuple(int(s) for s in sentinels)
        S = len(sents)
        if strategies is None or callable(strategies):
            strategies = (strategies,) * S
        if classifier_trees is None or isinstance(
            classifier_trees, (int, float)
        ):
            classifier_trees = (classifier_trees,) * S
        assert len(strategies) == S, (len(strategies), S)
        assert len(classifier_trees) == S, (len(classifier_trees), S)
        stages = tuple(
            TreeStage(
                sentinel=s,
                strategy=strategies[k],
                classifier_trees=(
                    None if classifier_trees[k] is None
                    else float(classifier_trees[k])
                ),
            )
            for k, s in enumerate(sents)
        )
        return cls(
            stages=stages,
            mode=mode,
            leaf_gather=leaf_gather,
            block_t=block_t,
            capacities=_as_capacities(capacities),
            launch_overhead_trees=launch_overhead_trees,
            query_exit=query_exit,
        )

    @classmethod
    def hybrid(
        cls,
        dense: DenseStage,
        sentinels: Sequence[int],
        strategies: Sequence[Strategy | None] | Strategy | None = None,
        *,
        classifier_trees: Sequence[float] | float | None = None,
        capacities: Sequence[int] | int | None = None,
        mode: str = "fused",
        leaf_gather: str = "auto",
        block_t: int = 16,
        launch_overhead_trees: float = 0.0,
        query_exit: QueryExitConfig | None = None,
    ) -> EngineConfig:
        """Dense stage 0 + tree stages from parallel sequences.

        ``capacities`` here covers the TREE stages (matching
        :meth:`trees`); the dense survivor bound rides on
        ``dense.capacity`` (``None`` → the engine's bucket default).
        """
        base = cls.trees(
            sentinels, strategies,
            classifier_trees=classifier_trees,
            mode=mode, leaf_gather=leaf_gather, block_t=block_t,
            launch_overhead_trees=launch_overhead_trees,
            query_exit=query_exit,
        )
        caps = _as_capacities(capacities)
        if isinstance(caps, tuple):
            dense_cap = dense.capacity if dense.capacity is not None else caps[-1]
            caps = (dense_cap, *caps)
        return dataclasses.replace(
            base, stages=(dense, *base.stages), capacities=caps
        )
