"""Heuristic early-exit baselines (Cambazoglu et al., WSDM'10) + the oracle.

All strategies act at a sentinel: given per-document *partial* scores after
``s`` trees, return the boolean ``continue`` mask over padded ``[Q, D]``
blocks. Exited documents keep their partial score as final score.

Strategies are traced INTO the compiled progressive step (and, under the
``mode="auto"`` engine, into both ``lax.cond`` branches), so they must be
pure jax functions of their operands — and *mask-invariant*: read
``partial`` only where the alive mask is set, because in staged execution
exited documents hold stale prefixes. All strategies below qualify.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.metrics.ranking import rank_from_scores

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class QueryExitConfig:
    """Static configuration of query-level early exit (arXiv 2004.14641).

    Document-level strategies exit *documents*; this knob exits whole
    *queries* once their top-``k`` can no longer change. Checked after
    each sentinel stage (from ``from_stage`` on): a converged query's
    remaining documents are removed from the alive mask, so they skip
    every later stage and the tail — and when ALL queries converge the
    tail kernel launch itself is skipped on device (the gated tail).

    ``margin`` picks the regime:

    - ``inf`` (default): *exact* — a query exits only when it has no
      alive documents left (every doc already exited at the document
      level). Skipping its tail work is then score-preserving: results
      are bit-exact with ``query_exit=None``.
    - finite: *approximate* — a query additionally exits when its
      partial top-``k`` is margin-stable (see :func:`query_converged`).
      Exited queries keep partial scores for all documents, trading
      bounded NDCG loss for tail work, exactly like the document-level
      threshold trades it.

    Frozen + hashable: the config is part of the compiled step's static
    cache key.
    """

    k: int = 10
    margin: float = math.inf
    from_stage: int = 0

    def __post_init__(self) -> None:
        assert self.k >= 1, self.k
        assert self.margin >= 0.0, self.margin
        assert self.from_stage >= 0, self.from_stage


def query_converged(
    partial: jax.Array, alive: jax.Array, k: int, margin: float
) -> jax.Array:
    """Per-query "top-k stabilized" predicate → ``[Q]`` bool.

    Built on the same machinery as :func:`ept_continue` (masked partial
    scores, ``lax.top_k`` over the candidate axis) but aggregated per
    query. With ``margin=inf`` a query converges only once it has zero
    alive documents. With finite ``margin`` a query also converges when
    its current top-``k`` set is stable: every alive document outside
    the top-``k`` trails the ``k``-th best alive partial score by MORE
    than ``margin`` (vacuously true when at most ``k`` documents are
    alive — no challenger exists). Ties between the ``k``-th score and
    the best challenger never converge (the difference is 0, never
    ``> margin``) — conservative under ties.

    ``k`` is clamped to the padded candidate count ``D`` (``k >= D``
    means no challenger can exist, so any finite margin converges every
    query that still has alive docs). Mask-invariant: ``partial`` is
    read only where ``alive`` is set.
    """
    n_alive = alive.sum(axis=-1)
    if math.isinf(margin):
        return n_alive == 0
    D = partial.shape[-1]
    kk = min(int(k), D)
    if kk >= D:
        return n_alive >= 0  # no challenger possible: always converged
    masked = jnp.where(alive, partial, NEG)
    top = jax.lax.top_k(masked, kk + 1)[0]
    kth, challenger = top[..., kk - 1], top[..., kk]
    stable = (kth - challenger) > margin
    return (n_alive <= kk) | stable


def ert_continue(partial: jax.Array, mask: jax.Array, k_s: int) -> jax.Array:
    """EE Using Rank Thresholds: keep the top-``k_s`` by partial score.

    ``k_s`` may exceed the padded candidate count ``D`` (small-query edge):
    ranks are always ``< D``, so every masked document then continues.
    """
    ranks = rank_from_scores(partial, mask)
    return mask & (ranks < k_s)


def dense_keep_fraction(
    partial: jax.Array, mask: jax.Array, keep_frac: float = 0.25
) -> jax.Array:
    """Dense-gate policy: keep the top ``⌈keep_frac · n_alive⌉`` per query.

    The natural operating point for a distilled stage-0 scorer: its scores
    are only a *proxy* for the ensemble, so the gate keeps a fixed
    fraction of each query's candidates (rank-based, like
    :func:`ert_continue`) rather than thresholding raw proxy scores —
    the survivor count, and therefore the dense stage's capacity
    planning, stays predictable regardless of the proxy's calibration.
    Scaling with ``n_alive`` (not the padded ``D``) keeps short queries
    from flooding the survivor block with padding. ``keep_frac`` is
    clamped to ``[0, 1]``; a query with any alive document always keeps
    at least its top-1 (``ceil`` of a positive fraction ≥ 1), so the
    dense stage can never silently zero out a live query.
    Mask-invariant: ranks are computed from masked scores only.
    """
    frac = min(max(float(keep_frac), 0.0), 1.0)
    ranks = rank_from_scores(partial, mask)
    n_alive = mask.sum(axis=-1, keepdims=True)
    keep = jnp.ceil(frac * n_alive).astype(jnp.int32)
    return mask & (ranks < keep)


def ept_continue(partial: jax.Array, mask: jax.Array, k_s: int, p: float) -> jax.Array:
    """EE Using Proximity Thresholds: keep docs with score ≥ σ_{k_s} − p.

    σ_{k_s} is the k_s-th best partial score of the query; larger ``p``
    keeps more documents (more conservative). ``k_s`` is clamped to the
    padded candidate count ``D`` (``jax.lax.top_k`` rejects k > axis size;
    a query block smaller than ``k_s`` must not crash the serving path).
    """
    masked = jnp.where(mask, partial, NEG)
    k = min(int(k_s), partial.shape[-1])
    kth = jax.lax.top_k(masked, k)[0][..., -1]              # [Q]
    return mask & (partial >= (kth - p)[..., None])


def ideal_continue(
    partial: jax.Array,
    full: jax.Array,
    labels: jax.Array,
    mask: jax.Array,
    k: int = 10,
) -> tuple[jax.Array, jax.Array]:
    """EE_ideal: per-query oracle cut k_s^q (paper §2, Table 1).

    The paper's oracle selects, per query, the **minimum** rank cut at the
    sentinel such that NDCG@k of the merged ranking (continuing docs score
    with the full ensemble, exited docs keep their partial score) equals
    the full ensemble's NDCG@k. This accounts for exited documents
    intruding into the top-k with partial scores — keeping the final top-k
    alone is not sufficient.

    Returns ``(continue_mask, k_s^q)`` so Table 1's k_s^μ / k_s^σ can be
    reported.
    """
    from repro.metrics.ranking import ndcg_at_k  # local import to avoid cycle

    sent_rank = rank_from_scores(partial, mask)
    ndcg_full = ndcg_at_k(full, labels, mask, k)                   # [Q]
    D = partial.shape[-1]

    def ndcg_at_cut(c):
        cont = mask & (sent_rank < c)
        scores = jnp.where(cont, full, partial)
        return ndcg_at_k(scores, labels, mask, k)                  # [Q]

    ndcgs = jax.lax.map(ndcg_at_cut, jnp.arange(D + 1))            # [D+1, Q]
    ok = ndcgs >= ndcg_full[None, :] - 1e-9
    first = jnp.argmax(ok, axis=0)                                 # first True
    cut = jnp.where(ok.any(axis=0), first, D)
    return mask & (sent_rank < cut[:, None]), cut
