from repro.data.synthetic import LetorDataset, make_letor_dataset, PRESETS
from repro.data.pipeline import TokenPipeline, QueryBatcher
from repro.data.graph_sampler import CSRGraph, sample_neighbors

__all__ = [
    "LetorDataset",
    "make_letor_dataset",
    "PRESETS",
    "TokenPipeline",
    "QueryBatcher",
    "CSRGraph",
    "sample_neighbors",
]
