"""CSR neighbor sampling for GNN minibatch training (``minibatch_lg`` shape).

JAX has no sparse neighbor-sampling primitive; this host-side sampler is
part of the system (spec: "``minibatch_lg`` needs a real neighbor
sampler"). Uniform sampling with replacement per GraphSAGE, layered
fanouts, output as a padded edge list + node set ready for
``segment_sum`` message passing on device.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # [n_nodes + 1]
    indices: np.ndarray  # [n_edges]

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]

    @staticmethod
    def random(n_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
        rng = np.random.default_rng(seed)
        deg = rng.poisson(avg_degree, size=n_nodes).clip(1)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = rng.integers(0, n_nodes, size=int(indptr[-1]), dtype=np.int64)
        return CSRGraph(indptr=indptr, indices=indices)


def sample_neighbors(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    seed: int = 0,
) -> dict[str, np.ndarray]:
    """Layered uniform neighbor sampling (with replacement).

    Returns a block: ``nodes`` (unique node ids, seeds first), ``edge_src`` /
    ``edge_dst`` (indices *into* ``nodes``), suitable for
    ``segment_sum(messages, edge_dst, num_segments=len(nodes))``.
    """
    rng = np.random.default_rng(seed)
    frontier = np.unique(seeds)
    node_ids = list(frontier)
    node_pos = {int(n): i for i, n in enumerate(frontier)}
    src_list, dst_list = [], []

    for fanout in fanouts:
        next_frontier = []
        for n in frontier:
            lo, hi = graph.indptr[n], graph.indptr[n + 1]
            if hi == lo:
                continue
            nbrs = graph.indices[lo + rng.integers(0, hi - lo, size=fanout)]
            for m in nbrs:
                m = int(m)
                if m not in node_pos:
                    node_pos[m] = len(node_ids)
                    node_ids.append(m)
                    next_frontier.append(m)
                src_list.append(node_pos[m])
                dst_list.append(node_pos[int(n)])
        frontier = np.asarray(next_frontier, dtype=np.int64)
        if frontier.size == 0:
            break

    return {
        "nodes": np.asarray(node_ids, dtype=np.int64),
        "edge_src": np.asarray(src_list, dtype=np.int64),
        "edge_dst": np.asarray(dst_list, dtype=np.int64),
        "n_seeds": np.int64(np.unique(seeds).shape[0]),
    }
