"""Deterministic, resumable data pipelines.

``TokenPipeline`` — synthetic LM token stream with an explicit integer
cursor; the cursor is part of the training checkpoint so a restarted job
resumes mid-epoch exactly (fault-tolerance requirement). Sharding is by
``(host_index, cursor)`` so every host draws a disjoint stream without
coordination.

``QueryBatcher`` — batches padded LETOR query blocks for the ranking
service, same cursor discipline.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch_size: int       # per-host batch
    seq_len: int
    seed: int = 0
    cursor: int = 0
    host_index: int = 0
    num_hosts: int = 1

    def next_batch(self) -> dict[str, np.ndarray]:
        """Markov-ish synthetic tokens: deterministic in (seed, host, cursor)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.host_index) * 2_654_435_761
            + self.cursor
        )
        # Zipf-distributed tokens + short-range repetition → a learnable LM task.
        base = rng.zipf(1.3, size=(self.batch_size, self.seq_len + 1))
        tokens = np.minimum(base, self.vocab_size - 1).astype(np.int32)
        rep = rng.random((self.batch_size, self.seq_len + 1)) < 0.3
        tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], tokens[:, 1:])
        self.cursor += 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])


@dataclasses.dataclass
class QueryBatcher:
    """Yields fixed-size blocks of padded queries; resumable cursor."""

    n_queries: int
    batch_queries: int
    cursor: int = 0

    def next_indices(self) -> np.ndarray:
        idx = (self.cursor + np.arange(self.batch_queries)) % self.n_queries
        self.cursor = (self.cursor + self.batch_queries) % self.n_queries
        return idx

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict) -> None:
        self.cursor = int(state["cursor"])
