"""Synthetic LETOR datasets calibrated to the paper's two benchmarks.

MSN-1 and Istella are not redistributable offline, so experiments run on
synthetic datasets matching their *published statistics* (paper §3):

- **msn1**: 136 features, ~120 docs/query, power-law label distribution with
  51% non-relevant (MSLR-WEB30K fold-1 marginals).
- **istella**: 220 features, ~317 docs/query (scaled down by default), 96%
  non-relevant with the relevant mass normally distributed around label 2.

Feature model: each document draws a latent quality ``z`` correlated with
its graded label; features split into informative (monotone transforms of
``z``), query-conditioned, and pure-noise groups — giving a ranking problem
that a GBDT genuinely has to learn (NDCG improves smoothly with ensemble
size, which is what sentinel-based early exit needs to be non-trivial).

Splits follow the paper: 60% λ-MART train / 20% classifier train /
5% classifier fine-tune / 15% test.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LetorPreset:
    n_features: int
    mean_docs: int
    label_probs: tuple[float, ...]  # P(label = 0..4)


PRESETS: dict[str, LetorPreset] = {
    "msn1": LetorPreset(
        n_features=136,
        mean_docs=120,
        label_probs=(0.514, 0.325, 0.134, 0.019, 0.008),
    ),
    "istella": LetorPreset(
        n_features=220,
        mean_docs=317,
        label_probs=(0.960, 0.0103, 0.0170, 0.0103, 0.0024),
    ),
}


@dataclasses.dataclass
class LetorDataset:
    X: np.ndarray        # [Q, D, F] float32
    labels: np.ndarray   # [Q, D] int32 (0..4)
    mask: np.ndarray     # [Q, D] bool
    name: str

    @property
    def n_queries(self) -> int:
        return self.X.shape[0]

    def select(self, idx: np.ndarray) -> LetorDataset:
        return LetorDataset(self.X[idx], self.labels[idx], self.mask[idx], self.name)

    def splits(self) -> dict[str, "LetorDataset"]:
        """Paper partitions: 60/20/5/15 = ranker / classifier / tune / test."""
        q = self.n_queries
        bounds = np.cumsum([int(q * f) for f in (0.60, 0.20, 0.05)])
        idx = np.arange(q)
        return {
            "train": self.select(idx[: bounds[0]]),
            "classifier": self.select(idx[bounds[0]: bounds[1]]),
            "tune": self.select(idx[bounds[1]: bounds[2]]),
            "test": self.select(idx[bounds[2]:]),
        }


def make_letor_dataset(
    preset: str = "msn1",
    n_queries: int = 400,
    max_docs: int | None = None,
    n_features: int | None = None,
    seed: int = 0,
    docs_scale: float = 1.0,
) -> LetorDataset:
    p = PRESETS[preset]
    F = n_features or p.n_features
    mean_docs = max(8, int(p.mean_docs * docs_scale))
    D = max_docs or int(mean_docs * 1.5)
    rng = np.random.default_rng(seed)

    n_docs = np.clip(
        rng.poisson(mean_docs, size=n_queries), 8, D
    )
    labels = np.zeros((n_queries, D), dtype=np.int32)
    mask = np.zeros((n_queries, D), dtype=bool)
    X = np.zeros((n_queries, D, F), dtype=np.float32)

    probs = np.asarray(p.label_probs)
    n_inf = max(4, F * 3 // 10)       # informative features
    n_qf = max(2, F * 2 // 10)        # query-conditioned features
    # Fixed per-feature response curves (shared across queries — a real
    # ranking function, not per-query noise).
    inf_slope = rng.uniform(0.4, 1.6, size=n_inf).astype(np.float32)
    inf_noise = rng.uniform(0.2, 1.0, size=n_inf).astype(np.float32)
    qf_slope = rng.uniform(0.2, 0.8, size=n_qf).astype(np.float32)

    for q in range(n_queries):
        d = n_docs[q]
        mask[q, :d] = True
        lab = rng.choice(5, size=d, p=probs)
        labels[q, :d] = lab
        z = lab / 4.0 + 0.25 * rng.normal(size=d)
        q_off = rng.normal()
        feats = np.zeros((d, F), dtype=np.float32)
        feats[:, :n_inf] = (
            inf_slope[None, :] * z[:, None]
            + inf_noise[None, :] * rng.normal(size=(d, n_inf))
        )
        feats[:, n_inf: n_inf + n_qf] = (
            qf_slope[None, :] * (z[:, None] + q_off)
            + 0.5 * rng.normal(size=(d, n_qf))
        )
        feats[:, n_inf + n_qf:] = rng.normal(size=(d, F - n_inf - n_qf))
        X[q, :d] = feats

    return LetorDataset(X=X, labels=labels, mask=mask, name=preset)
