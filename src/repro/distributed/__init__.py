from repro.distributed.sharding import (
    Rules,
    single_pod_rules,
    multi_pod_rules,
    local_rules,
    sharding_rules,
    current_rules,
    constrain,
    resolve,
    spec_to_sharding,
)

__all__ = [
    "Rules",
    "single_pod_rules",
    "multi_pod_rules",
    "local_rules",
    "sharding_rules",
    "current_rules",
    "constrain",
    "resolve",
    "spec_to_sharding",
]
