"""Logical-axis sharding rules → physical mesh axes.

Models annotate arrays with *logical* axis names ("batch", "ff", "experts",
"rows", …). A ``Rules`` table maps logical → physical mesh axes; the same
model code runs on the single-pod ``(data=16, model=16)`` mesh, the
multi-pod ``(pod=2, data=16, model=16)`` mesh, and an unsharded CPU (rules
absent → every constraint is a no-op). This is the device-count-independent
layer that makes elastic re-meshing (fault tolerance) a recompile rather
than a code change.

Key placement decisions (see DESIGN.md §5):

- ``batch``/``groups``/``edges``  → all data-parallel axes (pod, data).
- ``ff``/``vocab``/``qkv``        → tensor parallel ("model").
- ``embed``                       → "data": FSDP via the d_model dim of
  every weight matrix (robust to any layer count — 36/28-layer archs do
  not divide 16); GSPMD all-gathers per layer inside the scan, which the
  latency-hiding scheduler overlaps with compute.
- ``experts``                     → expert parallel ("model"; the expert
  FFN width additionally takes "pod" on the multi-pod mesh so 400B-scale
  expert weights shard 512 ways).
- ``kv_seq``                      → "model": decode-time KV caches shard the
  sequence axis (head counts don't divide 16); flash-decoding-style partial
  softmax reductions are handled by GSPMD.
- ``rows``                        → "model": embedding-table row sharding.
- ``cands``                       → every axis: 10⁶-candidate retrieval
  scoring is embarrassingly parallel.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Physical = Any  # str | tuple[str, ...] | None


@dataclasses.dataclass(frozen=True)
class Rules:
    table: dict[str, Physical]

    def physical(self, logical: str | None) -> Physical:
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        return self.table[logical]

    def resolve(self, *logical: str | None) -> PartitionSpec:
        return PartitionSpec(*(self.physical(ax) for ax in logical))


def single_pod_rules() -> Rules:
    return Rules(
        table={
            "batch": ("data",),
            "groups": ("data",),
            "edges": ("data", "model"),
            "seq": None,
            "seq_sp": "model",   # sequence parallelism (enabled per-config)
            # FSDP: weight matrices shard their d_model dim over the DP axis
            # (gathered per layer inside the scan); robust to any layer count.
            "embed": "data",
            "ff": "model",
            "qkv": "model",
            "vocab": "model",
            "heads": None,
            "kv_seq": "model",
            "layers": None,
            "experts": "model",
            "expert_ff": None,
            "rows": "model",
            "cands": ("data", "model"),
            "nodes": ("data",),
            "dense": None,
        }
    )


def multi_pod_rules() -> Rules:
    r = dict(single_pod_rules().table)
    r.update(
        {
            "batch": ("pod", "data"),
            "groups": ("pod", "data"),
            "edges": ("pod", "data", "model"),
            "nodes": ("pod", "data"),
            # Experts stay on "model" (the dispatch activation shares the
            # axis); the expert FFN width takes the pod axis instead, so
            # 400B-scale expert weights still shard 512 ways.
            "expert_ff": "pod",
            "cands": ("pod", "data", "model"),
        }
    )
    return Rules(table=r)


def local_rules() -> Rules:
    """Everything replicated — single-device testing."""
    return Rules(table={k: None for k in single_pod_rules().table})


_CURRENT: contextvars.ContextVar[Rules | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def sharding_rules(rules: Rules | None):
    token = _CURRENT.set(rules)
    try:
        yield
    finally:
        _CURRENT.reset(token)


def current_rules() -> Rules | None:
    return _CURRENT.get()


def resolve(*logical: str | None) -> PartitionSpec:
    rules = _CURRENT.get()
    if rules is None:
        return PartitionSpec()
    return rules.resolve(*logical)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x`` with a sharding constraint; no-op without active rules."""
    rules = _CURRENT.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.resolve(*logical))


def spec_to_sharding(mesh: Mesh, tree_of_specs):
    """PartitionSpec pytree → NamedSharding pytree for jit in_shardings."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda s: isinstance(s, PartitionSpec),
    )
