"""Tree-ensemble substrate: tensorized forests, scoring, and GBDT training.

The paper's workload is an additive ensemble of regression trees (λ-MART).
This package provides:

- :mod:`repro.forest.ensemble` — the tensorized ``TreeEnsemble`` pytree with
  QuickScorer-style false-node bitmasks, padded to ``[n_trees, n_nodes]``.
- :mod:`repro.forest.scoring` — pure-jnp reference scorers (bitvector and
  level-by-level traversal) used as oracles for the Pallas kernel.
- :mod:`repro.forest.binning` — quantile feature binning (256 bins).
- :mod:`repro.forest.gbdt` — histogram-based, level-wise GBDT trainer in JAX
  (L2 / logistic / LambdaRank objectives, per-instance weights).
- :mod:`repro.forest.lambdamart` — NDCG lambda gradients for λ-MART.
- :mod:`repro.forest.reorder` — learned tree reordering (QWYC-style):
  permute trees so partial prefix sums converge early, making every
  exit policy cheaper at matched quality.
"""

from repro.forest.ensemble import TreeEnsemble, slice_trees, concat_ensembles
from repro.forest.scoring import (
    score_bitvector,
    score_level,
    score_numpy_oracle,
    partial_scores,
)
from repro.forest.binning import quantile_bins, apply_bins
from repro.forest.reorder import (
    learn_order,
    reorder_trees,
    reordered_ensemble,
)
from repro.forest.gbdt import GBDTParams, train_gbdt, train_lambdamart

__all__ = [
    "TreeEnsemble",
    "slice_trees",
    "concat_ensembles",
    "score_bitvector",
    "score_level",
    "score_numpy_oracle",
    "partial_scores",
    "learn_order",
    "reorder_trees",
    "reordered_ensemble",
    "quantile_bins",
    "apply_bins",
    "GBDTParams",
    "train_gbdt",
    "train_lambdamart",
]
