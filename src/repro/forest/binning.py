"""Quantile feature binning for histogram GBDT (256 bins, LightGBM-style).

Binning convention: for feature ``f`` with interior boundaries
``edges[f] = [e_0 < e_1 < ...]``, ``bin(x) = #{j : e_j < x}`` (i.e.
``searchsorted(edges, x, side='left')``). This makes the split condition
``bin(x) <= b  ⟺  x <= edges[b]`` **exact**, so bin-space trees convert to
real-threshold trees without epsilon fudging.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantile_bins(X: np.ndarray, n_bins: int = 256) -> np.ndarray:
    """Per-feature interior boundaries ``[F, n_bins - 1]`` from quantiles.

    Duplicate quantiles (low-cardinality features) are padded with +inf so
    unused bins are simply never populated.
    """
    F = X.shape[1]
    n_edges = n_bins - 1
    edges = np.full((F, n_edges), np.inf, dtype=np.float32)
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    for f in range(F):
        e = np.unique(np.quantile(X[:, f], qs).astype(np.float32))
        edges[f, : e.shape[0]] = e
    return edges


def apply_bins(X: jax.Array, edges: jax.Array) -> jax.Array:
    """Bin a feature matrix: ``[D, F] float → [D, F] int32`` bin indices."""
    def one_feature(e, x):
        return jnp.searchsorted(e, x, side="left")

    return jax.vmap(one_feature, in_axes=(0, 1), out_axes=1)(
        edges, X
    ).astype(jnp.int32)


def bin_to_threshold(edges: np.ndarray, feat: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Real threshold for split ``bin(x) <= b`` on feature ``feat``: edges[feat, b].

    ``b == n_edges`` (degenerate all-left split) maps to +inf.
    """
    n_edges = edges.shape[1]
    padded = np.concatenate([edges, np.full((edges.shape[0], 1), np.inf, np.float32)], axis=1)
    return padded[feat, np.minimum(b, n_edges)]
