"""Tensorized additive tree ensembles with QuickScorer-style bitmasks.

A ``TreeEnsemble`` stores ``T`` binary decision trees padded to a common
``n_nodes`` internal-node count and ``n_leaves`` leaf count, as dense arrays
shaped ``[T, n_nodes]`` / ``[T, n_leaves]``. Two traversal encodings coexist:

1. **Structural** (``left``/``right`` child indices) for classic root→leaf
   level stepping. Child entries ``>= 0`` index internal nodes; entries
   ``< 0`` encode leaves as ``-(leaf_id + 1)``.
2. **QuickScorer bitmask** (``mask_lo``/``mask_hi``): for each internal node
   ``n``, a 64-bit mask (two uint32 lanes) with zeros at the leaves of the
   *left* subtree of ``n``. QuickScorer's theorem: the exit leaf of a
   document is the **lowest set bit** of the AND of the masks of its *false*
   nodes (nodes whose test ``x[feat] <= thr`` fails). True/padded nodes
   contribute the all-ones mask, making the reduction order-free — the key
   property that maps the CPU algorithm onto TPU vector units.

Leaves are numbered left-to-right (in-order), which is what makes the
lowest-set-bit rule correct. ``n_leaves`` must be ≤ 64 for the bitmask
encoding (the paper's trees have ≤ 64 leaves).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np

ALL_ONES = np.uint32(0xFFFFFFFF)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TreeEnsemble:
    """Dense, padded additive ensemble of binary regression trees.

    Instances additionally carry a lazily-attached ``_padded_cache`` dict
    (written via ``object.__setattr__`` by
    :func:`repro.kernels.ops.padded_forest`) holding kernel-aligned buffer
    sets keyed by segment boundaries × tree-block size — pad once, score
    many. The cache is NOT a pytree field: it does not survive jit
    boundaries or :func:`dataclasses.replace`, which is fine because it is
    only ever a cache.
    """

    feature: jax.Array    # [T, N] int32 — split feature per internal node
    threshold: jax.Array  # [T, N] float32 — split threshold (x <= thr → left)
    left: jax.Array       # [T, N] int32 — left child (neg = ~leaf encoding)
    right: jax.Array      # [T, N] int32
    mask_lo: jax.Array    # [T, N] uint32 — QS false-node mask, low lane
    mask_hi: jax.Array    # [T, N] uint32 — high lane
    leaf_value: jax.Array  # [T, L] float32
    base_score: jax.Array  # [] float32 — additive offset (e.g. logit prior)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def n_nodes(self) -> int:
        return self.feature.shape[1]

    @property
    def n_leaves(self) -> int:
        return self.leaf_value.shape[1]

    @property
    def depth(self) -> int:
        # Padded complete-tree depth bound: n_leaves = 2**depth.
        return int(np.log2(self.n_leaves))

    def astype(self, dtype) -> TreeEnsemble:
        return dataclasses.replace(
            self,
            threshold=self.threshold.astype(dtype),
            leaf_value=self.leaf_value.astype(dtype),
            base_score=self.base_score.astype(dtype),
        )


def slice_trees(ens: TreeEnsemble, start: int, stop: int) -> TreeEnsemble:
    """Sub-ensemble of trees [start, stop) — used to split at a sentinel."""
    keep_base = jnp.where(start == 0, ens.base_score, jnp.zeros_like(ens.base_score))
    return TreeEnsemble(
        feature=ens.feature[start:stop],
        threshold=ens.threshold[start:stop],
        left=ens.left[start:stop],
        right=ens.right[start:stop],
        mask_lo=ens.mask_lo[start:stop],
        mask_hi=ens.mask_hi[start:stop],
        leaf_value=ens.leaf_value[start:stop],
        base_score=keep_base,
    )


def concat_ensembles(parts: Sequence[TreeEnsemble]) -> TreeEnsemble:
    base = parts[0].base_score
    return TreeEnsemble(
        feature=jnp.concatenate([p.feature for p in parts]),
        threshold=jnp.concatenate([p.threshold for p in parts]),
        left=jnp.concatenate([p.left for p in parts]),
        right=jnp.concatenate([p.right for p in parts]),
        mask_lo=jnp.concatenate([p.mask_lo for p in parts]),
        mask_hi=jnp.concatenate([p.mask_hi for p in parts]),
        leaf_value=jnp.concatenate([p.leaf_value for p in parts]),
        base_score=base,
    )


# ---------------------------------------------------------------------------
# Construction from explicit tree structure (numpy, host side).
# ---------------------------------------------------------------------------


def _leaf_spans(left: np.ndarray, right: np.ndarray, n_nodes: int):
    """In-order leaf numbering: for each internal node return (lo, mid, hi) —
    its subtree covers leaves [lo, hi), left child covers [lo, mid)."""
    spans = np.zeros((n_nodes, 3), dtype=np.int64)
    counter = [0]

    def visit(node: int) -> tuple[int, int]:
        if node < 0:  # leaf
            i = counter[0]
            counter[0] += 1
            return i, i + 1
        lo, mid = visit(int(left[node]))
        _, hi = visit(int(right[node]))
        spans[node] = (lo, mid, hi)
        return lo, hi

    visit(0)
    return spans, counter[0]


def _span_mask(lo: int, hi: int) -> tuple[np.uint32, np.uint32]:
    """64-bit mask with zeros on bits [lo, hi), split into two uint32 lanes."""
    bits = ((1 << hi) - 1) ^ ((1 << lo) - 1)  # ones on [lo, hi)
    inv = (~bits) & ((1 << 64) - 1)
    return np.uint32(inv & 0xFFFFFFFF), np.uint32(inv >> 32)


def from_arrays(
    features: list[np.ndarray],
    thresholds: list[np.ndarray],
    lefts: list[np.ndarray],
    rights: list[np.ndarray],
    leaf_values: list[np.ndarray],
    base_score: float = 0.0,
    n_nodes: int | None = None,
    n_leaves: int | None = None,
) -> TreeEnsemble:
    """Build a padded TreeEnsemble from per-tree structure arrays.

    Per-tree convention: internal nodes indexed 0..n_int-1 (root = 0); child
    entries < 0 encode leaf ``-(leaf_slot+1)`` into that tree's
    ``leaf_values``. Leaf slots are renumbered here to in-order so the
    QuickScorer mask rule holds regardless of input numbering.
    """
    T = len(features)
    max_int = max(int(f.shape[0]) for f in features)
    n_nodes = n_nodes or max_int
    max_leaves = max(int(lv.shape[0]) for lv in leaf_values)
    n_leaves = n_leaves or max_leaves
    if n_leaves > 64:
        raise ValueError(f"bitmask encoding requires <=64 leaves, got {n_leaves}")

    feat = np.zeros((T, n_nodes), dtype=np.int32)
    thr = np.full((T, n_nodes), np.float32(np.inf))  # padded → always-true node
    left = np.full((T, n_nodes), -1, dtype=np.int32)
    right = np.full((T, n_nodes), -1, dtype=np.int32)
    mlo = np.full((T, n_nodes), ALL_ONES, dtype=np.uint32)
    mhi = np.full((T, n_nodes), ALL_ONES, dtype=np.uint32)
    lv = np.zeros((T, n_leaves), dtype=np.float32)

    for t in range(T):
        n_int = int(features[t].shape[0])
        feat[t, :n_int] = features[t]
        thr[t, :n_int] = thresholds[t]
        lt, rt = lefts[t].astype(np.int64), rights[t].astype(np.int64)
        spans, n_leaf_t = _leaf_spans(lt, rt, n_int)
        # Renumber leaves to in-order: walk again mapping old slot → in-order id.
        order = np.zeros(n_leaf_t, dtype=np.int64)  # in-order id → old slot
        counter = [0]

        def visit(node: int):
            if node < 0:
                order[counter[0]] = -(node + 1)
                counter[0] += 1
                return
            visit(int(lt[node]))
            visit(int(rt[node]))

        visit(0)
        lv[t, :n_leaf_t] = leaf_values[t][order]
        # Children re-encoded with in-order leaf ids.
        old2new = np.zeros(n_leaf_t, dtype=np.int64)
        old2new[order] = np.arange(n_leaf_t)
        for n in range(n_int):
            for arr_in, arr_out in ((lt, left), (rt, right)):
                c = int(arr_in[n])
                arr_out[t, n] = c if c >= 0 else -(int(old2new[-(c + 1)]) + 1)
            lo, mid, _hi = spans[n]
            mlo[t, n], mhi[t, n] = _span_mask(int(lo), int(mid))

    return TreeEnsemble(
        feature=jnp.asarray(feat),
        threshold=jnp.asarray(thr),
        left=jnp.asarray(left),
        right=jnp.asarray(right),
        mask_lo=jnp.asarray(mlo),
        mask_hi=jnp.asarray(mhi),
        leaf_value=jnp.asarray(lv),
        base_score=jnp.float32(base_score),
    )


def from_complete_arrays(
    feature: np.ndarray,   # [T, 2**D - 1] heap-ordered internal nodes
    threshold: np.ndarray,  # [T, 2**D - 1]
    leaf_value: np.ndarray,  # [T, 2**D] left-to-right leaves
    base_score: float = 0.0,
) -> TreeEnsemble:
    """Fast path for complete depth-D trees in heap layout (the GBDT output).

    Heap node ``n`` has children ``2n+1`` / ``2n+2``; leaves are already
    left-to-right so masks come from closed-form spans.
    """
    T, n_int = feature.shape
    depth = int(np.log2(n_int + 1))
    n_leaves = 1 << depth
    left = np.zeros((T, n_int), dtype=np.int32)
    right = np.zeros((T, n_int), dtype=np.int32)
    mlo = np.zeros((T, n_int), dtype=np.uint32)
    mhi = np.zeros((T, n_int), dtype=np.uint32)
    for n in range(n_int):
        d = int(np.floor(np.log2(n + 1)))
        # Heap node n is the (n - (2**d - 1))-th node of level d; its subtree
        # spans 2**(depth - d) leaves starting at that offset.
        pos = n - ((1 << d) - 1)
        span = 1 << (depth - d)
        lo = pos * span
        mid = lo + span // 2
        l_child, r_child = 2 * n + 1, 2 * n + 2
        left[:, n] = l_child if l_child < n_int else -(lo + 1)
        right[:, n] = r_child if r_child < n_int else -(mid + 1)
        a, b = _span_mask(lo, mid)
        mlo[:, n], mhi[:, n] = a, b
    return TreeEnsemble(
        feature=jnp.asarray(feature.astype(np.int32)),
        threshold=jnp.asarray(threshold.astype(np.float32)),
        left=jnp.asarray(left),
        right=jnp.asarray(right),
        mask_lo=jnp.asarray(mlo),
        mask_hi=jnp.asarray(mhi),
        leaf_value=jnp.asarray(leaf_value.astype(np.float32)),
        base_score=jnp.float32(base_score),
    )


def random_ensemble(
    key,
    n_trees: int,
    depth: int,
    n_features: int,
    leaf_scale: float = 0.1,
) -> TreeEnsemble:
    """Random complete-tree ensemble — used by tests and kernel sweeps."""
    rng = np.random.default_rng(np.asarray(key)[-1] if hasattr(key, "shape") else key)
    n_int = (1 << depth) - 1
    feature = rng.integers(0, n_features, size=(n_trees, n_int))
    threshold = rng.normal(size=(n_trees, n_int)).astype(np.float32)
    leaf_value = (leaf_scale * rng.normal(size=(n_trees, 1 << depth))).astype(np.float32)
    return from_complete_arrays(feature, threshold, leaf_value)
