"""Histogram-based gradient-boosted decision trees, pure JAX.

Level-wise growth of complete depth-``D`` trees (≤64 leaves, matching the
paper's LightGBM setting) with 256-bin quantile histograms. One boosting
round — gradient/hessian computation, histogram build, best-split search for
every node of every level, leaf fitting, prediction update — is a single
jit'd function; the boosting loop is a host loop over rounds.

Objectives:
- ``l2``        : squared error (MART regression)
- ``logistic``  : binary cross-entropy with per-instance weights — this is
  exactly what the LEAR Continue/Exit classifier needs (cost-sensitive
  ``w_d = 2^{r_d} / f_q(l_d)``).
- LambdaRank    : via :func:`repro.forest.lambdamart.lambda_grad_hess`,
  plugged in through :func:`train_lambdamart`.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest import binning
from repro.forest.ensemble import TreeEnsemble, from_complete_arrays
from repro.forest.lambdamart import lambda_grad_hess


@dataclasses.dataclass(frozen=True)
class GBDTParams:
    n_trees: int = 100
    depth: int = 6                 # complete trees → 2**depth leaves (≤64)
    learning_rate: float = 0.1
    reg_lambda: float = 1.0
    min_child_hess: float = 1e-3
    n_bins: int = 256
    base_score: float = 0.0


# ---------------------------------------------------------------------------
# Single-tree fit (jit-traceable; depth unrolled — it is static and ≤ 6).
# ---------------------------------------------------------------------------


def _fit_tree(Xb: jax.Array, g: jax.Array, h: jax.Array, p: GBDTParams):
    """Fit one complete depth-D tree on binned features.

    Xb: [N, F] int32 bins; g/h: [N] float32 (weights pre-folded).
    Returns (feat [n_int] i32, bin [n_int] i32, leaf_value [n_leaves] f32)
    in heap order.
    """
    N, F = Xb.shape
    n_bins = p.n_bins
    depth = p.depth
    feats, bins = [], []
    node = jnp.zeros((N,), dtype=jnp.int32)  # node-in-level index (relative)
    f_range = jnp.arange(F, dtype=jnp.int32)

    for level in range(depth):
        n_nodes = 1 << level
        gh = jnp.stack([g, h], axis=-1)  # [N, 2]
        hist = jnp.zeros((n_nodes, F, n_bins, 2), dtype=jnp.float32)
        hist = hist.at[node[:, None], f_range[None, :], Xb].add(gh[:, None, :])
        cum = jnp.cumsum(hist, axis=2)                     # left stats at split bin b
        total = cum[:, :, -1:, :]                          # [n_nodes, F, 1, 2]
        gl, hl = cum[..., 0], cum[..., 1]
        gt, ht = total[..., 0], total[..., 1]
        gr, hr = gt - gl, ht - hl
        lam = p.reg_lambda
        gain = (
            gl * gl / (hl + lam)
            + gr * gr / (hr + lam)
            - gt * gt / (ht + lam)
        )
        valid = (hl >= p.min_child_hess) & (hr >= p.min_child_hess)
        # Splitting at the last bin sends everything left — never a real split.
        valid = valid & (jnp.arange(n_bins)[None, None, :] < n_bins - 1)
        gain = jnp.where(valid, gain, -jnp.inf)
        flat = gain.reshape(n_nodes, F * n_bins)
        best = jnp.argmax(flat, axis=1)                    # [n_nodes]
        best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]
        bf = (best // n_bins).astype(jnp.int32)
        bb = (best % n_bins).astype(jnp.int32)
        # Degenerate node (no valid split): all-left sentinel split.
        dead = ~jnp.isfinite(best_gain)
        bf = jnp.where(dead, 0, bf)
        bb = jnp.where(dead, n_bins - 1, bb)
        feats.append(bf)
        bins.append(bb)
        # Route documents.
        xb_f = jnp.take_along_axis(Xb, bf[node][:, None], axis=1)[:, 0]
        go_left = xb_f <= bb[node]
        node = 2 * node + jnp.where(go_left, 0, 1)

    # Leaves: node is now the in-level (== left-to-right leaf) index.
    n_leaves = 1 << depth
    leaf_g = jnp.zeros((n_leaves,)).at[node].add(g)
    leaf_h = jnp.zeros((n_leaves,)).at[node].add(h)
    leaf_value = -leaf_g / (leaf_h + p.reg_lambda) * p.learning_rate
    feat_heap = jnp.concatenate(feats)  # heap order == level order for complete trees
    bin_heap = jnp.concatenate(bins)
    return feat_heap, bin_heap, leaf_value, node


def _predict_leaf_delta(leaf_value: jax.Array, leaf_idx: jax.Array) -> jax.Array:
    return leaf_value[leaf_idx]


# ---------------------------------------------------------------------------
# Objectives.
# ---------------------------------------------------------------------------


def grad_hess_l2(preds, y, w):
    return (preds - y) * w, w


def grad_hess_logistic(preds, y, w):
    prob = jax.nn.sigmoid(preds)
    return (prob - y) * w, jnp.maximum(prob * (1 - prob), 1e-6) * w


OBJECTIVES: dict[str, Callable] = {
    "l2": grad_hess_l2,
    "logistic": grad_hess_logistic,
}


# ---------------------------------------------------------------------------
# Boosting loops.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("objective", "params"))
def _boost_round(Xb, y, w, preds, objective: str, params: GBDTParams):
    g, h = OBJECTIVES[objective](preds, y, w)
    feat, bin_, leaf_value, leaf_idx = _fit_tree(Xb, g, h, params)
    preds = preds + _predict_leaf_delta(leaf_value, leaf_idx)
    return preds, (feat, bin_, leaf_value)


def train_gbdt(
    X: np.ndarray,
    y: np.ndarray,
    params: GBDTParams,
    objective: str = "l2",
    weights: np.ndarray | None = None,
    edges: np.ndarray | None = None,
    callback: Callable[[int, np.ndarray], None] | None = None,
) -> TreeEnsemble:
    """Train a GBDT on a flat dataset. Returns a real-threshold TreeEnsemble."""
    if edges is None:
        edges = binning.quantile_bins(X, params.n_bins)
    Xb = np.asarray(binning.apply_bins(jnp.asarray(X), jnp.asarray(edges)))
    w = np.ones_like(y, dtype=np.float32) if weights is None else weights.astype(np.float32)
    preds = jnp.full((X.shape[0],), params.base_score, dtype=jnp.float32)
    Xb_j, y_j, w_j = jnp.asarray(Xb), jnp.asarray(y, dtype=jnp.float32), jnp.asarray(w)

    trees = []
    for t in range(params.n_trees):
        preds, tree = _boost_round(Xb_j, y_j, w_j, preds, objective, params)
        trees.append(jax.tree.map(np.asarray, tree))
        if callback is not None:
            callback(t, np.asarray(preds))
    return _stack_trees(trees, edges, params)


def _stack_trees(trees, edges: np.ndarray, params: GBDTParams) -> TreeEnsemble:
    feat = np.stack([t[0] for t in trees])
    bin_ = np.stack([t[1] for t in trees])
    leaf = np.stack([t[2] for t in trees])
    thr = binning.bin_to_threshold(edges, feat, bin_)
    return from_complete_arrays(feat, thr, leaf, base_score=params.base_score)


# --- LambdaMART -------------------------------------------------------------


@partial(jax.jit, static_argnames=("params", "k"))
def _lambdamart_round(Xb, labels, mask, preds, params: GBDTParams, k: int):
    """One λ-MART round on padded per-query blocks.

    Xb: [Q, D, F] int32; labels/mask/preds: [Q, D].
    """
    g, h = lambda_grad_hess(preds, labels, mask, k=k)
    Q, D, F = Xb.shape
    flat_w = mask.reshape(-1).astype(jnp.float32)
    g = g.reshape(-1) * flat_w
    h = h.reshape(-1) * flat_w
    feat, bin_, leaf_value, leaf_idx = _fit_tree(Xb.reshape(Q * D, F), g, h, params)
    preds = preds + _predict_leaf_delta(leaf_value, leaf_idx).reshape(Q, D)
    return preds, (feat, bin_, leaf_value)


def train_lambdamart(
    X: np.ndarray,        # [Q, D, F] padded per-query features
    labels: np.ndarray,   # [Q, D] graded relevance
    mask: np.ndarray,     # [Q, D] bool
    params: GBDTParams,
    k: int = 10,
    edges: np.ndarray | None = None,
    callback: Callable[[int, np.ndarray], None] | None = None,
) -> TreeEnsemble:
    """Train a λ-MART ranker (NDCG@k lambda gradients)."""
    Q, D, F = X.shape
    flatX = X.reshape(Q * D, F)
    if edges is None:
        edges = binning.quantile_bins(flatX[np.asarray(mask).reshape(-1)], params.n_bins)
    Xb = np.asarray(binning.apply_bins(jnp.asarray(flatX), jnp.asarray(edges))).reshape(Q, D, F)
    preds = jnp.zeros((Q, D), dtype=jnp.float32)
    Xb_j = jnp.asarray(Xb)
    lab_j = jnp.asarray(labels, dtype=jnp.float32)
    mask_j = jnp.asarray(mask)

    trees = []
    for t in range(params.n_trees):
        preds, tree = _lambdamart_round(Xb_j, lab_j, mask_j, preds, params, k)
        trees.append(jax.tree.map(np.asarray, tree))
        if callback is not None:
            callback(t, np.asarray(preds))
    return _stack_trees(trees, edges, params)
