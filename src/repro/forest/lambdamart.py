"""LambdaRank gradients with |ΔNDCG| weighting (λ-MART objective).

Standard Burges-style lambdas: for a document pair (i, j) with
``label_i > label_j`` in the same query,

    ρ_ij  = 1 / (1 + exp(σ (s_i − s_j)))
    λ_ij  = −σ · ρ_ij · |ΔNDCG_ij|
    g_i  += λ_ij,  g_j −= λ_ij
    h_i  += σ² · ρ_ij (1 − ρ_ij) · |ΔNDCG_ij|   (and the same for j)

|ΔNDCG_ij| is the NDCG@k change from swapping i and j in the *current*
ranking. Computation is fully vectorized over padded ``[Q, D]`` blocks with
``[Q, D, D]`` pairwise intermediates, chunked over queries via ``lax.map``
to bound the working set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.metrics.ranking import gain, rank_from_scores

SIGMA = 1.0


def _per_query(scores, labels, mask, k: int):
    """Lambda gradients for one query. scores/labels/mask: [D]."""
    D = scores.shape[0]
    ranks = rank_from_scores(scores[None], mask[None])[0]        # [D]
    # Discount at each doc's current rank; 0 beyond the NDCG cutoff.
    disc = jnp.where(ranks < k, 1.0 / jnp.log2(ranks.astype(jnp.float32) + 2.0), 0.0)
    gains = jnp.where(mask, gain(labels), 0.0)
    idcg = _ideal_dcg(labels, mask, k)
    inv_idcg = jnp.where(idcg > 0, 1.0 / jnp.maximum(idcg, 1e-12), 0.0)

    # Pairwise: swap i and j ⇒ ΔDCG = (gain_i − gain_j) (disc_i − disc_j).
    dgain = gains[:, None] - gains[None, :]                      # [D, D]
    ddisc = disc[:, None] - disc[None, :]
    delta = jnp.abs(dgain * ddisc) * inv_idcg

    sdiff = scores[:, None] - scores[None, :]
    rho = jax.nn.sigmoid(-SIGMA * sdiff)                         # 1/(1+e^{σ(si−sj)})
    pair_valid = (
        (labels[:, None] > labels[None, :]) & mask[:, None] & mask[None, :]
    )
    lam = jnp.where(pair_valid, -SIGMA * rho * delta, 0.0)       # [D, D]
    hess = jnp.where(pair_valid, SIGMA * SIGMA * rho * (1 - rho) * delta, 0.0)

    # g_i accumulates λ_ij over j it beats, and −λ_ji over j that beat it.
    g = lam.sum(axis=1) - lam.sum(axis=0)
    h = hess.sum(axis=1) + hess.sum(axis=0)
    return g, jnp.maximum(h, 1e-6)


def _ideal_dcg(labels, mask, k: int):
    masked = jnp.where(mask, labels, -jnp.inf)
    top = jax.lax.top_k(masked, k)[0]
    disc = 1.0 / jnp.log2(jnp.arange(k, dtype=jnp.float32) + 2.0)
    g = jnp.where(jnp.isfinite(top), gain(top), 0.0)
    return (g * disc).sum()


@partial(jax.jit, static_argnames=("k", "chunk"))
def lambda_grad_hess(scores, labels, mask, k: int = 10, chunk: int = 64):
    """Vectorized lambdas over padded [Q, D] blocks, query-chunked."""
    Q = scores.shape[0]
    pad = (-Q) % chunk
    if pad:
        scores = jnp.pad(scores, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))

    def block(args):
        s, l, m = args
        return jax.vmap(_per_query, in_axes=(0, 0, 0, None))(s, l, m, k)

    Qp = scores.shape[0]
    s = scores.reshape(Qp // chunk, chunk, -1)
    l = labels.reshape(Qp // chunk, chunk, -1)
    m = mask.reshape(Qp // chunk, chunk, -1)
    g, h = jax.lax.map(block, (s, l, m))
    g = g.reshape(Qp, -1)[:Q]
    h = h.reshape(Qp, -1)[:Q]
    return g, h
