"""Learned tree reordering for additive ensembles (QWYC-style).

A GBDT's trees arrive in boosting order, but nothing in the additive
model requires traversing them that way. "Quit While You're Ahead"
(arXiv 1806.11202) showed that reordering trees so the *partial* prefix
sum converges to the full score as early as possible makes every
early-exit policy cheaper at matched quality: the sentinel sees a
better score estimate after the same number of trees, so document- and
query-level exits fire sooner.

This module learns such an order offline from per-tree contributions on
a validation slice and materializes the permuted ensemble:

- :func:`per_tree_contributions` — ``[B, T]`` leaf values per (doc,
  tree) on device (the same exit-leaf machinery the kernel implements);
- :func:`greedy_order` — greedy residual-fit: repeatedly pick the tree
  whose contribution best reduces the remaining squared residual to the
  full score (host numpy, float64);
- :func:`variance_order` — cheap baseline: descending contribution
  variance (high-variance trees decide ranks, play them first);
- :func:`reorder_trees` — apply a permutation to every tree-indexed
  array of a :class:`TreeEnsemble` (a NEW instance, so the per-instance
  ``padded_forest`` cache pads the permuted layout once and serves it);
- :func:`prefix_residual` — convergence diagnostic used by the tests
  and the tradeoff bench;
- :func:`learn_order` / :func:`reordered_ensemble` — the offline entry
  points the bench drives.

Determinism contract: reordering only *permutes* the per-tree terms; the
final score equals the identity ordering's score up to reassociation of
the tree-axis reduction, and is BIT-EXACT through every path that
reduces via ``_pairwise_tree_sum`` on the same tree count. That is why
this module sits under the TS003 lint scope
(``config.TREE_SUM_EXTRA_ROOT_SUFFIXES``): a bare ``sum`` anywhere
between leaf values and scores would silently void the invariance the
reorder tests pin. The order *learning* itself runs in host float64 and
never produces a score, so its linear algebra (matmul/einsum) is exempt
by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.ensemble import TreeEnsemble
from repro.forest.scoring import exit_leaves_bitvector
from repro.kernels.forest_score import _pairwise_tree_sum


def per_tree_contributions(ens: TreeEnsemble, X: jax.Array) -> jax.Array:
    """Leaf value each tree contributes per document → ``[B, T]`` f32.

    Exit leaves come from the same QuickScorer bitvector reduction the
    Pallas kernel implements, so the contributions match what any device
    path would accumulate. ``base_score`` is excluded: it is ordering-
    invariant by definition.
    """
    leaves = exit_leaves_bitvector(ens, X)                      # [B, T]
    return jnp.take_along_axis(
        ens.leaf_value[None, :, :], leaves[:, :, None], axis=2
    )[..., 0]


def full_from_contributions(ens: TreeEnsemble, per_tree: jax.Array) -> jax.Array:
    """Total score from a contribution matrix via the sanctioned reducer."""
    return _pairwise_tree_sum(per_tree) + ens.base_score


def greedy_order(contrib: np.ndarray) -> np.ndarray:
    """Greedy residual-fit ordering → permutation ``[T]`` int64.

    At each step, with residual ``r = full − prefix`` over the
    validation docs, adding tree ``t`` changes the squared residual by
    ``||r − C_t||² − ||r||² = ||C_t||² − 2⟨r, C_t⟩`` — so pick the tree
    maximizing ``2⟨r, C_t⟩ − ||C_t||²``. The Gram matrix makes each step
    O(T): picking ``t`` shifts every inner product by ``−G[:, t]``.

    Runs in float64 on host: this learns an *order*, not a score, so it
    is outside the bit-exactness contract — stability across platforms
    comes from float64 headroom plus deterministic argmax tie-breaking
    (numpy argmax takes the first maximum).
    """
    C = np.asarray(contrib, dtype=np.float64)
    B, T = C.shape
    assert B >= 1 and T >= 1, C.shape
    gram = C.T @ C                                              # [T, T]
    # ⟨C_t, r₀⟩ where r₀ = Σ_u C_u: a row of Gram-column totals.
    score = np.einsum("tu->t", gram)
    sq = np.diagonal(gram).copy()
    used = np.zeros(T, dtype=bool)
    order = np.empty(T, dtype=np.int64)
    for i in range(T):
        gain = np.where(used, -np.inf, 2.0 * score - sq)
        t = int(np.argmax(gain))
        order[i] = t
        used[t] = True
        score = score - gram[:, t]
    return order


def variance_order(contrib: np.ndarray) -> np.ndarray:
    """Descending contribution variance → permutation ``[T]`` int64.

    The cheap baseline: a tree whose contribution varies across
    documents separates them; a near-constant tree only shifts every
    score and can safely run late. Stable sort keeps boosting order
    among ties (deterministic across platforms).
    """
    C = np.asarray(contrib, dtype=np.float64)
    B = C.shape[0]
    mean = np.einsum("bt->t", C) / B
    ex2 = np.einsum("bt,bt->t", C, C) / B
    var = ex2 - mean * mean
    return np.argsort(-var, kind="stable").astype(np.int64)


def prefix_residual(contrib: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Mean squared full-score residual after each prefix → ``[T]`` f64.

    ``out[m]`` = mean over docs of ``(prefix_{m+1} − full)²`` under
    ``order`` — the convergence curve an ordering is judged by (lower
    earlier = every exit policy sees a better estimate sooner).
    Float64 diagnostic; einsum keeps the tree-axis discipline the TS003
    scope expects even though no score leaves this function.
    """
    C = np.asarray(contrib, dtype=np.float64)[:, np.asarray(order)]
    prefix = np.cumsum(C, axis=1)                               # [B, T]
    resid = prefix - prefix[:, -1:]
    return np.einsum("bt,bt->t", resid, resid) / C.shape[0]


def reorder_trees(ens: TreeEnsemble, order: np.ndarray) -> TreeEnsemble:
    """Materialize the permuted ensemble (validated permutation).

    Every ``[T, ...]`` array is gathered along axis 0; ``base_score`` is
    ordering-invariant. Returns a NEW ``TreeEnsemble`` instance — its
    per-instance padded-buffer cache starts empty, so the permuted
    layout is padded once on first kernel use and reused after, exactly
    like any other ensemble.
    """
    idx = np.asarray(order)
    T = ens.n_trees
    assert idx.shape == (T,), (idx.shape, T)
    assert np.array_equal(np.sort(idx), np.arange(T)), "not a permutation"
    take = jnp.asarray(idx, dtype=jnp.int32)
    return TreeEnsemble(
        feature=jnp.take(ens.feature, take, axis=0),
        threshold=jnp.take(ens.threshold, take, axis=0),
        left=jnp.take(ens.left, take, axis=0),
        right=jnp.take(ens.right, take, axis=0),
        mask_lo=jnp.take(ens.mask_lo, take, axis=0),
        mask_hi=jnp.take(ens.mask_hi, take, axis=0),
        leaf_value=jnp.take(ens.leaf_value, take, axis=0),
        base_score=ens.base_score,
    )


def learn_order(
    ens: TreeEnsemble,
    X_valid: jax.Array,
    method: str = "greedy",
    max_docs: int | None = 4096,
) -> np.ndarray:
    """Learn a traversal order from a validation slice → ``[T]`` int64.

    ``X_valid`` is ``[B, F]`` flat documents (rank the validation fold's
    docs however you like — the objective is per-document). ``max_docs``
    caps the slice with a deterministic stride (not a prefix: query
    blocks arrive grouped, and a prefix would overfit the first
    queries). ``method`` ∈ {"greedy", "variance", "identity"}.
    """
    assert method in ("greedy", "variance", "identity"), method
    if method == "identity":
        return np.arange(ens.n_trees, dtype=np.int64)
    B = X_valid.shape[0]
    if max_docs is not None and B > max_docs:
        stride = -(-B // max_docs)  # ceil: keeps ≤ max_docs rows
        X_valid = X_valid[::stride]
    contrib = np.asarray(per_tree_contributions(ens, X_valid))
    if method == "greedy":
        return greedy_order(contrib)
    return variance_order(contrib)


def reordered_ensemble(
    ens: TreeEnsemble,
    X_valid: jax.Array,
    method: str = "greedy",
    max_docs: int | None = 4096,
) -> tuple[TreeEnsemble, np.ndarray]:
    """One-call offline entry point: learned order + permuted ensemble."""
    order = learn_order(ens, X_valid, method=method, max_docs=max_docs)
    return reorder_trees(ens, order), order
