"""Reference scorers for tensorized tree ensembles (pure jnp oracles).

Three implementations with identical semantics:

- :func:`score_numpy_oracle` — per-document recursive traversal in numpy;
  slowest, trusted ground truth for tests.
- :func:`score_level` — vectorized root→leaf stepping (``depth`` dependent
  gather steps). Mirrors classic batched traversal.
- :func:`score_bitvector` — QuickScorer-adapted: order-free AND-reduction of
  false-node masks, exit leaf = lowest set bit. This is the algorithm the
  Pallas kernel implements; it is also the fastest pure-XLA path on TPU
  because it has no sequentially-dependent gathers.

All scorers take ``X: [B, F]`` float and return ``[B]`` scores
(plus optionally per-tree partials).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.ensemble import TreeEnsemble


def _ctz64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Count trailing zeros of a 64-bit value in two uint32 lanes.

    ctz(m) = popcount(~m & (m - 1)); the AND of QS masks is never 0 (the
    exit leaf bit always survives), so no special case is needed.
    """
    lo_nz = lo != 0
    m = jnp.where(lo_nz, lo, hi)
    ctz32 = jax.lax.population_count(~m & (m - jnp.uint32(1)))
    return jnp.where(lo_nz, ctz32, ctz32 + jnp.uint32(32)).astype(jnp.int32)


def exit_leaves_bitvector(ens: TreeEnsemble, X: jax.Array) -> jax.Array:
    """Exit leaf index per (doc, tree) via mask AND-reduction. → [B, T] int32."""
    # Gather tested feature values: [B, T, N].
    xf = X[:, ens.feature]  # fancy-index over axis 1 with [T, N] indices
    pred_true = xf <= ens.threshold[None, :, :]
    ones = jnp.uint32(0xFFFFFFFF)
    m_lo = jnp.where(pred_true, ones, ens.mask_lo[None, :, :])
    m_hi = jnp.where(pred_true, ones, ens.mask_hi[None, :, :])
    # Order-free AND-reduction over the node axis.
    and_lo = jax.lax.reduce(m_lo, ones, jax.lax.bitwise_and, dimensions=(2,))
    and_hi = jax.lax.reduce(m_hi, ones, jax.lax.bitwise_and, dimensions=(2,))
    return _ctz64(and_hi, and_lo)


def score_bitvector(
    ens: TreeEnsemble, X: jax.Array, return_per_tree: bool = False
):
    leaves = exit_leaves_bitvector(ens, X)  # [B, T]
    per_tree = jnp.take_along_axis(
        ens.leaf_value[None, :, :], leaves[:, :, None], axis=2
    )[..., 0]
    scores = per_tree.sum(axis=1) + ens.base_score
    if return_per_tree:
        return scores, per_tree
    return scores


def score_level(ens: TreeEnsemble, X: jax.Array) -> jax.Array:
    """Classic batched root→leaf traversal (depth dependent steps)."""
    B = X.shape[0]
    T = ens.n_trees
    node = jnp.zeros((B, T), dtype=jnp.int32)
    done = jnp.zeros((B, T), dtype=bool)
    leaf = jnp.zeros((B, T), dtype=jnp.int32)

    def step(carry, _):
        node, done, leaf = carry
        safe = jnp.where(done, 0, node)
        f = ens.feature[jnp.arange(T)[None, :], safe]          # [B, T]
        t = ens.threshold[jnp.arange(T)[None, :], safe]
        l = ens.left[jnp.arange(T)[None, :], safe]
        r = ens.right[jnp.arange(T)[None, :], safe]
        xv = jnp.take_along_axis(X, f.reshape(B, -1), axis=1).reshape(B, T)
        child = jnp.where(xv <= t, l, r)
        is_leaf = child < 0
        new_leaf = jnp.where(~done & is_leaf, -(child + 1), leaf)
        new_node = jnp.where(~done & ~is_leaf, child, node)
        new_done = done | is_leaf
        return (new_node, new_done, new_leaf), None

    (node, done, leaf), _ = jax.lax.scan(
        step, (node, done, leaf), None, length=ens.depth + 1
    )
    per_tree = jnp.take_along_axis(ens.leaf_value[None], leaf[:, :, None], axis=2)[..., 0]
    return per_tree.sum(axis=1) + ens.base_score


def partial_scores(ens: TreeEnsemble, X: jax.Array, sentinel: int) -> tuple[jax.Array, jax.Array]:
    """(scores after first ``sentinel`` trees, scores of the remaining tail).

    Full score = partial + tail + base. Used by all early-exit strategies.
    """
    _, per_tree = score_bitvector(ens, X, return_per_tree=True)
    head = per_tree[:, :sentinel].sum(axis=1) + ens.base_score
    tail = per_tree[:, sentinel:].sum(axis=1)
    return head, tail


def score_numpy_oracle(ens: TreeEnsemble, X: np.ndarray) -> np.ndarray:
    """Per-document recursive traversal — trusted ground truth."""
    feature = np.asarray(ens.feature)
    threshold = np.asarray(ens.threshold)
    left = np.asarray(ens.left)
    right = np.asarray(ens.right)
    leaf_value = np.asarray(ens.leaf_value)
    B = X.shape[0]
    out = np.full(B, float(ens.base_score), dtype=np.float64)
    for b in range(B):
        for t in range(ens.n_trees):
            n = 0
            while True:
                child = left[t, n] if X[b, feature[t, n]] <= threshold[t, n] else right[t, n]
                if child < 0:
                    out[b] += leaf_value[t, -(child + 1)]
                    break
                n = child
    return out.astype(np.float32)
