# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from repro.kernels.forest_score import LEAF_GATHERS
from repro.kernels.ops import (
    ENGINE_BLOCK_B,
    LEAF_SELECT_MAX,
    PaddedForest,
    env_int,
    forest_score,
    forest_score_range,
    forest_score_segments,
    launch_counts,
    padded_forest,
    reset_launch_counts,
    resolve_leaf_gather,
)

__all__ = [
    "ENGINE_BLOCK_B",
    "LEAF_GATHERS",
    "LEAF_SELECT_MAX",
    "PaddedForest",
    "env_int",
    "forest_score",
    "forest_score_range",
    "forest_score_segments",
    "launch_counts",
    "padded_forest",
    "reset_launch_counts",
    "resolve_leaf_gather",
]
