# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from repro.kernels.ops import (
    PaddedForest,
    forest_score,
    forest_score_range,
    forest_score_segments,
    launch_counts,
    padded_forest,
    reset_launch_counts,
)

__all__ = [
    "PaddedForest",
    "forest_score",
    "forest_score_range",
    "forest_score_segments",
    "launch_counts",
    "padded_forest",
    "reset_launch_counts",
]
