"""Pallas TPU kernel: additive tree-ensemble scoring (QuickScorer, TPU-native).

Tiling
------
Grid ``(B / BB, T_run / BT)``; docs are the parallel axis, tree-blocks the
sequential (minor) accumulation axis. Per grid step, VMEM holds:

- one doc block      ``x        [BB, F]``   (f32)
- one tree block     ``feature  [BT, N]`` / ``threshold [BT, N]`` (i32/f32)
-                    ``mask_lo/hi [BT, N]`` (u32, QuickScorer false-node masks)
-                    ``leaf_value [BT, L]`` (f32)
- the output block   ``scores   [BB]``     (f32, accumulated across tree blocks)

Algorithm (per doc block × tree block)
--------------------------------------
1. **Feature gather as MXU matmul** — the CPU algorithm's per-node feature
   load becomes ``x [BB, F] @ onehot(feature)ᵀ [F, BT·N]``, a dense matmul
   the MXU executes at full rate. One-hot is built in-register from a lane
   iota; no gather instruction is emitted.
2. Node predicates ``x_f <= θ`` select either the all-ones word or the
   node's false-mask (two u32 lanes).
3. Order-free AND-reduction over the node axis (contiguous-halves tree
   reduction — legal because AND is associative/commutative).
4. Exit leaf = count-trailing-zeros via ``popcount(~m & (m−1))`` on the two
   lanes, then leaf values are resolved through one of three *leaf-gather
   paths* (see below).
5. Tree-block partial scores accumulate into the output block; the first
   tree step zero-initializes.

Leaf-gather paths
-----------------
Resolving ``leaf_value[t, leaf[b, t]]`` dominated VPU time at the default
L=64: the original formulation builds a ``[BB, BT, L]`` one-hot
(compare + multiply + reduce ≈ 3·L VPU ops per doc·tree and an L-wide
temp). Three interchangeable paths now exist, selected by the static
``leaf_gather`` argument; all move the SAME f32 values, so they are
bit-exact with each other:

- ``"select"`` (default for L ≤ :data:`repro.kernels.ops.LEAF_SELECT_MAX`): a two-level
  select tree — log2(L) rounds of lane selects on the bits of the ctz
  leaf index, MSB first, so every round slices the value array into
  *contiguous halves* (lane-friendly on the VPU, no strided shuffles).
  ≈ L selects per doc·tree (the rounds halve: L/2 + L/4 + … + 1) and the
  widest temp is ``[BB, BT, L/2]`` — the first round reads the ``[BT, L]``
  table directly. Requires a power-of-two leaf axis; the padded-buffer
  builder (:func:`repro.kernels.ops.padded_forest`) pads the leaf axis
  and tags the layout (``leaf_layout="pow2"``).
- ``"mxu"`` (default for L > :data:`repro.kernels.ops.LEAF_SELECT_MAX`): the one-hot is
  contracted against the leaf table on the MXU — a ``dot_general`` with
  the tree axis as batch dim (per tree: ``[BB, L] @ [L]``), so the
  multiply-reduce leaves the VPU entirely. Exact because each output row
  sums one ``v·1.0`` against L−1 zeros.
- ``"onehot"``: the original broadcast-multiply-reduce, kept as the
  in-kernel reference path (and the oracle the parity tests pin the new
  paths against).

Both entry points are dispatched through the counting wrapper in
:mod:`repro.kernels.ops` (``_counted_pallas``): launches are recorded at
staging time (per eager call, per trace under an enclosing ``jit``), so the
cascade engine's end-to-end jitted step keeps a testable launch contract
while XLA fuses the surrounding compact/gather/scatter work.

Tree ranges (head/tail from one buffer)
---------------------------------------
``tree_block_offset`` / ``n_tree_blocks`` restrict a launch to the padded
tree-block range ``[offset, offset + n)`` of a single device-resident buffer
set: the grid's minor axis shrinks to ``n`` and the tree-side index maps add
the static offset. Head and tail of a cascade therefore score from the SAME
padded arrays — no per-call re-slice / re-pad, no extra HBM copies.

Sentinel-segmented output mode
------------------------------
:func:`forest_score_segments_pallas` replaces the scalar accumulator with a
``[B, S]`` per-segment accumulator, where the S static segment boundaries
(``seg_block_starts``, in tree-block units) partition the launched tree
range. Each grid step derives its segment id from ``program_id(1)`` (a
static unrolled sum of ``j >= start`` predicates — scalar work) and
accumulates its partial into that segment's column via a tiny ``[BB, S]``
one-hot multiply-add (order-free, no dynamic stores). One launch therefore
yields the partial score of every document at EVERY sentinel; prefix scores
are a ``[B, S]`` cumsum outside the kernel. This is what lets an S-stage
cascade issue one head launch instead of S ``pallas_call``s with one HBM
round-trip each.

VMEM budget (defaults ``BB=256, BT=16, N=63→64, L=64, F≤256``):
x 256·256·4 = 256 KiB; node tables 16·64·(4+4+4+4+4) ≈ 20 KiB;
onehot intermediate 256·1024·4 = 1 MiB; masks 256·16·64·4·2 = 2 MiB →
well under the ~16 MiB/core VMEM envelope with double buffering. The
segmented mode adds only the ``[BB, S]`` accumulator (S ≤ ~8 sentinels:
256·8·4 = 8 KiB) and an ``[BB, S]`` one-hot temp — VMEM-negligible, and the
extra VPU cost per grid step is O(BB·S) against the O(BB·BT·N) scoring work
(< 0.1% at the defaults).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.typecheck import Array, Float32, Int32, Ref, UInt32

ALL_ONES = np.uint32(0xFFFFFFFF)

LEAF_GATHERS = ("onehot", "select", "mxu")


def _next_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


def _ctz64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    lo_nz = lo != 0
    m = jnp.where(lo_nz, lo, hi)
    ctz32 = jax.lax.population_count(~m & (m - jnp.uint32(1)))
    return jnp.where(lo_nz, ctz32, ctz32 + jnp.uint32(32)).astype(jnp.int32)


def _leaf_values_onehot(leaf: jax.Array, leaf_tab: jax.Array) -> jax.Array:
    """Reference path: ``[BB, BT, L]`` one-hot broadcast-multiply-reduce."""
    L = leaf_tab.shape[1]
    onehot = (
        leaf[:, :, None] == jax.lax.iota(jnp.int32, L)[None, None, :]
    ).astype(jnp.float32)
    # repro: noqa(TS003) -- reduces over the LEAF axis, not trees: each
    # row of the one-hot has exactly one nonzero, so the sum SELECTS a
    # single leaf value and is order-free by construction.
    return jnp.sum(onehot * leaf_tab[None, :, :], axis=2)


def _leaf_values_select(leaf: jax.Array, leaf_tab: jax.Array) -> jax.Array:
    """Two-level select tree: log2(L) rounds of contiguous-half lane selects
    on the leaf-index bits, MSB first. L must be a power of two."""
    BT, L = leaf_tab.shape
    assert L & (L - 1) == 0, f"select path needs a power-of-two leaf axis: {L}"
    if L == 1:
        return jnp.broadcast_to(leaf_tab[None, :, 0], leaf.shape)
    levels = L.bit_length() - 1
    # Round 0 reads the [BT, L] table directly — the widest materialized
    # temp is [BB, BT, L/2], not the one-hot path's [BB, BT, L].
    half = L // 2
    take_hi = ((leaf >> (levels - 1)) & 1) == 1                  # [BB, BT]
    cur = jnp.where(
        take_hi[:, :, None], leaf_tab[None, :, half:], leaf_tab[None, :, :half]
    )
    for r in range(levels - 2, -1, -1):
        half = cur.shape[2] // 2
        take_hi = ((leaf >> r) & 1) == 1
        cur = jnp.where(take_hi[:, :, None], cur[..., half:], cur[..., :half])
    return cur[..., 0]


def _leaf_values_mxu(leaf: jax.Array, leaf_tab: jax.Array) -> jax.Array:
    """MXU contraction: one-hot rows dotted against the leaf table, tree
    axis batched — per tree a ``[BB, L] @ [L]`` matvec."""
    L = leaf_tab.shape[1]
    onehot = (
        leaf[:, :, None] == jax.lax.iota(jnp.int32, L)[None, None, :]
    ).astype(jnp.float32)
    per_tree = jax.lax.dot_general(
        onehot, leaf_tab,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                            # [BT, BB]
    return per_tree.T


_LEAF_VALUE_FNS = {
    "onehot": _leaf_values_onehot,
    "select": _leaf_values_select,
    "mxu": _leaf_values_mxu,
}


def _pairwise_tree_sum(per_tree: jax.Array) -> jax.Array:
    """Deterministic contiguous-halves sum over the tree axis: [BB, BT]→[BB].

    Explicit elementwise adds instead of ``jnp.sum`` — a ``reduce``'s
    association is implementation-defined and shifts with how XLA fuses the
    surrounding graph, which would break the leaf-gather paths' bit-for-bit
    parity (their per-tree values are identical; only a reassociated final
    sum could diverge). Handles non-power-of-two BT by carrying the odd
    trailing element.
    """
    n = per_tree.shape[1]
    while n > 1:
        half = n // 2
        summed = per_tree[:, :half] + per_tree[:, half:2 * half]
        if n % 2:
            summed = jnp.concatenate([summed, per_tree[:, 2 * half:]], axis=1)
        per_tree = summed
        n = per_tree.shape[1]
    return per_tree[:, 0]


def _score_block(
    x_ref: Ref, feat_ref: Ref, thr_ref: Ref, mlo_ref: Ref, mhi_ref: Ref,
    leaf_ref: Ref,
    leaf_gather: str = "onehot",
) -> jax.Array:
    """One doc-block × tree-block partial score [BB] (steps 1-4 above)."""
    x = x_ref[...]
    feat = feat_ref[...]
    BB, F = x.shape
    BT, N = feat.shape

    # (1) Feature gather via one-hot MXU matmul: xf[b, t*N+n] = x[b, feat[t,n]].
    flat_feat = feat.reshape(BT * N)
    onehot = (flat_feat[:, None] == jax.lax.iota(jnp.int32, F)[None, :]).astype(x.dtype)
    xf = jax.lax.dot_general(
        x, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(BB, BT, N)

    # (2) Predicates → mask selection.
    pred_true = xf <= thr_ref[...][None, :, :]
    m_lo = jnp.where(pred_true, ALL_ONES, mlo_ref[...][None, :, :])
    m_hi = jnp.where(pred_true, ALL_ONES, mhi_ref[...][None, :, :])

    # (3) AND tree-reduction over nodes (N padded to a power of two upstream).
    n = N
    while n > 1:
        half = n // 2
        m_lo = m_lo[..., :half] & m_lo[..., half:n]
        m_hi = m_hi[..., :half] & m_hi[..., half:n]
        n = half
    and_lo = m_lo[..., 0]
    and_hi = m_hi[..., 0]

    # (4) Exit leaf → leaf-value resolution via the selected gather path.
    leaf = _ctz64(and_hi, and_lo)                                   # [BB, BT]
    per_tree = _LEAF_VALUE_FNS[leaf_gather](leaf, leaf_ref[...])    # [BB, BT]
    return _pairwise_tree_sum(per_tree)                             # [BB]


def _forest_score_kernel(
    x_ref: Ref,        # [BB, F] f32
    feat_ref: Ref,     # [BT, N] i32
    thr_ref: Ref,      # [BT, N] f32
    mlo_ref: Ref,      # [BT, N] u32
    mhi_ref: Ref,      # [BT, N] u32
    leaf_ref: Ref,     # [BT, L] f32
    out_ref: Ref,      # [BB] f32 (accumulated over tree-block grid axis)
    *,
    leaf_gather: str,
) -> None:
    partial = _score_block(
        x_ref, feat_ref, thr_ref, mlo_ref, mhi_ref, leaf_ref,
        leaf_gather=leaf_gather,
    )

    # (5) Accumulate across the sequential tree-block axis.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def _forest_score_segments_kernel(
    x_ref: Ref, feat_ref: Ref, thr_ref: Ref, mlo_ref: Ref, mhi_ref: Ref,
    leaf_ref: Ref,
    out_ref: Ref,  # [BB, S] f32 — per-segment partials, accumulated over j
    *,
    seg_block_starts: tuple[int, ...],
    leaf_gather: str,
) -> None:
    partial = _score_block(
        x_ref, feat_ref, thr_ref, mlo_ref, mhi_ref, leaf_ref,
        leaf_gather=leaf_gather,
    )

    # Segment id of this tree block: static unrolled predicate sum (scalar).
    j = pl.program_id(1)
    seg = jnp.int32(0)
    for start in seg_block_starts[1:]:
        seg = seg + (j >= start).astype(jnp.int32)

    n_seg = len(seg_block_starts)
    seg_onehot = (jax.lax.iota(jnp.int32, n_seg) == seg).astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Order-free accumulate into the segment's column; no dynamic store.
    out_ref[...] += partial[:, None] * seg_onehot[None, :]


def _tree_specs(
    block_t: int, n: int, leaves: int, offset: int
) -> list[pl.BlockSpec]:
    spec = lambda width: pl.BlockSpec((block_t, width), lambda i, j: (j + offset, 0))
    return [spec(n), spec(n), spec(n), spec(n), spec(leaves)]


def _check_leaf_gather(leaf_gather: str, n_leaves: int) -> None:
    assert leaf_gather in LEAF_GATHERS, leaf_gather
    if leaf_gather == "select":
        assert n_leaves & (n_leaves - 1) == 0, (
            f"leaf_gather='select' needs a power-of-two leaf axis, got "
            f"{n_leaves} — use repro.kernels.ops.padded_forest (it pads the "
            f"leaf axis and tags the layout) or pass 'mxu'/'onehot'"
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_b", "block_t", "tree_block_offset", "n_tree_blocks",
        "leaf_gather", "interpret",
    ),
)
def forest_score_pallas(
    x: Float32[Array, "b f"],          # B % block_b == 0, F lane-padded
    feature: Int32[Array, "t n"],      # T % block_t == 0, N power of two
    threshold: Float32[Array, "t n"],
    mask_lo: UInt32[Array, "t n"],
    mask_hi: UInt32[Array, "t n"],
    leaf_value: Float32[Array, "t l"],
    *,
    block_b: int = 256,
    block_t: int = 16,
    tree_block_offset: int = 0,
    n_tree_blocks: int | None = None,
    leaf_gather: str = "onehot",
    interpret: bool = True,
) -> Float32[Array, "b"]:
    B, F = x.shape
    T, N = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0, (B, block_b, T, block_t)
    assert N & (N - 1) == 0, f"node axis must be a power of two, got {N}"
    _check_leaf_gather(leaf_gather, L)
    total_blocks = T // block_t
    if n_tree_blocks is None:
        n_tree_blocks = total_blocks - tree_block_offset
    assert 0 < n_tree_blocks <= total_blocks - tree_block_offset, (
        n_tree_blocks, tree_block_offset, total_blocks
    )

    grid = (B // block_b, n_tree_blocks)
    return pl.pallas_call(
        functools.partial(_forest_score_kernel, leaf_gather=leaf_gather),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
            *_tree_specs(block_t, N, L, tree_block_offset),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(x, feature, threshold, mask_lo, mask_hi, leaf_value)


@functools.partial(
    jax.jit,
    static_argnames=(
        "seg_block_starts", "n_tree_blocks", "block_b", "block_t",
        "leaf_gather", "interpret",
    ),
)
def forest_score_segments_pallas(
    x: Float32[Array, "b f"],          # B % block_b == 0, F lane-padded
    feature: Int32[Array, "t n"],      # T % block_t == 0, N power of two
    threshold: Float32[Array, "t n"],
    mask_lo: UInt32[Array, "t n"],
    mask_hi: UInt32[Array, "t n"],
    leaf_value: Float32[Array, "t l"],
    *,
    seg_block_starts: tuple[int, ...],  # ascending, seg_block_starts[0] == 0
    n_tree_blocks: int,                 # launch covers blocks [0, n)
    block_b: int = 256,
    block_t: int = 16,
    leaf_gather: str = "onehot",
    interpret: bool = True,
) -> Float32[Array, "b s"]:
    """Single launch → per-segment partial scores ``[B, S]``.

    Segment ``k`` covers tree blocks ``[seg_block_starts[k],
    seg_block_starts[k+1])`` (the last runs to ``n_tree_blocks``). Prefix
    scores at sentinel ``k`` are ``cumsum(out, axis=1)[:, k]``.
    """
    B, F = x.shape
    T, N = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0, (B, block_b, T, block_t)
    assert N & (N - 1) == 0, f"node axis must be a power of two, got {N}"
    _check_leaf_gather(leaf_gather, L)
    assert seg_block_starts[0] == 0
    assert list(seg_block_starts) == sorted(set(seg_block_starts))
    assert 0 < n_tree_blocks <= T // block_t
    assert seg_block_starts[-1] < n_tree_blocks
    n_seg = len(seg_block_starts)

    grid = (B // block_b, n_tree_blocks)
    kernel = functools.partial(
        _forest_score_segments_kernel,
        seg_block_starts=seg_block_starts,
        leaf_gather=leaf_gather,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
            *_tree_specs(block_t, N, L, 0),
        ],
        out_specs=pl.BlockSpec((block_b, n_seg), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_seg), jnp.float32),
        interpret=interpret,
    )(x, feature, threshold, mask_lo, mask_hi, leaf_value)
