"""Jit'd public wrapper around the Pallas forest-scoring kernel.

Handles padding to kernel alignment (doc blocks, tree blocks, power-of-two
node axis, lane-padded feature axis) and unpadding of the result. On CPU
(this container) the kernel runs in interpret mode; on TPU it compiles to
Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.ensemble import TreeEnsemble
from repro.kernels.forest_score import forest_score_pallas

LANE = 128


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def forest_score(
    ens: TreeEnsemble,
    X: jax.Array,
    *,
    block_b: int = 256,
    block_t: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """Score ``X: [B, F]`` through the ensemble with the Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = X.shape
    T, N = ens.feature.shape

    block_b = min(block_b, _next_pow2(max(B, 8)))
    block_t = min(block_t, _next_pow2(max(T, 1)))

    x = _pad_to(X.astype(jnp.float32), 0, block_b)
    x = _pad_to(x, 1, LANE)
    n_pad = _next_pow2(max(N, 2))
    # Padded nodes: threshold +inf ⇒ predicate always true ⇒ all-ones mask.
    feat = _pad_to(_pad_to(ens.feature, 1, n_pad), 0, block_t)
    thr = _pad_to(_pad_to(ens.threshold.astype(jnp.float32), 1, n_pad, np.inf),
                  0, block_t, np.inf)
    ones = np.uint32(0xFFFFFFFF)
    mlo = _pad_to(_pad_to(ens.mask_lo, 1, n_pad, ones), 0, block_t, ones)
    mhi = _pad_to(_pad_to(ens.mask_hi, 1, n_pad, ones), 0, block_t, ones)
    # Padded trees: leaf values 0 ⇒ contribute nothing.
    leaf = _pad_to(ens.leaf_value.astype(jnp.float32), 0, block_t)

    scores = forest_score_pallas(
        x, feat, thr, mlo, mhi, leaf,
        block_b=block_b, block_t=block_t, interpret=interpret,
    )
    return scores[:B] + ens.base_score
