"""Jit'd public wrappers around the Pallas forest-scoring kernels.

Handles padding to kernel alignment (doc blocks, tree blocks, power-of-two
node axis, lane-padded feature axis) and unpadding of the result. On CPU
(this container) the kernel runs in interpret mode; on TPU it compiles to
Mosaic.

Padded-buffer caching
---------------------
:func:`padded_forest` builds the kernel-aligned device buffers for an
ensemble ONCE and caches them on the :class:`TreeEnsemble` instance (keyed
by segment boundaries × tree-block size × leaf-gather path), so repeated
scoring — the serving hot path — never re-pads. Segment boundaries (cascade
sentinels) need NOT be tree-block aligned: each segment is padded
independently with no-op trees (threshold ``+inf`` ⇒ always-true ⇒ all-ones
mask; leaf values 0), which makes every segment start block-aligned by
construction. Head and tail of a cascade then score from the same buffer
set via ``tree_block_offset`` / ``n_tree_blocks`` —
:func:`repro.forest.ensemble.slice_trees` re-padding is gone from the hot
path.

Leaf-gather layout
------------------
The buffer set carries a per-path leaf layout: the kernel's select-tree
leaf gather (:mod:`repro.kernels.forest_score`, ``leaf_gather="select"``)
walks the leaf-index bits over contiguous halves of the value array, so it
needs the leaf axis padded to a power of two (``leaf_layout="pow2"``,
padding values 0 — never selected, the ctz leaf index stays below the real
leaf count). The one-hot and MXU paths read the native leaf axis
(``leaf_layout="native"``). ``leaf_gather="auto"`` (the default) resolves
via :func:`repro.kernels.forest_score.resolve_leaf_gather`: select tree up
to ``LEAF_SELECT_MAX`` padded leaves, MXU contraction above. All paths are
bit-exact with each other, so the resolved choice is a pure perf knob.

Launch accounting
-----------------
Every kernel dispatch below goes through :func:`_counted_pallas`, a counting
``pallas_call`` wrapper that records the launch **when the call is staged**:
eagerly that is once per call, and under an enclosing ``jax.jit`` it is once
per *trace* — a cached re-execution of the compiled computation adds zero,
because no new launch is staged into it. This is what lets the whole
progressive cascade step (segmented head → stage decisions → compaction →
tail → scatter) compile into ONE XLA computation while the 1-head-launch
contract stays testable: tests trace a fresh step, read
:func:`launch_counts` (split ``plain`` / ``segmented`` / ``gated`` — the
last for launches staged behind a run-time skip condition, e.g. the
query-exit gated tail), and assert the counts do not move on cached
re-executions.

Trace-time vs run-time, under ``lax.cond``: the counters describe the
launches *staged into* a computation, not the launches a particular batch
*executed*. The distinction only matters for the combined
``mode="auto"`` progressive step, where BOTH execution branches live under
one ``lax.cond``: tracing it stages the fused branch's launches (1
segmented + ≤1 plain) AND the staged branch's (≤S+1 plain) — each exactly
once — while at run time only the branch the device pick selects actually
dispatches. So the auto-step contract is ``segmented == 1`` and
``plain == S+2`` (with a tail region; S ≥ 2) per trace, stable across
re-executions regardless of which branch each batch takes.
"""

from __future__ import annotations

import dataclasses
import os
import typing
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.forest_score import (
    _next_pow2,
    forest_score_pallas,
    forest_score_segments_pallas,
)

if typing.TYPE_CHECKING:  # annotation-only: keeps ops importable before
    from repro.forest.ensemble import TreeEnsemble  # repro.forest (no cycle)

LANE = 128
ALL_ONES = np.uint32(0xFFFFFFFF)


def env_int(name: str, default: int, *, minimum: int = 1) -> int:
    """THE environment-override helper for the engine's tuning constants.

    Deployment knobs that used to be hard module constants
    (:data:`PADDED_CACHE_MAX`, :data:`LEAF_SELECT_MAX`,
    :data:`repro.core.features.RANK_BLOCKED_MIN_D`) read their value
    through this single chokepoint at import time: unset or empty →
    ``default``; a non-integer or a value below ``minimum`` raises
    immediately (a silently-ignored typo'd override is worse than a
    startup crash). Overrides are read ONCE at module import — set the
    variable before the first ``repro`` import, as with ``XLA_FLAGS``.
    """
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be an integer, got {raw!r}"
        ) from None
    if value < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {value}")
    return value


# Default doc-block size of every kernel dispatch below. Decision-time
# pricing (repro.metrics.speedup.progressive_cost_model, block_b-rounded
# survivor counts) must quote the same number, so it lives here as THE
# engine constant rather than as scattered literals.
ENGINE_BLOCK_B = 256

# Bound on cached (boundaries, block_t) buffer layouts per ensemble: a
# long-running service sweeping sentinel configs must not leak device
# memory. Eviction is LRU; a re-requested layout is simply re-padded.
PADDED_CACHE_MAX = env_int("REPRO_PADDED_CACHE_MAX", 8)

# Auto leaf-gather policy cutoff: select tree up to this many (padded)
# leaves, MXU contraction above. The paper's trees cap at 64 leaves (the
# bitmask bound), so serving traffic takes the select path; the MXU
# fallback covers wide synthetic/padded leaf tables. The crossover was
# measured in interpret mode (ROADMAP item 1 revisits it on real
# hardware), hence overridable per deployment.
LEAF_SELECT_MAX = env_int("REPRO_LEAF_SELECT_MAX", 64)


def resolve_leaf_gather(n_leaves: int) -> str:
    """Concrete leaf-gather path for ``"auto"``: select tree for small leaf
    axes (after power-of-two padding), MXU contraction for wide ones."""
    return "select" if _next_pow2(n_leaves) <= LEAF_SELECT_MAX else "mxu"

_LAUNCH_COUNTS = {"plain": 0, "segmented": 0, "gated": 0}


def reset_launch_counts() -> None:
    """Zero all counters (typically right before tracing a fresh step)."""
    for kind in _LAUNCH_COUNTS:
        _LAUNCH_COUNTS[kind] = 0


def launch_counts() -> dict[str, int]:
    """Launches STAGED since the last reset, keyed ``plain`` /
    ``segmented`` / ``gated``.

    ``gated`` counts launches staged behind a run-time skip condition:
    with query-level exit enabled, the progressive tail launch sits
    under a ``lax.cond`` on the survivor count, so a batch whose queries
    all converged dispatches no tail kernel at all. Like the other two
    counters this is TRACE-time accounting — the gate's run-time
    outcome shows up in the trees-traversed metric, not here.

    Trace-time accounting: a cached re-execution of a compiled step adds
    zero; a ``lax.cond`` with kernel calls in both branches adds both
    branches once (see the module docstring). Use with
    :func:`reset_launch_counts` to assert launch contracts in tests.
    """
    return dict(_LAUNCH_COUNTS)


def _counted_pallas(
    kind: str, call: typing.Callable[..., jax.Array],
    *args: object, **kwargs: object,
) -> jax.Array:
    """Counting ``pallas_call`` wrapper: record the launch at staging time.

    ``call`` is one of the (jitted) Pallas entry points. The counter bumps
    when this wrapper's Python body runs — per call in eager execution, per
    trace under an enclosing ``jit`` — so counts reflect launches staged
    into each computation, and stay stable across cached re-executions.
    """
    _LAUNCH_COUNTS[kind] += 1
    return call(*args, **kwargs)


def _pad_to(
    x: jax.Array, axis: int, multiple: int, value: float | jax.Array = 0
) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def effective_block_b(block_b: int, n_rows: int) -> int:
    """Doc-block size a launch over ``n_rows`` rows actually uses: the
    requested block, shrunk to the padded row count for small batches.
    THE block policy — :func:`_prep_x` applies it to every dispatch and
    the decision-time cost model
    (:func:`repro.metrics.speedup.progressive_cost_model`) imports it to
    price staged stages, so the two cannot drift apart.
    """
    return min(block_b, _next_pow2(max(int(n_rows), 8)))


@dataclasses.dataclass(frozen=True)
class PaddedForest:
    """Kernel-aligned device buffers for one ensemble + segment layout.

    Segment ``k`` occupies padded tree blocks
    ``[seg_block_starts[k], seg_block_starts[k] + seg_blocks[k])``; segments
    are contiguous, so any segment range is one contiguous block range.
    """

    feature: jax.Array     # [T_pad, N_pad] i32
    threshold: jax.Array   # [T_pad, N_pad] f32
    mask_lo: jax.Array     # [T_pad, N_pad] u32
    mask_hi: jax.Array     # [T_pad, N_pad] u32
    leaf_value: jax.Array  # [T_pad, L_layout] f32 — see leaf_layout
    base_score: jax.Array  # [] f32
    boundaries: tuple[int, ...]       # cumulative tree-unit segment ends
    seg_block_starts: tuple[int, ...]  # per-segment start, in blocks
    seg_blocks: tuple[int, ...]        # per-segment length, in blocks
    block_t: int
    leaf_gather: str = "onehot"   # resolved kernel path for this buffer set
    leaf_layout: str = "native"   # "pow2": leaf axis padded for the select
    #   path's contiguous-half bit walk; "native": ensemble leaf axis as-is

    @property
    def n_segments(self) -> int:
        return len(self.boundaries)

    @property
    def n_trees(self) -> int:
        return self.boundaries[-1]


def padded_forest(
    ens: TreeEnsemble,
    boundaries: tuple[int, ...] | None = None,
    block_t: int = 16,
    leaf_gather: str = "auto",
) -> PaddedForest:
    """Pad once, score many: cached kernel-aligned buffers for ``ens``.

    ``boundaries`` are cumulative segment ends in tree units (ascending,
    last == ``ens.n_trees``); ``None`` means one segment. ``leaf_gather``
    picks the kernel's leaf-value resolution path (and with it the leaf
    buffer layout — the select tree needs a power-of-two leaf axis);
    ``"auto"`` resolves per :func:`~repro.kernels.forest_score.resolve_leaf_gather`.
    The result is cached on the ensemble instance keyed by ``(boundaries,
    block_t, leaf_gather)``, bounded to the :data:`PADDED_CACHE_MAX` most
    recently used layouts (LRU eviction — sweeping sentinel configs must
    not leak device memory).
    """
    T, N = ens.feature.shape
    boundaries = tuple(boundaries) if boundaries is not None else (T,)
    assert boundaries[-1] == T, (boundaries, T)
    assert all(b > 0 for b in boundaries)
    assert list(boundaries) == sorted(set(boundaries)), boundaries
    block_t = min(block_t, _next_pow2(max(T, 1)))
    if leaf_gather == "auto":
        leaf_gather = resolve_leaf_gather(ens.n_leaves)

    cache = getattr(ens, "_padded_cache", None)
    if cache is None:
        cache = OrderedDict()
        object.__setattr__(ens, "_padded_cache", cache)
    key = (boundaries, block_t, leaf_gather)
    if key in cache:
        cache.move_to_end(key)
        return cache[key]

    # The builder may run while an enclosing cascade step is TRACING (the
    # classifier's kernel path calls in from inside the jitted step); the
    # buffers must still be concrete — they are cached on the ensemble and
    # outlive the trace. ensure_compile_time_eval escapes the trace: all
    # padding ops below execute eagerly on the concrete ensemble arrays.
    with jax.ensure_compile_time_eval():
        return _build_padded_forest(
            ens, cache, key, boundaries, block_t, leaf_gather
        )


def _build_padded_forest(
    ens: TreeEnsemble,
    cache: OrderedDict,
    key: tuple,
    boundaries: tuple[int, ...],
    block_t: int,
    leaf_gather: str,
) -> PaddedForest:
    N = ens.feature.shape[1]
    n_pad = _next_pow2(max(N, 2))
    # Padded nodes: threshold +inf ⇒ predicate always true ⇒ all-ones mask.
    feat = _pad_to(ens.feature, 1, n_pad)
    thr = _pad_to(ens.threshold.astype(jnp.float32), 1, n_pad, np.inf)
    mlo = _pad_to(ens.mask_lo, 1, n_pad, ALL_ONES)
    mhi = _pad_to(ens.mask_hi, 1, n_pad, ALL_ONES)
    leaf = ens.leaf_value.astype(jnp.float32)
    # Per-path leaf layout: the select tree's contiguous-half bit walk
    # needs a power-of-two leaf axis; pad values are 0 and unreachable
    # (every ctz leaf index is below the real leaf count).
    leaf_layout = "native"
    if leaf_gather == "select":
        Lp = _next_pow2(max(ens.n_leaves, 1))
        if Lp != ens.n_leaves:
            leaf = _pad_to(leaf, 1, Lp)
        leaf_layout = "pow2"

    # Per-segment tree padding: no-op trees (always-true nodes, zero leaves).
    parts = {name: [] for name in ("feat", "thr", "mlo", "mhi", "leaf")}
    seg_block_starts, seg_blocks = [], []
    start = offset = 0
    for end in boundaries:
        parts["feat"].append(_pad_to(feat[start:end], 0, block_t))
        parts["thr"].append(_pad_to(thr[start:end], 0, block_t, np.inf))
        parts["mlo"].append(_pad_to(mlo[start:end], 0, block_t, ALL_ONES))
        parts["mhi"].append(_pad_to(mhi[start:end], 0, block_t, ALL_ONES))
        parts["leaf"].append(_pad_to(leaf[start:end], 0, block_t))
        nb = parts["feat"][-1].shape[0] // block_t
        seg_block_starts.append(offset)
        seg_blocks.append(nb)
        offset += nb
        start = end

    pf = PaddedForest(
        feature=jnp.concatenate(parts["feat"]),
        threshold=jnp.concatenate(parts["thr"]),
        mask_lo=jnp.concatenate(parts["mlo"]),
        mask_hi=jnp.concatenate(parts["mhi"]),
        leaf_value=jnp.concatenate(parts["leaf"]),
        base_score=ens.base_score,
        boundaries=boundaries,
        seg_block_starts=tuple(seg_block_starts),
        seg_blocks=tuple(seg_blocks),
        block_t=block_t,
        leaf_gather=leaf_gather,
        leaf_layout=leaf_layout,
    )
    cache[key] = pf
    while len(cache) > PADDED_CACHE_MAX:
        cache.popitem(last=False)
    return pf


def _prep_x(X: jax.Array, block_b: int) -> tuple[jax.Array, int]:
    B = X.shape[0]
    block_b = effective_block_b(block_b, B)
    x = _pad_to(X.astype(jnp.float32), 0, block_b)
    x = _pad_to(x, 1, LANE)
    return x, block_b


def forest_score_range(
    pf: PaddedForest,
    X: jax.Array,
    seg_lo: int = 0,
    seg_hi: int | None = None,
    *,
    block_b: int = ENGINE_BLOCK_B,
    interpret: bool | None = None,
    count_as: str = "plain",
) -> jax.Array:
    """Score ``X: [B, F]`` through segments ``[seg_lo, seg_hi)`` — 1 launch.

    ``base_score`` is added only when the range starts at segment 0
    (mirroring :func:`repro.forest.ensemble.slice_trees` semantics).
    ``count_as`` picks the launch-accounting bucket: ``"plain"`` for an
    unconditional launch, ``"gated"`` when the caller stages this launch
    behind a run-time skip condition (the query-exit gated tail).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    seg_hi = pf.n_segments if seg_hi is None else seg_hi
    assert 0 <= seg_lo < seg_hi <= pf.n_segments, (seg_lo, seg_hi)
    assert count_as in ("plain", "gated"), count_as
    B = X.shape[0]
    x, block_b = _prep_x(X, block_b)

    scores = _counted_pallas(
        count_as, forest_score_pallas,
        x, pf.feature, pf.threshold, pf.mask_lo, pf.mask_hi, pf.leaf_value,
        block_b=block_b,
        block_t=pf.block_t,
        tree_block_offset=pf.seg_block_starts[seg_lo],
        n_tree_blocks=sum(pf.seg_blocks[seg_lo:seg_hi]),
        leaf_gather=pf.leaf_gather,
        interpret=interpret,
    )
    base = pf.base_score if seg_lo == 0 else jnp.zeros_like(pf.base_score)
    return scores[:B] + base


def forest_score_segments(
    pf: PaddedForest,
    X: jax.Array,
    n_segments: int | None = None,
    *,
    block_b: int = ENGINE_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-segment partial scores ``[B, S]`` for segments ``[0, S)`` — 1 launch.

    ``cumsum(result, axis=1) + base_score`` gives the prefix score of every
    document at every segment boundary (i.e. at every cascade sentinel).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = pf.n_segments if n_segments is None else n_segments
    assert 0 < S <= pf.n_segments, (S, pf.n_segments)
    B = X.shape[0]
    x, block_b = _prep_x(X, block_b)

    seg_scores = _counted_pallas(
        "segmented", forest_score_segments_pallas,
        x, pf.feature, pf.threshold, pf.mask_lo, pf.mask_hi, pf.leaf_value,
        seg_block_starts=pf.seg_block_starts[:S],
        n_tree_blocks=pf.seg_block_starts[S - 1] + pf.seg_blocks[S - 1],
        block_b=block_b,
        block_t=pf.block_t,
        leaf_gather=pf.leaf_gather,
        interpret=interpret,
    )
    return seg_scores[:B]


def forest_score(
    ens: TreeEnsemble,
    X: jax.Array,
    *,
    block_b: int = ENGINE_BLOCK_B,
    block_t: int = 16,
    leaf_gather: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Score ``X: [B, F]`` through the ensemble with the Pallas kernel."""
    pf = padded_forest(ens, block_t=block_t, leaf_gather=leaf_gather)
    return forest_score_range(pf, X, block_b=block_b, interpret=interpret)
