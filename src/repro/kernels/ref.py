"""Pure-jnp oracle for the forest-scoring kernel (no Pallas).

Semantically identical to :func:`repro.forest.scoring.score_bitvector`, kept
self-contained here per the kernels/ convention so the kernel test sweep has
a dependency-free reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ALL_ONES = jnp.uint32(0xFFFFFFFF)


def _ctz64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    lo_nz = lo != 0
    m = jnp.where(lo_nz, lo, hi)
    ctz32 = jax.lax.population_count(~m & (m - jnp.uint32(1)))
    return jnp.where(lo_nz, ctz32, ctz32 + jnp.uint32(32)).astype(jnp.int32)


def leaf_values_ref(leaf: jax.Array, leaf_value: jax.Array) -> jax.Array:
    """Gather oracle for the kernel's leaf-gather paths: a plain
    ``take_along_axis`` of ``leaf_value[t, leaf[b, t]]`` — the exact values
    every in-kernel path (one-hot, select tree, MXU contraction) must
    reproduce bit-for-bit. ``leaf: [B, T] i32``, ``leaf_value: [T, L]``."""
    return jnp.take_along_axis(leaf_value[None], leaf[:, :, None], axis=2)[..., 0]


def forest_score_ref(
    x: jax.Array,
    feature: jax.Array,
    threshold: jax.Array,
    mask_lo: jax.Array,
    mask_hi: jax.Array,
    leaf_value: jax.Array,
) -> jax.Array:
    """x: [B, F]; tree arrays [T, N] / [T, L] → scores [B] f32."""
    xf = x[:, feature]                                  # [B, T, N]
    pred_true = xf <= threshold[None]
    m_lo = jnp.where(pred_true, ALL_ONES, mask_lo[None])
    m_hi = jnp.where(pred_true, ALL_ONES, mask_hi[None])
    and_lo = jax.lax.reduce(m_lo, ALL_ONES, jax.lax.bitwise_and, dimensions=(2,))
    and_hi = jax.lax.reduce(m_hi, ALL_ONES, jax.lax.bitwise_and, dimensions=(2,))
    leaf = _ctz64(and_hi, and_lo)                       # [B, T]
    per_tree = leaf_values_ref(leaf, leaf_value)
    return per_tree.sum(axis=1).astype(jnp.float32)
