import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
2. resolves the arch's logical axes against the mesh rules,
3. ``jax.jit(step, in_shardings=…).lower(abstract_state, input_specs)``,
4. ``.compile()`` — proving the sharded program is coherent (no sharding
   mismatches, no unsupported collectives, memory fits),
5. records ``memory_analysis()`` / ``cost_analysis()`` / the roofline terms
   to ``artifacts/dryrun/<cell>.json`` for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, list_archs
from repro.configs.base import TransformerConfig
from repro.distributed.sharding import (
    multi_pod_rules,
    sharding_rules,
    single_pod_rules,
)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rf

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def is_logical(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )


def to_shardings(mesh, rules, logical_tree):
    return jax.tree.map(
        lambda lg: jax.sharding.NamedSharding(mesh, rules.resolve(*lg)),
        logical_tree,
        is_leaf=is_logical,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             override_cfg=None) -> dict:
    from repro.models.api import make_cell

    cfg = override_cfg or get_config(arch)
    shapes = {s.name: s for s in cfg.shapes}
    shape = shapes[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    if shape.skip_reason:
        record["skipped"] = shape.skip_reason
        return record, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = multi_pod_rules() if multi_pod else single_pod_rules()
    cell = make_cell(cfg, shape)

    t0 = time.time()
    with sharding_rules(rules), jax.sharding.set_mesh(mesh):
        state_sh = to_shardings(mesh, rules, cell.state_logical())
        input_sh = to_shardings(mesh, rules, cell.input_logical())
        lowered = jax.jit(
            cell.step, in_shardings=(state_sh, input_sh)
        ).lower(cell.abstract_state(), cell.input_specs())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        chips = mesh.devices.size
        model_flops = (
            rf.lm_model_flops(cfg, shape)
            if isinstance(cfg, TransformerConfig) else 0.0
        )
        hlo_text = compiled.as_text()
        roof = rf.roofline(compiled, chips=chips, model_flops=model_flops,
                           hlo_text=hlo_text)

    record.update(
        {
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "chips": chips,
            "memory": _mem_dict(mem, chips),
            "roofline": roof.to_dict(),
        }
    )
    return record, hlo_text


def _mem_dict(mem, chips: int) -> dict:
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, field, None)
        if v is not None:
            out[field] = int(v)
    # XLA:CPU reports whole-program sizes; per-device = /chips under SPMD.
    if "argument_size_in_bytes" in out:
        out["per_device_total_gib"] = round(
            (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)) / chips / 2**30, 3
        )
    return out


def all_cells(include_forest: bool = True):
    archs = list(ASSIGNED_ARCHS) + (["lear-msn1"] if include_forest else [])
    for arch in archs:
        cfg = get_config(arch)
        for shape in cfg.shapes:
            for multi_pod in (False, True):
                yield arch, shape.name, multi_pod


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list_archs())
    p.add_argument("--shape")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default=None)
    args = p.parse_args()

    out_dir = args.out or os.path.normpath(ARTIFACTS)
    os.makedirs(out_dir, exist_ok=True)

    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, multi_pod in cells:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        tag = f"{arch}__{shape}__{mesh_name}".replace("/", "_")
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                cached = json.load(f)
            if "error" not in cached:
                print(f"[skip-cached] {tag}")
                continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            record, hlo_text = run_cell(arch, shape, multi_pod)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            record, hlo_text = {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }, None
            print(f"  FAILED: {record['error']}", flush=True)
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        if hlo_text is not None:
            import gzip

            with gzip.open(os.path.join(out_dir, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo_text)
        if "roofline" in record:
            r = record["roofline"]
            print(
                f"  ok: compile={record['compile_s']}s "
                f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                f"coll={r['collective_s']:.2e}s dominant={r['dominant']}",
                flush=True,
            )
        elif "skipped" in record:
            print(f"  skipped: {record['skipped']}", flush=True)
    print(f"done, {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
