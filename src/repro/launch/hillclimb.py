import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing: named variants of the three selected cells.

Cells (selection rationale in EXPERIMENTS.md §Perf):
- lear-msn1 / rank_xl      — the paper's own technique: the compacted
  cascade IS the optimization; baseline = full scoring (paper's "Full").
- qwen2.5-14b / train_4k   — most representative large-LM training cell;
  collective-bound baseline with a known GSPMD pathology (embedding gather
  → involuntary full rematerialization).
- nequip / ogb_products    — worst roofline cell, 61.8M-edge full-graph
  training; collective term dominates everything by ~3 orders.

Each variant is a config override; the cell is re-lowered/re-compiled and
its roofline recorded to artifacts/perf/.

    PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C]
"""

import argparse
import dataclasses
import json

from repro.configs import get_config

ART = os.path.join(os.path.dirname(__file__), "../../../artifacts/perf")


def variants():
    lear = get_config("lear-msn1")
    qwen = get_config("qwen2.5-14b")
    neq = get_config("nequip")
    r = dataclasses.replace
    return {
        "A": [
            ("lear-msn1", "rank_xl", lear,
             "A0-full-reference (paper 'Full': every doc × every tree)"),
            ("lear-msn1", "rank_xl", r(lear, capacity_frac=0.25),
             "A1-paper-compacted (LEAR cascade, per-query capacity 25%)"),
            ("lear-msn1", "rank_xl",
             r(lear, capacity_frac=0.25, sentinel2=150, capacity2_frac=0.08),
             "A2-two-sentinel (beyond-paper: second cut at tree 150, 8%)"),
            ("lear-msn1", "rank_xl",
             r(lear, capacity_frac=0.20, sentinel2=100, capacity2_frac=0.05),
             "A3-aggressive (cap 20%, second cut at 100, 5%)"),
        ],
        "B": [
            ("qwen2.5-14b", "train_4k", qwen, "B0-baseline"),
            ("qwen2.5-14b", "train_4k", r(qwen, embed_onehot=True),
             "B1-embed-onehot (kill involuntary remat on vocab-sharded gather)"),
            ("qwen2.5-14b", "train_4k",
             r(qwen, embed_onehot=True, causal_skip=True),
             "B2-causal-skip (+upper-triangle attention never computed)"),
            ("qwen2.5-14b", "train_4k",
             r(qwen, embed_onehot=True, causal_skip=True, remat_policy="dots"),
             "B3-remat-dots (save matmul outputs; trade memory for recompute)"),
            ("qwen2.5-14b", "train_4k",
             r(qwen, causal_skip=True, seq_parallel=True),
             "B4-seq-parallel (Megatron-SP residual: TP ARs → RS+AG, "
             "norm/residual work seq-sharded)"),
        ],
        "C": [
            ("nequip", "ogb_products", neq, "C0-baseline (f32 messages)"),
            ("nequip", "ogb_products", r(neq, dtype="bfloat16"),
             "C1-bf16-messages (halve per-edge tensors and node all-reduce)"),
            ("nequip", "ogb_products", r(neq, premix_messages=True),
             "C2-premix (channel-mix per edge before segment-sum: AR payload "
             "1120→288 floats/node by linearity)"),
            ("nequip", "ogb_products",
             r(neq, premix_messages=True, dtype="bfloat16"),
             "C3-premix-bf16 (compound; AR still f32 per XLA scatter "
             "semantics but gathers halve)"),
        ],
    }


def main():
    from repro.launch import dryrun

    p = argparse.ArgumentParser()
    p.add_argument("--cell", choices=["A", "B", "C"], default=None)
    args = p.parse_args()

    os.makedirs(os.path.normpath(ART), exist_ok=True)
    todo = variants()
    cells = [args.cell] if args.cell else list(todo)
    for cell in cells:
        for arch, shape, cfg, label in todo[cell]:
            tag = label.split(" ")[0]
            path = os.path.join(os.path.normpath(ART), f"{tag}.json")
            if os.path.exists(path):
                print(f"[skip-cached] {tag}")
                continue
            print(f"[perf] {tag}: {label}", flush=True)
            try:
                record, _hlo = dryrun.run_cell(
                    arch, shape, multi_pod=False, override_cfg=cfg
                )
                record["label"] = label
            except Exception as e:  # noqa: BLE001
                record = {"label": label, "error": f"{type(e).__name__}: {e}"}
                print(f"  FAILED: {record['error']}")
            with open(path, "w") as f:
                json.dump(record, f, indent=1)
            if "roofline" in record:
                ro = record["roofline"]
                print(
                    f"  compute={ro['compute_s']:.3e} memory={ro['memory_s']:.3e} "
                    f"coll={ro['collective_s']:.3e} dominant={ro['dominant']} "
                    f"useful={ro['useful_ratio']:.2f}", flush=True,
                )


if __name__ == "__main__":
    main()
