"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's built-in ``cost_analysis()`` does NOT multiply ``while``-loop bodies
by their trip counts, which makes it useless for scan-over-layers programs
(a 36-layer model reports ~1 layer of FLOPs). This walker fixes that:

- parses the SPMD-partitioned module into computations;
- extracts each while loop's trip count from its condition computation
  (``compare(counter, constant)`` — the canonical ``lax.scan`` lowering);
- walks the entry computation multiplying nested loop bodies;
- FLOPs: ``2 · numel(result) · contraction`` per ``dot`` (batch dims
  excluded from the contraction product correctly, since the result numel
  already carries batch dims);
- HBM-traffic estimate: Σ over *fusion-boundary* instructions of
  (operand + result bytes) — fusion-internal ops do not touch HBM;
  pure-view ops (tuple/gte/parameter/bitcast/constant) excluded;
- collective bytes: max(result, operand) bytes per collective, all-reduce
  counted ×2 (ring ≈ reduce-scatter + all-gather).

Because the module is the post-partitioning per-device program, every
returned number is **per device**; roofline terms follow directly.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Shape group is lazy `.*?` (tuple shapes embed `/*index=N*/` comments that
# contain `=`); the op is the first `word(` after the shape.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_VIEW_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
             "iota", "after-all", "partition-id", "replica-id"}

# Elementwise/reduce ops counted as 1 FLOP (or equivalent VPU op) per
# element — the compute term for non-matmul workloads (the paper's forest
# scorer is entirely compare/AND/select/popcount on the VPU).
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "xor", "not", "exponential", "log",
    "rsqrt", "sqrt", "tanh", "logistic", "power", "negate", "abs",
    "popcnt", "count-leading-zeros", "shift-left", "shift-right-logical",
    "clamp", "floor", "ceil", "round-nearest-afz",
}


def _shape_numel_bytes(shape_str: str) -> tuple[int, int]:
    numel_total, bytes_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return numel_total, bytes_total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str

    def attr(self, key: str) -> str | None:
        m = re.search(key + r"=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def operands(self) -> list[str]:
        # Names inside the call parens, before any ), attr list.
        depth, out, cur = 0, [], ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
                continue
            if ch == ")":
                depth -= 1
                if depth < 0:
                    break
                continue
            cur += ch
        for tok in cur.split(","):
            tok = tok.strip()
            if tok.startswith("%"):
                out.append(tok[1:])
        return out


def parse_module(hlo: str) -> tuple[dict[str, list[Instr]], str]:
    comps: dict[str, list[Instr]] = {}
    entry = None
    current: list[Instr] | None = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and "->" in line:
            name = m.group(1)
            comps[name] = []
            current = comps[name]
            if line.strip().startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            current.append(Instr(*mi.groups()))
    if entry is None:
        entry = next(iter(comps))
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Extract N from the canonical `counter < N` condition.

    The compare may be wrapped in a kLoop fusion; condition computations are
    tiny, so the loop bound is simply the largest integer constant present
    (the only other candidates are induction-start 0 / step 1).
    """
    best = 0
    for i in comps.get(cond_name, []):
        if i.op == "constant" and i.shape.startswith("s32"):
            m = re.match(r"(\d+)\)", i.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best if best > 0 else 1


def _dot_flops(instr: Instr, by_name: dict[str, Instr]) -> int:
    res_numel, _ = _shape_numel_bytes(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m:
        return 2 * res_numel  # dot with no contraction info: assume 1
    cdims = [int(d) for d in m.group(1).split(",") if d]
    ops = instr.operands()
    if not ops or ops[0] not in by_name:
        return 2 * res_numel
    lhs_shape = by_name[ops[0]].shape
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 2 * res_numel
    dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contraction = 1
    for c in cdims:
        if c < len(dims):
            contraction *= dims[c]
    return 2 * res_numel * contraction


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0          # per device
    bytes: float = 0.0          # per device HBM-traffic estimate
    coll_bytes: float = 0.0     # per device collective traffic
    coll_breakdown: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    unknown_trip_counts: int = 0


def analyze(hlo: str) -> HloCost:
    comps, entry = parse_module(hlo)
    cost = HloCost()

    def walk(comp: str, mult: float, in_fusion: bool):
        instrs = comps.get(comp, [])
        by_name = {i.name: i for i in instrs}
        for i in instrs:
            if i.op == "while":
                body, cond = i.attr("body"), i.attr("condition")
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    walk(body, mult * max(trips, 1), in_fusion)
                continue
            if i.op in ("call", "conditional", "async-start"):
                tgt = i.attr("to_apply") or i.attr("calls")
                if tgt:
                    walk(tgt, mult, in_fusion)
                continue
            if i.op == "fusion":
                tgt = i.attr("calls")
                if not in_fusion:
                    _account_bytes(i, by_name, mult)
                if tgt:
                    walk(tgt, mult, True)   # FLOPs only inside fusions
                continue
            if i.op == "dot":
                cost.flops += mult * _dot_flops(i, by_name)
            elif i.op in _EW_OPS:
                n, _ = _shape_numel_bytes(i.shape)
                cost.flops += mult * n
            elif i.op in ("reduce", "reduce-window"):
                op_n = sum(
                    _shape_numel_bytes(by_name[o].shape)[0]
                    for o in i.operands() if o in by_name
                )
                cost.flops += mult * op_n
            for kind in _COLLECTIVES:
                if i.op == kind or i.op.startswith(kind + "-"):
                    _, res_b = _shape_numel_bytes(i.shape)
                    op_b = sum(
                        _shape_numel_bytes(by_name[o].shape)[1]
                        for o in i.operands() if o in by_name
                    )
                    moved = max(res_b, op_b) * (2 if kind == "all-reduce" else 1)
                    cost.coll_bytes += mult * moved
                    cost.coll_breakdown[kind] += mult * moved
                    break
            if not in_fusion and i.op not in _VIEW_OPS:
                _account_bytes(i, by_name, mult)

    def _account_bytes(i: Instr, by_name, mult: float):
        _, res_b = _shape_numel_bytes(i.shape)
        op_b = sum(
            _shape_numel_bytes(by_name[o].shape)[1]
            for o in i.operands() if o in by_name and
            by_name[o].op not in ("tuple",)
        )
        cost.bytes += mult * (res_b + op_b)

    walk(entry, 1.0, False)
    return cost
