"""Production mesh construction.

Single pod: 16 × 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 × 16 × 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an additional data-parallel dimension with slower (DCI)
links; logical rules place only batch-like axes (and the widest expert
dimension) on it.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh() -> jax.sharding.Mesh:
    """1×1 mesh over the single local device — CPU tests of the mesh path."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
