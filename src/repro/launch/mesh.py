"""Production mesh construction.

Single pod: 16 × 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 × 16 × 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an additional data-parallel dimension with slower (DCI)
links; logical rules place only batch-like axes (and the widest expert
dimension) on it.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after 0.4.x; older runtimes use the
    # default (Auto) axis semantics implicitly.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """1×1 mesh over the single local device — CPU tests of the mesh path."""
    return _make_mesh((1, 1), ("data", "model"))
