"""Re-derive roofline records from saved .hlo.gz artifacts (no recompile).

    PYTHONPATH=src python -m repro.launch.reanalyze
"""

from __future__ import annotations

import glob
import gzip
import json
import os

from repro.configs import get_config
from repro.configs.base import TransformerConfig
from repro.launch import roofline as rf

ART = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")
)


def main():
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            record = json.load(f)
        if "skipped" in record or "error" in record:
            continue
        hlo_path = path.replace(".json", ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        cfg = get_config(record["arch"])
        shape = {s.name: s for s in cfg.shapes}[record["shape"]]
        model_flops = (
            rf.lm_model_flops(cfg, shape)
            if isinstance(cfg, TransformerConfig) else 0.0
        )
        roof = rf.roofline(None, chips=record["chips"],
                           model_flops=model_flops, hlo_text=hlo)
        record["roofline"] = roof.to_dict()
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(os.path.basename(path), roof.dominant,
              f"bound={roof.bound_s:.3e}")


if __name__ == "__main__":
    main()
