"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs            / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_accessed   / (chips × 819e9  B/s HBM)
    collective = collective_bytes     / (chips × 50e9   B/s ICI per link)

``cost_analysis()`` supplies FLOPs and bytes. Collective bytes are parsed
from the post-optimization HLO (``compiled.as_text()``): we sum the result
shapes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, with all-reduce counted twice (ring = reduce-scatter +
all-gather). This is the standard static estimate; it ignores link
contention and overlap (the §Perf log reasons about both explicitly).

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) is computed per arch so the
useful-compute ratio exposes remat and dispatch overheads.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind summed result bytes from post-opt HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", stripped)
        if not m:
            continue
        shape_str, op = m.groups()
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                out[kind] += _shape_bytes(shape_str)
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict[str, int]
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "dominant": self.dominant,
            "bound_s": self.bound_s,
        }


def roofline(compiled, chips: int, model_flops: float = 0.0,
             hlo_text: str | None = None) -> Roofline:
    """Roofline terms from the SPMD-partitioned per-device HLO.

    Uses the trip-count-aware walker (repro.launch.hlo_analysis) — XLA's
    ``cost_analysis()`` does not multiply while-loop bodies, which is off by
    ~layers × microbatches for scanned programs. The walker's numbers are
    per device, so terms need no further division by ``chips``;
    ``model_flops`` is a global quantity and is compared against
    ``flops × chips``.
    """
    from repro.launch import hlo_analysis

    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_analysis.analyze(text)
    total_flops = cost.flops * chips
    return Roofline(
        flops=cost.flops,
        bytes_accessed=cost.bytes,
        coll_bytes=cost.coll_bytes,
        coll_breakdown={k: int(v) for k, v in cost.coll_breakdown.items()},
        chips=chips,
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.bytes / HBM_BW,
        collective_s=cost.coll_bytes / ICI_BW,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS per arch (6·N·D rule).
# ---------------------------------------------------------------------------


def lm_param_count(cfg, active: bool = False) -> float:
    """Parameter count (total or active-per-token) for a TransformerConfig."""
    D, V = cfg.d_model, cfg.vocab_size
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
    embed = 2 * V * D
    total = embed
    n_dense = cfg.n_dense_layers if cfg.is_moe else cfg.n_layers
    dense_ff = cfg.dense_d_ff or cfg.d_ff
    total += n_dense * (attn + 3 * D * dense_ff)
    if cfg.is_moe:
        Fe = cfg.d_ff_expert or cfg.d_ff
        n_active = cfg.top_k if active else cfg.n_experts
        expert = 3 * D * Fe
        shared = cfg.n_shared_experts * 3 * D * Fe
        total += cfg.n_moe_layers * (attn + n_active * expert + shared
                                     + D * cfg.n_experts)
    return float(total)


def lm_model_flops(cfg, shape) -> float:
    n_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        n_tokens = shape.global_batch
    n = lm_param_count(cfg, active=True)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * n_tokens
