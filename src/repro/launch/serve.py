"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

LM archs: prefill + a few decode steps (reduced config on CPU).
RecSys archs: batched scoring + candidate retrieval.
Forest (lear-msn1): the LEAR cascade ranking service.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config, list_archs
from repro.configs.base import (
    ForestConfig,
    RecSysConfig,
    ShapeSpec,
    TransformerConfig,
)
from repro.models.api import make_cell
from repro.models.synth import synthesize_inputs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list_archs(), required=True)
    p.add_argument("--batches", type=int, default=3)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    if isinstance(cfg, TransformerConfig):
        _serve_lm(cfg, args)
    elif isinstance(cfg, RecSysConfig):
        _serve_recsys(cfg, args)
    elif isinstance(cfg, ForestConfig):
        _serve_forest(cfg, args)
    else:
        raise SystemExit(f"{cfg.name}: GNN potentials are trained, not served")


def _serve_lm(cfg, args):
    from repro.models import transformer as tfm
    from repro.serve.lm_serve import generate

    params = tfm.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = jax.numpy.asarray(
        rng.integers(0, cfg.vocab_size, size=(2, 16)).astype(np.int32)
    )
    t0 = time.time()
    out = generate(cfg, params, prompt, n_steps=8)
    print(f"generated {out.shape} tokens in {time.time() - t0:.2f}s")
    print(np.asarray(out))


def _serve_recsys(cfg, args):
    shape = ShapeSpec(name="cli_serve", kind="serve", batch=32)
    cell = make_cell(cfg, shape)
    params = cell.init_state(jax.random.key(0))
    step = jax.jit(cell.step)
    for i in range(args.batches):
        scores = step(params, synthesize_inputs(cell, seed=i))
        print(f"batch {i}: scored {scores.shape[0]} requests, "
              f"mean={float(scores.mean()):+.3f}")


def _serve_forest(cfg, args):
    shape = ShapeSpec(name="cli_rank", kind="serve", batch=4)
    cell = make_cell(cfg, shape)
    params = cell.init_state(jax.random.key(0))
    step = jax.jit(cell.step)
    for i in range(args.batches):
        scores, cont = step(params, synthesize_inputs(cell, seed=i))
        rate = float(cont.mean())
        print(f"batch {i}: ranked {scores.shape[0]} queries, "
              f"continue rate {rate:.1%}")


if __name__ == "__main__":
    main()
