"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REDUCED config on CPU by default (the full configs only lower via
dryrun.py in this container); on a real TPU slice the same entry point runs
the full config by passing ``--full`` under a real mesh. Implements the
production loop: resumable pipeline, periodic checkpointing, watchdog-style
failure handling (any step exception → restore from last checkpoint and
continue — the single-process analogue of the restart-on-node-failure
policy described in DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import ShapeSpec
from repro.models.api import make_cell
from repro.models.synth import synthesize_inputs
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list_archs(), required=True)
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--ckpt-every", type=int, default=10)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--full", action="store_true",
                   help="use the full (not smoke) config — TPU slices only")
    args = p.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    shape = _train_shape(cfg)
    cell = make_cell(cfg, shape)
    ckpt_dir = args.ckpt_dir or os.path.join("artifacts", "train", cfg.name)

    state = cell.init_state(jax.random.key(0))
    start = 0
    if latest_step(ckpt_dir) is not None:
        state, extra = restore_checkpoint(ckpt_dir, state)
        start = int(extra["step"])
        print(f"resumed from step {start}")

    step_fn = jax.jit(cell.step)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = synthesize_inputs(cell, seed=i)
        try:
            state, metrics = step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — watchdog path
            print(f"step {i} failed ({e}); restoring last checkpoint")
            state, extra = restore_checkpoint(ckpt_dir, state)
            continue
        if (i + 1) % 5 == 0:
            print(f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time() - t0) / (i + 1 - start):.2f}s/step)")
        if (i + 1) % args.ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, state, extra={"step": i + 1})
    print("done")


def _train_shape(cfg) -> ShapeSpec:
    from repro.configs.base import (
        NequIPConfig, RecSysConfig, TransformerConfig,
    )

    if isinstance(cfg, TransformerConfig):
        return ShapeSpec(name="cli_train", kind="train", seq_len=64,
                         global_batch=8, microbatch=4)
    if isinstance(cfg, NequIPConfig):
        return ShapeSpec(name="cli_train", kind="train", n_nodes=64,
                         n_edges=192, graph_batch=4)
    if isinstance(cfg, RecSysConfig):
        return ShapeSpec(name="cli_train", kind="train", batch=64)
    raise SystemExit(f"{cfg.name} is not trainable (forest configs use "
                     f"examples/quickstart.py)")


if __name__ == "__main__":
    main()
