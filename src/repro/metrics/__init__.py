from repro.metrics.ranking import dcg_at_k, ndcg_at_k, rank_from_scores, mean_ndcg
from repro.metrics.classification import precision_recall
from repro.metrics.speedup import trees_traversed, speedup_vs_full

__all__ = [
    "dcg_at_k",
    "ndcg_at_k",
    "rank_from_scores",
    "mean_ndcg",
    "precision_recall",
    "trees_traversed",
    "speedup_vs_full",
]
