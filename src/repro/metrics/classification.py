"""Binary Continue/Exit classifier metrics (paper Table 2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def precision_recall(
    pred_continue: jax.Array, true_continue: jax.Array, mask: jax.Array
) -> dict[str, float]:
    """Per-class precision/recall for the Continue (1) / Exit (0) classes.

    Returns a dict matching the paper's Table 2 layout.
    """
    pred_continue = pred_continue & mask
    true_continue = true_continue & mask
    pred_exit = (~pred_continue) & mask
    true_exit = (~true_continue) & mask

    def _pr(pred, true):
        tp = (pred & true).sum()
        p = tp / jnp.maximum(pred.sum(), 1)
        r = tp / jnp.maximum(true.sum(), 1)
        return float(p), float(r)

    p_c, r_c = _pr(pred_continue, true_continue)
    p_e, r_e = _pr(pred_exit, true_exit)
    return {
        "continue_precision": p_c,
        "continue_recall": r_c,
        "exit_precision": p_e,
        "exit_recall": r_e,
    }
