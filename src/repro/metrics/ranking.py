"""Ranking quality metrics (NDCG@k) over padded per-query blocks.

All functions take padded arrays ``[Q, D]`` with a boolean ``mask`` marking
real documents; padding never contributes to gains or ranks. Exponential
gains ``2^label - 1`` and log2 discounts, per the paper (NDCG@10 on 0..4
graded labels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def gain(labels: jax.Array) -> jax.Array:
    return jnp.exp2(labels.astype(jnp.float32)) - 1.0


def rank_from_scores(scores: jax.Array, mask: jax.Array) -> jax.Array:
    """0-based rank of each doc within its query (0 = best). Padding ranks last.

    Deterministic tie-break by document index (stable argsort).
    """
    masked = jnp.where(mask, scores, NEG)
    order = jnp.argsort(-masked, axis=-1, stable=True)     # [Q, D] doc ids by rank
    ranks = jnp.argsort(order, axis=-1, stable=True)       # [Q, D] rank of each doc
    return ranks.astype(jnp.int32)


def dcg_at_k(scores: jax.Array, labels: jax.Array, mask: jax.Array, k: int) -> jax.Array:
    ranks = rank_from_scores(scores, mask)
    disc = 1.0 / jnp.log2(ranks.astype(jnp.float32) + 2.0)
    contrib = jnp.where(mask & (ranks < k), gain(labels) * disc, 0.0)
    return contrib.sum(axis=-1)


def ideal_dcg_at_k(labels: jax.Array, mask: jax.Array, k: int) -> jax.Array:
    return dcg_at_k(labels.astype(jnp.float32), labels, mask, k)


def ndcg_at_k(scores: jax.Array, labels: jax.Array, mask: jax.Array, k: int = 10) -> jax.Array:
    """Per-query NDCG@k; queries with zero ideal DCG get NDCG 1 (convention)."""
    idcg = ideal_dcg_at_k(labels, mask, k)
    dcg = dcg_at_k(scores, labels, mask, k)
    return jnp.where(idcg > 0, dcg / jnp.maximum(idcg, 1e-12), 1.0)


def mean_ndcg(
    scores: jax.Array, labels: jax.Array, mask: jax.Array, k: int = 10
) -> jax.Array:
    return ndcg_at_k(scores, labels, mask, k).mean()
