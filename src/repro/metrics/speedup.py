"""Scoring-cost accounting in the paper's own currency: trees traversed.

The paper (§3, Table 1) estimates speedup as
``total trees traversed by Full / total trees traversed by the EE method``,
where a document that exits at sentinel ``s`` costs ``s`` trees and a
continuing document costs ``n_trees``; the EE classifier itself costs
``classifier_trees`` per scored document (LEAR's 10-tree forest), which we
charge explicitly — the paper includes classifier latency in its timings.
"""

from __future__ import annotations

import jax.numpy as jnp


def trees_traversed(
    continue_mask,
    mask,
    sentinel: int,
    n_trees: int,
    classifier_trees: int = 0,
) -> jnp.ndarray:
    """Total tree traversals for one EE configuration. Arrays are [Q, D]."""
    n_docs = mask.sum()
    n_cont = (continue_mask & mask).sum()
    return (
        n_docs * (sentinel + classifier_trees)
        + n_cont * (n_trees - sentinel)
    ).astype(jnp.float32)


def speedup_vs_full(
    continue_mask, mask, sentinel: int, n_trees: int, classifier_trees: int = 0
) -> float:
    full = mask.sum() * n_trees
    ee = trees_traversed(continue_mask, mask, sentinel, n_trees, classifier_trees)
    return float(full / ee)
