"""Scoring-cost accounting in the paper's own currency: trees traversed.

The paper (§3, Table 1) estimates speedup as
``total trees traversed by Full / total trees traversed by the EE method``,
where a document that exits at sentinel ``s`` costs ``s`` trees and a
continuing document costs ``n_trees``; the EE classifier itself costs
``classifier_trees`` per scored document (LEAR's 10-tree forest), which we
charge explicitly — the paper includes classifier latency in its timings.
"""

from __future__ import annotations

import jax.numpy as jnp


def trees_traversed(
    continue_mask,
    mask,
    sentinel: int,
    n_trees: int,
    classifier_trees: int = 0,
) -> jnp.ndarray:
    """Total tree traversals for one EE configuration. Arrays are [Q, D]."""
    n_docs = mask.sum()
    n_cont = (continue_mask & mask).sum()
    return (
        n_docs * (sentinel + classifier_trees)
        + n_cont * (n_trees - sentinel)
    ).astype(jnp.float32)


def speedup_vs_full(
    continue_mask, mask, sentinel: int, n_trees: int, classifier_trees: int = 0
) -> float:
    full = mask.sum() * n_trees
    ee = trees_traversed(continue_mask, mask, sentinel, n_trees, classifier_trees)
    return float(full / ee)


def trees_traversed_progressive(
    mask,
    stage_masks,
    sentinels,
    n_trees: int,
    classifier_trees=0,
) -> jnp.ndarray:
    """Multi-sentinel generalization of :func:`trees_traversed`.

    ``stage_masks[k]`` is the (nested) continue mask AFTER stage ``k``'s
    decision at ``sentinels[k]``; ``mask`` is the request mask. A document
    exiting at stage ``k`` costs ``sentinels[k-1]`` trees plus one
    classifier evaluation per stage it reached; survivors of the last stage
    cost the full ``n_trees``. ``classifier_trees`` is an int (same cost at
    every stage) or a per-stage sequence for heterogeneous classifiers.
    With one sentinel this reduces exactly to :func:`trees_traversed`.
    """
    S = len(sentinels)
    if isinstance(classifier_trees, int):
        classifier_trees = [classifier_trees] * S
    assert len(classifier_trees) == S
    alive = mask
    prev_s = 0
    total = jnp.float32(0.0)
    for s, cont, ct in zip(sentinels, stage_masks, classifier_trees):
        n_alive = alive.sum()
        total += n_alive * (s - prev_s) + n_alive * ct
        alive = cont & alive
        prev_s = s
    total += alive.sum() * (n_trees - prev_s)
    return total.astype(jnp.float32)


def progressive_cost_model(
    n_docs: float,
    stage_survivors,
    sentinels,
    n_trees: int,
    mode: str,
    launch_overhead_trees: float = 0.0,
    stage_capacities=None,
) -> float:
    """Estimated device cost of one progressive batch, in tree-traversal
    equivalents, for picking fused vs per-stage-tail execution.

    ``stage_survivors[k]`` is the (expected) survivor count after stage
    ``k``'s decision. The fused head scores every document through all
    ``sentinels[-1]`` head trees in one segmented launch; the staged head
    scores segment ``k`` only on the stage-(k−1) survivors but pays one
    extra launch (dispatch + gather/scatter HBM round trip) per stage,
    priced at ``launch_overhead_trees`` tree-traversal equivalents each.
    A staged stage kernel actually scores its full ``capacity``-sized
    compacted block, not just the live survivors, so when
    ``stage_capacities`` is given the staged stage work is priced at the
    block size — otherwise a capacity floor well above the survivor count
    would make the model systematically underestimate staged cost. Both
    modes run the same compacted tail. Host-side arithmetic only — never
    traced, never syncs.
    """
    S = len(sentinels)
    assert mode in ("fused", "staged"), mode
    assert len(stage_survivors) == S
    surv = [min(float(s), float(n_docs)) for s in stage_survivors]
    has_tail = sentinels[-1] < n_trees
    tail = surv[-1] * (n_trees - sentinels[-1])
    if mode == "fused":
        head = n_docs * sentinels[-1]
        launches = 1 + (1 if has_tail else 0)
    else:
        if stage_capacities is not None:
            assert len(stage_capacities) == S
            surv = [
                min(float(c), float(n_docs)) for c in stage_capacities
            ]
        head = n_docs * sentinels[0] + sum(
            surv[k] * (sentinels[k + 1] - sentinels[k]) for k in range(S - 1)
        )
        launches = S + (1 if has_tail else 0)
    return float(head + tail + launch_overhead_trees * launches)


def speedup_progressive(
    mask, stage_masks, sentinels, n_trees: int, classifier_trees=0
) -> jnp.ndarray:
    """Lazy device scalar (no host sync) — ``float()`` it in a stats path."""
    full = mask.sum() * n_trees
    ee = trees_traversed_progressive(
        mask, stage_masks, sentinels, n_trees, classifier_trees
    )
    return full / ee
