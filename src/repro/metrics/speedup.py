"""Scoring-cost accounting in the paper's own currency: trees traversed.

The paper (§3, Table 1) estimates speedup as
``total trees traversed by Full / total trees traversed by the EE method``,
where a document that exits at sentinel ``s`` costs ``s`` trees and a
continuing document costs ``n_trees``; the EE classifier itself costs
``classifier_trees`` per scored document (LEAR's 10-tree forest), which we
charge explicitly — the paper includes classifier latency in its timings.
"""

from __future__ import annotations

import jax.numpy as jnp


def trees_traversed(
    continue_mask,
    mask,
    sentinel: int,
    n_trees: int,
    classifier_trees: int = 0,
) -> jnp.ndarray:
    """Total tree traversals for one EE configuration. Arrays are [Q, D]."""
    n_docs = mask.sum()
    n_cont = (continue_mask & mask).sum()
    return (
        n_docs * (sentinel + classifier_trees)
        + n_cont * (n_trees - sentinel)
    ).astype(jnp.float32)


def speedup_vs_full(
    continue_mask, mask, sentinel: int, n_trees: int, classifier_trees: int = 0
) -> float:
    full = mask.sum() * n_trees
    ee = trees_traversed(continue_mask, mask, sentinel, n_trees, classifier_trees)
    return float(full / ee)


def trees_traversed_progressive(
    mask,
    stage_masks,
    sentinels,
    n_trees: int,
    classifier_trees=0,
) -> jnp.ndarray:
    """Multi-sentinel generalization of :func:`trees_traversed`.

    ``stage_masks[k]`` is the (nested) continue mask AFTER stage ``k``'s
    decision at ``sentinels[k]``; ``mask`` is the request mask. A document
    exiting at stage ``k`` costs ``sentinels[k-1]`` trees plus one
    classifier evaluation per stage it reached; survivors of the last stage
    cost the full ``n_trees``. ``classifier_trees`` is an int (same cost at
    every stage) or a per-stage sequence for heterogeneous classifiers.
    With one sentinel this reduces exactly to :func:`trees_traversed`.
    """
    S = len(sentinels)
    if isinstance(classifier_trees, int):
        classifier_trees = [classifier_trees] * S
    assert len(classifier_trees) == S
    alive = mask
    prev_s = 0
    total = jnp.float32(0.0)
    for s, cont, ct in zip(sentinels, stage_masks, classifier_trees):
        n_alive = alive.sum()
        total += n_alive * (s - prev_s) + n_alive * ct
        alive = cont & alive
        prev_s = s
    total += alive.sum() * (n_trees - prev_s)
    return total.astype(jnp.float32)


def speedup_progressive(
    mask, stage_masks, sentinels, n_trees: int, classifier_trees=0
) -> jnp.ndarray:
    """Lazy device scalar (no host sync) — ``float()`` it in a stats path."""
    full = mask.sum() * n_trees
    ee = trees_traversed_progressive(
        mask, stage_masks, sentinels, n_trees, classifier_trees
    )
    return full / ee
