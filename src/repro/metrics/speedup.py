"""Scoring-cost accounting in the paper's own currency: trees traversed.

The paper (§3, Table 1) estimates speedup as
``total trees traversed by Full / total trees traversed by the EE method``,
where a document that exits at sentinel ``s`` costs ``s`` trees and a
continuing document costs ``n_trees``; the EE classifier itself costs
``classifier_trees`` per scored document (LEAR's 10-tree forest), which we
charge explicitly — the paper includes classifier latency in its timings.

Units, everywhere in this module: one unit = one *document·tree traversal*.
Launch overhead (:func:`progressive_cost_model`'s only tunable) is priced
in the same currency — "how many doc·tree traversals does one extra kernel
dispatch plus its gather/scatter HBM round trip cost" — so calibrating it
(:func:`repro.serve.calibration.calibrate_launch_overhead_trees`) is a
division of two measured wall times, and the model stays hardware-relative.

Accounting time: the ``trees_traversed*`` / ``speedup*`` functions are
*run-time* accounting — they trace into the compiled step and return lazy
device scalars describing what the batch actually did. The
``progressive_cost_model*`` pair is *decision-time* pricing — an estimate
from smoothed survivor counts used to pick the execution mode before (host
variant) or inside (device variant) the compiled step.
"""

from __future__ import annotations

import math
from collections.abc import Sequence

import jax
import jax.numpy as jnp

# THE kernel block policy, not a mirror of it: the same function _prep_x
# applies to every dispatch prices the staged stages below, so the cost
# model cannot drift from what the kernel actually scores.
from repro.kernels.ops import effective_block_b as _stage_block


def _sane_survivors(
    stage_survivors: Sequence[float], n_docs: float
) -> list[float]:
    """Clamp decision-time survivor estimates to ``[0, n_docs]``, mapping
    non-finite inputs to the bound they exceed (NaN → 0 — an estimate the
    model knows nothing about must not poison the pick).

    The EMA that feeds the mode pick comes from run-time stats; an
    all-masked batch, a zero-survivor stage, or a poisoned stats pipeline
    (NaN from a degenerate reduction upstream) must degrade to a
    well-defined pick, never to NaN/inf costs — a NaN cost makes every
    ``<`` comparison False and silently pins the service to one branch.
    """
    out = []
    for s in stage_survivors:
        s = float(s)
        if math.isnan(s):
            s = 0.0
        out.append(min(max(s, 0.0), n_docs))  # ±inf land on the bounds
    return out


def trees_traversed(
    continue_mask: jax.Array,
    mask: jax.Array,
    sentinel: int,
    n_trees: int,
    classifier_trees: int = 0,
) -> jnp.ndarray:
    """Total tree traversals for one EE configuration. Arrays are [Q, D]."""
    n_docs = mask.sum()
    n_cont = (continue_mask & mask).sum()
    return (
        n_docs * (sentinel + classifier_trees)
        + n_cont * (n_trees - sentinel)
    ).astype(jnp.float32)


def speedup_vs_full(
    continue_mask: jax.Array,
    mask: jax.Array,
    sentinel: int,
    n_trees: int,
    classifier_trees: int = 0,
) -> float:
    full = mask.sum() * n_trees
    ee = trees_traversed(continue_mask, mask, sentinel, n_trees, classifier_trees)
    return float(full / ee)


def trees_traversed_progressive(
    mask: jax.Array,
    stage_masks: Sequence[jax.Array],
    sentinels: Sequence[int],
    n_trees: int,
    classifier_trees: float | Sequence[float] = 0,
) -> jnp.ndarray:
    """Multi-sentinel generalization of :func:`trees_traversed`.

    ``stage_masks[k]`` is the (nested) continue mask AFTER stage ``k``'s
    decision at ``sentinels[k]``; ``mask`` is the request mask. A document
    exiting at stage ``k`` costs ``sentinels[k-1]`` trees plus one
    classifier evaluation per stage it reached; survivors of the last stage
    cost the full ``n_trees``. ``classifier_trees`` is a scalar (same cost
    at every stage) or a per-stage sequence for heterogeneous classifiers;
    fractional costs express non-tree stage work in tree equivalents.
    With one sentinel this reduces exactly to :func:`trees_traversed`.

    Hybrid cascades account through the same formula with the dense gate
    spliced in as a zero-sentinel stage: ``sentinels = (0, *tree_sents)``
    and ``classifier_trees = (dense_cost_trees, *tree_costs)`` charges
    every candidate one dense evaluation and no trees, then charges the
    first tree sentinel only on the dense survivors.
    """
    S = len(sentinels)
    if isinstance(classifier_trees, (int, float)):
        classifier_trees = [classifier_trees] * S
    assert len(classifier_trees) == S
    alive = mask
    prev_s = 0
    total = jnp.float32(0.0)
    for s, cont, ct in zip(sentinels, stage_masks, classifier_trees):
        n_alive = alive.sum()
        total += n_alive * (s - prev_s) + n_alive * ct
        alive = cont & alive
        prev_s = s
    total += alive.sum() * (n_trees - prev_s)
    return total.astype(jnp.float32)


def progressive_cost_model(
    n_docs: float,
    stage_survivors: Sequence[float],
    sentinels: Sequence[int],
    n_trees: int,
    mode: str,
    launch_overhead_trees: float = 0.0,
    stage_capacities: Sequence[int] | None = None,
    block_b: int = 1,
    query_exit_rate: float = 0.0,
    dense_cost_trees: float = 0.0,
    dense_stage: bool = False,
) -> float:
    """Estimated device cost of one progressive batch, in tree-traversal
    equivalents, for picking fused vs per-stage-tail execution.

    ``dense_stage=True`` prices a hybrid cascade (dense gate at stage 0):
    ``stage_survivors`` and ``stage_capacities`` then carry one leading
    entry for the dense stage (``len == len(sentinels) + 1``, capacities
    required), every candidate is charged ``dense_cost_trees``, and BOTH
    modes' tree-head terms are priced at the dense survivor capacity —
    the tree kernels score the full dense-compacted block regardless of
    how many survivors occupy it, and the dense matmul itself adds no
    launch, so the launch terms are unchanged. The dense term is
    symmetric across modes (it can never flip the pick); it keeps the
    absolute costs honest.

    ``query_exit_rate`` is the estimated probability that query-level
    exit empties the batch before the tail (the service's EMA of the
    all-queries-converged indicator). It discounts ONLY the tail
    launch's overhead: the tail *work* term already shrinks through the
    survivor estimates (a fully-exited batch reports zero last-stage
    survivors into the EMA), but the launch overhead is paid per
    dispatch, and the gated tail skips the dispatch itself. The discount
    is symmetric across modes (both run the same gated tail), so it
    never flips the pick by itself — it keeps the absolute costs honest
    for operators reading them.

    ``stage_survivors[k]`` is the (expected) survivor count after stage
    ``k``'s decision. The fused head scores every document through all
    ``sentinels[-1]`` head trees in one segmented launch; the staged head
    scores segment ``k`` only on the stage-(k−1) survivors but pays one
    extra launch (dispatch + gather/scatter HBM round trip) per stage,
    priced at ``launch_overhead_trees`` doc·tree equivalents each.

    Staged stage pricing: survivors are first rounded UP to the stage's
    effective kernel doc-block (``block_b`` clipped per
    ``repro.kernels.ops._prep_x`` — the kernel cannot score less than one
    block), then clipped at the stage capacity when ``stage_capacities`` is
    given. ``block_b=1`` (the default) disables the rounding and reproduces
    the bare ``min(capacity, survivors)`` model. This sits deliberately
    between two wrong extremes: pricing the full capacity block would count
    the serving buckets' safety slack (headroom multiplier, power-of-two
    rounding, a cold-start floor that never shrinks) as real work and lock
    the pick into fused on exactly the sparse traffic where the measured
    bench shows staged winning, while pricing raw survivors pretends a
    3-survivor stage is ~free when the kernel still scores a full
    ``block_b`` doc block. Block-rounded survivors price the work the
    kernel actually cannot avoid. Both modes run the same compacted tail
    (block slack there cancels out of the comparison). Host-side
    arithmetic only — never traced, never syncs.
    :func:`progressive_cost_model_device` is the traced mirror used by the
    in-program mode pick; callers must hand BOTH the same ``block_b``
    (serving passes ``repro.kernels.ops.ENGINE_BLOCK_B``) or the picks can
    disagree.
    """
    S = len(sentinels)
    assert mode in ("fused", "staged"), mode
    n_stages = S + 1 if dense_stage else S
    assert len(stage_survivors) == n_stages
    n_docs = max(float(n_docs), 0.0)   # empty batch: costs reduce to the
    #   per-launch overhead — finite, and identical tail for both modes
    surv = _sane_survivors(stage_survivors, n_docs)
    caps = list(stage_capacities) if stage_capacities is not None else None
    dense_term = 0.0
    if dense_stage:
        assert caps is not None and len(caps) == n_stages, caps
        dense_term = n_docs * float(dense_cost_trees)
        head_docs = float(caps[0])   # the tree kernels score the whole
        #   dense-compacted block, not just its occupied rows
        caps, surv = caps[1:], surv[1:]
    else:
        head_docs = n_docs
    has_tail = sentinels[-1] < n_trees
    qe = min(max(float(query_exit_rate), 0.0), 1.0)
    tail_launch = (1.0 - qe) if has_tail else 0.0
    tail = surv[-1] * (n_trees - sentinels[-1])
    if mode == "fused":
        head = head_docs * sentinels[-1]
        launches = 1 + tail_launch
    else:
        if caps is None:
            caps = [n_docs] * S
        assert len(caps) == S
        if block_b > 1:
            surv = [
                math.ceil(s / _stage_block(block_b, c)) * _stage_block(block_b, c)
                for c, s in zip(caps, surv)
            ]
        surv = [min(float(c), float(s)) for c, s in zip(caps, surv)]
        head = head_docs * sentinels[0] + sum(
            surv[k] * (sentinels[k + 1] - sentinels[k]) for k in range(S - 1)
        )
        launches = S + tail_launch
    return float(dense_term + head + tail + launch_overhead_trees * launches)


def progressive_cost_model_device(
    n_docs: int,
    stage_survivors: jax.Array,   # [S] f32 — traced survivor estimates
    sentinels: Sequence[int],
    n_trees: int,
    launch_overhead_trees: float = 0.0,
    stage_capacities: Sequence[int] | None = None,
    block_b: int = 1,
    query_exit_rate: jax.Array | float = 0.0,
    dense_cost_trees: float = 0.0,
    dense_stage: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Traced mirror of :func:`progressive_cost_model` for the IN-PROGRAM
    mode pick: returns ``(fused_cost, staged_cost)`` as f32 device scalars.

    ``dense_stage`` follows the host model's hybrid convention:
    ``stage_survivors``/``stage_capacities`` carry a leading dense entry,
    the tree heads are priced at the dense capacity, and the (symmetric)
    dense term is added to both returned costs.

    ``query_exit_rate`` may be a TRACED scalar (the service ships its
    tail-skip EMA next to ``stage_ema`` at submit time) — like the host
    model it discounts only the tail launch's overhead, identically in
    both modes.

    Same arithmetic, same units (doc·tree traversals), same staged pricing
    (block-rounded survivors clipped at capacity) — only the survivor
    estimates are a traced operand (the service's smoothed continue rates
    live on device), so ``staged_cost < fused_cost`` can feed a
    ``lax.cond`` without a host round trip. ``n_docs``, ``sentinels``,
    ``stage_capacities``, ``block_b`` and the overhead are static
    configuration baked into the trace. Chooses the same branch as the
    host model away from exact cost ties (the host compares in float64,
    this in float32; all inputs are small exact integers/EMAs, so ties —
    and survivor estimates landing exactly on a block edge — are the only
    divergence points).
    """
    S = len(sentinels)
    n_stages = S + 1 if dense_stage else S
    assert stage_survivors.shape == (n_stages,), (
        stage_survivors.shape, n_stages
    )
    n_docs = max(int(n_docs), 0)
    # Same sanitization as the host model (_sane_survivors): NaN → 0,
    # ±inf/out-of-range → clamped, so the traced costs are always finite
    # and the lax.cond predicate is always a real decision.
    surv = jnp.nan_to_num(
        stage_survivors.astype(jnp.float32),
        nan=0.0, posinf=float(n_docs), neginf=0.0,
    )
    surv = jnp.clip(surv, 0.0, float(n_docs))
    caps = list(stage_capacities) if stage_capacities is not None else None
    dense_term = 0.0
    if dense_stage:
        assert caps is not None and len(caps) == n_stages, caps
        dense_term = n_docs * dense_cost_trees
        head_docs = 1.0 * caps[0]   # static config int → python float
        caps, surv = caps[1:], surv[1:]
    else:
        head_docs = 1.0 * n_docs
    has_tail = sentinels[-1] < n_trees
    qe = jnp.clip(jnp.asarray(query_exit_rate, jnp.float32), 0.0, 1.0)
    tail_launch = (1.0 - qe) if has_tail else jnp.float32(0.0)
    tail = surv[-1] * float(n_trees - sentinels[-1])
    fused = (
        dense_term
        + head_docs * float(sentinels[-1])
        + tail
        + launch_overhead_trees * (1.0 + tail_launch)
    )
    if caps is None:
        caps = [n_docs] * S
    assert len(caps) == S
    s_surv = surv
    if block_b > 1:
        effs = jnp.asarray(
            [_stage_block(block_b, c) for c in caps], jnp.float32
        )
        s_surv = jnp.ceil(s_surv / effs) * effs
    s_surv = jnp.minimum(s_surv, jnp.asarray(caps, jnp.float32))
    deltas = jnp.asarray(
        [sentinels[k + 1] - sentinels[k] for k in range(S - 1)], jnp.float32
    )
    staged = (
        dense_term
        + head_docs * float(sentinels[0])
        + (s_surv[: S - 1] * deltas).sum()
        + tail
        + launch_overhead_trees * (float(S) + tail_launch)
    )
    return (
        jnp.asarray(fused, jnp.float32),
        jnp.asarray(staged, jnp.float32),
    )


def speedup_progressive(
    mask: jax.Array,
    stage_masks: Sequence[jax.Array],
    sentinels: Sequence[int],
    n_trees: int,
    classifier_trees: float | Sequence[float] = 0,
) -> jnp.ndarray:
    """Lazy device scalar (no host sync) — ``float()`` it in a stats path."""
    full = mask.sum() * n_trees
    ee = trees_traversed_progressive(
        mask, stage_masks, sentinels, n_trees, classifier_trees
    )
    return full / ee
