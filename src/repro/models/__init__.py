"""Model zoo: the 10 assigned architectures + the paper's forest cascade.

LM transformers (scan-over-layers, GQA, optional qk-norm/QKV-bias/MoE):
qwen2.5-14b, minitron-4b, qwen3-4b, deepseek-moe-16b, llama4-maverick.
GNN: nequip (E(3)-equivariant tensor products). RecSys: bert4rec, din,
deepfm, dlrm-rm2 (EmbeddingBag built from take + segment_sum).

Cascade-facing: :mod:`repro.models.dense_scorer` — the distilled dense
stage-0 scorer of the hybrid cascade (DLRM ``dot_interact`` idiom over
projected LTR features; trained by :mod:`repro.train.distill`).
"""

from repro.models.dense_scorer import (
    DENSE_COST_TREES,
    dense_score,
    init_dense_scorer,
    make_dense_scorer,
)

__all__ = [
    "DENSE_COST_TREES",
    "dense_score",
    "init_dense_scorer",
    "make_dense_scorer",
]
