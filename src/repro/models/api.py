"""Unified architecture API: one entry point per (arch × shape) cell.

``make_cell(cfg, shape)`` returns a :class:`Cell` bundling everything the
launcher needs:

- ``abstract_state()``  — ShapeDtypeStruct pytree of the step's carried
  state (TrainState for ``train`` shapes; params (+caches) for serving).
- ``state_logical()``   — matching logical-axis pytree.
- ``input_specs()``     — ShapeDtypeStruct stand-ins for one step's inputs.
- ``input_logical()``   — logical axes for those inputs.
- ``step``              — the pure step function ``(state, inputs) → ...``
  that the dry-run lowers and the trainer/server jit.

The SAME step functions power CPU smoke tests (reduced configs, real
arrays) and the 512-device dry-run (full configs, abstract arrays).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ForestConfig,
    NequIPConfig,
    RecSysConfig,
    ShapeSpec,
    TransformerConfig,
)
from repro.models import nequip as nequip_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.train.optimizer import get_optimizer
from repro.train.trainer import TrainState, make_train_step

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass
class Cell:
    cfg: Any
    shape: ShapeSpec
    step: Callable
    abstract_state: Callable[[], Any]
    state_logical: Callable[[], Any]
    input_specs: Callable[[], Any]
    input_logical: Callable[[], Any]
    init_state: Callable[[jax.Array], Any]  # real init (smoke tests / training)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Optimizer-state logical axes.
# ---------------------------------------------------------------------------


def _opt_logical(opt_name: str, abstract_params, param_logical):
    if opt_name == "adamw":
        return {"m": param_logical, "v": param_logical, "count": ()}
    if opt_name == "adafactor":
        def leaf(p, lg):
            lg = tuple(lg)
            if p.ndim >= 2:
                return {"vr": lg[:-1], "vc": lg[:-2] + lg[-1:]}
            return {"v": lg}

        f = jax.tree.map(
            leaf, abstract_params, param_logical,
            is_leaf=lambda x: hasattr(x, "shape"),
        )
        return {"f": f, "count": ()}
    if opt_name == "adagrad_rowwise":
        from repro.train.optimizer import ROWWISE_MIN_ROWS

        def leaf(p, lg):
            lg = tuple(lg)
            if p.ndim == 2 and p.shape[0] >= ROWWISE_MIN_ROWS:
                return lg[:1]
            return lg

        return {
            "acc": jax.tree.map(
                leaf, abstract_params, param_logical,
                is_leaf=lambda x: hasattr(x, "shape"),
            )
        }
    raise ValueError(opt_name)


def _train_cell(cfg, shape, loss_fn, abstract_params_fn, param_logical,
                init_fn, inputs_fn, inputs_logical, microbatch=0,
                accum_dtype=jnp.float32):
    opt = get_optimizer(cfg.optimizer)
    step = make_train_step(loss_fn, opt, microbatch=microbatch,
                           accum_dtype=accum_dtype)

    def abstract_state():
        params = abstract_params_fn()
        opt_state = jax.eval_shape(opt.init, params)
        return TrainState(params=params, opt_state=opt_state,
                          step=_sds((), I32))

    def state_logical():
        return TrainState(
            params=param_logical,
            opt_state=_opt_logical(cfg.optimizer, abstract_params_fn(),
                                   param_logical),
            step=(),
        )

    def init_state(key):
        from repro.train.trainer import init_state as _init

        return _init(init_fn(key), opt)

    return Cell(
        cfg=cfg, shape=shape, step=step,
        abstract_state=abstract_state, state_logical=state_logical,
        input_specs=inputs_fn, input_logical=inputs_logical,
        init_state=init_state,
    )


# ---------------------------------------------------------------------------
# LM transformers.
# ---------------------------------------------------------------------------


def _lm_cell(cfg: TransformerConfig, shape: ShapeSpec) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    plogical = tfm.param_logical(cfg)

    if shape.kind == "train":
        def inputs():
            return {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}

        def inputs_logical():
            return {"tokens": ("batch", None), "labels": ("batch", None)}

        accum = jnp.bfloat16 if cfg.optimizer == "adafactor" else jnp.float32
        return _train_cell(
            cfg, shape, partial(tfm.loss_fn, cfg),
            lambda: tfm.abstract_params(cfg), plogical,
            lambda key: tfm.init(cfg, key),
            inputs, inputs_logical,
            microbatch=shape.microbatch, accum_dtype=accum,
        )

    if shape.kind == "prefill":
        def step(params, inputs):
            return tfm.prefill(cfg, params, inputs["tokens"], cache_len=S)

        def inputs():
            return {"tokens": _sds((B, S), I32)}

        return Cell(
            cfg=cfg, shape=shape, step=step,
            abstract_state=lambda: tfm.abstract_params(cfg),
            state_logical=lambda: plogical,
            input_specs=inputs,
            input_logical=lambda: {"tokens": ("batch", None)},
            init_state=lambda key: tfm.init(cfg, key),
        )

    # decode
    def step(params, inputs):
        return tfm.decode_step(cfg, params, inputs["token"], inputs["caches"],
                               inputs["pos"])

    def cache_sds():
        return jax.eval_shape(lambda: tfm.make_decode_caches(cfg, B, S))

    def inputs():
        return {
            "token": _sds((B, 1), I32),
            "caches": cache_sds(),
            "pos": _sds((), I32),
        }

    def inputs_logical():
        cache_lg = jax.tree.map(
            lambda _: (None, "batch", "kv_seq", None, None),
            cache_sds(), is_leaf=lambda x: hasattr(x, "shape"),
        )
        return {"token": ("batch", None), "caches": cache_lg, "pos": ()}

    return Cell(
        cfg=cfg, shape=shape, step=step,
        abstract_state=lambda: tfm.abstract_params(cfg),
        state_logical=lambda: plogical,
        input_specs=inputs, input_logical=inputs_logical,
        init_state=lambda key: tfm.init(cfg, key),
    )


# ---------------------------------------------------------------------------
# NequIP.
# ---------------------------------------------------------------------------


def _pad512(n: int) -> int:
    """Graph/candidate axes padded to 512 so every mesh factoring divides
    (data=16, data×model=256, pod×data×model=512). The data pipeline emits
    dummy entries (self-edges on a ghost node / zero-weight rows)."""
    return -(-n // 512) * 512


def _nequip_inputs(shape: ShapeSpec):
    if shape.graph_batch and shape.n_nodes < 10_000:
        # batched-small-graphs: totals = per-graph size × batch
        N = _pad512(shape.n_nodes * shape.graph_batch)
        E = _pad512(shape.n_edges * shape.graph_batch)
    else:
        N, E = _pad512(shape.n_nodes), _pad512(shape.n_edges)
    n_graphs = shape.graph_batch or 1
    specs = {
        "positions": _sds((N, 3), F32),
        "species": _sds((N,), I32),
        "edge_src": _sds((E,), I32),
        "edge_dst": _sds((E,), I32),
        "energy": _sds((n_graphs,), F32),
    }
    logical = {
        "positions": ("nodes", None),
        "species": ("nodes",),
        "edge_src": ("edges",),
        "edge_dst": ("edges",),
        "energy": (None,),
    }
    if shape.graph_batch:
        specs["graph_id"] = _sds((N,), I32)
        logical["graph_id"] = ("nodes",)
        specs["forces"] = _sds((N, 3), F32)
        logical["forces"] = ("nodes", None)
    if shape.d_feat:
        specs["node_feat"] = _sds((N, shape.d_feat), F32)
        logical["node_feat"] = ("nodes", None)
    return specs, logical


def _nequip_cell(cfg: NequIPConfig, shape: ShapeSpec) -> Cell:
    d_feat = shape.d_feat
    with_forces = bool(shape.graph_batch)
    loss = partial(nequip_mod.loss_fn, cfg, with_forces=with_forces)
    loss_fn = lambda params, batch: loss(params, batch)
    specs, logical = _nequip_inputs(shape)
    plogical = nequip_mod.param_logical(cfg, d_feat)
    return _train_cell(
        cfg, shape, loss_fn,
        lambda: jax.eval_shape(
            lambda: nequip_mod.init(cfg, jax.random.key(0), d_feat)
        ),
        plogical,
        lambda key: nequip_mod.init(cfg, key, d_feat),
        lambda: specs, lambda: logical,
    )


# ---------------------------------------------------------------------------
# RecSys.
# ---------------------------------------------------------------------------


def _recsys_inputs(cfg: RecSysConfig, shape: ShapeSpec):
    B = shape.batch
    fam = cfg.family
    if shape.n_candidates:
        C = _pad512(shape.n_candidates)
        if fam == "dlrm":
            specs = {
                "dense": _sds((1, cfg.n_dense), F32),
                "sparse": _sds((1, cfg.n_sparse - 1, cfg.multi_hot), I32),
                "cand_ids": _sds((C,), I32),
            }
            logical = {"dense": (None, None), "sparse": (None, None, None),
                       "cand_ids": ("cands",)}
        elif fam == "deepfm":
            specs = {"ids": _sds((1, cfg.n_sparse - 1), I32),
                     "cand_ids": _sds((C,), I32)}
            logical = {"ids": (None, None), "cand_ids": ("cands",)}
        elif fam == "din":
            specs = {"hist_ids": _sds((1, cfg.seq_len), I32),
                     "cand_ids": _sds((C,), I32)}
            logical = {"hist_ids": (None, None), "cand_ids": ("cands",)}
        else:  # bert4rec
            specs = {"ids": _sds((1, cfg.seq_len), I32),
                     "cand_ids": _sds((C,), I32)}
            logical = {"ids": (None, None), "cand_ids": ("cands",)}
        return specs, logical

    if fam == "dlrm":
        specs = {
            "dense": _sds((B, cfg.n_dense), F32),
            "sparse": _sds((B, cfg.n_sparse, cfg.multi_hot), I32),
        }
        logical = {"dense": ("batch", None), "sparse": ("batch", None, None)}
    elif fam == "deepfm":
        specs = {"ids": _sds((B, cfg.n_sparse), I32)}
        logical = {"ids": ("batch", None)}
    elif fam == "din":
        specs = {"hist_ids": _sds((B, cfg.seq_len), I32),
                 "target_id": _sds((B,), I32)}
        logical = {"hist_ids": ("batch", None), "target_id": ("batch",)}
    else:  # bert4rec
        specs = {"ids": _sds((B, cfg.seq_len), I32)}
        logical = {"ids": ("batch", None)}

    if shape.kind == "train":
        if fam == "bert4rec":
            specs.update({"labels": _sds((B, cfg.seq_len), I32),
                          "mask_pos": _sds((B, cfg.seq_len), F32)})
            logical.update({"labels": ("batch", None),
                            "mask_pos": ("batch", None)})
        else:
            specs["label"] = _sds((B,), F32)
            logical["label"] = ("batch",)
    elif fam in ("din", "bert4rec") and shape.kind == "serve":
        if fam == "bert4rec":
            specs["target_id"] = _sds((B,), I32)
            logical["target_id"] = ("batch",)
    return specs, logical


def _recsys_cell(cfg: RecSysConfig, shape: ShapeSpec) -> Cell:
    fam = cfg.family
    plogical = recsys_mod.LOGICAL[fam](cfg)
    specs, logical = _recsys_inputs(cfg, shape)
    init_fn = lambda key: recsys_mod.INIT[fam](cfg, key)
    abstract = lambda: jax.eval_shape(lambda: recsys_mod.INIT[fam](cfg, jax.random.key(0)))

    if shape.kind == "train":
        return _train_cell(
            cfg, shape, partial(recsys_mod.loss_fn, cfg),
            abstract, plogical, init_fn,
            lambda: specs, lambda: logical,
            microbatch=shape.microbatch,
        )

    if shape.n_candidates:
        fwd = recsys_mod.SCORE_CANDIDATES[fam]
    else:
        fwd = recsys_mod.FORWARD[fam]

    def step(params, inputs):
        return fwd(cfg, params, inputs)

    return Cell(
        cfg=cfg, shape=shape, step=step,
        abstract_state=abstract, state_logical=lambda: plogical,
        input_specs=lambda: specs, input_logical=lambda: logical,
        init_state=init_fn,
    )


# ---------------------------------------------------------------------------
# Forest (the paper's arch): LEAR cascade serving.
# ---------------------------------------------------------------------------


def _forest_abstract(cfg: ForestConfig):
    from repro.forest.ensemble import TreeEnsemble

    n_int = (1 << cfg.depth) - 1
    n_leaf = 1 << cfg.depth

    def ens(T, F):
        return TreeEnsemble(
            feature=_sds((T, n_int), I32),
            threshold=_sds((T, n_int), F32),
            left=_sds((T, n_int), I32),
            right=_sds((T, n_int), I32),
            mask_lo=_sds((T, n_int), jnp.uint32),
            mask_hi=_sds((T, n_int), jnp.uint32),
            leaf_value=_sds((T, n_leaf), F32),
            base_score=_sds((), F32),
        )

    return {
        "ranker": ens(cfg.n_trees, cfg.n_features),
        "classifier": ens(cfg.classifier_trees, cfg.n_features + 4),
        "threshold": _sds((), F32),
    }


def _forest_real(cfg: ForestConfig, key):
    from repro.forest.ensemble import random_ensemble

    seed = int(jax.random.randint(key, (), 0, 2**31 - 1))
    return {
        "ranker": random_ensemble(seed, cfg.n_trees, cfg.depth, cfg.n_features),
        "classifier": random_ensemble(
            seed + 1, cfg.classifier_trees, cfg.depth, cfg.n_features + 4
        ),
        "threshold": jnp.float32(0.5),
    }


def _forest_step(cfg: ForestConfig):
    from repro.core.lear import augment_features
    from repro.forest.ensemble import slice_trees
    from repro.forest.scoring import score_bitvector

    def _score(ens, x2d):
        return score_bitvector(ens, x2d)

    def step(params, inputs):
        """LEAR cascade over a padded [Q, D, F] block.

        capacity_frac == 0 → reference path: every document runs every
        tree, exits applied arithmetically (the paper's *quality*
        semantics, used as the §Perf baseline = "Full" cost).

        capacity_frac > 0 → compacted path: per query, only the top
        ⌈frac·D⌉ survivors (stable-partitioned by the classifier verdict)
        traverse the tail trees — the doc dimension of the dominant kernel
        shrinks by ~4× at the paper's continue rates. sentinel2 adds a
        second rank-based cut (beyond-paper multi-sentinel cascade).
        """
        X, mask = inputs["X"], inputs["mask"]
        Q, D, F = X.shape
        ranker = params["ranker"]
        head = slice_trees(ranker, 0, cfg.sentinel)
        part = _score(head, X.reshape(Q * D, F)).reshape(Q, D)
        aug = augment_features(X, part, mask)
        logits = _score(
            params["classifier"], aug.reshape(Q * D, F + 4)
        ).reshape(Q, D)
        cont = mask & (jax.nn.sigmoid(logits) >= params["threshold"])

        if cfg.capacity_frac <= 0:
            tail = slice_trees(ranker, cfg.sentinel, cfg.n_trees)
            tail_scores = _score(tail, X.reshape(Q * D, F)).reshape(Q, D)
            return jnp.where(cont, part + tail_scores, part), cont

        C1 = max(1, int(np.ceil(cfg.capacity_frac * D)))
        order = jnp.argsort(~cont, axis=1, stable=True)            # [Q, D]
        sel = order[:, :C1]                                        # [Q, C1]
        x_sel = jnp.take_along_axis(X, sel[..., None], axis=1)     # [Q, C1, F]
        part_sel = jnp.take_along_axis(part, sel, axis=1)
        valid = jnp.take_along_axis(cont, sel, axis=1)

        s2 = cfg.sentinel2
        if s2 and s2 > cfg.sentinel:
            mid = slice_trees(ranker, cfg.sentinel, s2)
            mid_sel = _score(mid, x_sel.reshape(Q * C1, F)).reshape(Q, C1)
            part2 = part_sel + mid_sel
            C2 = max(1, int(np.ceil((cfg.capacity2_frac or cfg.capacity_frac / 2) * D)))
            C2 = min(C2, C1)
            # Second cut: rank threshold on the refreshed partial scores.
            rank2 = jnp.argsort(
                jnp.argsort(jnp.where(valid, -part2, np.inf), axis=1), axis=1
            )
            keep2 = valid & (rank2 < C2)
            order2 = jnp.argsort(~keep2, axis=1, stable=True)[:, :C2]
            x_sel2 = jnp.take_along_axis(x_sel, order2[..., None], axis=1)
            valid2 = jnp.take_along_axis(keep2, order2, axis=1)
            tail = slice_trees(ranker, s2, cfg.n_trees)
            tail_sel = _score(tail, x_sel2.reshape(Q * C2, F)).reshape(Q, C2)
            delta2 = jnp.zeros((Q, C1)).at[
                jnp.arange(Q)[:, None], order2
            ].add(jnp.where(valid2, tail_sel, 0.0))
            deltas = jnp.where(valid, mid_sel, 0.0) + delta2
        else:
            tail = slice_trees(ranker, cfg.sentinel, cfg.n_trees)
            tail_sel = _score(tail, x_sel.reshape(Q * C1, F)).reshape(Q, C1)
            deltas = jnp.where(valid, tail_sel, 0.0)

        scores = part + jnp.zeros_like(part).at[
            jnp.arange(Q)[:, None], sel
        ].add(deltas)
        return scores, cont

    return step


def _forest_cell(cfg: ForestConfig, shape: ShapeSpec) -> Cell:
    Q, D, F = shape.batch, cfg.max_docs, cfg.n_features

    def inputs():
        return {"X": _sds((Q, D, F), F32), "mask": _sds((Q, D), jnp.bool_)}

    def logical():
        return {"X": ("batch", None, None), "mask": ("batch", None)}

    def plogical():
        from repro.forest.ensemble import TreeEnsemble

        def ens_lg():
            # Trees replicated (documents are the parallel axis).
            return TreeEnsemble(
                feature=(None, None), threshold=(None, None),
                left=(None, None), right=(None, None),
                mask_lo=(None, None), mask_hi=(None, None),
                leaf_value=(None, None), base_score=(),
            )

        return {"ranker": ens_lg(), "classifier": ens_lg(), "threshold": ()}

    return Cell(
        cfg=cfg, shape=shape, step=_forest_step(cfg),
        abstract_state=lambda: _forest_abstract(cfg),
        state_logical=plogical,
        input_specs=inputs, input_logical=logical,
        init_state=lambda key: _forest_real(cfg, key),
    )


# ---------------------------------------------------------------------------
# Dispatch.
# ---------------------------------------------------------------------------


def make_cell(cfg, shape: ShapeSpec) -> Cell:
    if isinstance(cfg, TransformerConfig):
        return _lm_cell(cfg, shape)
    if isinstance(cfg, NequIPConfig):
        return _nequip_cell(cfg, shape)
    if isinstance(cfg, RecSysConfig):
        return _recsys_cell(cfg, shape)
    if isinstance(cfg, ForestConfig):
        return _forest_cell(cfg, shape)
    raise TypeError(type(cfg))
