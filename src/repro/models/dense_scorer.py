"""Distilled dense stage-0 scorer for the hybrid cascade.

A deliberately tiny model (KB-scale parameters) whose one job is to stand
in for the GBDT ensemble on the *easy majority* of documents: the hybrid
engine (:class:`repro.core.stage.DenseStage`) scores the entire flat
``[Q·D, F]`` candidate block through it in one shot, the gate policy
(:func:`repro.core.strategies.dense_keep_fraction`) keeps the contested
head, and only those survivors ever touch a tree. Architecture borrows
the DLRM ``dot_interact`` idiom (:mod:`repro.models.recsys`): a single
projection matmul lifts the raw LTR feature vector into ``n_vec`` small
embedding vectors, the pairwise upper-triangle dots capture second-order
feature interactions at negligible FLOP cost, and a two-layer MLP head
maps ``[projection ‖ interactions]`` to one score. Everything is plain
XLA — the hybrid engine's launch-accounting contract depends on the dense
stage dispatching NO Pallas kernel.

Sizing knobs are env-overridable through the one sanctioned chokepoint
(:func:`repro.kernels.ops.env_int`) and read at import, matching the rest
of the kernel-facing constants (see ``tests/test_env_overrides.py``):

- ``REPRO_DENSE_N_VEC`` (default 4): interaction vectors per document.
- ``REPRO_DENSE_VEC_DIM`` (default 16): dimension of each vector.
- ``REPRO_DENSE_HIDDEN`` (default 32): MLP head width.
- ``REPRO_DENSE_COST_TREES`` (default 4): accounting price of ONE dense
  evaluation in doc·tree-traversal equivalents. The raw FLOP count is
  far higher than 4 trees' worth of node visits, but the matmul runs on
  the MXU while the tree kernel is VPU gather/compare bound — pricing at
  FLOP parity would make the cost models reject exactly the trade the
  hybrid exists to exploit. Calibrate against wall clock the same way
  ``launch_overhead_trees`` is.

Params are a flat dict pytree (jittable, optimizer-transformable by
:mod:`repro.train.optimizer`); :func:`make_dense_scorer` closes a trained
pytree over :func:`dense_score` to produce the stable-identity
``[B, F] → [B]`` callable a :class:`~repro.core.stage.DenseStage` wants —
reuse ONE closure per trained model or the engine's step cache re-traces.
"""

from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import env_int
from repro.models.recsys import _mlp_init

DENSE_N_VEC = env_int("REPRO_DENSE_N_VEC", 4, minimum=2)
DENSE_VEC_DIM = env_int("REPRO_DENSE_VEC_DIM", 16)
DENSE_HIDDEN = env_int("REPRO_DENSE_HIDDEN", 32)
DENSE_COST_TREES = env_int("REPRO_DENSE_COST_TREES", 4)

#: Trained parameter pytree of the dense scorer (flat dict of arrays).
DenseParams = dict


def dot_interact(vecs: jax.Array) -> jax.Array:
    """``[B, n, d]`` → upper-triangle pairwise dots ``[B, n(n−1)/2]``.

    The DLRM interaction (see ``repro.models.recsys._dot_interaction``):
    one einsum builds the full Gram matrix, the static ``triu_indices``
    gather keeps each unordered pair once. ``n`` is static, so the
    gather indices are trace-time constants.
    """
    n = vecs.shape[1]
    z = jnp.einsum("bnd,bmd->bnm", vecs, vecs)
    iu, ju = np.triu_indices(n, k=1)
    return z[:, iu, ju]


def init_dense_scorer(
    key: jax.Array,
    n_features: int,
    n_vec: int = DENSE_N_VEC,
    vec_dim: int = DENSE_VEC_DIM,
    hidden: int = DENSE_HIDDEN,
) -> DenseParams:
    """Initialize the scorer pytree for ``n_features``-dim LTR vectors.

    The projection is stored ``[F, n_vec, vec_dim]`` so :func:`dense_score`
    can recover the vector split from the param shapes alone — the pytree
    stays all-array (no static ints smuggled through optimizer maps).
    """
    k_proj, k_head = jax.random.split(key)
    n_pairs = n_vec * (n_vec - 1) // 2
    head_in = n_vec * vec_dim + n_pairs
    proj = (
        jax.random.normal(k_proj, (n_features, n_vec, vec_dim), jnp.float32)
        * n_features**-0.5
    )
    # The projection bias exists so affine input transforms (the feature
    # whitening the distiller trains under) can be folded INTO the params:
    # the deployed scorer then consumes raw features — see
    # repro.train.distill.
    pb = jnp.zeros((n_vec, vec_dim), jnp.float32)
    (w1, b1), (w2, b2) = _mlp_init(k_head, (head_in, hidden, 1))
    return {"proj": proj, "pb": pb, "w1": w1, "b1": b1, "w2": w2, "b2": b2}


def dense_score(params: DenseParams, x: jax.Array) -> jax.Array:
    """Score a flat feature block: ``[B, F]`` → ``[B]`` float32.

    One MXU contraction lifts every document into its interaction
    vectors; the head MLP sees the flattened vectors plus their pairwise
    dots. Pure function of ``(params, x)`` — safe to trace into the
    progressive step (the engine closes params over it as constants).
    """
    vecs = jnp.einsum("bf,fnd->bnd", x, params["proj"]) + params["pb"]
    flat = vecs.reshape(vecs.shape[0], -1)
    feats = jnp.concatenate([flat, dot_interact(vecs)], axis=-1)
    h = jax.nn.relu(feats @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def make_dense_scorer(params: DenseParams) -> Callable[[jax.Array], jax.Array]:
    """Close ``params`` over :func:`dense_score` → the ``[B, F] → [B]``
    scorer callable a :class:`repro.core.stage.DenseStage` takes.

    The returned closure's *identity* is part of the engine's step-cache
    key (callables hash by ``id``): build it once per trained model and
    reuse it across batches, exactly like strategy callables.
    """
    def scorer(x: jax.Array) -> jax.Array:
        return dense_score(params, x)

    return scorer
