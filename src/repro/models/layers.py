"""Shared neural layers: RMSNorm, RoPE, blockwise attention, GLU MLP.

Attention is implemented flash-style in pure JAX: an online-softmax double
scan over query and key/value blocks, so no ``[S, S]`` score matrix is ever
materialized — mandatory for the 32k-token prefill shapes, and the reason
``long_500k`` would be *memory*-feasible were the assigned archs not
quadratic-compute in the first place (see DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def rope_frequencies(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] or [S]."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _online_softmax_block(carry, scores, v_blk):
    """One online-softmax update. scores: [..., Q, K]; v_blk: [..., K, Dh]."""
    acc, row_max, row_sum = carry
    blk_max = scores.max(axis=-1)
    new_max = jnp.maximum(row_max, blk_max)
    correction = jnp.exp(row_max - new_max)
    p = jnp.exp(scores - new_max[..., None])
    acc = acc * correction[..., None] + jnp.einsum(
        "...qk,...kd->...qd", p, v_blk.astype(jnp.float32)
    )
    row_sum = row_sum * correction + p.sum(axis=-1)
    return acc, new_max, row_sum


def blockwise_attention(
    q: jax.Array,      # [B, Sq, H, Dh]
    k: jax.Array,      # [B, Skv, Hkv, Dh]
    v: jax.Array,      # [B, Skv, Hkv, Dh]
    *,
    causal: bool = True,
    q_offset: int = 0,          # absolute position of q[0] (chunked prefill)
    q_block: int = 512,
    kv_block: int = 1024,
    causal_skip: bool = False,  # §Perf: unroll q blocks, skip masked kv blocks
) -> jax.Array:
    """GQA flash-style attention; returns [B, Sq, H, Dh].

    ``causal_skip`` replaces the q-block scan with a python unroll whose
    kv scan only covers blocks at-or-below the causal diagonal — halving
    attention FLOPs (upper triangle never computed) at the cost of an
    HLO that grows with the number of q blocks.
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / np.sqrt(Dh)

    # Block axes LEADING so lax.scan iterates blocks, not batch.
    qr = (
        q.reshape(B, nq, q_block, Hkv, G, Dh)
        .transpose(1, 0, 3, 4, 2, 5)          # [nq, B, Hkv, G, q_block, Dh]
        .astype(jnp.float32)
    )
    kr = k.reshape(B, nk, kv_block, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_block, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    # kr/vr: [nk, B, Hkv, kv_block, Dh]

    def q_step(q_t, q_idx, n_kv_blocks):
        # q_t: [B, Hkv, G, q_block, Dh]
        init = (
            jnp.zeros((B, Hkv, G, q_block, Dh), jnp.float32),
            jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, q_block), jnp.float32),
        )

        def kv_step(carry, ki):
            k_blk, v_blk, k_idx = ki  # [B, Hkv, kv_block, Dh]
            scores = jnp.einsum(
                "bhgqd,bhkd->bhgqk", q_t, k_blk.astype(jnp.float32)
            ) * scale
            if causal:
                qpos = q_offset + q_idx * q_block + jnp.arange(q_block)
                kpos = k_idx * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            vb = v_blk[:, :, None]  # [B, Hkv, 1, kv_block, Dh]
            return _online_softmax_block(carry, scores, vb), None

        (acc, _, row_sum), _ = jax.lax.scan(
            kv_step, init,
            (kr[:n_kv_blocks], vr[:n_kv_blocks], jnp.arange(n_kv_blocks)),
        )
        out = acc / jnp.maximum(row_sum[..., None], 1e-30)
        return out  # [B, Hkv, G, q_block, Dh]

    if causal_skip and causal:
        # Unrolled q blocks: block i attends kv blocks [0, ceil(end/kv_block)).
        outs = []
        for i in range(nq):
            q_end = q_offset + (i + 1) * q_block
            n_kv = min(nk, -(-q_end // kv_block))
            outs.append(q_step(qr[i], jnp.int32(i), n_kv))
        outs = jnp.stack(outs)
    else:
        _, outs = jax.lax.scan(
            lambda _, qi: (None, q_step(qi[0], qi[1], nk)),
            None, (qr, jnp.arange(nq)),
        )
    # outs: [nq, B, Hkv, G, q_block, Dh] → [B, Sq, H, Dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, H, Dh] current-token queries
    k_cache: jax.Array,  # [B, S_max, Hkv, Dh]
    v_cache: jax.Array,
    pos: jax.Array,      # [] current length (tokens < pos are valid)
) -> jax.Array:
    B, _, H, Dh = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(Dh)
    qf = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, None, None, :] < pos
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def glu_mlp(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = constrain(h, "batch", None, "ff")
    return h @ w_down
