"""Mixture-of-Experts FFN: grouped token-choice dispatch, GSPMD-shardable.

TPU adaptation of switch/GShard routing without the ``[tokens, E, C]``
one-hot dispatch einsum (which is memory-infeasible at 1M tokens): tokens
are organized into static *dispatch groups* (one group per sequence at
train/prefill; a single group at decode). Within each group, each expert
gathers its top-``C`` chosen tokens by router probability (token-choice
with capacity, priority = probability), runs the expert FFN as one batched
einsum over ``[G, E, C, D]``, and scatter-adds results back weighted by the
(renormalized) router probabilities.

Sharding: groups → data axes, experts → EP axes ("model", + "pod" on the
multi-pod mesh). The gather/scatter is *within-group*, hence local to a
data shard; the activation reshard between group-sharded and expert-sharded
layouts is GSPMD's all-to-all — exactly classic MoE dispatch.

Capacity: C = ceil(T_group · top_k / E · capacity_factor). Tokens beyond an
expert's capacity are dropped (standard GShard semantics); the residual
connection carries them unchanged. An auxiliary load-balancing loss
(Switch-style) is returned to the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def _capacity(tokens_per_group: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(tokens_per_group * top_k * cf / n_experts) + 1
    return min(max(4, c), tokens_per_group)


def moe_ffn(
    x: jax.Array,            # [G, T, D] tokens in dispatch groups
    router_w: jax.Array,     # [D, E]
    w_gate: jax.Array,       # [E, D, F]
    w_up: jax.Array,         # [E, D, F]
    w_down: jax.Array,       # [E, F, D]
    *,
    top_k: int,
    capacity_factor: float,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [G, T, D], aux load-balance loss [])."""
    G, T, D = x.shape
    E = router_w.shape[1]
    C = _capacity(T, E, top_k, capacity_factor)

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [G, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, top_k)                     # [G, T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # Per-token-per-expert routing weight (0 if not chosen).
    chose = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)            # [G, T, k, E]
    weight = (chose * top_p[..., None]).sum(axis=2)                  # [G, T, E]

    # Switch aux loss: E * Σ_e (fraction routed to e) · (mean prob of e).
    frac = (weight > 0).astype(jnp.float32).mean(axis=1)             # [G, E]
    mean_p = probs.mean(axis=1)
    aux = (E * (frac * mean_p).sum(axis=-1)).mean()

    # Token-choice with capacity: each expert takes its top-C tokens by prob.
    priority = jnp.where(weight > 0, weight, -1.0)                   # [G, T, E]
    _, token_idx = jax.lax.top_k(priority.transpose(0, 2, 1), C)     # [G, E, C]

    def gather_group(xg, idxg, wg):
        x_sel = xg[idxg]                                             # [E, C, D]
        w_sel = jnp.take_along_axis(wg.transpose(1, 0), idxg, axis=1)  # [E, C]
        return x_sel, w_sel

    x_sel, w_sel = jax.vmap(gather_group)(x, token_idx, weight)      # [G,E,C,D]
    w_sel = jnp.maximum(w_sel, 0.0)                                  # padding → 0
    x_sel = constrain(x_sel, "groups", "experts", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_sel, w_gate)) * jnp.einsum(
        "gecd,edf->gecf", x_sel, w_up
    )
    y_sel = jnp.einsum("gecf,efd->gecd", h, w_down)                  # [G,E,C,D]
    y_sel = y_sel * w_sel[..., None].astype(y_sel.dtype)

    def scatter_group(idxg, yg):
        flat_idx = idxg.reshape(E * C)
        flat_y = yg.reshape(E * C, D)
        return jnp.zeros((T, D), flat_y.dtype).at[flat_idx].add(flat_y)

    y = jax.vmap(scatter_group)(token_idx, y_sel)                    # [G, T, D]
    y = constrain(y, "groups", None, None)
    return y.astype(x.dtype), aux
