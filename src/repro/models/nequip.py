"""NequIP: O(3)-equivariant interatomic potential (arXiv:2101.03164).

Irreps: ``d_hidden`` channels each of (0e, 1o, 2e) — features are a dict
``{l: [N, mul, 2l+1]}``. One interaction layer:

1. Per edge: Bessel radial basis × polynomial cutoff envelope; real SH
   ``Y_l`` of the edge direction.
2. Tensor-product messages, uvu-style: for each admissible path
   ``(l1, l2 → l3)``, ``m3[e,c] = R_path(rbf_e)[c] · CG ⊗ (h^{l1}[src,c] ⊗
   Y^{l2}[e])`` — the radial MLP emits one weight per (path, channel).
3. ``jax.ops.segment_sum`` over edges → per-node aggregates (JAX sparse is
   BCOO-only; scatter-based message passing IS the substrate here),
   normalized by √avg_degree.
4. Self-interaction (per-l channel mix) + path mix + equivariant gate
   (scalars: SiLU; l>0: sigmoid-gated by learned scalar gates).

Readout: linear on scalars → per-atom energy → segment-sum per graph.
Forces (= −∂E/∂positions) via ``jax.grad`` for molecule-batch training.

Sharding: edges → ("data", "model") axes (the dominant per-edge TP work),
nodes → "data"; segment-sum over sharded edges lowers to partial sums +
all-reduce (structurally identical to DP gradient reduction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NequIPConfig
from repro.distributed.sharding import constrain
from repro.models import so3

LS = (0, 1, 2)


# ---------------------------------------------------------------------------
# Radial basis.
# ---------------------------------------------------------------------------


def bessel_basis(d: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """sin(nπ d / r_c) / d Bessel basis with smooth polynomial envelope."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d[..., None] / cutoff) / d[..., None]
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    # p=6 polynomial envelope (DimeNet): 1 − 28x⁶ + 48x⁷ − 21x⁸  (C² at r_c).
    env = 1.0 - 28.0 * x**6 + 48.0 * x**7 - 21.0 * x**8
    return basis * env[..., None]


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def init(cfg: NequIPConfig, key, d_feat: int = 0):
    paths = so3.allowed_paths(cfg.l_max)
    mul = cfg.d_hidden
    n_paths = len(paths)
    keys = iter(jax.random.split(key, 8 + 4 * cfg.n_layers))
    norm = lambda k, s, fan: jax.random.normal(k, s, jnp.float32) * fan**-0.5

    params = {
        "species_embed": norm(next(keys), (cfg.n_species, mul), 1.0) * 0.5,
        "readout_w": norm(next(keys), (mul, 1), mul),
    }
    if d_feat:
        params["feat_proj"] = norm(next(keys), (d_feat, mul), d_feat)

    h0, h1 = cfg.radial_mlp
    L = cfg.n_layers
    params["layers"] = {
        "radial_w0": norm(next(keys), (L, cfg.n_rbf, h0), cfg.n_rbf),
        "radial_w1": norm(next(keys), (L, h0, h1), h0),
        "radial_w2": norm(next(keys), (L, h1, n_paths * mul), h1),
        # Per-l: self-interaction, message mix (n_paths_l → 1), gate source.
        "w_self": {l: norm(next(keys), (L, mul, mul), mul) for l in LS},
        "w_msg": {
            l: norm(next(keys), (L, _n_paths_to(paths, l) * mul, mul),
                    _n_paths_to(paths, l) * mul)
            for l in LS
        },
        "w_gate": {l: norm(next(keys), (L, mul, mul), mul) for l in (1, 2)},
    }
    return params


def param_logical(cfg: NequIPConfig, d_feat: int = 0):
    logical = {
        "species_embed": (None, None),
        "readout_w": (None, None),
        "layers": {
            "radial_w0": ("layers", None, None),
            "radial_w1": ("layers", None, None),
            "radial_w2": ("layers", None, None),
            "w_self": {l: ("layers", None, None) for l in LS},
            "w_msg": {l: ("layers", None, None) for l in LS},
            "w_gate": {l: ("layers", None, None) for l in (1, 2)},
        },
    }
    if d_feat:
        logical["feat_proj"] = (None, None)
    return logical


def _n_paths_to(paths, l3: int) -> int:
    return sum(1 for (_, _, o) in paths if o == l3)


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------


def _interaction(cfg, layer, h, edge_src, edge_dst, rbf, Y, n_nodes):
    """One NequIP interaction layer. h: {l: [N, mul, 2l+1]}."""
    paths = so3.allowed_paths(cfg.l_max)
    mul = cfg.d_hidden
    dt = jnp.dtype(cfg.dtype)

    # Radial weights per (path, channel).
    r = jax.nn.silu(rbf @ layer["radial_w0"])
    r = jax.nn.silu(r @ layer["radial_w1"])
    r = (r @ layer["radial_w2"]).reshape(-1, len(paths), mul)      # [E, P, mul]
    r = r.astype(dt)

    msgs: dict[int, list[jax.Array]] = {l: [] for l in LS}
    for p_idx, (l1, l2, l3) in enumerate(paths):
        C = jnp.asarray(so3.clebsch_gordan(l1, l2, l3)).astype(dt)  # [d3,d1,d2]
        h_src = h[l1][edge_src]                                    # [E, mul, d1]
        # m[e, u, m3] = Σ_{m1 m2} C[m3, m1, m2] h_src[e, u, m1] Y[e, m2]
        m = jnp.einsum("abc,eub,ec->eua", C, h_src, Y[l2].astype(dt))
        msgs[l3].append(m * r[:, p_idx, :, None])                  # [E, mul, d3]

    out = {}
    inv_deg = dt.type(1.0 / np.sqrt(cfg.avg_degree))
    for l in LS:
        w_msg = layer["w_msg"][l].astype(dt)                       # [P_l*mul, mul]
        if cfg.premix_messages:
            # Σ_p (m_p @ w_msg[block_p]) per EDGE, then one small-payload
            # segment-sum — identical by linearity to mix-after-aggregate.
            mul_ = h[l].shape[1] if False else msgs[l][0].shape[1]
            pre = None
            for p_i, m in enumerate(msgs[l]):
                blk = w_msg[p_i * mul_:(p_i + 1) * mul_]           # [mul, mul]
                term = jnp.einsum("eud,um->emd", m, blk)
                pre = term if pre is None else pre + term
            agg = jax.ops.segment_sum(pre, edge_dst, num_segments=n_nodes)
            mixed = constrain(agg, "nodes", None, None) * inv_deg
        else:
            stacked = jnp.concatenate(msgs[l], axis=1)             # [E, P_l*mul, d]
            agg = jax.ops.segment_sum(stacked, edge_dst, num_segments=n_nodes)
            agg = constrain(agg, "nodes", None, None) * inv_deg
            mixed = jnp.einsum("nkd,km->nmd", agg, w_msg)
        out[l] = jnp.einsum("ncd,cm->nmd", h[l],
                            layer["w_self"][l].astype(dt)) + mixed

    # Equivariant gate: scalars through SiLU; l>0 scaled by learned gates.
    scalars = out[0]
    gated = {0: jax.nn.silu(scalars)}
    s = scalars[..., 0]                                            # [N, mul]
    for l in (1, 2):
        gate = jax.nn.sigmoid(s @ layer["w_gate"][l].astype(dt))   # [N, mul]
        gated[l] = out[l] * gate[..., None]
    return gated


def _embed_nodes(cfg, params, species, node_feat):
    mul = cfg.d_hidden
    dt = jnp.dtype(cfg.dtype)
    n = species.shape[0]
    scalars = params["species_embed"][species]                     # [N, mul]
    if node_feat is not None:
        scalars = scalars + node_feat @ params["feat_proj"]
    h = {
        0: scalars[..., None].astype(dt),
        1: jnp.zeros((n, mul, 3), dt),
        2: jnp.zeros((n, mul, 5), dt),
    }
    return h


def forward_energy(cfg: NequIPConfig, params, positions, species, edge_src,
                   edge_dst, graph_id=None, n_graphs: int = 1, node_feat=None):
    """Per-graph energies. positions [N,3]; edges index into nodes."""
    n_nodes = positions.shape[0]
    edge_src = constrain(edge_src, "edges")
    edge_dst = constrain(edge_dst, "edges")
    rel = positions[edge_src] - positions[edge_dst]                # [E, 3]
    # Smooth norm: grad of ‖·‖ at 0 is NaN, and degenerate (self-)edges must
    # not poison the force computation.
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    unit = rel / dist[..., None]
    rbf = constrain(bessel_basis(dist, cfg.n_rbf, cfg.cutoff), "edges", None)
    Y = {l: _sph_jax(unit, l) for l in LS}

    h = _embed_nodes(cfg, params, species, node_feat)

    def step(h, layer):
        h = _interaction(cfg, layer, h, edge_src, edge_dst, rbf, Y, n_nodes)
        return h, None

    h, _ = jax.lax.scan(step, h, params["layers"])
    atom_e = (jax.nn.silu(h[0][..., 0]) @ params["readout_w"])[..., 0]  # [N]
    if graph_id is None:
        return atom_e.sum()[None]
    return jax.ops.segment_sum(atom_e, graph_id, num_segments=n_graphs)


def _sph_jax(v: jax.Array, l: int) -> jax.Array:
    """jnp version of so3.real_sph_harm (same polynomials)."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return jnp.ones_like(x)[..., None]
    if l == 1:
        return jnp.stack([y, z, x], axis=-1) * np.sqrt(3.0)
    r2 = x * x + y * y + z * z
    return jnp.stack(
        [
            np.sqrt(15.0) * x * y,
            np.sqrt(15.0) * y * z,
            np.sqrt(5.0) / 2.0 * (3 * z * z - r2),
            np.sqrt(15.0) * x * z,
            np.sqrt(15.0) / 2.0 * (x * x - y * y),
        ],
        axis=-1,
    )


def loss_fn(cfg: NequIPConfig, params, batch, with_forces: bool = False):
    """Energy (+ optional force) matching loss."""
    def energy(pos):
        return forward_energy(
            cfg, params, pos, batch["species"], batch["edge_src"],
            batch["edge_dst"], batch.get("graph_id"),
            int(batch["energy"].shape[0]), batch.get("node_feat"),
        ).sum()

    e = forward_energy(
        cfg, params, batch["positions"], batch["species"], batch["edge_src"],
        batch["edge_dst"], batch.get("graph_id"), int(batch["energy"].shape[0]),
        batch.get("node_feat"),
    )
    loss = jnp.mean((e - batch["energy"]) ** 2)
    if with_forces and "forces" in batch:
        f = -jax.grad(energy)(batch["positions"])
        loss = loss + jnp.mean((f - batch["forces"]) ** 2)
    return loss
