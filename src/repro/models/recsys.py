"""RecSys architectures: DLRM, DeepFM, DIN, BERT4Rec.

The hot path is the sparse embedding lookup. JAX has no native EmbeddingBag
and no CSR sparse — :func:`embedding_bag` builds it from ``jnp.take`` +
``jax.ops.segment_sum`` (sum-combined multi-hot bags). Tables are
row-sharded ("rows" → "model"); GSPMD lowers the gather over a row-sharded
table to per-shard range gathers + all-reduce, which is exactly how
large-scale TBE sharding works.

``retrieval_cand`` (1 query × 10⁶ candidates) is served by per-family
``score_candidates`` functions that compute the user side once and batch
the candidate side as one dense matmul/interaction sweep — never a loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecSysConfig
from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# Embedding substrate.
# ---------------------------------------------------------------------------


ROW_PAD = 512  # tables padded to shard boundaries (16 | 32 model ways)


def pad_rows(v: int) -> int:
    return -(-v // ROW_PAD) * ROW_PAD


def embedding_bag(table: jax.Array, ids: jax.Array, combine: str = "sum"):
    """table [V, D]; ids [..., n_per_bag] → [..., D] (sum/mean over the bag)."""
    vecs = jnp.take(table, ids, axis=0)
    out = vecs.sum(axis=-2)
    if combine == "mean":
        out = out / ids.shape[-1]
    return out


def _mlp(x, weights, final_activation=None):
    *hidden, (w_last, b_last) = weights
    for w, b in hidden:
        x = jax.nn.relu(x @ w + b)
    x = x @ w_last + b_last
    if final_activation is not None:
        x = final_activation(x)
    return x


def _mlp_init(key, dims: tuple[int, ...], dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    out = []
    for i, k in enumerate(keys):
        fan = dims[i]
        out.append(
            (
                jax.random.normal(k, (dims[i], dims[i + 1]), dtype) * fan**-0.5,
                jnp.zeros((dims[i + 1],), dtype),
            )
        )
    return out


def _mlp_logical(dims: tuple[int, ...]):
    # Dense-MLP weights are KB-scale: replicate (sharding 40-wide layers over
    # 16 devices fails divisibility and saves nothing).
    return [((None, None), (None,)) for _ in range(len(dims) - 1)]


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091) — dot interaction.
# ---------------------------------------------------------------------------


def dlrm_init(cfg: RecSysConfig, key):
    keys = jax.random.split(key, 3 + len(cfg.vocab_sizes))
    tables = {
        f"t{i}": jax.random.normal(keys[i], (pad_rows(v), cfg.embed_dim), jnp.float32)
        * v**-0.25 * 0.1
        for i, v in enumerate(cfg.vocab_sizes)
    }
    n_vec = len(cfg.vocab_sizes) + 1
    n_pairs = n_vec * (n_vec - 1) // 2
    top_in = cfg.bot_mlp[-1] + n_pairs
    return {
        "tables": tables,
        "bot": _mlp_init(keys[-2], (cfg.n_dense, *cfg.bot_mlp)),
        "top": _mlp_init(keys[-1], (top_in, *cfg.top_mlp)),
    }


def dlrm_logical(cfg: RecSysConfig):
    return {
        "tables": {f"t{i}": ("rows", None) for i in range(len(cfg.vocab_sizes))},
        "bot": _mlp_logical((cfg.n_dense, *cfg.bot_mlp)),
        "top": _mlp_logical((cfg.bot_mlp[-1] + 1, *cfg.top_mlp)),
    }


def _dot_interaction(vecs: jax.Array) -> jax.Array:
    """vecs [B, n, D] → upper-triangle pairwise dots [B, n(n−1)/2]."""
    n = vecs.shape[1]
    z = jnp.einsum("bnd,bmd->bnm", vecs, vecs)
    iu, ju = np.triu_indices(n, k=1)
    return z[:, iu, ju]


def dlrm_forward(cfg: RecSysConfig, params, batch) -> jax.Array:
    dense = constrain(batch["dense"], "batch", None)              # [B, 13]
    sparse = constrain(batch["sparse"], "batch", None, None)      # [B, 26, hot]
    bot = _mlp(dense, params["bot"], jax.nn.relu)                 # [B, D]
    embs = [
        embedding_bag(params["tables"][f"t{i}"], sparse[:, i])
        for i in range(len(cfg.vocab_sizes))
    ]
    vecs = jnp.stack([bot, *embs], axis=1)                        # [B, 27, D]
    feats = jnp.concatenate([bot, _dot_interaction(vecs)], axis=-1)
    return _mlp(feats, params["top"])[..., 0]                     # logits [B]


def dlrm_score_candidates(cfg: RecSysConfig, params, batch) -> jax.Array:
    """1 user (dense + 25 fields) × C candidate items (last field)."""
    dense = batch["dense"]                                        # [1, 13]
    sparse = batch["sparse"]                                      # [1, 25, hot]
    cands = constrain(batch["cand_ids"], "cands")                 # [C]
    bot = _mlp(dense, params["bot"], jax.nn.relu)                 # [1, D]
    user_embs = [
        embedding_bag(params["tables"][f"t{i}"], sparse[:, i])
        for i in range(len(cfg.vocab_sizes) - 1)
    ]
    user_vecs = jnp.concatenate([bot, *user_embs], axis=0)        # [26, D]
    cand_vec = jnp.take(params["tables"][f"t{len(cfg.vocab_sizes) - 1}"],
                        cands, axis=0)                            # [C, D]
    # User-user dots are candidate-independent; compute once.
    n_u = user_vecs.shape[0]
    uu = jnp.einsum("nd,md->nm", user_vecs, user_vecs)
    iu, ju = np.triu_indices(n_u, k=1)
    uu_flat = uu[iu, ju]                                          # [n_u(n_u-1)/2]
    uc = jnp.einsum("cd,nd->cn", cand_vec, user_vecs)             # [C, n_u]
    C = cands.shape[0]
    feats = jnp.concatenate(
        [
            jnp.broadcast_to(bot[0], (C, bot.shape[1])),
            jnp.broadcast_to(uu_flat, (C, uu_flat.shape[0])),
            uc,
        ],
        axis=-1,
    )
    return _mlp(feats, params["top"])[..., 0]                     # [C]


# ---------------------------------------------------------------------------
# DeepFM (arXiv:1703.04247) — FM + deep on one concatenated table.
# ---------------------------------------------------------------------------


def deepfm_init(cfg: RecSysConfig, key):
    V = pad_rows(sum(cfg.vocab_sizes))
    k = jax.random.split(key, 4)
    deep_in = cfg.n_sparse * cfg.embed_dim
    return {
        "table": jax.random.normal(k[0], (V, cfg.embed_dim), jnp.float32) * 0.01,
        "first_order": jax.random.normal(k[1], (V, 1), jnp.float32) * 0.01,
        "deep": _mlp_init(k[2], (deep_in, *cfg.mlp, 1)),
        "bias": jnp.zeros(()),
    }


def deepfm_logical(cfg: RecSysConfig):
    return {
        "table": ("rows", None),
        "first_order": ("rows", None),
        "deep": _mlp_logical((cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1)),
        "bias": (),
    }


def deepfm_forward(cfg: RecSysConfig, params, batch) -> jax.Array:
    ids = constrain(batch["ids"], "batch", None)                  # [B, 39] global ids
    v = jnp.take(params["table"], ids, axis=0)                    # [B, 39, D]
    w = jnp.take(params["first_order"], ids, axis=0)[..., 0]      # [B, 39]
    fm1 = w.sum(-1)
    s = v.sum(axis=1)
    fm2 = 0.5 * ((s * s).sum(-1) - (v * v).sum(axis=(1, 2)))
    deep = _mlp(v.reshape(v.shape[0], -1), params["deep"])[..., 0]
    return fm1 + fm2 + deep + params["bias"]


def deepfm_score_candidates(cfg: RecSysConfig, params, batch) -> jax.Array:
    """User fields fixed, candidate = last field swept over C ids."""
    ids = batch["ids"]                                            # [1, 38]
    cands = constrain(batch["cand_ids"], "cands")                 # [C]
    vu = jnp.take(params["table"], ids[0], axis=0)                # [38, D]
    wu = jnp.take(params["first_order"], ids[0], axis=0).sum()
    vc = jnp.take(params["table"], cands, axis=0)                 # [C, D]
    wc = jnp.take(params["first_order"], cands, axis=0)[..., 0]   # [C]
    su = vu.sum(0)
    s = su[None] + vc
    fm2 = 0.5 * ((s * s).sum(-1) - ((vu * vu).sum() + (vc * vc).sum(-1)))
    deep_in = jnp.concatenate(
        [jnp.broadcast_to(vu.reshape(-1), (cands.shape[0], vu.size)), vc], axis=-1
    )
    deep = _mlp(deep_in, params["deep"])[..., 0]
    return wu + wc + fm2 + deep + params["bias"]


# ---------------------------------------------------------------------------
# DIN (arXiv:1706.06978) — target attention over user history.
# ---------------------------------------------------------------------------


def din_init(cfg: RecSysConfig, key):
    k = jax.random.split(key, 3)
    D = cfg.embed_dim
    return {
        "item_table": jax.random.normal(k[0], (pad_rows(cfg.item_vocab), D), jnp.float32) * 0.01,
        "attn": _mlp_init(k[1], (4 * D, *cfg.attn_mlp, 1)),
        "out": _mlp_init(k[2], (3 * D, *cfg.mlp, 1)),
    }


def din_logical(cfg: RecSysConfig):
    return {
        "item_table": ("rows", None),
        "attn": _mlp_logical((4 * cfg.embed_dim, *cfg.attn_mlp, 1)),
        "out": _mlp_logical((3 * cfg.embed_dim, *cfg.mlp, 1)),
    }


def _din_user_vec(params, hist_vec, target_vec, hist_mask):
    """hist [B, S, D], target [B, D] → attention-pooled user vec [B, D]."""
    t = jnp.broadcast_to(target_vec[:, None], hist_vec.shape)
    attn_in = jnp.concatenate(
        [t, hist_vec, t - hist_vec, t * hist_vec], axis=-1
    )
    scores = _mlp(attn_in, params["attn"])[..., 0]                 # [B, S]
    scores = jnp.where(hist_mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bs,bsd->bd", w, hist_vec)


def din_forward(cfg: RecSysConfig, params, batch) -> jax.Array:
    hist = constrain(batch["hist_ids"], "batch", None)            # [B, S]
    target = constrain(batch["target_id"], "batch")               # [B]
    hist_mask = hist >= 0
    hist_vec = jnp.take(params["item_table"], jnp.maximum(hist, 0), axis=0)
    target_vec = jnp.take(params["item_table"], target, axis=0)
    user = _din_user_vec(params, hist_vec, target_vec, hist_mask)
    feats = jnp.concatenate([user, target_vec, user * target_vec], axis=-1)
    return _mlp(feats, params["out"])[..., 0]


def din_score_candidates(cfg: RecSysConfig, params, batch) -> jax.Array:
    """One user history × C candidates — candidate-dependent attention."""
    hist = batch["hist_ids"][0]                                   # [S]
    cands = constrain(batch["cand_ids"], "cands")                 # [C]
    hist_mask = (hist >= 0)[None]
    hist_vec = jnp.take(params["item_table"], jnp.maximum(hist, 0), axis=0)
    cand_vec = jnp.take(params["item_table"], cands, axis=0)      # [C, D]
    hv = jnp.broadcast_to(hist_vec[None], (cands.shape[0], *hist_vec.shape))
    user = _din_user_vec(params, hv, cand_vec, hist_mask)
    feats = jnp.concatenate([user, cand_vec, user * cand_vec], axis=-1)
    return _mlp(feats, params["out"])[..., 0]


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690) — bidirectional transformer, tied softmax.
# ---------------------------------------------------------------------------


def bert4rec_init(cfg: RecSysConfig, key):
    D, L = cfg.embed_dim, cfg.n_blocks
    k = jax.random.split(key, 8)
    norm = lambda kk, s, fan: jax.random.normal(kk, s, jnp.float32) * fan**-0.5
    d_ff = 4 * D
    return {
        "item_embed": norm(k[0], (pad_rows(cfg.item_vocab + 1), D), 1.0) * 0.02,  # +1 = [MASK]
        "pos_embed": norm(k[1], (cfg.seq_len, D), 1.0) * 0.02,
        "blocks": {
            "ln1": jnp.ones((L, D)),
            "ln2": jnp.ones((L, D)),
            "wqkv": norm(k[2], (L, D, 3 * D), D),
            "wo": norm(k[3], (L, D, D), D),
            "w1": norm(k[4], (L, D, d_ff), D),
            "b1": jnp.zeros((L, d_ff)),
            "w2": norm(k[5], (L, d_ff, D), d_ff),
            "b2": jnp.zeros((L, D)),
        },
        "final_ln": jnp.ones((D,)),
    }


def bert4rec_logical(cfg: RecSysConfig):
    return {
        "item_embed": ("rows", None),
        "pos_embed": (None, None),
        "blocks": {
            "ln1": ("layers", None),
            "ln2": ("layers", None),
            "wqkv": ("layers", None, "qkv"),
            "wo": ("layers", "qkv", None),
            "w1": ("layers", None, "ff"),
            "b1": ("layers", "ff"),
            "w2": ("layers", "ff", None),
            "b2": ("layers", None),
        },
        "final_ln": (None,),
    }


def bert4rec_encode(cfg: RecSysConfig, params, ids: jax.Array) -> jax.Array:
    """ids [B, S] → hidden [B, S, D]; bidirectional (no causal mask)."""
    from repro.models.layers import rms_norm  # shared RMSNorm

    B, S = ids.shape
    D, H = cfg.embed_dim, cfg.n_heads
    Dh = D // H
    x = jnp.take(params["item_embed"], ids, axis=0) + params["pos_embed"][None, :S]
    x = constrain(x, "batch", None, None)

    def block(x, blk):
        h = rms_norm(x, blk["ln1"])
        qkv = (h @ blk["wqkv"]).reshape(B, S, 3, H, Dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(Dh)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(B, S, D)
        x = x + o @ blk["wo"]
        h = rms_norm(x, blk["ln2"])
        x = x + jax.nn.gelu(h @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    return rms_norm(x, params["final_ln"])


def bert4rec_masked_loss(cfg: RecSysConfig, params, batch) -> jax.Array:
    """Cloze training: predict items at masked positions (tied softmax)."""
    h = bert4rec_encode(cfg, params, batch["ids"])                # [B, S, D]
    logits = jnp.einsum("bsd,vd->bsv", h, params["item_embed"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    nll = (logz - gold) * batch["mask_pos"]
    return nll.sum() / jnp.maximum(batch["mask_pos"].sum(), 1.0)


def bert4rec_forward(cfg: RecSysConfig, params, batch) -> jax.Array:
    """Serve: next-item score for a provided target at the last position."""
    h = bert4rec_encode(cfg, params, batch["ids"])[:, -1]         # [B, D]
    tgt = jnp.take(params["item_embed"], batch["target_id"], axis=0)
    return (h * tgt).sum(-1)


def bert4rec_score_candidates(cfg: RecSysConfig, params, batch) -> jax.Array:
    h = bert4rec_encode(cfg, params, batch["ids"])[:, -1]         # [1, D]
    cands = constrain(batch["cand_ids"], "cands")
    cand_vec = jnp.take(params["item_embed"], cands, axis=0)      # [C, D]
    return (cand_vec @ h[0])


# ---------------------------------------------------------------------------
# Family dispatch.
# ---------------------------------------------------------------------------

INIT = {"dlrm": dlrm_init, "deepfm": deepfm_init, "din": din_init,
        "bert4rec": bert4rec_init}
LOGICAL = {"dlrm": dlrm_logical, "deepfm": deepfm_logical, "din": din_logical,
           "bert4rec": bert4rec_logical}
FORWARD = {"dlrm": dlrm_forward, "deepfm": deepfm_forward, "din": din_forward,
           "bert4rec": bert4rec_forward}
SCORE_CANDIDATES = {
    "dlrm": dlrm_score_candidates,
    "deepfm": deepfm_score_candidates,
    "din": din_score_candidates,
    "bert4rec": bert4rec_score_candidates,
}


def loss_fn(cfg: RecSysConfig, params, batch) -> jax.Array:
    if cfg.family == "bert4rec":
        return bert4rec_masked_loss(cfg, params, batch)
    logits = FORWARD[cfg.family](cfg, params, batch)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
