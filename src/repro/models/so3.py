"""Real spherical harmonics (ℓ ≤ 2) and Clebsch–Gordan coupling tensors.

NequIP's core op is the equivariant tensor product
``(h^{l1} ⊗ Y^{l2}) → l3`` contracted with Clebsch–Gordan coefficients in
the **real** SH basis. Rather than transcribing real-basis CG tables (an
error-prone change of basis from the complex convention), we *solve* for
them numerically once at import:

1. Wigner-D matrices in the real basis are recovered for any rotation R by
   evaluating ``Y_l`` on a set of sample directions and solving
   ``Y_l(R v) = D_l(R) · Y_l(v)`` in the least-squares sense (exact — Y_l
   spans an irreducible subspace).
2. The coupling tensor ``C[m3, m1, m2]`` is the null space of the
   equivariance constraint ``D3(R) C − C (D1(R) ⊗ D2(R))`` stacked over a
   handful of random rotations (the invariant subspace is 1-dimensional for
   each admissible (l1, l2, l3)).

The equivariance property is verified directly in tests (rotate inputs ⇒
outputs rotate with the appropriate Wigner-D).
"""

from __future__ import annotations

import functools

import numpy as np

L_DIMS = {0: 1, 1: 3, 2: 5}


def real_sph_harm(v: np.ndarray, l: int) -> np.ndarray:
    """Real SH of unit vectors ``v: [..., 3]`` → ``[..., 2l+1]``.

    Component-normalized (e3nn ``normalize=True, normalization='component'``
    convention up to constant factors — constants only rescale channels and
    are absorbed by the learned weights; what matters is the irreducible
    transformation law, which these polynomials satisfy exactly).
    """
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return np.ones_like(x)[..., None]
    if l == 1:
        return np.stack([y, z, x], axis=-1) * np.sqrt(3.0)
    if l == 2:
        r2 = x * x + y * y + z * z
        out = np.stack(
            [
                np.sqrt(15.0) * x * y,
                np.sqrt(15.0) * y * z,
                np.sqrt(5.0) / 2.0 * (3 * z * z - r2),
                np.sqrt(15.0) * x * z,
                np.sqrt(15.0) / 2.0 * (x * x - y * y),
            ],
            axis=-1,
        )
        return out
    raise NotImplementedError(f"l={l}")


def wigner_d(R: np.ndarray, l: int) -> np.ndarray:
    """Real-basis Wigner-D for rotation matrix R (3×3) → [(2l+1), (2l+1)]."""
    if l == 0:
        return np.ones((1, 1))
    rng = np.random.default_rng(1234)
    v = rng.normal(size=(64, 3))
    v /= np.linalg.norm(v, axis=-1, keepdims=True)
    Yv = real_sph_harm(v, l)              # [64, d]
    YRv = real_sph_harm(v @ R.T, l)       # [64, d]
    # Y(Rv) = D Y(v)  ⇒  D = argmin ‖Yv Dᵀ − YRv‖.
    D, *_ = np.linalg.lstsq(Yv, YRv, rcond=None)
    return D.T


def _random_rotation(rng) -> np.ndarray:
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Coupling tensor C: [d3, d1, d2] with D3 C = C (D1 ⊗ D2), ‖C‖=1.

    Raises if (l1, l2, l3) violates the triangle inequality (empty null
    space).
    """
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        raise ValueError(f"triangle violation ({l1},{l2},{l3})")
    d1, d2, d3 = L_DIMS[l1], L_DIMS[l2], L_DIMS[l3]
    rng = np.random.default_rng(0)
    rows = []
    for _ in range(6):
        R = _random_rotation(rng)
        D1, D2, D3 = wigner_d(R, l1), wigner_d(R, l2), wigner_d(R, l3)
        # Constraint on vec(C): (I_{d1 d2} ⊗ D3 − (D1 ⊗ D2)ᵀ ⊗ I_{d3}) vec = 0
        # with C[m3, m1 m2]: D3 C − C (D1 ⊗ D2) = 0.
        K = np.kron(np.eye(d1 * d2), D3) - np.kron(np.kron(D1, D2).T, np.eye(d3))
        rows.append(K)
    K = np.concatenate(rows, axis=0)
    _, s, vh = np.linalg.svd(K)
    # vec ordering: C[m3, m1, m2] flattened with (m1 m2) major, m3 minor.
    c = vh[-1].reshape(d1 * d2, d3).T.reshape(d3, d1, d2)
    resid = s[-1]
    if resid > 1e-8:
        raise RuntimeError(f"no invariant coupling for ({l1},{l2},{l3}): σ={resid}")
    # Deterministic sign: make the largest-|.| entry positive.
    idx = np.unravel_index(np.argmax(np.abs(c)), c.shape)
    c = c * np.sign(c[idx])
    return (c / np.linalg.norm(c)).astype(np.float32)


# Parity-respecting paths for the NequIP irreps set {0e, 1o, 2e} with
# Y-parities (+,−,+): output parity = p(h_l1) · p(Y_l2) must match.
def allowed_paths(l_max: int = 2) -> list[tuple[int, int, int]]:
    parity_h = {0: +1, 1: -1, 2: +1}
    parity_y = {0: +1, 1: -1, 2: +1}
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                if parity_h[l1] * parity_y[l2] == parity_h[l3]:
                    paths.append((l1, l2, l3))
    return paths
