"""Synthesize valid random inputs for any Cell (smoke tests / examples).

Integer inputs are drawn within the valid range implied by the config
(vocab sizes, node counts, …); ``ShapeDtypeStruct`` specs come straight
from ``cell.input_specs()`` so smoke tests exercise exactly the dry-run
input structure.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import (
    ForestConfig,
    NequIPConfig,
    RecSysConfig,
    TransformerConfig,
)
from repro.models.api import Cell


def synthesize_inputs(cell: Cell, seed: int = 0):
    rng = np.random.default_rng(seed)
    cfg, shape = cell.cfg, cell.shape
    specs = cell.input_specs()
    out = {}
    for name, spec in specs.items():
        out[name] = _one(name, spec, cfg, shape, rng)
    return out


def _ints(rng, shape, hi):
    return rng.integers(0, max(int(hi), 1), size=shape).astype(np.int32)


def _one(name, spec, cfg, shape, rng):
    import jax

    if isinstance(spec, dict) or not hasattr(spec, "shape"):
        return jax.tree.map(
            lambda s: _one(name, s, cfg, shape, rng), spec,
            is_leaf=lambda x: hasattr(x, "shape"),
        )
    shp, dt = spec.shape, spec.dtype

    if np.issubdtype(dt, np.floating):
        if name == "mask_pos":
            return (rng.random(shp) < 0.15).astype(np.float32)
        return rng.normal(size=shp).astype(dt)
    if dt == np.bool_:
        m = rng.random(shp) < 0.8
        if m.ndim == 2:
            m[:, 0] = True
        return m

    # Integer inputs: range depends on semantics.
    if isinstance(cfg, TransformerConfig):
        if name == "pos":
            return np.int32(min(8, shape.seq_len - 1))
        return _ints(rng, shp, cfg.vocab_size)
    if isinstance(cfg, NequIPConfig):
        if name == "species":
            return _ints(rng, shp, cfg.n_species)
        if name in ("edge_src", "edge_dst"):
            return _ints(rng, shp, shape.n_nodes)
        if name == "graph_id":
            n_graphs = shape.graph_batch or 1
            return np.sort(_ints(rng, shp, n_graphs))
        return _ints(rng, shp, 4)
    if isinstance(cfg, RecSysConfig):
        if cfg.family == "dlrm" and name == "sparse":
            ids = np.stack(
                [_ints(rng, shp[:1] + shp[2:], v) for v in cfg.vocab_sizes[: shp[1]]],
                axis=1,
            )
            return ids
        if cfg.family == "deepfm" and name == "ids":
            offs = np.cumsum([0, *cfg.vocab_sizes[:-1]])
            cols = shp[1]
            ids = np.stack(
                [offs[i] + _ints(rng, shp[:1], cfg.vocab_sizes[i]) for i in range(cols)],
                axis=1,
            )
            return ids.astype(np.int32)
        if name == "cand_ids":
            hi = {
                "dlrm": cfg.vocab_sizes[-1] if cfg.vocab_sizes else 1,
                "deepfm": sum(cfg.vocab_sizes),
                "din": cfg.item_vocab,
                "bert4rec": cfg.item_vocab,
            }[cfg.family]
            return _ints(rng, shp, hi)
        if name in ("hist_ids", "target_id", "ids", "labels"):
            return _ints(rng, shp, cfg.item_vocab or sum(cfg.vocab_sizes))
    if isinstance(cfg, ForestConfig):
        return _ints(rng, shp, 2)
    return _ints(rng, shp, 2)
