"""Decoder LM: GQA attention, optional qk-norm / QKV bias / MoE FFN.

Production structure (MaxText-style):
- **scan over layers** with stacked parameters ``[L, ...]`` — keeps HLO
  size O(1) in depth (mandatory for 48-layer × 512-device dry-run compiles)
  and enables layer-axis FSDP (stacked params sharded L→"data").
- **remat** per layer (``nothing_saveable``) so train-time activation
  memory is one residual per layer boundary.
- MoE archs hold two stacks: ``n_dense_layers`` leading dense layers
  (DeepSeek-MoE places a dense FFN first) and the MoE stack.
- Cross-entropy is computed in sequence chunks so the ``[B, S, V]`` logits
  tensor never materializes (V up to 202k).

All functions are pure; params/caches are plain pytrees of arrays.
``param_logical`` mirrors ``init`` 1:1 with logical axis names consumed by
:mod:`repro.distributed.sharding`.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.distributed.sharding import constrain
from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    decode_attention,
    glu_mlp,
    rms_norm,
)
from repro.models.moe import moe_ffn

Params = Any


# ---------------------------------------------------------------------------
# Init + logical axes.
# ---------------------------------------------------------------------------


def _dtype(cfg: TransformerConfig):
    return jnp.dtype(cfg.dtype)


def _attn_block(cfg: TransformerConfig, n_layers: int, key, dt):
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k = jax.random.split(key, 4)
    init = lambda kk, shape, fan: (
        jax.random.normal(kk, shape, dt) * float(fan) ** -0.5
    )
    block = {
        "wq": init(k[0], (n_layers, D, H * Dh), D),
        "wk": init(k[1], (n_layers, D, Hkv * Dh), D),
        "wv": init(k[2], (n_layers, D, Hkv * Dh), D),
        "wo": init(k[3], (n_layers, H * Dh, D), H * Dh),
    }
    if cfg.qkv_bias:
        block["bq"] = jnp.zeros((n_layers, H * Dh), dt)
        block["bk"] = jnp.zeros((n_layers, Hkv * Dh), dt)
        block["bv"] = jnp.zeros((n_layers, Hkv * Dh), dt)
    if cfg.qk_norm:
        block["q_norm"] = jnp.ones((n_layers, Dh), dt)
        block["k_norm"] = jnp.ones((n_layers, Dh), dt)
    return block


def _attn_logical(cfg: TransformerConfig):
    block = {
        "wq": ("layers", "embed", "qkv"),
        "wk": ("layers", "embed", "qkv"),
        "wv": ("layers", "embed", "qkv"),
        "wo": ("layers", "qkv", "embed"),
    }
    if cfg.qkv_bias:
        block.update({"bq": ("layers", "qkv"), "bk": ("layers", "qkv"),
                      "bv": ("layers", "qkv")})
    if cfg.qk_norm:
        block.update({"q_norm": ("layers", None), "k_norm": ("layers", None)})
    return block


def _dense_mlp_block(n_layers: int, D: int, F: int, key, dt):
    k = jax.random.split(key, 3)
    init = lambda kk, shape, fan: jax.random.normal(kk, shape, dt) * float(fan) ** -0.5
    return {
        "w_gate": init(k[0], (n_layers, D, F), D),
        "w_up": init(k[1], (n_layers, D, F), D),
        "w_down": init(k[2], (n_layers, F, D), F),
    }


_DENSE_MLP_LOGICAL = {
    "w_gate": ("layers", "embed", "ff"),
    "w_up": ("layers", "embed", "ff"),
    "w_down": ("layers", "ff", "embed"),
}


def _layer_stack(cfg: TransformerConfig, n_layers: int, moe: bool, key, dt):
    D = cfg.d_model
    keys = jax.random.split(key, 4)
    stack = {
        "ln1": jnp.ones((n_layers, D), dt),
        "ln2": jnp.ones((n_layers, D), dt),
        "attn": _attn_block(cfg, n_layers, keys[0], dt),
    }
    if not moe:
        F = cfg.dense_d_ff or cfg.d_ff
        stack["mlp"] = _dense_mlp_block(n_layers, D, F, keys[1], dt)
    else:
        E, Fe = cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
        k = jax.random.split(keys[1], 4)
        init = lambda kk, shape, fan: (
            jax.random.normal(kk, shape, dt) * float(fan) ** -0.5
        )
        stack["moe"] = {
            "router": init(k[0], (n_layers, D, E), D).astype(jnp.float32),
            "w_gate": init(k[1], (n_layers, E, D, Fe), D),
            "w_up": init(k[2], (n_layers, E, D, Fe), D),
            "w_down": init(k[3], (n_layers, E, Fe, D), Fe),
        }
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * Fe
            stack["shared"] = _dense_mlp_block(n_layers, D, Fs, keys[2], dt)
    return stack


def _stack_logical(cfg: TransformerConfig, moe: bool):
    stack = {
        "ln1": ("layers", None),
        "ln2": ("layers", None),
        "attn": _attn_logical(cfg),
    }
    if not moe:
        stack["mlp"] = dict(_DENSE_MLP_LOGICAL)
    else:
        stack["moe"] = {
            "router": ("layers", "embed", None),
            "w_gate": ("layers", "experts", "embed", "expert_ff"),
            "w_up": ("layers", "experts", "embed", "expert_ff"),
            "w_down": ("layers", "experts", "expert_ff", "embed"),
        }
        if cfg.n_shared_experts:
            stack["shared"] = dict(_DENSE_MLP_LOGICAL)
    return stack


def init(cfg: TransformerConfig, key) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4)
    D, V = cfg.d_model, cfg.vocab_size
    params = {
        "embed": jax.random.normal(keys[0], (V, D), dt) * 0.02,
        "final_norm": jnp.ones((D,), dt),
        "lm_head": jax.random.normal(keys[1], (D, V), dt) * float(D) ** -0.5,
    }
    if cfg.is_moe:
        if cfg.n_dense_layers:
            params["dense_stack"] = _layer_stack(
                cfg, cfg.n_dense_layers, moe=False, key=keys[2], dt=dt
            )
        params["moe_stack"] = _layer_stack(
            cfg, cfg.n_moe_layers, moe=True, key=keys[3], dt=dt
        )
    else:
        params["dense_stack"] = _layer_stack(
            cfg, cfg.n_layers, moe=False, key=keys[2], dt=dt
        )
    return params


def param_logical(cfg: TransformerConfig):
    logical = {
        "embed": ("vocab", "embed"),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
    }
    if cfg.is_moe:
        if cfg.n_dense_layers:
            logical["dense_stack"] = _stack_logical(cfg, moe=False)
        logical["moe_stack"] = _stack_logical(cfg, moe=True)
    else:
        logical["dense_stack"] = _stack_logical(cfg, moe=False)
    return logical


def abstract_params(cfg: TransformerConfig) -> Params:
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Mode:
    kind: str                   # "train" | "prefill" | "decode"
    pos: jax.Array | None = None  # decode position


def _attention(cfg: TransformerConfig, layer, x, positions, mode: _Mode, cache):
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    a = layer["attn"]
    q = x @ a["wq"]
    k = x @ a["wk"]
    v = x @ a["wv"]
    if cfg.qkv_bias:
        q, k, v = q + a["bq"], k + a["bk"], v + a["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, a["q_norm"])
        k = rms_norm(k, a["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode.kind == "decode":
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, mode.pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, mode.pos, 0, 0)
        )
        out = decode_attention(q, k_cache, v_cache, mode.pos + 1)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = blockwise_attention(
            q, k, v,
            causal=cfg.causal,
            q_block=min(cfg.attn_q_block, S),
            kv_block=min(cfg.attn_kv_block, S),
            causal_skip=cfg.causal_skip,
        )
        if mode.kind == "prefill":
            new_cache = {"k": constrain(k, "batch", "kv_seq", None, None),
                         "v": constrain(v, "batch", "kv_seq", None, None)}
    return out.reshape(B, S, H * Dh) @ a["wo"], new_cache


def _layer_fn(cfg: TransformerConfig, moe: bool):
    seq_axis = "seq_sp" if cfg.seq_parallel else None

    def body(x, layer, positions, mode: _Mode, cache):
        h, new_cache = _attention(
            cfg, layer, rms_norm(x, layer["ln1"]), positions, mode, cache
        )
        x = x + h
        x = constrain(x, "batch", seq_axis, None)
        h = rms_norm(x, layer["ln2"])
        aux = jnp.float32(0.0)
        if not moe:
            h = glu_mlp(h, layer["mlp"]["w_gate"], layer["mlp"]["w_up"],
                        layer["mlp"]["w_down"])
        else:
            B, S, D = h.shape
            if mode.kind == "decode":
                groups = h.reshape(1, B * S, D)       # one dispatch group
            else:
                groups = h                            # one group per sequence
            m = layer["moe"]
            y, aux = moe_ffn(
                groups, m["router"], m["w_gate"], m["w_up"], m["w_down"],
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            )
            y = y.reshape(B, S, D)
            if cfg.n_shared_experts:
                y = y + glu_mlp(h, layer["shared"]["w_gate"],
                                layer["shared"]["w_up"], layer["shared"]["w_down"])
            h = y
        x = x + h
        return constrain(x, "batch", seq_axis, None), new_cache, aux

    return body


def _run_stack(cfg: TransformerConfig, stack, x, positions, mode: _Mode,
               cache, moe: bool):
    """scan over stacked layer params; optionally remat each layer."""
    body = _layer_fn(cfg, moe)

    def step(carry, layer_and_cache):
        x = carry
        layer, layer_cache = layer_and_cache
        x, new_cache, aux = body(x, layer, positions, mode, layer_cache)
        return x, (new_cache, aux)

    if cfg.remat and mode.kind == "train":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        step = jax.checkpoint(step, policy=policy)

    x, (new_cache, aux) = jax.lax.scan(step, x, (stack, cache))
    return x, new_cache, aux.sum()


def _stacks(cfg: TransformerConfig, params):
    out = []
    if "dense_stack" in params:
        n = cfg.n_dense_layers if cfg.is_moe else cfg.n_layers
        out.append(("dense_stack", n, False))
    if cfg.is_moe:
        out.append(("moe_stack", cfg.n_moe_layers, True))
    return out


def _embed_lookup(cfg: TransformerConfig, embed, tokens):
    """Token embedding. ``embed_onehot``: express the lookup as a one-hot
    matmul — on a vocab-sharded table GSPMD partitions the contraction
    cleanly (local matmul + all-reduce) instead of the gather's
    involuntary full rematerialization (replicate-then-slice)."""
    if not cfg.embed_onehot:
        return embed[tokens]
    V = embed.shape[0]
    flat = tokens.reshape(-1)
    onehot = jax.nn.one_hot(flat, V, dtype=embed.dtype)
    out = onehot @ embed
    return out.reshape(*tokens.shape, embed.shape[1])


def _forward(cfg: TransformerConfig, params, tokens, positions, mode: _Mode,
             caches=None):
    x = _embed_lookup(cfg, params["embed"], tokens).astype(_dtype(cfg))
    x = constrain(x, "batch", None, None)
    new_caches = {}
    aux_total = jnp.float32(0.0)
    for name, n_layers, moe in _stacks(cfg, params):
        cache = None if caches is None else caches[name]
        if cache is None:
            cache = _null_cache(cfg, n_layers, tokens.shape[0])
        x, new_cache, aux = _run_stack(
            cfg, params[name], x, positions, mode, cache, moe
        )
        new_caches[name] = new_cache
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"])
    return x, new_caches, aux_total


def _null_cache(cfg, n_layers, batch):
    """Zero-length placeholder so scan xs have a consistent structure."""
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    z = jnp.zeros((n_layers, batch, 0, Hkv, Dh), _dtype(cfg))
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# Public steps.
# ---------------------------------------------------------------------------


def chunked_cross_entropy(h, lm_head, labels, chunk: int = 512):
    """Mean next-token CE without materializing [B, S, V]."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    hc = h.reshape(B, S // chunk, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, S // chunk, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def step(tot, xs):
        hb, lb = xs
        logits = (hb @ lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return tot + (logz - gold).sum(), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (hc, lc))
    return total / (B * S)


def loss_fn(cfg: TransformerConfig, params, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    positions = jnp.arange(tokens.shape[1])
    h, _, aux = _forward(cfg, params, tokens, positions, _Mode("train"))
    ce = chunked_cross_entropy(h, params["lm_head"], labels)
    return ce + 0.01 * aux


def prefill(cfg: TransformerConfig, params, tokens, cache_len: int):
    """Full-sequence prefill; returns (last-token logits, KV caches)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    h, caches, _ = _forward(cfg, params, tokens, positions, _Mode("prefill"))
    logits = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)
    caches = _pad_caches(cfg, caches, cache_len)
    return logits, caches


def _pad_caches(cfg, caches, cache_len: int):
    def pad(x):
        L, B, S, Hkv, Dh = x.shape
        if S >= cache_len:
            return x[:, :, :cache_len]
        return jnp.pad(x, ((0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0)))

    return jax.tree.map(pad, caches)


def make_decode_caches(cfg: TransformerConfig, batch: int, cache_len: int):
    def zeros(n_layers):
        z = jnp.zeros((n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head),
                      _dtype(cfg))
        return {"k": z, "v": z}

    out = {}
    if not cfg.is_moe:
        out["dense_stack"] = zeros(cfg.n_layers)
    else:
        if cfg.n_dense_layers:
            out["dense_stack"] = zeros(cfg.n_dense_layers)
        out["moe_stack"] = zeros(cfg.n_moe_layers)
    return out


def decode_step(cfg: TransformerConfig, params, token, caches, pos):
    """One token for every sequence. token: [B, 1]; pos: [] int32."""
    positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    # scan-stack caches: decode mode updates at (batch, pos) inside each layer.
    h, new_caches, _ = _forward(cfg, params, token, positions,
                                _Mode("decode", pos=pos), caches)
    logits = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)
    return logits, new_caches
