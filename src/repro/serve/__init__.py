from repro.serve.batching import (
    BatcherHooks,
    BatcherStats,
    BucketPolicy,
    ContinuousBatcher,
)
from repro.serve.clock import Clock, MonotonicClock
from repro.serve.degradation import (
    DegradationController,
    DegradationPolicy,
    ExitRung,
)
from repro.serve.errors import (
    BatcherStopped,
    DeadlineExceeded,
    Overloaded,
    ServeError,
    WorkerCrashed,
    WorkerFailed,
)
from repro.serve.lm_serve import generate
from repro.serve.placement import ServePlacement
from repro.serve.ranking_service import (
    RankingService,
    ServiceConfig,
    ServiceStats,
)
from repro.serve.supervisor import SupervisorHealth, WorkerSupervisor
from repro.serve.tier import ServingTier, TierConfig
from repro.serve.warmup import enable_persistent_cache, warmup_service

__all__ = [
    "BatcherHooks",
    "BatcherStats",
    "BatcherStopped",
    "BucketPolicy",
    "Clock",
    "ContinuousBatcher",
    "DeadlineExceeded",
    "DegradationController",
    "DegradationPolicy",
    "ExitRung",
    "MonotonicClock",
    "Overloaded",
    "RankingService",
    "ServeError",
    "ServePlacement",
    "ServiceConfig",
    "ServiceStats",
    "ServingTier",
    "SupervisorHealth",
    "TierConfig",
    "WorkerCrashed",
    "WorkerFailed",
    "WorkerSupervisor",
    "enable_persistent_cache",
    "generate",
    "warmup_service",
]
