from repro.serve.ranking_service import RankingService, ServiceStats
from repro.serve.lm_serve import generate

__all__ = ["RankingService", "ServiceStats", "generate"]
