from repro.serve.batching import BucketPolicy, ContinuousBatcher
from repro.serve.lm_serve import generate
from repro.serve.placement import ServePlacement
from repro.serve.ranking_service import (
    RankingService,
    ServiceConfig,
    ServiceStats,
)
from repro.serve.tier import ServingTier, TierConfig
from repro.serve.warmup import enable_persistent_cache, warmup_service

__all__ = [
    "BucketPolicy",
    "ContinuousBatcher",
    "RankingService",
    "ServePlacement",
    "ServiceConfig",
    "ServiceStats",
    "ServingTier",
    "TierConfig",
    "enable_persistent_cache",
    "generate",
    "warmup_service",
]
