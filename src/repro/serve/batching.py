"""Continuous batching: many small concurrent queries → padded engine blocks.

The progressive engine wants big padded ``[Q, D, F]`` blocks (jit-stable
shapes, one fused device read per batch); real serving traffic is a stream
of single queries with ragged candidate counts. The
:class:`ContinuousBatcher` closes that gap:

- **Submit** is non-blocking: a query's features go into the pending set
  keyed by its *document bucket* (candidate count rounded up to a power of
  two, floored at ``BucketPolicy.min_docs``) and the caller gets a
  ``Future``.
- **One worker thread owns every engine call** — the RankingService's
  adaptive state (per-bucket peaks/EMA, jit step cache) is touched from
  exactly one thread, so the service itself needs no locks.
- **Flush policy**: a bucket flushes when it holds ``max_queries`` queries
  (full-bucket trigger — the batch the engine was sized for) or when its
  oldest request has waited ``max_wait_ms`` (deadline trigger — bounded
  p99 under trickle traffic). The worker sleeps on a condition variable
  with the earliest pending deadline as its timeout: no polling loop, no
  idle CPU burn.
- **Scatter-back**: the flushed block is padded to the next power-of-two
  query count (so the engine sees the same handful of shapes forever —
  these are exactly the buckets AOT warmup compiles), scored once, and
  each query's slice of the result is scattered back to its Future with a
  per-request top-k. The per-request top-k reproduces ``lax.top_k``'s
  tie-break (descending score, ascending index) so a batched response is
  *bit-exact* with submitting the same query alone.

Fault tolerance (see also :mod:`repro.serve.errors`):

- **Admission control**: ``BucketPolicy.max_queue_depth`` bounds the
  pending set; a submit against a full queue raises
  :class:`~repro.serve.errors.Overloaded` (counted in
  ``BatcherStats.shed_overload``) instead of growing the queue without
  limit.
- **Request deadlines**: ``submit(features, deadline_ms=…)`` gives the
  request an end-to-end budget. The flush schedule subtracts the
  *expected engine time* for the request's bucket (observed flush-time
  EMA, seeded from the startup calibration probe) so a deadlined request
  flushes early enough to make it; a request whose budget still expires
  in the queue is resolved to
  :class:`~repro.serve.errors.DeadlineExceeded` *before* the engine call
  — an already-dead request never wastes engine work.
- **Supervision**: the worker thread runs under a
  :class:`~repro.serve.supervisor.WorkerSupervisor` — a crash fails the
  in-flight bucket with :class:`~repro.serve.errors.WorkerCrashed`,
  queued requests survive, and the worker restarts with bounded backoff.
  Engine errors and per-request poison are contained inside
  :meth:`ContinuousBatcher._flush` (the bucket's — or the one request's —
  futures fail; the loop survives).
- **Degradation**: an optional
  :class:`~repro.serve.degradation.DegradationController` observes each
  flush's queue delay from the worker thread and steps the service
  through its pre-warmed exit rungs.

Padding rows carry ``mask=False`` everywhere, and the engine's masked
reductions make dead rows inert — which is what makes the bit-exactness
claim hold: scoring is per-document, the LEAR features are per-query
masked reductions, and compaction touches only alive documents, so a
query's scores do not depend on its neighbors in the block.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import typing
from collections.abc import Callable, Sequence
from concurrent.futures import Future

import numpy as np

import jax.numpy as jnp

from repro.kernels.forest_score import _next_pow2
from repro.serve.calibration import expected_engine_seconds
from repro.serve.clock import SYSTEM_CLOCK, Clock
from repro.serve.errors import (
    BatcherStopped,
    DeadlineExceeded,
    Overloaded,
    WorkerCrashed,
    WorkerFailed,
)
from repro.serve.ranking_service import RankingService
from repro.serve.supervisor import (
    STATE_NEW,
    SupervisorHealth,
    WorkerSupervisor,
)

if typing.TYPE_CHECKING:  # annotation-only: placement is constructed by
    from numpy.typing import ArrayLike  # the tier, never by the batcher

    from repro.serve.degradation import DegradationController
    from repro.serve.placement import ServePlacement

#: Sliding window of completed-request latencies backing the p50/p99 in
#: ``health()`` — bounded so introspection can never grow without limit.
LATENCY_WINDOW = 512

#: Smoothing for the per-bucket observed engine-seconds EMA that feeds
#: deadline-aware flush scheduling.
ENGINE_TIME_EMA_ALPHA = 0.3

#: Scheduling slack subtracted from a request's deadline when placing its
#: flush: condition-variable wakeups are not instant, and a flush timed at
#: exactly ``expires_at - engine_time`` would race its own expiry check.
FLUSH_SLACK_S = 5e-3


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """When to flush, which padded shapes exist, and how deep the queue goes.

    ``max_queries`` is both the full-bucket flush trigger and the largest
    padded Q; with power-of-two padding the engine sees at most
    ``log2(max_queries)+1`` query shapes per document bucket.
    ``max_queue_depth`` is the admission-control bound: a submit that
    would push the TOTAL pending count past it is rejected with
    :class:`~repro.serve.errors.Overloaded` (``None`` = unbounded, for
    offline/batch use only — a serving deployment should always bound it).
    """

    max_queries: int = 8
    max_wait_ms: float = 2.0
    min_docs: int = 8
    max_docs: int = 4096
    max_queue_depth: int | None = 1024

    def __post_init__(self) -> None:
        assert self.max_queries >= 1
        assert _next_pow2(self.max_queries) == self.max_queries, (
            "max_queries must be a power of two", self.max_queries
        )
        assert self.min_docs >= 1 and self.max_docs >= self.min_docs
        assert self.max_queue_depth is None or self.max_queue_depth >= 1, (
            self.max_queue_depth
        )

    def doc_bucket(self, n_docs: int) -> int:
        assert 1 <= n_docs <= self.max_docs, (n_docs, self.max_docs)
        return max(self.min_docs, _next_pow2(n_docs))

    def query_bucket(self, n_queries: int) -> int:
        return min(self.max_queries, _next_pow2(n_queries))

    def buckets(self, doc_counts: Sequence[int]) -> list[tuple[int, int]]:
        """The (Q, D) padded shapes this policy produces for the given doc
        counts — the warmup list: every query bucket up to ``max_queries``
        crossed with each distinct document bucket."""
        q = 1
        qs = []
        while q <= self.max_queries:
            qs.append(q)
            q *= 2
        ds = sorted({self.doc_bucket(d) for d in doc_counts})
        return [(q, d) for d in ds for q in qs]


@dataclasses.dataclass
class _Pending:
    features: np.ndarray   # [n_docs, F] f32
    n_docs: int
    future: Future
    flush_at: float        # clock time by which this request must flush
    expires_at: float      # end-to-end deadline (inf = none)
    deadline_ms: float     # as submitted (inf = none), for error messages
    enqueued_at: float     # clock time of submit, for latency accounting


@dataclasses.dataclass
class BatcherStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_drain: int = 0
    padded_query_slots: int = 0   # dead rows shipped (padding overhead)
    max_queue_depth: int = 0      # high-water mark actually observed
    shed_overload: int = 0        # submits rejected by admission control
    shed_deadline: int = 0        # submits dead on arrival (budget <= 0)
    expired_deadline: int = 0     # requests that timed out in the queue
    worker_crashes: int = 0       # in-flight buckets lost to worker death

    @property
    def flushes(self) -> int:
        return self.flushes_full + self.flushes_deadline + self.flushes_drain

    @property
    def shed_rate(self) -> float:
        return self.shed_overload / max(self.submitted, 1)

    @property
    def deadline_miss_rate(self) -> float:
        return (
            self.shed_deadline + self.expired_deadline
        ) / max(self.submitted, 1)


@dataclasses.dataclass
class BatcherHooks:
    """Fault-injection seams, exercised by ``tests/faults.py``.

    ``on_flush(doc_bucket, n_reqs)`` runs on the worker thread after a
    bucket is popped but before the engine call; an exception here escapes
    the worker loop — i.e. it IS a worker crash, handled by the
    supervisor. ``on_result(future)`` runs per request during
    scatter-back; an exception poisons only that request (its future
    fails, its bucket-mates complete).
    """

    on_flush: Callable[[int, int], None] | None = None
    on_result: Callable[[Future], None] | None = None


class ContinuousBatcher:
    """Packs concurrent single-query submissions into engine-sized blocks.

    Lifecycle: ``start()`` → any number of ``submit()`` (thread-safe, from
    any thread) → ``stop()`` (drains pending requests, then joins the
    worker). ``submit`` after ``stop`` raises
    :class:`~repro.serve.errors.BatcherStopped`; the stop/submit handoff
    is atomic under the condition lock, so a submit either lands before
    the drain snapshot (and is served) or raises — never silently lost.
    """

    def __init__(
        self,
        service: RankingService,
        n_features: int,
        policy: BucketPolicy | None = None,
        placement: ServePlacement | None = None,
        *,
        clock: Clock | None = None,
        hooks: BatcherHooks | None = None,
        degradation: DegradationController | None = None,
        max_restarts: int = 5,
        backoff_base_s: float = 0.05,
        backoff_max_s: float = 2.0,
    ) -> None:
        self.service = service
        self.n_features = int(n_features)
        self.policy = policy or BucketPolicy()
        self.placement = placement
        self.hooks = hooks
        self.degradation = degradation
        self.stats = BatcherStats()
        self.max_restarts = int(max_restarts)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        self._clock = clock or SYSTEM_CLOCK
        self._pending: dict[int, list[_Pending]] = {}
        self._inflight: list[_Pending] = []
        self._cond = threading.Condition()
        self._running = False
        self._failed = False
        self._supervisor: WorkerSupervisor | None = None
        self._last_sup_health: SupervisorHealth | None = None
        self._latencies: collections.deque[float] = collections.deque(
            maxlen=LATENCY_WINDOW
        )
        self._engine_s_ema: dict[int, float] = {}

    # -- client side ------------------------------------------------------

    def start(self) -> None:
        assert self._supervisor is None, "batcher already started"
        with self._cond:
            self._running = True
            self._failed = False
        self._supervisor = WorkerSupervisor(
            self._run,
            name="repro-batcher",
            backoff_base_s=self.backoff_base_s,
            backoff_max_s=self.backoff_max_s,
            max_restarts=self.max_restarts,
            clock=self._clock,
            on_crash=self._on_worker_crash,
            on_failed=self._on_worker_failed,
        )
        self._supervisor.start()

    def submit(
        self, features: ArrayLike, deadline_ms: float | None = None
    ) -> Future:
        """Enqueue one query's ``[n_docs, F]`` candidate features; returns a
        Future resolving to ``(top_idx [k], scores [n_docs])``.

        ``deadline_ms`` is the request's END-TO-END budget from this call:
        the batcher schedules the flush early enough to cover the expected
        engine time, and resolves the future to
        :class:`~repro.serve.errors.DeadlineExceeded` if the budget
        expires while queued (a non-positive budget is dead on arrival —
        resolved immediately, never enqueued). Raises
        :class:`~repro.serve.errors.Overloaded` when the queue is at
        ``max_queue_depth`` and :class:`~repro.serve.errors.BatcherStopped`
        after (or racing) ``stop()``.
        """
        feats = np.asarray(features, np.float32)
        assert feats.ndim == 2 and feats.shape[1] == self.n_features, (
            feats.shape, self.n_features
        )
        n_docs = feats.shape[0]
        db = self.policy.doc_bucket(n_docs)
        fut: Future = Future()
        now = self._clock.now()
        with self._cond:
            if self._failed:
                raise WorkerFailed(
                    "serving worker exhausted its restart budget"
                )
            if not self._running:
                raise BatcherStopped("batcher is not running")
            self.stats.submitted += 1
            if deadline_ms is not None and deadline_ms <= 0.0:
                # Dead on arrival: resolve without ever queueing — the
                # engine must not be asked to score an expired request.
                self.stats.shed_deadline += 1
                self.stats.failed += 1
                fut.set_exception(DeadlineExceeded(float(deadline_ms), 0.0))
                return fut
            depth = sum(len(v) for v in self._pending.values())
            limit = self.policy.max_queue_depth
            if limit is not None and depth >= limit:
                self.stats.shed_overload += 1
                raise Overloaded(depth, limit)
            flush_at = now + self.policy.max_wait_ms / 1e3
            expires_at = math.inf
            if deadline_ms is not None:
                expires_at = now + float(deadline_ms) / 1e3
                # Flush early enough that the engine call itself fits in
                # the remaining budget (estimated from the calibrated
                # cost model / observed flush times, plus wakeup slack),
                # clamped at "now" — an already-tight request flushes as
                # soon as possible.
                budget = self._engine_seconds_estimate(db) + FLUSH_SLACK_S
                flush_at = min(flush_at, max(now, expires_at - budget))
            req = _Pending(
                features=feats,
                n_docs=n_docs,
                future=fut,
                flush_at=flush_at,
                expires_at=expires_at,
                deadline_ms=(
                    math.inf if deadline_ms is None else float(deadline_ms)
                ),
                enqueued_at=now,
            )
            self._pending.setdefault(db, []).append(req)
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth, depth + 1
            )
            self._cond.notify()
        return fut

    def stop(self) -> None:
        """Drain everything still queued, then stop the worker.

        The handoff is atomic: under the condition lock the batcher flips
        to not-running AND snapshots the pending map, so a concurrent
        ``submit`` either landed in the snapshot (and is drained below) or
        observes not-running and raises — no request can slip into a dict
        nobody will ever flush."""
        with self._cond:
            self._running = False
            drain, self._pending = self._pending, {}
            self._cond.notify_all()
        if self._supervisor is not None:
            self._supervisor.stop()
            self._last_sup_health = self._supervisor.health()
            self._supervisor = None
        # Whatever the worker left behind (requests that arrived in its
        # final instants) flushes on the caller's thread — in engine-sized
        # chunks: a drained bucket can hold MORE than max_queries (the
        # worker never popped it), and a flush must never exceed the
        # padded block it allocates.
        step = self.policy.max_queries
        for db, reqs in sorted(drain.items()):
            for i in range(0, len(reqs), step):
                self.stats.flushes_drain += 1
                self._flush(db, reqs[i:i + step])

    def health(self) -> dict:
        """Liveness snapshot: supervisor state + queue depth + latency
        percentiles over the last :data:`LATENCY_WINDOW` completions."""
        sup = (
            self._supervisor.health()
            if self._supervisor is not None
            else self._last_sup_health or SupervisorHealth(STATE_NEW, 0, 0, None)
        )
        with self._cond:
            depth = sum(len(v) for v in self._pending.values())
            lat = list(self._latencies)
        p50 = p99 = 0.0
        if lat:
            arr = np.asarray(lat, np.float64) * 1e3
            p50 = float(np.percentile(arr, 50))
            p99 = float(np.percentile(arr, 99))
        return {
            "state": sup.state,
            "restarts": sup.restarts,
            "crashes": sup.crashes,
            "last_error": sup.last_error,
            "queue_depth": depth,
            "p50_ms": p50,
            "p99_ms": p99,
        }

    # -- supervision callbacks (guard thread) -----------------------------

    def _on_worker_crash(self, exc: BaseException) -> None:
        """Worker died mid-bucket: fail exactly the in-flight requests.
        Queued requests stay queued and are served after the restart."""
        with self._cond:
            inflight, self._inflight = self._inflight, []
            self.stats.worker_crashes += 1
        err = WorkerCrashed(f"serving worker died: {exc!r}")
        err.__cause__ = exc
        for r in inflight:
            self._fail(r, err)

    def _on_worker_failed(self, exc: BaseException) -> None:
        """Supervisor gave up: nothing will ever drain the queue, so fail
        every pending and in-flight future and refuse new submits."""
        with self._cond:
            self._failed = True
            pending, self._pending = self._pending, {}
            inflight, self._inflight = self._inflight, []
            self._cond.notify_all()
        err = WorkerFailed(f"serving worker restart budget exhausted: {exc!r}")
        err.__cause__ = exc
        for reqs in pending.values():
            for r in reqs:
                self._fail(r, err)
        for r in inflight:
            self._fail(r, err)

    # -- worker side ------------------------------------------------------

    def _engine_seconds_estimate(self, db: int) -> float:
        """Expected wall time of one engine flush at doc bucket ``db``:
        the observed per-bucket EMA once traffic exists, else the
        calibration probe's prior (0 when neither is available)."""
        ema = self._engine_s_ema.get(db)
        if ema is not None:
            return ema
        ensemble = getattr(self.service, "ensemble", None)
        if ensemble is None:
            return 0.0
        return expected_engine_seconds(
            self.policy.max_queries * db, ensemble.n_trees
        )

    def _take_ready(
        self, now: float
    ) -> tuple[int | None, list[_Pending] | None, str | None, float | None]:
        """Pop the bucket to flush now, with its trigger, or the earliest
        future flush time. Full buckets beat timer flushes (they amortize
        best); among timer-ripe buckets the most urgent request wins."""
        for db, reqs in sorted(self._pending.items()):
            if len(reqs) >= self.policy.max_queries:
                self._pending[db] = reqs[self.policy.max_queries:]
                return db, reqs[: self.policy.max_queries], "full", None
        ripe_db, ripe_t = None, None
        for db, reqs in self._pending.items():
            if not reqs:
                continue
            t = min(r.flush_at for r in reqs)
            if ripe_t is None or t < ripe_t:
                ripe_db, ripe_t = db, t
        if ripe_t is not None and ripe_t <= now:
            reqs = self._pending.pop(ripe_db)
            return ripe_db, reqs, "deadline", None
        return None, None, None, ripe_t

    def _run(self) -> None:
        while True:
            with self._cond:
                db = reqs = None
                while True:
                    now = self._clock.now()
                    db, reqs, trigger, next_t = self._take_ready(now)
                    if reqs is not None:
                        break
                    if not self._running:
                        return  # leftovers flush in stop()
                    self._clock.wait(
                        self._cond,
                        None if next_t is None else max(next_t - now, 0.0),
                    )
                self._inflight = reqs
                queue_delay = now - min(r.enqueued_at for r in reqs)
            if trigger == "full":
                self.stats.flushes_full += 1
            else:
                self.stats.flushes_deadline += 1
            if self.degradation is not None:
                # Worker thread: the only place allowed to step the
                # service through its pre-warmed degradation rungs.
                self.degradation.observe(queue_delay)
            hooks = self.hooks
            if hooks is not None and hooks.on_flush is not None:
                # Outside _flush's containment on purpose: an injected
                # failure here IS a worker crash (supervisor territory).
                hooks.on_flush(db, len(reqs))
            t0 = self._clock.now()
            self._flush(db, reqs)
            elapsed = self._clock.now() - t0
            with self._cond:
                self._inflight = []
                a = ENGINE_TIME_EMA_ALPHA
                prev = self._engine_s_ema.get(db)
                self._engine_s_ema[db] = (
                    elapsed if prev is None else (1 - a) * prev + a * elapsed
                )

    def _flush(self, db: int, reqs: list[_Pending]) -> None:
        """Score one padded block and scatter per-query results back.

        Failure containment, tightest scope first: an expired request is
        resolved without engine work; a request that cannot even be packed
        fails alone (its block row stays masked dead — inert to the
        engine); an engine error fails this bucket's futures but returns
        normally (the worker loop survives); a per-request scatter error
        (injected poison, cancelled future) fails that request alone.
        Anything escaping this method is a worker crash for the
        supervisor.
        """
        now = self._clock.now()
        live: list[_Pending | None] = []
        for r in reqs:
            if r.expires_at <= now:
                self._expire(r, now)
            else:
                live.append(r)
        if not live:
            return  # the whole bucket died in the queue: no engine launch
        qb = self.policy.query_bucket(len(live))
        X = np.zeros((qb, db, self.n_features), np.float32)
        mask = np.zeros((qb, db), bool)
        for i, r in enumerate(live):
            try:
                X[i, : r.n_docs] = r.features
                mask[i, : r.n_docs] = True
            except Exception as e:
                # A malformed request fails alone; its dead row is inert.
                mask[i] = False
                self._fail(r, e)
                live[i] = None
        self.stats.padded_query_slots += qb - len(live)
        try:
            _, scores = self.service.rank_batch(
                jnp.asarray(X), jnp.asarray(mask), placement=self.placement
            )
            scores = np.asarray(scores)
        except Exception as e:
            # Engine failure: this bucket's futures must not hang, and the
            # worker loop must survive to serve the next bucket.
            for r in live:
                if r is not None:
                    self._fail(r, e)
            return
        hooks = self.hooks
        for i, r in enumerate(live):
            if r is None:
                continue
            try:
                if hooks is not None and hooks.on_result is not None:
                    hooks.on_result(r.future)
                s = scores[i, : r.n_docs].copy()
                k = min(self.service.top_k, r.n_docs)
                # lax.top_k order: descending score, ascending index.
                top = np.lexsort((np.arange(r.n_docs), -s))[:k]
                r.future.set_result((top.astype(np.int32), s))
                self.stats.completed += 1
                self._latencies.append(self._clock.now() - r.enqueued_at)
            except Exception as e:
                # Poisoned scatter: one request fails, bucket-mates don't.
                self._fail(r, e)

    # -- resolution helpers -----------------------------------------------

    def _fail(self, r: _Pending, exc: BaseException) -> None:
        if not r.future.done():
            r.future.set_exception(exc)
            self.stats.failed += 1

    def _expire(self, r: _Pending, now: float) -> None:
        self.stats.expired_deadline += 1
        self._fail(
            r,
            DeadlineExceeded(r.deadline_ms, (now - r.enqueued_at) * 1e3),
        )
