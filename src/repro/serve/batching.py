"""Continuous batching: many small concurrent queries → padded engine blocks.

The progressive engine wants big padded ``[Q, D, F]`` blocks (jit-stable
shapes, one fused device read per batch); real serving traffic is a stream
of single queries with ragged candidate counts. The
:class:`ContinuousBatcher` closes that gap:

- **Submit** is non-blocking: a query's features go into the pending set
  keyed by its *document bucket* (candidate count rounded up to a power of
  two, floored at ``BucketPolicy.min_docs``) and the caller gets a
  ``Future``.
- **One worker thread owns every engine call** — the RankingService's
  adaptive state (per-bucket peaks/EMA, jit step cache) is touched from
  exactly one thread, so the service itself needs no locks.
- **Flush policy**: a bucket flushes when it holds ``max_queries`` queries
  (full-bucket trigger — the batch the engine was sized for) or when its
  oldest request has waited ``max_wait_ms`` (deadline trigger — bounded
  p99 under trickle traffic). The worker sleeps on a condition variable
  with the earliest pending deadline as its timeout: no polling loop, no
  idle CPU burn.
- **Scatter-back**: the flushed block is padded to the next power-of-two
  query count (so the engine sees the same handful of shapes forever —
  these are exactly the buckets AOT warmup compiles), scored once, and
  each query's slice of the result is scattered back to its Future with a
  per-request top-k. The per-request top-k reproduces ``lax.top_k``'s
  tie-break (descending score, ascending index) so a batched response is
  *bit-exact* with submitting the same query alone.

Padding rows carry ``mask=False`` everywhere, and the engine's masked
reductions make dead rows inert — which is what makes the bit-exactness
claim hold: scoring is per-document, the LEAR features are per-query
masked reductions, and compaction touches only alive documents, so a
query's scores do not depend on its neighbors in the block.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import typing
from collections.abc import Sequence
from concurrent.futures import Future

import numpy as np

import jax.numpy as jnp

from repro.kernels.forest_score import _next_pow2
from repro.serve.ranking_service import RankingService

if typing.TYPE_CHECKING:  # annotation-only: placement is constructed by
    from numpy.typing import ArrayLike  # the tier, never by the batcher
    from repro.serve.placement import ServePlacement


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """When to flush, and which padded shapes exist.

    ``max_queries`` is both the full-bucket flush trigger and the largest
    padded Q; with power-of-two padding the engine sees at most
    ``log2(max_queries)+1`` query shapes per document bucket.
    """

    max_queries: int = 8
    max_wait_ms: float = 2.0
    min_docs: int = 8
    max_docs: int = 4096

    def __post_init__(self) -> None:
        assert self.max_queries >= 1
        assert _next_pow2(self.max_queries) == self.max_queries, (
            "max_queries must be a power of two", self.max_queries
        )
        assert self.min_docs >= 1 and self.max_docs >= self.min_docs

    def doc_bucket(self, n_docs: int) -> int:
        assert 1 <= n_docs <= self.max_docs, (n_docs, self.max_docs)
        return max(self.min_docs, _next_pow2(n_docs))

    def query_bucket(self, n_queries: int) -> int:
        return min(self.max_queries, _next_pow2(n_queries))

    def buckets(self, doc_counts: Sequence[int]) -> list[tuple[int, int]]:
        """The (Q, D) padded shapes this policy produces for the given doc
        counts — the warmup list: every query bucket up to ``max_queries``
        crossed with each distinct document bucket."""
        q = 1
        qs = []
        while q <= self.max_queries:
            qs.append(q)
            q *= 2
        ds = sorted({self.doc_bucket(d) for d in doc_counts})
        return [(q, d) for d in ds for q in qs]


@dataclasses.dataclass
class _Pending:
    features: np.ndarray   # [n_docs, F] f32
    n_docs: int
    future: Future
    deadline: float        # perf_counter() time at which it must flush


@dataclasses.dataclass
class BatcherStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_drain: int = 0
    padded_query_slots: int = 0   # dead rows shipped (padding overhead)
    max_queue_depth: int = 0

    @property
    def flushes(self) -> int:
        return self.flushes_full + self.flushes_deadline + self.flushes_drain


class ContinuousBatcher:
    """Packs concurrent single-query submissions into engine-sized blocks.

    Lifecycle: ``start()`` → any number of ``submit()`` (thread-safe, from
    any thread) → ``stop()`` (drains pending requests, then joins the
    worker). ``submit`` after ``stop`` raises.
    """

    def __init__(
        self,
        service: RankingService,
        n_features: int,
        policy: BucketPolicy | None = None,
        placement: ServePlacement | None = None,
    ) -> None:
        self.service = service
        self.n_features = int(n_features)
        self.policy = policy or BucketPolicy()
        self.placement = placement
        self.stats = BatcherStats()
        self._pending: dict[int, list[_Pending]] = {}
        self._cond = threading.Condition()
        self._running = False
        self._worker: threading.Thread | None = None

    # -- client side ------------------------------------------------------

    def start(self) -> None:
        assert self._worker is None, "batcher already started"
        self._running = True
        self._worker = threading.Thread(
            target=self._run, name="repro-batcher", daemon=True
        )
        self._worker.start()

    def submit(self, features: ArrayLike) -> Future:
        """Enqueue one query's ``[n_docs, F]`` candidate features; returns a
        Future resolving to ``(top_idx [k], scores [n_docs])``."""
        feats = np.asarray(features, np.float32)
        assert feats.ndim == 2 and feats.shape[1] == self.n_features, (
            feats.shape, self.n_features
        )
        n_docs = feats.shape[0]
        db = self.policy.doc_bucket(n_docs)
        fut: Future = Future()
        req = _Pending(
            features=feats,
            n_docs=n_docs,
            future=fut,
            deadline=time.perf_counter() + self.policy.max_wait_ms / 1e3,
        )
        with self._cond:
            assert self._running, "batcher is not running"
            self._pending.setdefault(db, []).append(req)
            self.stats.submitted += 1
            depth = sum(len(v) for v in self._pending.values())
            self.stats.max_queue_depth = max(self.stats.max_queue_depth, depth)
            self._cond.notify()
        return fut

    def stop(self) -> None:
        """Drain everything still queued, then stop the worker."""
        with self._cond:
            if not self._running:
                return
            self._running = False
            self._cond.notify()
        self._worker.join()
        self._worker = None
        # Whatever the worker left behind (requests that arrived in its
        # final instants) flushes on the caller's thread.
        for db, reqs in sorted(self._pending.items()):
            if reqs:
                self.stats.flushes_drain += 1
                self._flush(db, reqs)
        self._pending.clear()

    # -- worker side ------------------------------------------------------

    def _take_ready(
        self, now: float
    ) -> tuple[int | None, list[_Pending] | None, str | None, float | None]:
        """Pop the bucket to flush now, with its trigger, or the earliest
        future deadline. Full buckets beat deadline flushes (they amortize
        best); among deadline-ripe buckets the oldest request wins."""
        for db, reqs in sorted(self._pending.items()):
            if len(reqs) >= self.policy.max_queries:
                self._pending[db] = reqs[self.policy.max_queries:]
                return db, reqs[: self.policy.max_queries], "full", None
        ripe_db, ripe_t = None, None
        for db, reqs in self._pending.items():
            if not reqs:
                continue
            t = min(r.deadline for r in reqs)
            if ripe_t is None or t < ripe_t:
                ripe_db, ripe_t = db, t
        if ripe_t is not None and ripe_t <= now:
            reqs = self._pending.pop(ripe_db)
            return ripe_db, reqs, "deadline", None
        return None, None, None, ripe_t

    def _run(self) -> None:
        while True:
            with self._cond:
                db = reqs = None
                while True:
                    now = time.perf_counter()
                    db, reqs, trigger, next_t = self._take_ready(now)
                    if reqs is not None:
                        break
                    if not self._running:
                        return  # leftovers flush in stop()
                    self._cond.wait(
                        timeout=None if next_t is None else max(next_t - now, 0.0)
                    )
            if trigger == "full":
                self.stats.flushes_full += 1
            else:
                self.stats.flushes_deadline += 1
            self._flush(db, reqs)

    def _flush(self, db: int, reqs: list[_Pending]) -> None:
        """Score one padded block and scatter per-query results back."""
        try:
            qb = self.policy.query_bucket(len(reqs))
            X = np.zeros((qb, db, self.n_features), np.float32)
            mask = np.zeros((qb, db), bool)
            for i, r in enumerate(reqs):
                X[i, : r.n_docs] = r.features
                mask[i, : r.n_docs] = True
            self.stats.padded_query_slots += qb - len(reqs)
            _, scores = self.service.rank_batch(
                jnp.asarray(X), jnp.asarray(mask), placement=self.placement
            )
            scores = np.asarray(scores)
            for i, r in enumerate(reqs):
                s = scores[i, : r.n_docs].copy()
                k = min(self.service.top_k, r.n_docs)
                # lax.top_k order: descending score, ascending index.
                top = np.lexsort((np.arange(r.n_docs), -s))[:k]
                r.future.set_result((top.astype(np.int32), s))
                self.stats.completed += 1
        except BaseException as e:  # noqa: BLE001 — futures must not hang
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
                    self.stats.failed += 1
