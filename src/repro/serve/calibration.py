"""Launch-overhead calibration: measure, don't guess, the cost model's knob.

:func:`repro.metrics.speedup.progressive_cost_model` prices one extra
kernel launch (dispatch + gather/scatter HBM round trip) at
``launch_overhead_trees`` doc·tree equivalents. PR 2 shipped a fixed
default; the right value is a property of the *machine* (dispatch latency
vs per-tree scoring throughput), not of the workload — so we measure it
once per process with a short timing probe and reuse it for every service.

The probe scores a tiny synthetic forest twice through the plain kernel —
once over a single tree block (launch-dominated) and once over the full
forest (tree-work-dominated) — and solves::

    per_doctree = (t_full − t_small) / (docs · (trees_full − trees_small))
    overhead_trees = max(t_small − per_doctree · docs · trees_small, 0)
                     / per_doctree

i.e. "the launch's fixed latency, expressed in doc·tree traversals". The
result is cached per backend (module-level) so constructing many
:class:`~repro.serve.ranking_service.RankingService` instances probes only
once, and can be recorded into ``BENCH_kernels.json`` (the kernel bench
does this) so the perf trajectory keeps the calibrated value alongside the
measured fused/staged crossover it should reproduce.

CPU-interpret caveat: on this container the kernel runs in interpret mode,
so the measured overhead is the interpreter's dispatch cost — large, but
directionally correct (staged mode's extra launches are genuinely more
expensive here). On a real TPU the same probe measures Mosaic dispatch.
"""

from __future__ import annotations

import contextlib
import json
import os
import time
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.ensemble import random_ensemble
from repro.kernels.ops import forest_score_range, padded_forest

DEFAULT_LAUNCH_OVERHEAD_TREES = 4096.0  # fallback when the probe degenerates

# One calibration per (backend, probe shape) per process; keyed so tests
# with a custom probe cannot poison the serving default.
_CALIBRATION_CACHE: dict = {}


def _min_time_us(
    fn: Callable[..., object], *args: object, iters: int
) -> float:
    fn(*args)  # compile / warm caches outside the timed window
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def calibrate_launch_overhead_trees(
    n_docs: int = 128,
    n_trees: int = 64,
    block_t: int = 16,
    iters: int = 5,
    record_path: str | None = None,
) -> float:
    """Measure launch latency in doc·tree equivalents (cached per backend).

    Returns the calibrated ``launch_overhead_trees`` for the current jax
    backend. Degenerate measurements (non-positive per-tree slope, e.g. on
    a noisy box where the small launch out-timed the big one) fall back to
    :data:`DEFAULT_LAUNCH_OVERHEAD_TREES`. With ``record_path`` the probe
    merges its report under ``"launch_calibration"`` into that JSON file —
    an operator-facing hook for deployments that track the value out of
    band. The kernel bench does NOT use it (its ``main()`` rewrites
    ``BENCH_kernels.json`` wholesale); it embeds :func:`last_calibration`
    into its own payload instead.
    """
    key = (jax.default_backend(), n_docs, n_trees, block_t)
    cached = _CALIBRATION_CACHE.get(key)
    if cached is not None:
        if record_path is not None:
            _record(record_path, cached)
        return cached["launch_overhead_trees"]

    # A probe-only forest: shape matters (one aligned block vs the full
    # range), values do not. Segment 0 is exactly one tree block so the
    # small launch is as launch-dominated as the kernel allows.
    ens = random_ensemble(0, n_trees=n_trees, depth=3, n_features=16)
    pf = padded_forest(ens, boundaries=(block_t, n_trees), block_t=block_t)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(n_docs, 16)).astype(np.float32)
    )

    t_small = _min_time_us(
        lambda v: forest_score_range(pf, v, 0, 1), x, iters=iters
    )
    t_full = _min_time_us(
        lambda v: forest_score_range(pf, v, 0, 2), x, iters=iters
    )

    per_doctree = (t_full - t_small) / max(n_docs * (n_trees - block_t), 1)
    if per_doctree <= 0:
        overhead = DEFAULT_LAUNCH_OVERHEAD_TREES
    else:
        launch_us = max(t_small - per_doctree * n_docs * block_t, 0.0)
        overhead = launch_us / per_doctree

    payload = {
        "backend": jax.default_backend(),
        "probe_docs": n_docs,
        "probe_trees": n_trees,
        "block_t": block_t,
        "t_small_us": round(t_small, 1),
        "t_full_us": round(t_full, 1),
        "per_doctree_us": round(per_doctree, 6),
        "launch_overhead_trees": overhead,
    }
    _CALIBRATION_CACHE[key] = payload
    if record_path is not None:
        _record(record_path, payload)
    return overhead


def last_calibration() -> dict | None:
    """Most recent probe report (for embedding in bench payloads)."""
    return next(reversed(_CALIBRATION_CACHE.values()), None) \
        if _CALIBRATION_CACHE else None


def expected_engine_seconds(n_docs: int, n_trees: int) -> float:
    """Prior estimate of one engine call's wall time, in seconds.

    Extrapolates the calibration probe's per-doc·tree slope to a full
    block of ``n_docs × n_trees`` work plus one launch overhead — the
    batcher's deadline-aware flush scheduler uses this as the cold-start
    prior before it has observed real flush times for a bucket. Returns
    ``0.0`` when no probe has run in this process (the scheduler then
    assumes the engine is instant, i.e. legacy flush timing).
    """
    cal = last_calibration()
    if cal is None:
        return 0.0
    per_us = float(cal["per_doctree_us"])
    overhead_trees = float(cal["launch_overhead_trees"])
    return max(per_us * (n_docs * n_trees + overhead_trees), 0.0) * 1e-6


def _record(path: str, payload: dict) -> None:
    """Merge the calibration under ``"launch_calibration"``; never raise —
    a read-only checkout or a corrupt target file must not take the
    serving path down (ValueError covers json.JSONDecodeError)."""
    with contextlib.suppress(OSError, ValueError):
        doc = {}
        if os.path.exists(path):
            with open(path) as f:
                doc = json.load(f)
        if not isinstance(doc, dict):
            doc = {}
        doc["launch_calibration"] = payload
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
