"""Injectable time source for the serving tier.

Everything in ``serve/`` that reads the wall clock or sleeps on a
condition variable goes through a :class:`Clock`, so the fault-injection
harness (``tests/faults.py``) can substitute a fake clock and drive
deadline/backoff logic deterministically — a chaos test advances virtual
time instead of really sleeping, which keeps the whole suite fast and
flake-free.

The production implementation, :class:`MonotonicClock`, is
``time.perf_counter`` plus real condition waits; it is the default
everywhere and costs nothing over calling ``perf_counter`` directly.
"""

from __future__ import annotations

import threading
import time
import typing


@typing.runtime_checkable
class Clock(typing.Protocol):
    """Monotonic time + interruptible waiting, as one injectable seam."""

    def now(self) -> float:
        """Seconds on a monotonic axis (``time.perf_counter`` semantics)."""
        ...

    def wait(self, cond: threading.Condition, timeout: float | None) -> bool:
        """Wait on ``cond`` (which the caller holds) for up to ``timeout``
        seconds (``None`` = forever). Returns True if notified."""
        ...

    def sleep(self, cond: threading.Condition, seconds: float) -> None:
        """Sleep up to ``seconds``, interruptibly: acquires ``cond`` and
        waits on it so a notify (e.g. stop()) wakes the sleeper early."""
        ...


class MonotonicClock:
    """The real clock: ``perf_counter`` + genuine condition waits."""

    def now(self) -> float:
        return time.perf_counter()

    def wait(self, cond: threading.Condition, timeout: float | None) -> bool:
        return cond.wait(timeout=timeout)

    def sleep(self, cond: threading.Condition, seconds: float) -> None:
        with cond:
            cond.wait(timeout=max(seconds, 0.0))


SYSTEM_CLOCK = MonotonicClock()
