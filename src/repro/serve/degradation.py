"""Load-adaptive degradation: trade NDCG for latency with LEAR's own knobs.

The paper's exit thresholds are *budget* knobs — tighter thresholds, a
finite query-exit margin, or a more aggressive dense gate all buy latency
at a bounded quality cost. Under overload those are exactly the levers a
serving tier should pull before shedding traffic. This module makes that
a first-class policy:

- :class:`ExitRung` — one degradation step, expressed as overrides of the
  service's exit knobs (LEAR continue ``threshold``, a
  :class:`~repro.core.strategies.QueryExitConfig` with a finite margin,
  a higher-pruning ``dense_keep_frac`` for the hybrid gate). ``None``
  fields inherit the baseline value.
- :class:`DegradationPolicy` — the ordered rung ladder plus the
  hysteresis band: degrade one rung when the queue-delay EMA exceeds
  ``degrade_above_ms``, recover one rung when it falls below
  ``recover_below_ms`` (strictly lower — no flapping at a single
  threshold), with at least ``dwell_flushes`` engine flushes between
  moves so one spiky batch cannot ping-pong the ladder.
- :class:`DegradationController` — the runtime: owns the EMA and the
  current level, and calls :meth:`RankingService.set_rung` from the
  batcher's worker thread (the only thread allowed to touch the engine).

Every rung is installed up front (:meth:`RankingService.install_rungs`)
and AOT-compiled by :func:`repro.serve.warmup.warmup_service`, so
stepping the ladder at peak load swaps pre-built strategy closures and
hits a hot step cache — degrading never triggers a jit.
"""

from __future__ import annotations

import dataclasses
import threading
import typing

from repro.core.strategies import QueryExitConfig
from repro.serve.clock import SYSTEM_CLOCK, Clock

if typing.TYPE_CHECKING:  # annotation-only: avoids a serve-package cycle
    from repro.serve.ranking_service import RankingService


@dataclasses.dataclass(frozen=True)
class ExitRung:
    """One degradation step: overrides of the service's exit knobs.

    ``None`` inherits the baseline service configuration, so a rung names
    only what it tightens. ``threshold`` replaces the LEAR continue
    threshold at every tree stage (higher = fewer survivors = cheaper);
    ``query_exit`` replaces the service's query-exit config (typically a
    finite margin); ``dense_keep_frac`` re-points the hybrid dense gate at
    :func:`repro.core.strategies.dense_keep_fraction` with a smaller keep
    fraction (ignored unless the service has a dense stage — installing
    such a rung on an all-trees service is an error).
    """

    name: str
    threshold: float | None = None
    query_exit: QueryExitConfig | None = None
    dense_keep_frac: float | None = None

    def __post_init__(self) -> None:
        assert self.name, "rung needs a name"
        assert self.threshold is None or 0.0 <= self.threshold <= 1.0, (
            self.threshold
        )
        assert self.dense_keep_frac is None or (
            0.0 < self.dense_keep_frac <= 1.0
        ), self.dense_keep_frac


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """The rung ladder + when to move on it.

    ``rungs`` are ordered cheapest-last; level 0 is always the baseline
    service configuration (implicit — not listed here). The queue-delay
    EMA (seconds a flushed bucket's oldest request waited) is the load
    signal: above ``degrade_above_ms`` step one rung down the ladder,
    below ``recover_below_ms`` step one rung back up. The two thresholds
    form the hysteresis band; ``dwell_flushes`` is the minimum number of
    observations between consecutive moves.
    """

    rungs: tuple[ExitRung, ...]
    degrade_above_ms: float = 10.0
    recover_below_ms: float = 2.0
    ema_alpha: float = 0.2
    dwell_flushes: int = 4

    def __post_init__(self) -> None:
        object.__setattr__(self, "rungs", tuple(self.rungs))
        assert len(self.rungs) >= 1, "need at least one degradation rung"
        assert 0.0 <= self.recover_below_ms < self.degrade_above_ms, (
            "hysteresis band must be non-empty",
            self.recover_below_ms, self.degrade_above_ms,
        )
        assert 0.0 < self.ema_alpha <= 1.0, self.ema_alpha
        assert self.dwell_flushes >= 1, self.dwell_flushes


class DegradationController:
    """Runtime of one :class:`DegradationPolicy` over one service.

    ``observe`` MUST be called from the batcher's worker thread only — it
    may call :meth:`RankingService.set_rung`, and the engine's adaptive
    state is single-threaded by design. ``snapshot`` is safe from any
    thread (operator introspection).
    """

    def __init__(
        self,
        service: RankingService,
        policy: DegradationPolicy,
        clock: Clock | None = None,
    ) -> None:
        self.service = service
        self.policy = policy
        self.clock = clock or SYSTEM_CLOCK
        self._lock = threading.Lock()
        self._level = 0
        self._delay_ema_ms: float | None = None
        self._since_move = policy.dwell_flushes  # free to move immediately
        self._degrade_steps = 0
        self._recover_steps = 0

    @property
    def n_levels(self) -> int:
        return len(self.policy.rungs) + 1  # + the implicit baseline

    @property
    def level(self) -> int:
        with self._lock:
            return self._level

    def install(self) -> None:
        """Install the full rung ladder (baseline + policy rungs) on the
        service. Called by the tier before warmup so every rung's step is
        AOT-compiled."""
        self.service.install_rungs(self.policy.rungs)

    def observe(self, queue_delay_s: float) -> int:
        """Fold one flush's queue delay into the EMA and move the ladder
        if the hysteresis band says so. Returns the (possibly new) level.
        Worker thread only."""
        delay_ms = max(float(queue_delay_s), 0.0) * 1e3
        p = self.policy
        with self._lock:
            if self._delay_ema_ms is None:
                self._delay_ema_ms = delay_ms
            else:
                self._delay_ema_ms = (
                    (1.0 - p.ema_alpha) * self._delay_ema_ms
                    + p.ema_alpha * delay_ms
                )
            self._since_move += 1
            move = 0
            if self._since_move >= p.dwell_flushes:
                if (
                    self._delay_ema_ms > p.degrade_above_ms
                    and self._level < self.n_levels - 1
                ):
                    move = 1
                elif (
                    self._delay_ema_ms < p.recover_below_ms
                    and self._level > 0
                ):
                    move = -1
            if move:
                self._level += move
                self._since_move = 0
                if move > 0:
                    self._degrade_steps += 1
                else:
                    self._recover_steps += 1
            level = self._level
        if move:
            # Outside the lock: set_rung swaps closures on the service;
            # snapshot() readers must not block on the engine.
            self.service.set_rung(level)
        return level

    def snapshot(self) -> dict:
        """Operator view: current rung, smoothed delay, transition counts."""
        with self._lock:
            level = self._level
            rung = (
                "baseline" if level == 0
                else self.policy.rungs[level - 1].name
            )
            return {
                "level": level,
                "rung": rung,
                "n_levels": self.n_levels,
                "queue_delay_ema_ms": self._delay_ema_ms,
                "degrade_steps": self._degrade_steps,
                "recover_steps": self._recover_steps,
                "degrade_above_ms": self.policy.degrade_above_ms,
                "recover_below_ms": self.policy.recover_below_ms,
            }
