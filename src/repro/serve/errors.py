"""Typed failure modes for the serving tier.

Every way a request can fail short of an engine bug gets its own exception
type, so callers can branch on *policy* (retry, shed to a fallback ranker,
return a cached page) instead of string-matching messages:

- :class:`Overloaded` — admission control rejected the submit because the
  pending queue is at ``BucketPolicy.max_queue_depth``. Raised
  synchronously from ``submit`` (the request never enters the queue).
- :class:`DeadlineExceeded` — the request's end-to-end deadline expired
  before the engine would have finished it. Set on the Future (also a
  ``TimeoutError`` so generic timeout handling catches it).
- :class:`BatcherStopped` — submit raced a ``stop()``; the batcher is
  draining or drained. Raised synchronously.
- :class:`WorkerCrashed` — the worker thread died mid-flight (engine
  exception or injected fault); in-flight futures are failed with this
  while the supervisor restarts the worker. Requests submitted after the
  restart are served normally.
- :class:`WorkerFailed` — the supervisor exhausted its restart budget and
  gave up; the tier is unhealthy until restarted by the operator.
"""

from __future__ import annotations


class ServeError(RuntimeError):
    """Base class for every typed serving-tier failure."""


class Overloaded(ServeError):
    """Admission control: the pending queue is full; the request was shed.

    ``depth`` is the queue depth observed at rejection time and ``limit``
    the configured ``BucketPolicy.max_queue_depth``.
    """

    def __init__(self, depth: int, limit: int) -> None:
        super().__init__(
            f"serving queue overloaded: depth {depth} >= limit {limit}"
        )
        self.depth = depth
        self.limit = limit


class DeadlineExceeded(ServeError, TimeoutError):
    """The request's end-to-end deadline expired before scoring."""

    def __init__(self, deadline_ms: float, waited_ms: float) -> None:
        super().__init__(
            f"request deadline of {deadline_ms:.3f} ms exceeded "
            f"(waited {waited_ms:.3f} ms)"
        )
        self.deadline_ms = deadline_ms
        self.waited_ms = waited_ms


class BatcherStopped(ServeError):
    """submit() raced or followed stop(); the batcher accepts no work."""


class WorkerCrashed(ServeError):
    """The worker thread died with this request in flight; it will be
    restarted by the supervisor. The request itself is lost."""


class WorkerFailed(ServeError):
    """The supervisor gave up restarting the worker (restart budget
    exhausted); the tier needs operator attention."""
