"""LM serving driver: prefill once, decode autoregressively with KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.models import transformer as tfm


def generate(
    cfg: TransformerConfig,
    params: dict,
    prompt_tokens: jax.Array,   # [B, S_prompt]
    n_steps: int,
    cache_len: int | None = None,
    temperature: float = 0.0,
    key: jax.Array | None = None,
) -> jax.Array:
    """Greedy (or sampled) generation; returns [B, n_steps] tokens."""
    B, S = prompt_tokens.shape
    cache_len = cache_len or (S + n_steps)
    logits, caches = jax.jit(
        lambda p, t: tfm.prefill(cfg, p, t, cache_len=cache_len)
    )(params, prompt_tokens)

    decode = jax.jit(
        lambda p, tok, c, pos: tfm.decode_step(cfg, p, tok, c, pos)
    )

    out = []
    tok = _pick(logits, temperature, key, 0)
    for i in range(n_steps):
        out.append(tok)
        logits, caches = decode(params, tok, caches, jnp.int32(S + i))
        tok = _pick(logits, temperature, key, i + 1)
    return jnp.concatenate(out, axis=1)


def _pick(
    logits: jax.Array, temperature: float, key: jax.Array | None, i: int
) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    k = jax.random.fold_in(key, i)
    return jax.random.categorical(k, logits / temperature, axis=-1).astype(
        jnp.int32
    )[:, None]
