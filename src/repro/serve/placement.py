"""Device placement for the serving tier: mesh + logical-axis rules.

The engine is shape-polymorphic and sharding-oblivious; placement is the
tier's job. A :class:`ServePlacement` pairs a mesh with the repo's
logical-axis :class:`~repro.distributed.sharding.Rules` table and pins the
batched request operands before submit: the query axis of ``X [Q, D, F]``
and ``mask [Q, D]`` carries the logical ``"batch"`` axis (data parallel —
queries are independent), documents and features stay replicated per
device. GSPMD then partitions the whole compiled step along Q; no engine
code changes.

``single_device()`` (``mesh=None``) is the fast path: ``put`` is the
identity, so serving on one device is *bit-exact* with the pre-placement
code — there is no "sharded but degenerate" overhead to pay, and the
1-device mesh path (:func:`local`) is itself a numerical no-op the tests
cross-check against it.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed.sharding import Rules, single_pod_rules
from repro.launch.mesh import make_local_mesh


@dataclasses.dataclass(frozen=True)
class ServePlacement:
    """Where serving batches live. ``mesh=None`` → plain single device."""

    mesh: Mesh | None = None
    rules: Rules | None = None

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.size

    def _batch_shards(self) -> int:
        """How many ways the logical "batch" axis is split on this mesh."""
        phys = self.rules.physical("batch")
        if phys is None:
            return 1
        axes = (phys,) if isinstance(phys, str) else phys
        n = 1
        for ax in axes:
            n *= self.mesh.shape[ax]
        return n

    def put(
        self, X: jax.Array, mask: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Pin ``X [Q, D, F]`` / ``mask [Q, D]`` to the mesh, query-axis
        data-parallel. Identity when ``mesh is None``. A Q not divisible
        by the batch-axis shard count falls back to replication (the
        batcher's power-of-two query buckets make this the exception, not
        the rule — but a stray shape must degrade, never crash)."""
        if self.mesh is None:
            return X, mask
        if X.shape[0] % max(self._batch_shards(), 1) == 0:
            x_spec = self.rules.resolve("batch", None, None)
            m_spec = self.rules.resolve("batch", None)
        else:
            x_spec = m_spec = PartitionSpec()
        return (
            jax.device_put(X, NamedSharding(self.mesh, x_spec)),
            jax.device_put(mask, NamedSharding(self.mesh, m_spec)),
        )


def single_device() -> ServePlacement:
    """No mesh at all — today's path, byte for byte."""
    return ServePlacement(mesh=None, rules=None)


def local() -> ServePlacement:
    """1×1 mesh over the local device with the production rules table:
    exercises the full placement machinery with nothing actually split."""
    return ServePlacement(mesh=make_local_mesh(), rules=single_pod_rules())


def data_parallel(n_devices: int | None = None) -> ServePlacement:
    """(n, 1) mesh over ("data", "model"): query axis split n ways."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    assert 1 <= n <= len(devs), (n, len(devs))
    mesh = jax.make_mesh((n, 1), ("data", "model"), devices=devs[:n])
    return ServePlacement(mesh=mesh, rules=single_pod_rules())


def auto() -> ServePlacement:
    """Data-parallel over every visible device; plain single-device path
    when there is only one (keeps the 1-device case bit-exact)."""
    return data_parallel() if len(jax.devices()) > 1 else single_device()
