"""Batched ranking service with the LEAR cascade as a first-class feature.

The serving path the paper targets: a query arrives with its candidate
documents (already feature-extracted); the service scores them through the
λ-MART ensemble with document-level early exit and returns the top-k.

Production concerns handled here:
- request batching into fixed-size padded blocks (jit-stable shapes);
- the multi-sentinel progressive engine
  (:meth:`repro.core.cascade.CascadeRanker.rank_progressive`), end-to-end
  jitted — all three forests in the path (ranker head, LEAR classifier,
  ranker tail) go through the same Pallas kernel inside ONE XLA
  computation per batch, and the LEAR augmented features (sort-free
  per-query rank, min/max segment reductions — :mod:`repro.core.features`)
  are built on device between the head launch and the classifier launch;
- adaptive execution mode, picked ON DEVICE: the compiled step contains
  both the fused segmented head and the per-stage-tail branch under a
  ``lax.cond``, and
  :func:`repro.metrics.speedup.progressive_cost_model_device` prices them
  from the smoothed survivor counts (shipped as a tiny operand at submit
  time) — no host round trip and no batch-boundary decision lag; the
  host-side :func:`repro.metrics.speedup.progressive_cost_model` pick is
  kept as the reference the device pick must agree with
  (:meth:`RankingService._pick_mode`);
- a calibrated cost model: ``launch_overhead_trees="auto"`` (the default)
  measures dispatch latency at service startup
  (:func:`repro.serve.calibration.calibrate_launch_overhead_trees`,
  cached per process) instead of trusting a fixed constant;
- compaction capacity from a running per-stage survivor peak with
  headroom, never below the cold-start estimate, bucketed to powers of
  two so re-jits stay bounded;
- cost accounting per batch (trees traversed, the paper's own metric) and
  service-level stats — the ENTIRE host read (top-k response, scores,
  per-stage survivors, cost, overflow, batch doc count, picked branch) is
  ONE fused ``jax.device_get``: between batch submit and that read the
  hot path performs zero device→host transfers (guarded by
  :func:`repro.utils.count_host_transfers` in the tests);
- graceful degradation: if survivors exceed capacity, the overflow
  documents keep their sentinel scores (bounded quality loss, never a
  crash) and the stats record it.

The same class serves the beyond-paper cascade for recsys retrieval
(sentinel scorer = any cheap model, full scorer = any expensive model) via
the ``sentinel_fn`` / ``full_fn`` hooks — see examples/cascade_retrieval.py.
"""

from __future__ import annotations

import dataclasses
import typing
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp

if typing.TYPE_CHECKING:  # annotation-only: avoids a serve-package cycle
    import numpy as np

    from repro.serve.placement import ServePlacement

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.lear import LearClassifier, augment_features
from repro.core.strategies import QueryExitConfig
from repro.forest.ensemble import TreeEnsemble
from repro.kernels.ops import ENGINE_BLOCK_B
from repro.metrics.speedup import (
    progressive_cost_model,
    trees_traversed_progressive,
)
from repro.serve.calibration import calibrate_launch_overhead_trees


@dataclasses.dataclass
class _BucketAdaptState:
    """Adaptive state for ONE padded batch shape ``(Q, D)``.

    The serving tier packs traffic into power-of-two capacity buckets
    (:mod:`repro.serve.batching`); survivor behavior is a function of the
    batch shape (D bounds the survivor count, Q·D scales the head work), so
    both adaptation signals live per bucket: the running survivor ``peaks``
    drive that bucket's compaction-capacity ratchet and the smoothed
    survivor ``ema`` feeds that bucket's fused-vs-staged pick. A sparse
    Q=1 trickle must not shrink (or mis-mode) the Q=64 bulk bucket.
    """

    peaks: list[int] | None = None  # running max survivors per stage
    ema: list[float] | None = None  # smoothed survivors per stage
    tail_skip: float | None = None  # smoothed P(batch skipped the gated
    #   tail launch) — feeds the cost model's query_exit_rate discount


@dataclasses.dataclass
class ServiceStats:
    batches: int = 0
    queries: int = 0
    docs: int = 0
    docs_continued: int = 0
    overflow_docs: int = 0
    trees_traversed: float = 0.0
    trees_full_equiv: float = 0.0
    batches_fused: int = 0
    batches_staged: int = 0
    queries_exited: int = 0  # query-level exit fired (query_exit enabled)

    @property
    def speedup(self) -> float:
        return self.trees_full_equiv / max(self.trees_traversed, 1.0)

    @property
    def continue_rate(self) -> float:
        return self.docs_continued / max(self.docs, 1)

    @property
    def query_exit_rate(self) -> float:
        return self.queries_exited / max(self.queries, 1)


class RankingService:
    """LEAR-cascade ranking over padded [Q, D, F] request blocks.

    ``extra_classifiers`` turn the service into a multi-sentinel cascade:
    stages are ordered by sentinel and each stage's classifier gates the
    survivors of the previous one (nested exit masks). With none, the
    service is the paper's single-sentinel cascade served through the same
    progressive engine (a sentinel list of length 1).
    """

    def __init__(
        self,
        ensemble: TreeEnsemble,
        classifier: LearClassifier,
        threshold: float = 0.5,
        capacity_headroom: float = 1.25,
        top_k: int = 10,
        extra_classifiers: Sequence[LearClassifier] = (),
        use_kernel_classifier: bool = True,
        execution_mode: str = "auto",
        launch_overhead_trees: float | str = "auto",
        survivor_ema: float = 0.3,
        query_exit: QueryExitConfig | None = None,
    ) -> None:
        assert execution_mode in ("auto", "fused", "staged"), execution_mode
        # The capacity ratchet needs strictly-positive headroom: in staged
        # mode observed survivor peaks are clipped AT the current bucket (a
        # power of two), so only peak × headroom > bucket can round up to
        # the next bucket — with headroom <= 1 capacity would never grow
        # and an undersized stage would silently overflow forever.
        assert capacity_headroom > 1.0, capacity_headroom
        self.ensemble = ensemble
        self.classifier = classifier
        self.threshold = threshold
        self.headroom = capacity_headroom
        self.top_k = top_k
        self.use_kernel_classifier = use_kernel_classifier
        self.execution_mode = execution_mode
        # Price of one extra kernel launch + gather/scatter HBM round trip,
        # in doc·tree equivalents — the cost model's only tunable. "auto"
        # measures it at startup (short timing probe, cached per process)
        # instead of trusting a machine-independent constant.
        if launch_overhead_trees == "auto":
            launch_overhead_trees = calibrate_launch_overhead_trees()
        self.launch_overhead_trees = float(launch_overhead_trees)
        self.survivor_ema = survivor_ema
        # Query-level exit config (None = document-level LEAR only). Part
        # of the compiled step's static key; the per-bucket tail-skip EMA
        # it produces feeds the auto-mode cost model as a traced operand.
        assert query_exit is None or isinstance(query_exit, QueryExitConfig)
        self.query_exit = query_exit
        self.stats = ServiceStats()
        # Adaptive state is PER padded batch shape (capacity bucket): each
        # (Q, D) the service has seen owns its survivor peaks and EMA.
        # ``_active_key`` is the bucket of the most recent rank_batch —
        # the introspection surface (_stage_peaks/_stage_ema properties,
        # _pick_capacities, _pick_mode) reads through it.
        self._adapt: dict[tuple[int, int] | None, _BucketAdaptState] = {}
        self._active_key: tuple[int, int] | None = None

        stages = sorted([classifier, *extra_classifiers], key=lambda c: c.sentinel)
        self.stage_classifiers = stages
        self.sentinels = tuple(c.sentinel for c in stages)
        assert len(set(self.sentinels)) == len(stages), (
            "stage sentinels must be distinct", self.sentinels
        )
        self.stage_strategies = [self._make_strategy(c) for c in stages]

        self.cascade = CascadeRanker(
            ensemble=ensemble,
            sentinel=stages[0].sentinel,
            strategy=self.stage_strategies[0],
            classifier_trees=stages[0].n_trees,
        )

    def bucket_state(self, Q: int, D: int) -> _BucketAdaptState:
        """Adaptive state for batch shape ``(Q, D)``, created on first use.

        The warmup path (:func:`repro.serve.warmup.warmup_service`) seeds
        ``peaks`` here BEFORE the bucket's first trace so the compaction
        capacities are stable from batch 1 — one trace per bucket and no
        cold-start overflow.
        """
        return self._adapt.setdefault((Q, D), _BucketAdaptState())

    def _active_state(self) -> _BucketAdaptState:
        return self._adapt.setdefault(self._active_key, _BucketAdaptState())

    # Back-compat introspection surface: the pre-bucketing attributes now
    # read/write the ACTIVE bucket's state (the shape most recently served).
    @property
    def _stage_peaks(self) -> list[int] | None:
        return self._active_state().peaks

    @_stage_peaks.setter
    def _stage_peaks(self, value: list[int] | None) -> None:
        self._active_state().peaks = value

    @property
    def _stage_ema(self) -> list[float] | None:
        return self._active_state().ema

    @_stage_ema.setter
    def _stage_ema(self, value: list[float] | None) -> None:
        self._active_state().ema = value

    def _make_strategy(self, clf: LearClassifier) -> Callable[..., jax.Array]:
        # NOTE: the strategy is traced into the cached jitted cascade step,
        # so ``self.threshold`` is baked in at trace time — construct a new
        # service (or clear the cascade's step cache) to change it.
        def strategy(partial, mask, features=None):
            aug = augment_features(features, partial, mask)
            return clf.continue_mask(
                aug, mask, self.threshold, use_kernel=self.use_kernel_classifier
            )

        return strategy

    def _cold_start_estimate(self, n_docs: int) -> int:
        # Cold start: assume a 40% survivor rate at EVERY stage
        # (conservative — survivors only shrink; undersizing a later
        # stage on batch 1 would cause real overflow).
        return int(0.4 * n_docs * self.headroom)

    def _pick_capacities(self, n_docs: int) -> list[int]:
        """Per-stage compaction capacities with p99-style headroom.

        Reads the ACTIVE batch-shape bucket's survivor peaks — each padded
        ``(Q, D)`` shape ratchets its own capacities. Each stage gets its
        own bucket sized from the RUNNING MAX of its
        observed survivor counts times ``headroom``, and never below the
        cold-start estimate — one sparse batch must not shrink the bucket
        under the traffic the service has already seen (that would silently
        overflow the next normal batch). Each stage gets its own bucket
        (survivor sets shrink stage over stage; sizing every stage off the
        last one would report phantom overflow at the early stages), and
        buckets are powers of two to bound re-jits. When a stage still
        overflows (survivors were clipped at the old bucket), the observed
        peak equals the old capacity, so ``peak × headroom`` rounds up to
        the next bucket — capacity ratchets up until overflow stops.
        """
        cold = self._cold_start_estimate(n_docs)
        if self._stage_peaks is None:
            want = [cold] * len(self.sentinels)
        else:
            want = [
                max(cold, int(peak * self.headroom))
                for peak in self._stage_peaks
            ]
        return [bucket_capacity(w, n_docs) for w in want]

    def _pick_mode(
        self, n_docs: int, capacities: Sequence[int] | None = None
    ) -> str:
        """Host-side REFERENCE pick: fused head vs per-stage tails.

        Serving no longer calls this per batch — with
        ``execution_mode="auto"`` the same decision happens on device
        inside the compiled step (``lax.cond`` on
        :func:`repro.metrics.speedup.progressive_cost_model_device`). This
        method remains the host mirror of that pick, used by tests to
        assert the two agree and by operators for introspection.

        Until the first batch lands there are no observed rates — default
        fused (1 segmented + ≤1 tail launch is the safe floor). After
        that, price both modes with the cost model on the smoothed
        survivor counts — staged stage work at block-rounded survivors
        clipped at capacity (``block_b=ENGINE_BLOCK_B``, matching the
        in-program pick) — and take the cheaper.
        """
        if self.execution_mode != "auto":
            return self.execution_mode
        if self._stage_ema is None or len(self.sentinels) == 1:
            return "fused"
        if capacities is None:
            capacities = self._pick_capacities(n_docs)
        T = self.ensemble.n_trees
        cost = {
            m: progressive_cost_model(
                n_docs, self._stage_ema, self.sentinels, T, m,
                launch_overhead_trees=self.launch_overhead_trees,
                stage_capacities=capacities,
                block_b=ENGINE_BLOCK_B,
                query_exit_rate=self._query_exit_rate_estimate(),
            )
            for m in ("fused", "staged")
        }
        return "staged" if cost["staged"] < cost["fused"] else "fused"

    def _query_exit_rate_estimate(self) -> float:
        """Smoothed tail-skip probability for the ACTIVE bucket.

        0.0 while query exit is off (no discount) or before the bucket's
        first batch (cold start must not assume the tail gets skipped).
        """
        if self.query_exit is None:
            return 0.0
        return self._active_state().tail_skip or 0.0

    def rank_batch(
        self,
        X: jax.Array,
        mask: jax.Array,
        placement: ServePlacement | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """X: [Q, D, F]; returns (top-k doc indices [Q, k], scores [Q, D]).

        Device-resident end to end: the step is submitted with everything
        it needs (with ``execution_mode="auto"``, also this bucket's
        survivor EMA as a tiny f32 operand for the in-program mode pick),
        and the ONLY device→host transfer is the single fused
        ``jax.device_get`` at the end — response and stats together.

        Adaptation (survivor peaks → capacities, EMA → mode pick) is keyed
        by the padded batch shape ``(Q, D)`` — each serving bucket adapts
        to its own traffic.

        ``placement`` (a :class:`repro.serve.placement.ServePlacement`, or
        anything with ``.put(X, mask)``) pins the operands to a device
        mesh before submit; ``None`` is the single-device fast path and
        is bit-exact with any 1-device placement.
        """
        if placement is not None:
            X, mask = placement.put(X, mask)
        Q, D, _ = X.shape
        self._active_key = (Q, D)
        n_docs = Q * D
        capacities = self._pick_capacities(n_docs)
        mode = self.execution_mode
        extra = {}
        if mode == "auto":
            if len(self.sentinels) == 1:
                mode = "fused"  # S=1: both modes are the same computation
            else:
                # Ship the survivor estimate at submit; the pick happens
                # inside the compiled step. Cold start (no observed rates
                # yet): have_ema=False forces the fused branch.
                S = len(self.sentinels)
                ema = self._stage_ema or [float(n_docs)] * S
                extra = dict(
                    stage_ema=jnp.asarray(ema, jnp.float32),
                    have_ema=self._stage_ema is not None,
                    launch_overhead_trees=self.launch_overhead_trees,
                    query_exit_rate=jnp.asarray(
                        self._query_exit_rate_estimate(), jnp.float32
                    ),
                )
        result = self.cascade.rank_progressive(
            X, mask,
            sentinels=self.sentinels,
            capacities=capacities,
            strategies=self.stage_strategies,
            classifier_trees=[c.n_trees for c in self.stage_classifiers],
            mode=mode,
            query_exit=self.query_exit,
            features=X,
            **extra,
        )
        # Top-k is the response (clamped to the candidate count — a small
        # query block must not crash top_k).
        masked = jnp.where(mask, result.scores, -jnp.inf)
        top_idx = jax.lax.top_k(masked, min(self.top_k, D))[1]

        # ONE fused device read: the response (top-k + scores) AND the
        # stats (per-stage survivors, cost metric, overflow, doc count,
        # picked branch) — no other host sync anywhere on this path.
        T = self.ensemble.n_trees
        clf_trees = [c.n_trees for c in self.stage_classifiers]
        picked_staged = (
            result.picked_staged
            if result.picked_staged is not None
            else mode == "staged"
        )
        q_exited = (
            result.query_exited.sum()
            if result.query_exited is not None
            else jnp.int32(0)
        )
        (top_idx, scores, survivors, traversed, overflow, batch_docs,
         picked_staged, q_exited) = jax.device_get((
            top_idx,
            result.scores,
            jnp.stack([m.sum() for m in result.stage_masks]),
            trees_traversed_progressive(
                mask, result.stage_masks, self.sentinels, T, clf_trees
            ),
            result.overflow,
            mask.sum(),
            picked_staged,
            q_exited,
        ))
        # Adapt: running max sizes the buckets, the EMA feeds the cost
        # model. Peaks and EMA seed independently — warmup pre-seeds peaks
        # (the no-overflow guarantee) but leaves the EMA to real traffic.
        a = self.survivor_ema
        state = self._active_state()
        if state.peaks is None:
            state.peaks = [int(n) for n in survivors]
        else:
            state.peaks = [
                max(p, int(n)) for p, n in zip(state.peaks, survivors)
            ]
        if state.ema is None:
            state.ema = [float(n) for n in survivors]
        else:
            state.ema = [
                (1 - a) * e + a * float(n)
                for e, n in zip(state.ema, survivors)
            ]
        if self.query_exit is not None:
            # Zero final-stage survivors ⟺ the gated tail launch was
            # skipped this batch; its smoothed rate is what the cost
            # model discounts the tail launch term by next submit.
            skipped = 1.0 if int(survivors[-1]) == 0 else 0.0
            if state.tail_skip is None:
                state.tail_skip = skipped
            else:
                state.tail_skip = (1 - a) * state.tail_skip + a * skipped

        s = self.stats
        s.batches += 1
        s.batches_staged += bool(picked_staged)
        s.batches_fused += not bool(picked_staged)
        s.queries += Q
        s.docs += int(batch_docs)
        s.docs_continued += int(survivors[-1])
        s.overflow_docs += int(overflow)
        s.queries_exited += int(q_exited)
        s.trees_traversed += float(traversed)
        s.trees_full_equiv += int(batch_docs) * T

        return top_idx, scores


@dataclasses.dataclass
class TwoStageCascade:
    """Beyond-paper: LEAR-style cascade over arbitrary scorers.

    ``sentinel_fn`` cheaply scores all candidates; a learned (or threshold)
    filter keeps the promising ones; ``full_fn`` scores the survivors. Used
    for recsys ``retrieval_cand`` in examples/cascade_retrieval.py.
    """

    sentinel_fn: Callable[[jax.Array], jax.Array]   # ids -> cheap scores
    full_fn: Callable[[jax.Array], jax.Array]       # ids -> full scores
    keep_fraction: float = 0.05

    def score(
        self, cand_ids: jax.Array
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        cheap = self.sentinel_fn(cand_ids)
        C = cand_ids.shape[0]
        keep = max(1, int(C * self.keep_fraction))
        top_vals, top_idx = jax.lax.top_k(cheap, keep)
        survivors = cand_ids[top_idx]
        full = self.full_fn(survivors)
        return survivors, full, cheap
