"""Batched ranking service with the LEAR cascade as a first-class feature.

The serving path the paper targets: a query arrives with its candidate
documents (already feature-extracted); the service scores them through the
λ-MART ensemble with document-level early exit and returns the top-k.

Production concerns handled here:
- request batching into fixed-size padded blocks (jit-stable shapes);
- the multi-sentinel progressive engine
  (:meth:`repro.core.cascade.CascadeRanker.rank_progressive`): ONE
  sentinel-segmented Pallas launch scores the head, stage decisions are
  vector work, one tail launch runs on the cumsum-compacted survivors —
  all three forests in the path (ranker head, LEAR classifier, ranker
  tail) go through the same Pallas kernel;
- compaction capacity chosen from observed continue rates (p99 headroom),
  bucketed to powers of two so re-jits stay bounded;
- cost accounting per batch (trees traversed, the paper's own metric) and
  service-level stats — overflow is surfaced from a lazy device scalar so
  the ranking hot path never blocks on it;
- graceful degradation: if survivors exceed capacity, the overflow
  documents keep their sentinel scores (bounded quality loss, never a
  crash) and the stats record it.

The same class serves the beyond-paper cascade for recsys retrieval
(sentinel scorer = any cheap model, full scorer = any expensive model) via
the ``sentinel_fn`` / ``full_fn`` hooks — see examples/cascade_retrieval.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.lear import LearClassifier, augment_features
from repro.forest.ensemble import TreeEnsemble
from repro.metrics.speedup import trees_traversed_progressive


@dataclasses.dataclass
class ServiceStats:
    batches: int = 0
    queries: int = 0
    docs: int = 0
    docs_continued: int = 0
    overflow_docs: int = 0
    trees_traversed: float = 0.0
    trees_full_equiv: float = 0.0

    @property
    def speedup(self) -> float:
        return self.trees_full_equiv / max(self.trees_traversed, 1.0)

    @property
    def continue_rate(self) -> float:
        return self.docs_continued / max(self.docs, 1)


class RankingService:
    """LEAR-cascade ranking over padded [Q, D, F] request blocks.

    ``extra_classifiers`` turn the service into a multi-sentinel cascade:
    stages are ordered by sentinel and each stage's classifier gates the
    survivors of the previous one (nested exit masks). With none, the
    service is the paper's single-sentinel cascade served through the same
    progressive engine (a sentinel list of length 1).
    """

    def __init__(
        self,
        ensemble: TreeEnsemble,
        classifier: LearClassifier,
        threshold: float = 0.5,
        capacity_headroom: float = 1.25,
        top_k: int = 10,
        extra_classifiers: Sequence[LearClassifier] = (),
        use_kernel_classifier: bool = True,
    ):
        self.ensemble = ensemble
        self.classifier = classifier
        self.threshold = threshold
        self.headroom = capacity_headroom
        self.top_k = top_k
        self.use_kernel_classifier = use_kernel_classifier
        self.stats = ServiceStats()
        self._stage_buckets: list[int] | None = None  # per-stage survivor est.

        stages = sorted([classifier, *extra_classifiers], key=lambda c: c.sentinel)
        self.stage_classifiers = stages
        self.sentinels = tuple(c.sentinel for c in stages)
        assert len(set(self.sentinels)) == len(stages), (
            "stage sentinels must be distinct", self.sentinels
        )
        self.stage_strategies = [self._make_strategy(c) for c in stages]

        self.cascade = CascadeRanker(
            ensemble=ensemble,
            sentinel=stages[0].sentinel,
            strategy=self.stage_strategies[0],
            classifier_trees=stages[0].n_trees,
        )

    def _make_strategy(self, clf: LearClassifier) -> Callable[..., jax.Array]:
        def strategy(partial, mask, features=None):
            aug = augment_features(features, partial, mask)
            return clf.continue_mask(
                aug, mask, self.threshold, use_kernel=self.use_kernel_classifier
            )

        return strategy

    def _pick_capacities(self, n_docs: int) -> list[int]:
        """Per-stage compaction capacities from observed survivor counts.

        Each stage gets its own bucket (survivor sets shrink stage over
        stage; sizing every stage off the last one would report phantom
        overflow at the early stages). Buckets are powers of two to bound
        re-jits.
        """
        if self._stage_buckets is None:
            # Cold start: assume a 40% survivor rate at EVERY stage
            # (conservative — survivors only shrink; undersizing a later
            # stage on batch 1 would cause real overflow).
            want = [int(0.4 * n_docs * self.headroom)] * len(self.sentinels)
        else:
            want = self._stage_buckets
        return [bucket_capacity(w, n_docs) for w in want]

    def rank_batch(self, X: jax.Array, mask: jax.Array):
        """X: [Q, D, F]; returns (top-k doc indices [Q, k], scores [Q, D])."""
        Q, D, _ = X.shape
        n_docs = Q * D
        capacities = self._pick_capacities(n_docs)
        result = self.cascade.rank_progressive(
            X, mask,
            sentinels=self.sentinels,
            capacities=capacities,
            strategies=self.stage_strategies,
            classifier_trees=[c.n_trees for c in self.stage_classifiers],
            features=X,
        )
        # Top-k is the response; everything below is the stats path.
        masked = jnp.where(mask, result.scores, -jnp.inf)
        top_idx = jax.lax.top_k(masked, self.top_k)[1]

        # Stats path: one fused device read for the per-stage survivor
        # counts, the cost metric, and the overflow scalar.
        T = self.ensemble.n_trees
        clf_trees = [c.n_trees for c in self.stage_classifiers]
        survivors, traversed, overflow = jax.device_get((
            jnp.stack([m.sum() for m in result.stage_masks]),
            trees_traversed_progressive(
                mask, result.stage_masks, self.sentinels, T, clf_trees
            ),
            result.overflow,
        ))
        # Adapt each stage's capacity bucket to its observed survivor count.
        self._stage_buckets = [int(n * self.headroom) for n in survivors]

        s = self.stats
        s.batches += 1
        s.queries += Q
        s.docs += int(mask.sum())
        s.docs_continued += int(survivors[-1])
        s.overflow_docs += int(overflow)
        s.trees_traversed += float(traversed)
        s.trees_full_equiv += int(mask.sum()) * T

        return np.asarray(top_idx), np.asarray(result.scores)


@dataclasses.dataclass
class TwoStageCascade:
    """Beyond-paper: LEAR-style cascade over arbitrary scorers.

    ``sentinel_fn`` cheaply scores all candidates; a learned (or threshold)
    filter keeps the promising ones; ``full_fn`` scores the survivors. Used
    for recsys ``retrieval_cand`` in examples/cascade_retrieval.py.
    """

    sentinel_fn: Callable[[jax.Array], jax.Array]   # ids -> cheap scores
    full_fn: Callable[[jax.Array], jax.Array]       # ids -> full scores
    keep_fraction: float = 0.05

    def score(self, cand_ids: jax.Array):
        cheap = self.sentinel_fn(cand_ids)
        C = cand_ids.shape[0]
        keep = max(1, int(C * self.keep_fraction))
        top_vals, top_idx = jax.lax.top_k(cheap, keep)
        survivors = cand_ids[top_idx]
        full = self.full_fn(survivors)
        return survivors, full, cheap
