"""Batched ranking service with the LEAR cascade as a first-class feature.

The serving path the paper targets: a query arrives with its candidate
documents (already feature-extracted); the service scores them through the
λ-MART ensemble with document-level early exit and returns the top-k.

Production concerns handled here:
- request batching into fixed-size padded blocks (jit-stable shapes);
- the multi-sentinel progressive engine
  (:meth:`repro.core.cascade.CascadeRanker.rank_progressive`), end-to-end
  jitted — all three forests in the path (ranker head, LEAR classifier,
  ranker tail) go through the same Pallas kernel inside ONE XLA
  computation per batch, and the LEAR augmented features (sort-free
  per-query rank, min/max segment reductions — :mod:`repro.core.features`)
  are built on device between the head launch and the classifier launch;
- adaptive execution mode, picked ON DEVICE: the compiled step contains
  both the fused segmented head and the per-stage-tail branch under a
  ``lax.cond``, and
  :func:`repro.metrics.speedup.progressive_cost_model_device` prices them
  from the smoothed survivor counts (shipped as a tiny operand at submit
  time) — no host round trip and no batch-boundary decision lag; the
  host-side :func:`repro.metrics.speedup.progressive_cost_model` pick is
  kept as the reference the device pick must agree with
  (:meth:`RankingService._pick_mode`);
- a calibrated cost model: ``launch_overhead_trees="auto"`` (the default)
  measures dispatch latency at service startup
  (:func:`repro.serve.calibration.calibrate_launch_overhead_trees`,
  cached per process) instead of trusting a fixed constant;
- compaction capacity from a running per-stage survivor peak with
  headroom, never below the cold-start estimate, bucketed to powers of
  two so re-jits stay bounded;
- cost accounting per batch (trees traversed, the paper's own metric) and
  service-level stats — the ENTIRE host read (top-k response, scores,
  per-stage survivors, cost, overflow, batch doc count, picked branch) is
  ONE fused ``jax.device_get``: between batch submit and that read the
  hot path performs zero device→host transfers (guarded by
  :func:`repro.utils.count_host_transfers` in the tests);
- graceful degradation: if survivors exceed capacity, the overflow
  documents keep their sentinel scores (bounded quality loss, never a
  crash) and the stats record it.

The same class serves the beyond-paper cascade for recsys retrieval
(sentinel scorer = any cheap model, full scorer = any expensive model) via
the ``sentinel_fn`` / ``full_fn`` hooks — see examples/cascade_retrieval.py.
"""

from __future__ import annotations

import dataclasses
import functools
import typing
import warnings
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp

if typing.TYPE_CHECKING:  # annotation-only: avoids a serve-package cycle
    import numpy as np

    from repro.serve.degradation import ExitRung
    from repro.serve.placement import ServePlacement

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.lear import LearClassifier, augment_features
from repro.core.stage import DenseStage, EngineConfig, TreeStage
from repro.core.strategies import QueryExitConfig, dense_keep_fraction
from repro.forest.ensemble import TreeEnsemble
from repro.kernels.ops import ENGINE_BLOCK_B
from repro.metrics.speedup import (
    progressive_cost_model,
    trees_traversed_progressive,
)
from repro.serve.calibration import calibrate_launch_overhead_trees

_DEPRECATED_SERVICE_MSG = (
    "repro.serve.ranking_service.RankingService: keyword configuration "
    "(threshold=…, execution_mode=…, …) is deprecated; pass a "
    "ServiceConfig as the third argument. The shim builds the equivalent "
    "config and will be removed in a future release."
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen bundle of every :class:`RankingService` tuning knob.

    The serving mirror of :class:`repro.core.stage.EngineConfig`: one
    hashable value instead of nine constructor keywords. The model inputs
    (ensemble, classifiers) stay direct constructor arguments — they are
    the *data* being served, not its configuration.

    ``dense_stage`` (a :class:`repro.core.stage.DenseStage`) turns the
    service into the HYBRID cascade: the dense gate becomes stage 0 of
    every compiled step, survivor adaptation (peaks/EMA/capacities) grows
    a leading dense entry, and accounting charges ``dense.cost_trees``
    per candidate instead of tree traversals for dense-exited documents.
    Set ``dense_stage.capacity`` to pin the dense survivor block;
    ``None`` lets the per-bucket ratchet manage it like any tree stage.
    """

    threshold: float = 0.5
    capacity_headroom: float = 1.25
    top_k: int = 10
    use_kernel_classifier: bool = True
    execution_mode: str = "auto"
    launch_overhead_trees: float | str = "auto"
    survivor_ema: float = 0.3
    query_exit: QueryExitConfig | None = None
    dense_stage: DenseStage | None = None

    def __post_init__(self) -> None:
        assert self.execution_mode in ("auto", "fused", "staged"), (
            self.execution_mode
        )
        # The capacity ratchet needs strictly-positive headroom: in staged
        # mode observed survivor peaks are clipped AT the current bucket (a
        # power of two), so only peak × headroom > bucket can round up to
        # the next bucket — with headroom <= 1 capacity would never grow
        # and an undersized stage would silently overflow forever.
        assert self.capacity_headroom > 1.0, self.capacity_headroom
        assert self.top_k >= 1, self.top_k
        assert 0.0 < self.survivor_ema <= 1.0, self.survivor_ema
        assert self.query_exit is None or isinstance(
            self.query_exit, QueryExitConfig
        )
        assert self.dense_stage is None or isinstance(
            self.dense_stage, DenseStage
        )


@dataclasses.dataclass
class _BucketAdaptState:
    """Adaptive state for ONE padded batch shape ``(Q, D)``.

    The serving tier packs traffic into power-of-two capacity buckets
    (:mod:`repro.serve.batching`); survivor behavior is a function of the
    batch shape (D bounds the survivor count, Q·D scales the head work), so
    both adaptation signals live per bucket: the running survivor ``peaks``
    drive that bucket's compaction-capacity ratchet and the smoothed
    survivor ``ema`` feeds that bucket's fused-vs-staged pick. A sparse
    Q=1 trickle must not shrink (or mis-mode) the Q=64 bulk bucket.
    """

    peaks: list[int] | None = None  # running max survivors per stage
    ema: list[float] | None = None  # smoothed survivors per stage
    tail_skip: float | None = None  # smoothed P(batch skipped the gated
    #   tail launch) — feeds the cost model's query_exit_rate discount


@dataclasses.dataclass(frozen=True)
class _RungState:
    """One installed degradation rung, fully materialized.

    Everything a rung changes is pre-built at install time — strategy
    closures with the rung's threshold baked in, a rung-specific
    :class:`DenseStage` when the dense keep fraction changes — so
    :meth:`RankingService.set_rung` is a pure pointer swap: the same
    closure objects every time (they hash by identity) means every rung
    maps to ONE stable :class:`EngineConfig` and therefore ONE compiled
    step, warmed once by :func:`repro.serve.warmup.warmup_service`.
    """

    name: str
    threshold: float
    strategies: tuple[Callable[..., jax.Array], ...]
    query_exit: QueryExitConfig | None
    dense_stage: DenseStage | None


@dataclasses.dataclass
class ServiceStats:
    batches: int = 0
    queries: int = 0
    docs: int = 0
    docs_continued: int = 0
    overflow_docs: int = 0
    trees_traversed: float = 0.0
    trees_full_equiv: float = 0.0
    batches_fused: int = 0
    batches_staged: int = 0
    queries_exited: int = 0  # query-level exit fired (query_exit enabled)

    @property
    def speedup(self) -> float:
        return self.trees_full_equiv / max(self.trees_traversed, 1.0)

    @property
    def continue_rate(self) -> float:
        return self.docs_continued / max(self.docs, 1)

    @property
    def query_exit_rate(self) -> float:
        return self.queries_exited / max(self.queries, 1)


class RankingService:
    """LEAR-cascade ranking over padded [Q, D, F] request blocks.

    ``extra_classifiers`` turn the service into a multi-sentinel cascade:
    stages are ordered by sentinel and each stage's classifier gates the
    survivors of the previous one (nested exit masks). With none, the
    service is the paper's single-sentinel cascade served through the same
    progressive engine (a sentinel list of length 1).
    """

    def __init__(
        self,
        ensemble: TreeEnsemble,
        classifier: LearClassifier,
        config: ServiceConfig | None = None,
        extra_classifiers: Sequence[LearClassifier] = (),
        *,
        threshold: float | None = None,
        capacity_headroom: float | None = None,
        top_k: int | None = None,
        use_kernel_classifier: bool | None = None,
        execution_mode: str | None = None,
        launch_overhead_trees: float | str | None = None,
        survivor_ema: float | None = None,
        query_exit: QueryExitConfig | None = None,
    ) -> None:
        if config is not None and not isinstance(config, ServiceConfig):
            # Legacy POSITIONAL call: RankingService(ens, clf, 0.3, …)
            assert threshold is None, (config, threshold)
            config, threshold = None, float(config)
        legacy = {
            name: value
            for name, value in (
                ("threshold", threshold),
                ("capacity_headroom", capacity_headroom),
                ("top_k", top_k),
                ("use_kernel_classifier", use_kernel_classifier),
                ("execution_mode", execution_mode),
                ("launch_overhead_trees", launch_overhead_trees),
                ("survivor_ema", survivor_ema),
                ("query_exit", query_exit),
            )
            if value is not None
        }
        if config is None:
            if legacy:
                warnings.warn(
                    _DEPRECATED_SERVICE_MSG, DeprecationWarning, stacklevel=2
                )
            config = ServiceConfig(**legacy)
        elif legacy:
            raise TypeError(
                "RankingService: pass configuration via ServiceConfig OR "
                f"the deprecated keywords, not both (got {sorted(legacy)})"
            )
        self.config = config
        self.ensemble = ensemble
        self.classifier = classifier
        self.threshold = config.threshold
        self.headroom = config.capacity_headroom
        self.top_k = config.top_k
        self.use_kernel_classifier = config.use_kernel_classifier
        self.execution_mode = config.execution_mode
        # Price of one extra kernel launch + gather/scatter HBM round trip,
        # in doc·tree equivalents — the cost model's only tunable. "auto"
        # measures it at startup (short timing probe, cached per process)
        # instead of trusting a machine-independent constant.
        loh = config.launch_overhead_trees
        if loh == "auto":
            loh = calibrate_launch_overhead_trees()
        self.launch_overhead_trees = float(loh)
        self.survivor_ema = config.survivor_ema
        # Query-level exit config (None = document-level LEAR only). Part
        # of the compiled step's static key; the per-bucket tail-skip EMA
        # it produces feeds the auto-mode cost model as a traced operand.
        self.query_exit = config.query_exit
        self.dense_stage = config.dense_stage
        self.stats = ServiceStats()
        # Adaptive state is PER padded batch shape (capacity bucket): each
        # (Q, D) the service has seen owns its survivor peaks and EMA.
        # ``_active_key`` is the bucket of the most recent rank_batch —
        # the introspection surface (_stage_peaks/_stage_ema properties,
        # _pick_capacities, _pick_mode) reads through it.
        self._adapt: dict[tuple[int, int] | None, _BucketAdaptState] = {}
        self._active_key: tuple[int, int] | None = None

        stages = sorted([classifier, *extra_classifiers], key=lambda c: c.sentinel)
        self.stage_classifiers = stages
        self.sentinels = tuple(c.sentinel for c in stages)
        assert len(set(self.sentinels)) == len(stages), (
            "stage sentinels must be distinct", self.sentinels
        )
        self.stage_strategies = [self._make_strategy(c) for c in stages]

        # Stage tuples are cached per (strategy identities, dense stage)
        # (see _engine_stage_tuple); the accounting view is fixed at
        # construction. For a hybrid service the dense gate is a
        # zero-sentinel stage charging cost_trees per candidate. The cache
        # is a dict so degradation rungs (each with its own closures and
        # possibly its own dense stage) keep their stage tuples — and
        # therefore their EngineConfig identity — stable across swaps.
        self._stages_cache: dict[tuple, tuple] = {}
        # Degradation rung ladder: None until install_rungs; level 0 is
        # always the baseline configuration.
        self._rungs: tuple[_RungState, ...] | None = None
        self._rung_level = 0
        if self.dense_stage is not None:
            self._acct_sentinels = (0, *self.sentinels)
            self._acct_classifier_trees = (
                float(self.dense_stage.cost_trees),
                *(float(c.n_trees) for c in stages),
            )
        else:
            self._acct_sentinels = self.sentinels
            self._acct_classifier_trees = tuple(
                float(c.n_trees) for c in stages
            )
        self.n_stages = len(self.sentinels) + (
            1 if self.dense_stage is not None else 0
        )

        self.cascade = CascadeRanker(
            ensemble=ensemble,
            sentinel=stages[0].sentinel,
            strategy=self.stage_strategies[0],
            classifier_trees=stages[0].n_trees,
        )

    def bucket_state(self, Q: int, D: int) -> _BucketAdaptState:
        """Adaptive state for batch shape ``(Q, D)``, created on first use.

        The warmup path (:func:`repro.serve.warmup.warmup_service`) seeds
        ``peaks`` here BEFORE the bucket's first trace so the compaction
        capacities are stable from batch 1 — one trace per bucket and no
        cold-start overflow.
        """
        return self._adapt.setdefault((Q, D), _BucketAdaptState())

    def _active_state(self) -> _BucketAdaptState:
        return self._adapt.setdefault(self._active_key, _BucketAdaptState())

    # Back-compat introspection surface: the pre-bucketing attributes now
    # read/write the ACTIVE bucket's state (the shape most recently served).
    @property
    def _stage_peaks(self) -> list[int] | None:
        return self._active_state().peaks

    @_stage_peaks.setter
    def _stage_peaks(self, value: list[int] | None) -> None:
        self._active_state().peaks = value

    @property
    def _stage_ema(self) -> list[float] | None:
        return self._active_state().ema

    @_stage_ema.setter
    def _stage_ema(self, value: list[float] | None) -> None:
        self._active_state().ema = value

    def _engine_stage_tuple(self) -> tuple:
        """The EngineConfig stage list, rebuilt only when the strategy
        callables (tests swap ``stage_strategies`` in place) or the dense
        stage (degradation rungs swap it) change.

        Caching on the strategy identities keeps the per-batch
        EngineConfigs structurally equal — the TreeStage objects (and the
        closures inside, which hash by identity) are the SAME objects
        every batch, so the engine's compiled-step cache stays hot. A
        dict (not a single slot) so rung switching under load revisits
        cached tuples instead of thrashing one entry.
        """
        key = (tuple(self.stage_strategies), self.dense_stage)
        stages = self._stages_cache.get(key)
        if stages is None:
            tree_stages = tuple(
                TreeStage(
                    sentinel=c.sentinel,
                    strategy=strat,
                    classifier_trees=float(c.n_trees),
                )
                for c, strat in zip(self.stage_classifiers, key[0])
            )
            stages = (
                (self.dense_stage, *tree_stages)
                if self.dense_stage is not None else tree_stages
            )
            self._stages_cache[key] = stages
        return stages

    def _make_strategy(
        self, clf: LearClassifier, threshold: float | None = None
    ) -> Callable[..., jax.Array]:
        # NOTE: the strategy is traced into the cached jitted cascade step,
        # so the threshold is baked in at trace time — ``None`` reads
        # ``self.threshold`` at trace time (the construction-time default);
        # degradation rungs pass their own explicit threshold and get their
        # own closure, hence their own compiled step.
        def strategy(partial, mask, features=None):
            aug = augment_features(features, partial, mask)
            th = self.threshold if threshold is None else threshold
            return clf.continue_mask(
                aug, mask, th, use_kernel=self.use_kernel_classifier
            )

        return strategy

    # -- degradation rungs -------------------------------------------------

    @property
    def n_rungs(self) -> int:
        """Installed rung count (baseline included); 0 = no ladder."""
        return len(self._rungs) if self._rungs is not None else 0

    @property
    def rung_level(self) -> int:
        return self._rung_level

    @property
    def rung_names(self) -> tuple[str, ...]:
        return tuple(r.name for r in self._rungs or ())

    def install_rungs(self, rungs: Sequence[ExitRung]) -> None:
        """Materialize the degradation ladder: level 0 is the CURRENT
        configuration (baseline), level ``i`` applies ``rungs[i-1]``'s
        overrides. Each rung's strategy closures (and dense stage, when
        ``dense_keep_frac`` is overridden) are built exactly once here, so
        :meth:`set_rung` swaps stable objects and every rung owns one
        compiled engine step. Install before warmup — the warmup pass
        AOT-compiles every installed rung per bucket."""
        assert self._rungs is None, "rungs already installed"
        assert self._rung_level == 0
        ladder = [_RungState(
            name="baseline",
            threshold=self.threshold,
            strategies=tuple(self.stage_strategies),
            query_exit=self.query_exit,
            dense_stage=self.dense_stage,
        )]
        for rung in rungs:
            th = rung.threshold if rung.threshold is not None else self.threshold
            if rung.threshold is None:
                strategies = ladder[0].strategies  # same closures, same step
            else:
                strategies = tuple(
                    self._make_strategy(c, th)
                    for c in self.stage_classifiers
                )
            dense = self.dense_stage
            if rung.dense_keep_frac is not None:
                assert dense is not None, (
                    "rung overrides dense_keep_frac but the service has "
                    "no dense stage", rung.name,
                )
                dense = dataclasses.replace(
                    dense,
                    policy=functools.partial(
                        dense_keep_fraction,
                        keep_frac=float(rung.dense_keep_frac),
                    ),
                )
            ladder.append(_RungState(
                name=rung.name,
                threshold=th,
                strategies=strategies,
                query_exit=(
                    rung.query_exit if rung.query_exit is not None
                    else self.query_exit
                ),
                dense_stage=dense,
            ))
        self._rungs = tuple(ladder)

    def set_rung(self, level: int) -> None:
        """Swap the active exit configuration to ``level`` of the installed
        ladder. Pointer swaps only — no tracing, no allocation. MUST be
        called from the thread that owns the engine (the batcher worker):
        the next ``rank_batch`` picks up the rung atomically."""
        assert self._rungs is not None, "install_rungs first"
        assert 0 <= level < len(self._rungs), (level, len(self._rungs))
        r = self._rungs[level]
        self._rung_level = level
        self.threshold = r.threshold
        self.stage_strategies = list(r.strategies)
        self.query_exit = r.query_exit
        self.dense_stage = r.dense_stage

    def _cold_start_estimate(self, n_docs: int) -> int:
        # Cold start: assume a 40% survivor rate at EVERY stage
        # (conservative — survivors only shrink; undersizing a later
        # stage on batch 1 would cause real overflow).
        return int(0.4 * n_docs * self.headroom)

    def _pick_capacities(self, n_docs: int) -> list[int]:
        """Per-stage compaction capacities with p99-style headroom.

        Reads the ACTIVE batch-shape bucket's survivor peaks — each padded
        ``(Q, D)`` shape ratchets its own capacities. Each stage gets its
        own bucket sized from the RUNNING MAX of its
        observed survivor counts times ``headroom``, and never below the
        cold-start estimate — one sparse batch must not shrink the bucket
        under the traffic the service has already seen (that would silently
        overflow the next normal batch). Each stage gets its own bucket
        (survivor sets shrink stage over stage; sizing every stage off the
        last one would report phantom overflow at the early stages), and
        buckets are powers of two to bound re-jits. When a stage still
        overflows (survivors were clipped at the old bucket), the observed
        peak equals the old capacity, so ``peak × headroom`` rounds up to
        the next bucket — capacity ratchets up until overflow stops.
        """
        cold = self._cold_start_estimate(n_docs)
        if self._stage_peaks is None:
            want = [cold] * self.n_stages
        else:
            want = [
                max(cold, int(peak * self.headroom))
                for peak in self._stage_peaks
            ]
        caps = [bucket_capacity(w, n_docs) for w in want]
        if (
            self.dense_stage is not None
            and self.dense_stage.capacity is not None
        ):
            # A pinned dense capacity overrides the ratchet (the engine's
            # stage.capacity precedence would anyway); mirroring it here
            # keeps the host cost model pricing the real block size.
            caps[0] = min(int(self.dense_stage.capacity), n_docs)
        return caps

    def _pick_mode(
        self, n_docs: int, capacities: Sequence[int] | None = None
    ) -> str:
        """Host-side REFERENCE pick: fused head vs per-stage tails.

        Serving no longer calls this per batch — with
        ``execution_mode="auto"`` the same decision happens on device
        inside the compiled step (``lax.cond`` on
        :func:`repro.metrics.speedup.progressive_cost_model_device`). This
        method remains the host mirror of that pick, used by tests to
        assert the two agree and by operators for introspection.

        Until the first batch lands there are no observed rates — default
        fused (1 segmented + ≤1 tail launch is the safe floor). After
        that, price both modes with the cost model on the smoothed
        survivor counts — staged stage work at block-rounded survivors
        clipped at capacity (``block_b=ENGINE_BLOCK_B``, matching the
        in-program pick) — and take the cheaper.
        """
        if self.execution_mode != "auto":
            return self.execution_mode
        if self._stage_ema is None or len(self.sentinels) == 1:
            return "fused"
        if capacities is None:
            capacities = self._pick_capacities(n_docs)
        T = self.ensemble.n_trees
        dense = self.dense_stage
        cost = {
            m: progressive_cost_model(
                n_docs, self._stage_ema, self.sentinels, T, m,
                launch_overhead_trees=self.launch_overhead_trees,
                stage_capacities=capacities,
                block_b=ENGINE_BLOCK_B,
                query_exit_rate=self._query_exit_rate_estimate(),
                dense_cost_trees=(
                    float(dense.cost_trees) if dense is not None else 0.0
                ),
                dense_stage=dense is not None,
            )
            for m in ("fused", "staged")
        }
        return "staged" if cost["staged"] < cost["fused"] else "fused"

    def _query_exit_rate_estimate(self) -> float:
        """Smoothed tail-skip probability for the ACTIVE bucket.

        0.0 while query exit is off (no discount) or before the bucket's
        first batch (cold start must not assume the tail gets skipped).
        """
        if self.query_exit is None:
            return 0.0
        return self._active_state().tail_skip or 0.0

    def rank_batch(
        self,
        X: jax.Array,
        mask: jax.Array,
        placement: ServePlacement | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """X: [Q, D, F]; returns (top-k doc indices [Q, k], scores [Q, D]).

        Device-resident end to end: the step is submitted with everything
        it needs (with ``execution_mode="auto"``, also this bucket's
        survivor EMA as a tiny f32 operand for the in-program mode pick),
        and the ONLY device→host transfer is the single fused
        ``jax.device_get`` at the end — response and stats together.

        Adaptation (survivor peaks → capacities, EMA → mode pick) is keyed
        by the padded batch shape ``(Q, D)`` — each serving bucket adapts
        to its own traffic.

        ``placement`` (a :class:`repro.serve.placement.ServePlacement`, or
        anything with ``.put(X, mask)``) pins the operands to a device
        mesh before submit; ``None`` is the single-device fast path and
        is bit-exact with any 1-device placement.
        """
        if placement is not None:
            X, mask = placement.put(X, mask)
        Q, D, _ = X.shape
        self._active_key = (Q, D)
        n_docs = Q * D
        capacities = self._pick_capacities(n_docs)
        mode = self.execution_mode
        extra = {}
        if mode == "auto":
            if len(self.sentinels) == 1:
                mode = "fused"  # S=1: both modes are the same computation
            else:
                # Ship the survivor estimate at submit; the pick happens
                # inside the compiled step. Cold start (no observed rates
                # yet): have_ema=False forces the fused branch. The EMA
                # covers ALL stages (dense entry first for hybrid).
                ema = self._stage_ema or [float(n_docs)] * self.n_stages
                extra = dict(
                    stage_ema=jnp.asarray(ema, jnp.float32),
                    have_ema=self._stage_ema is not None,
                    query_exit_rate=jnp.asarray(
                        self._query_exit_rate_estimate(), jnp.float32
                    ),
                )
        engine_config = EngineConfig(
            stages=self._engine_stage_tuple(),
            mode=mode,
            capacities=tuple(capacities),
            launch_overhead_trees=self.launch_overhead_trees,
            query_exit=self.query_exit,
        )
        result = self.cascade.rank_progressive(
            X, mask, engine_config, features=X, **extra,
        )
        # Top-k is the response (clamped to the candidate count — a small
        # query block must not crash top_k).
        masked = jnp.where(mask, result.scores, -jnp.inf)
        top_idx = jax.lax.top_k(masked, min(self.top_k, D))[1]

        # ONE fused device read: the response (top-k + scores) AND the
        # stats (per-stage survivors, cost metric, overflow, doc count,
        # picked branch) — no other host sync anywhere on this path.
        T = self.ensemble.n_trees
        picked_staged = (
            result.picked_staged
            if result.picked_staged is not None
            else mode == "staged"
        )
        q_exited = (
            result.query_exited.sum()
            if result.query_exited is not None
            else jnp.int32(0)
        )
        (top_idx, scores, survivors, traversed, overflow, batch_docs,
         picked_staged, q_exited) = jax.device_get((
            top_idx,
            result.scores,
            jnp.stack([m.sum() for m in result.stage_masks]),
            trees_traversed_progressive(
                mask, result.stage_masks, self._acct_sentinels, T,
                list(self._acct_classifier_trees),
            ),
            result.overflow,
            mask.sum(),
            picked_staged,
            q_exited,
        ))
        # Adapt: running max sizes the buckets, the EMA feeds the cost
        # model. Peaks and EMA seed independently — warmup pre-seeds peaks
        # (the no-overflow guarantee) but leaves the EMA to real traffic.
        a = self.survivor_ema
        state = self._active_state()
        if state.peaks is None:
            state.peaks = [int(n) for n in survivors]
        else:
            state.peaks = [
                max(p, int(n)) for p, n in zip(state.peaks, survivors)
            ]
        if state.ema is None:
            state.ema = [float(n) for n in survivors]
        else:
            state.ema = [
                (1 - a) * e + a * float(n)
                for e, n in zip(state.ema, survivors)
            ]
        if self.query_exit is not None:
            # Zero final-stage survivors ⟺ the gated tail launch was
            # skipped this batch; its smoothed rate is what the cost
            # model discounts the tail launch term by next submit.
            skipped = 1.0 if int(survivors[-1]) == 0 else 0.0
            if state.tail_skip is None:
                state.tail_skip = skipped
            else:
                state.tail_skip = (1 - a) * state.tail_skip + a * skipped

        s = self.stats
        s.batches += 1
        s.batches_staged += bool(picked_staged)
        s.batches_fused += not bool(picked_staged)
        s.queries += Q
        s.docs += int(batch_docs)
        s.docs_continued += int(survivors[-1])
        s.overflow_docs += int(overflow)
        s.queries_exited += int(q_exited)
        s.trees_traversed += float(traversed)
        s.trees_full_equiv += int(batch_docs) * T

        return top_idx, scores


@dataclasses.dataclass
class TwoStageCascade:
    """Beyond-paper: LEAR-style cascade over arbitrary scorers.

    ``sentinel_fn`` cheaply scores all candidates; a learned (or threshold)
    filter keeps the promising ones; ``full_fn`` scores the survivors. Used
    for recsys ``retrieval_cand`` in examples/cascade_retrieval.py.
    """

    sentinel_fn: Callable[[jax.Array], jax.Array]   # ids -> cheap scores
    full_fn: Callable[[jax.Array], jax.Array]       # ids -> full scores
    keep_fraction: float = 0.05

    def score(
        self, cand_ids: jax.Array
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        cheap = self.sentinel_fn(cand_ids)
        C = cand_ids.shape[0]
        keep = max(1, int(C * self.keep_fraction))
        top_vals, top_idx = jax.lax.top_k(cheap, keep)
        survivors = cand_ids[top_idx]
        full = self.full_fn(survivors)
        return survivors, full, cheap
