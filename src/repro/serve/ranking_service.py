"""Batched ranking service with the LEAR cascade as a first-class feature.

The serving path the paper targets: a query arrives with its candidate
documents (already feature-extracted); the service scores them through the
λ-MART ensemble with document-level early exit and returns the top-k.

Production concerns handled here:
- request batching into fixed-size padded blocks (jit-stable shapes);
- compaction capacity chosen from observed continue rates (p99 headroom),
  re-jitting only when the capacity bucket changes;
- cost accounting per batch (trees traversed, the paper's own metric) and
  service-level stats;
- graceful degradation: if survivors exceed capacity, the overflow
  documents keep their sentinel scores (bounded quality loss, never a
  crash) and the stats record it.

The same class serves the beyond-paper cascade for recsys retrieval
(sentinel scorer = any cheap model, full scorer = any expensive model) via
the ``sentinel_fn`` / ``full_fn`` hooks — see examples/cascade_retrieval.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeRanker
from repro.core.lear import LearClassifier, augment_features
from repro.forest.ensemble import TreeEnsemble


@dataclasses.dataclass
class ServiceStats:
    batches: int = 0
    queries: int = 0
    docs: int = 0
    docs_continued: int = 0
    overflow_docs: int = 0
    trees_traversed: float = 0.0
    trees_full_equiv: float = 0.0

    @property
    def speedup(self) -> float:
        return self.trees_full_equiv / max(self.trees_traversed, 1.0)

    @property
    def continue_rate(self) -> float:
        return self.docs_continued / max(self.docs, 1)


class RankingService:
    """LEAR-cascade ranking over padded [Q, D, F] request blocks."""

    def __init__(
        self,
        ensemble: TreeEnsemble,
        classifier: LearClassifier,
        threshold: float = 0.5,
        capacity_headroom: float = 1.25,
        top_k: int = 10,
    ):
        self.ensemble = ensemble
        self.classifier = classifier
        self.threshold = threshold
        self.headroom = capacity_headroom
        self.top_k = top_k
        self.stats = ServiceStats()
        self._capacity_bucket: int | None = None

        def strategy(partial, mask, features=None):
            aug = augment_features(features, partial, mask)
            return self.classifier.continue_mask(aug, mask, self.threshold)

        self.cascade = CascadeRanker(
            ensemble=ensemble,
            sentinel=classifier.sentinel,
            strategy=strategy,
            classifier_trees=classifier.n_trees,
        )

    def _pick_capacity(self, n_docs: int) -> int:
        if self._capacity_bucket is None:
            # Cold start: assume 40% continue rate.
            want = int(0.4 * n_docs * self.headroom)
        else:
            want = self._capacity_bucket
        # Bucket to powers of two to bound re-jits.
        cap = 1 << max(6, int(np.ceil(np.log2(max(want, 64)))))
        return min(cap, n_docs)

    def rank_batch(self, X: jax.Array, mask: jax.Array):
        """X: [Q, D, F]; returns (top-k doc indices [Q, k], scores [Q, D])."""
        Q, D, _ = X.shape
        n_docs = Q * D
        capacity = self._pick_capacity(n_docs)
        result = self.cascade.rank_compacted(
            X, mask, capacity=capacity, features=X
        )
        n_cont = int(result.continue_mask.sum())
        # Adapt the capacity bucket to the observed continue rate.
        self._capacity_bucket = int(n_cont * self.headroom)

        s = self.stats
        s.batches += 1
        s.queries += Q
        s.docs += int(mask.sum())
        s.docs_continued += n_cont
        s.overflow_docs += result.overflow
        sentinel, T = self.classifier.sentinel, self.ensemble.n_trees
        s.trees_traversed += (
            int(mask.sum()) * (sentinel + self.classifier.n_trees)
            + n_cont * (T - sentinel)
        )
        s.trees_full_equiv += int(mask.sum()) * T

        masked = jnp.where(mask, result.scores, -jnp.inf)
        top_idx = jax.lax.top_k(masked, self.top_k)[1]
        return np.asarray(top_idx), np.asarray(result.scores)


@dataclasses.dataclass
class TwoStageCascade:
    """Beyond-paper: LEAR-style cascade over arbitrary scorers.

    ``sentinel_fn`` cheaply scores all candidates; a learned (or threshold)
    filter keeps the promising ones; ``full_fn`` scores the survivors. Used
    for recsys ``retrieval_cand`` in examples/cascade_retrieval.py.
    """

    sentinel_fn: Callable[[jax.Array], jax.Array]   # ids -> cheap scores
    full_fn: Callable[[jax.Array], jax.Array]       # ids -> full scores
    keep_fraction: float = 0.05

    def score(self, cand_ids: jax.Array):
        cheap = self.sentinel_fn(cand_ids)
        C = cand_ids.shape[0]
        keep = max(1, int(C * self.keep_fraction))
        top_vals, top_idx = jax.lax.top_k(cheap, keep)
        survivors = cand_ids[top_idx]
        full = self.full_fn(survivors)
        return survivors, full, cheap
