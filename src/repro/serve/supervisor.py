"""Worker supervision: detect thread death, restart with bounded backoff.

The continuous batcher's single worker thread owns every engine call; if
that thread dies, an unsupervised tier silently stops serving — submits
keep queueing, futures never resolve, and nothing tells the operator.
:class:`WorkerSupervisor` closes that hole:

- The worker body (``target``) runs inside a **guard thread** that treats
  a normal return as a clean exit and any exception as a crash.
- On crash the supervisor invokes ``on_crash(exc)`` (the batcher uses
  this to fail every in-flight future with a typed
  :class:`repro.serve.errors.WorkerCrashed` — no request ever hangs),
  then restarts the worker after an exponential backoff
  (``backoff_base_s · 2^k``, capped at ``backoff_max_s``) so a
  crash-looping engine cannot spin the CPU.
- After ``max_restarts`` consecutive crashes the supervisor gives up:
  state becomes ``"failed"``, ``on_failed(exc)`` fires, and pending work
  is failed by the owner rather than waiting forever.
- A successful run (the worker staying alive until clean stop) does not
  reset the restart counter — the budget bounds total flapping per
  supervisor lifetime, which is what an operator reasons about.

Backoff sleeps go through the injectable :class:`repro.serve.clock.Clock`
and are interruptible: ``stop()`` wakes a sleeping supervisor immediately.

``health()`` returns a :class:`SupervisorHealth` snapshot; the tier folds
it into :meth:`repro.serve.tier.ServingTier.health`.
"""

from __future__ import annotations

import dataclasses
import threading
from collections.abc import Callable

from repro.serve.clock import SYSTEM_CLOCK, Clock

STATE_NEW = "new"
STATE_RUNNING = "running"
STATE_BACKOFF = "backoff"
STATE_STOPPED = "stopped"
STATE_FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class SupervisorHealth:
    """Point-in-time snapshot of the supervised worker."""

    state: str
    restarts: int
    crashes: int
    last_error: str | None

    @property
    def healthy(self) -> bool:
        return self.state in (STATE_NEW, STATE_RUNNING)


class WorkerSupervisor:
    """Runs ``target`` in a guarded thread, restarting it on crashes.

    Lifecycle: ``start()`` → worker runs (restarting on crash with
    backoff) → ``stop()`` (joins the guard thread; a clean ``target``
    return while stopping is the normal shutdown path). ``target`` must
    exit promptly once the owner's own stop flag is set — the supervisor
    never interrupts a running worker, it only decides what happens after
    the worker returns or raises.
    """

    def __init__(
        self,
        target: Callable[[], None],
        *,
        name: str = "repro-worker",
        backoff_base_s: float = 0.05,
        backoff_max_s: float = 2.0,
        max_restarts: int = 5,
        clock: Clock | None = None,
        on_crash: Callable[[BaseException], None] | None = None,
        on_failed: Callable[[BaseException], None] | None = None,
    ) -> None:
        assert backoff_base_s > 0.0 and backoff_max_s >= backoff_base_s
        assert max_restarts >= 0
        self._target = target
        self._name = name
        self._backoff_base_s = float(backoff_base_s)
        self._backoff_max_s = float(backoff_max_s)
        self._max_restarts = int(max_restarts)
        self._clock = clock or SYSTEM_CLOCK
        self._on_crash = on_crash
        self._on_failed = on_failed
        self._cond = threading.Condition()
        self._state = STATE_NEW
        self._restarts = 0
        self._crashes = 0
        self._last_error: BaseException | None = None
        self._running = False
        self._guard: threading.Thread | None = None

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        with self._cond:
            assert self._guard is None, "supervisor already started"
            self._running = True
            self._state = STATE_RUNNING
        self._guard = threading.Thread(
            target=self._guard_loop, name=f"{self._name}-guard", daemon=True
        )
        self._guard.start()

    def stop(self) -> None:
        """Stop supervising and join the guard thread. The owner must have
        already told the worker body itself to exit (its own stop flag +
        notify) — this only stops the restart machinery."""
        with self._cond:
            if self._guard is None:
                return
            self._running = False
            self._cond.notify_all()  # wake a backoff sleeper
        self._guard.join()
        self._guard = None

    # -- introspection ----------------------------------------------------

    def health(self) -> SupervisorHealth:
        with self._cond:
            return SupervisorHealth(
                state=self._state,
                restarts=self._restarts,
                crashes=self._crashes,
                last_error=(
                    repr(self._last_error)
                    if self._last_error is not None else None
                ),
            )

    @property
    def state(self) -> str:
        with self._cond:
            return self._state

    # -- guard thread -----------------------------------------------------

    def _guard_loop(self) -> None:
        while True:
            exc: BaseException | None = None
            try:
                self._target()
            # repro: noqa(TS007) -- the supervisor IS the catch-all: any
            # worker escape must become a supervised crash, not a leak.
            except BaseException as e:
                exc = e
            with self._cond:
                if exc is None or not self._running:
                    # Clean worker return, or a crash during shutdown —
                    # either way supervision ends here.
                    self._state = STATE_STOPPED
                    if exc is not None:
                        self._crashes += 1
                        self._last_error = exc
                    return
                self._crashes += 1
                self._last_error = exc
            self._notify_crash(exc)
            with self._cond:
                if self._restarts >= self._max_restarts:
                    self._state = STATE_FAILED
                    break
                self._restarts += 1
                self._state = STATE_BACKOFF
                delay = min(
                    self._backoff_base_s * 2.0 ** (self._restarts - 1),
                    self._backoff_max_s,
                )
            self._clock.sleep(self._cond, delay)
            with self._cond:
                if not self._running:
                    self._state = STATE_STOPPED
                    return
                self._state = STATE_RUNNING
        self._notify_failed(exc)

    def _notify_crash(self, exc: BaseException) -> None:
        if self._on_crash is not None:
            try:
                self._on_crash(exc)
            except Exception:  # a broken crash callback must not kill the guard
                pass

    def _notify_failed(self, exc: BaseException | None) -> None:
        if self._on_failed is not None and exc is not None:
            try:
                self._on_failed(exc)
            except Exception:  # a broken failure callback must not kill the guard
                pass
