"""The serving tier: service + placement + AOT warmup + continuous batcher.

:class:`ServingTier` is the deployable unit — what `examples/` and the
load-generator bench (benchmarks/bench_serve.py) stand up:

    tier = ServingTier(service, n_features=F, doc_counts=(64, 256))
    tier.start()                 # persistent cache + AOT warmup + batcher
    fut = tier.submit(features)  # non-blocking, one query
    top_idx, scores = fut.result()
    tier.stop()

``start()`` does the three cold-start moves in order: point jax at the
persistent compilation cache (restarts replay compiled artifacts from
disk), AOT-warm every padded ``(Q, D)`` bucket the batching policy can
produce for the configured ``doc_counts`` (both execution branches), and
only then open the request queue — the first real request lands on a hot
step cache with capacity buckets seeded so cold-start overflow is
impossible.
"""

from __future__ import annotations

import dataclasses
import typing
import warnings
from collections.abc import Sequence
from concurrent.futures import Future

if typing.TYPE_CHECKING:
    import numpy as np
    from numpy.typing import ArrayLike

from repro.serve.batching import (
    BatcherHooks,
    BatcherStats,
    BucketPolicy,
    ContinuousBatcher,
)
from repro.serve.clock import Clock
from repro.serve.degradation import DegradationController, DegradationPolicy
from repro.serve.placement import ServePlacement, single_device
from repro.serve.ranking_service import RankingService
from repro.serve.warmup import (
    WarmupReport,
    enable_persistent_cache,
    warmup_service,
)

_DEPRECATED_TIER_MSG = (
    "repro.serve.tier.ServingTier: keyword configuration (doc_counts=…, "
    "warmup=…, …) is deprecated; pass a TierConfig as the third argument. "
    "The shim builds the equivalent config and will be removed in a "
    "future release."
)


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Frozen bundle of the :class:`ServingTier` deployment knobs.

    The tier-level mirror of :class:`repro.serve.ranking_service.ServiceConfig`
    — what to warm, whether to warm, and where compiled artifacts persist.
    ``policy`` and ``placement`` stay direct constructor arguments: they
    are live objects (thread-owning batcher policy, device mesh), not
    declarative configuration.
    """

    doc_counts: tuple[int, ...] = (64,)
    warmup: bool = True
    persistent_cache: bool = True
    cache_dir: str | None = None
    degradation: DegradationPolicy | None = None

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "doc_counts", tuple(int(d) for d in self.doc_counts)
        )
        assert len(self.doc_counts) >= 1, "need at least one doc count"
        assert self.degradation is None or isinstance(
            self.degradation, DegradationPolicy
        )


class ServingTier:
    def __init__(
        self,
        service: RankingService,
        n_features: int,
        config: TierConfig | None = None,
        policy: BucketPolicy | None = None,
        placement: ServePlacement | None = None,
        *,
        clock: Clock | None = None,
        hooks: BatcherHooks | None = None,
        doc_counts: Sequence[int] | None = None,
        warmup: bool | None = None,
        persistent_cache: bool | None = None,
        cache_dir: str | None = None,
    ) -> None:
        if config is not None and not isinstance(config, TierConfig):
            # Legacy POSITIONAL call: ServingTier(svc, F, (64, 256), …)
            assert doc_counts is None, (config, doc_counts)
            config, doc_counts = None, tuple(config)
        legacy = {
            name: value
            for name, value in (
                ("doc_counts", doc_counts), ("warmup", warmup),
                ("persistent_cache", persistent_cache),
                ("cache_dir", cache_dir),
            )
            if value is not None
        }
        if config is None:
            if legacy:
                warnings.warn(
                    _DEPRECATED_TIER_MSG, DeprecationWarning, stacklevel=2
                )
            config = TierConfig(**legacy)
        elif legacy:
            raise TypeError(
                "ServingTier: pass configuration via TierConfig OR the "
                f"deprecated keywords, not both (got {sorted(legacy)})"
            )
        self.config = config
        self.service = service
        self.n_features = int(n_features)
        self.policy = policy or BucketPolicy()
        self.placement = placement or single_device()
        self.doc_counts = config.doc_counts
        self.do_warmup = config.warmup
        self.persistent_cache = config.persistent_cache
        self.cache_dir = config.cache_dir
        self.warmup_report: WarmupReport | None = None
        self.degradation = (
            DegradationController(service, config.degradation, clock=clock)
            if config.degradation is not None else None
        )
        self.batcher = ContinuousBatcher(
            service, self.n_features, self.policy,
            placement=self.placement, clock=clock, hooks=hooks,
            degradation=self.degradation,
        )
        self._started = False

    def start(self) -> ServingTier:
        assert not self._started, "tier already started"
        cache_dir = (
            enable_persistent_cache(self.cache_dir)
            if self.persistent_cache else None
        )
        if self.degradation is not None and self.service.n_rungs == 0:
            # Materialize every rung BEFORE warmup so the warmup pass
            # below AOT-compiles the whole ladder — degrading at peak
            # load must never trigger a jit.
            self.degradation.install()
        if self.do_warmup:
            self.warmup_report = warmup_service(
                self.service,
                self.n_features,
                self.policy.buckets(self.doc_counts),
                placement=self.placement,
            )
            self.warmup_report.cache_dir = cache_dir
        self.batcher.start()
        self._started = True
        return self

    def submit(
        self, features: ArrayLike, deadline_ms: float | None = None
    ) -> Future:
        """Non-blocking: one query's ``[n_docs, F]`` candidates → Future of
        ``(top_idx, scores)``. ``deadline_ms`` is the request's end-to-end
        budget (see :meth:`repro.serve.batching.ContinuousBatcher.submit`
        for the typed rejection/expiry behavior)."""
        return self.batcher.submit(features, deadline_ms=deadline_ms)

    def rank(self, features: ArrayLike) -> tuple[np.ndarray, np.ndarray]:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(features).result()

    def stop(self) -> None:
        if self._started:
            self.batcher.stop()
            self._started = False

    def stats(self) -> dict:
        """Operator snapshot: batcher counters + service aggregates."""
        svc, b = self.service.stats, self.batcher.stats
        return {
            "batcher": {
                f.name: getattr(b, f.name)
                for f in BatcherStats.__dataclass_fields__.values()
            },
            "service": {
                "batches": svc.batches,
                "queries": svc.queries,
                "docs": svc.docs,
                "overflow_docs": svc.overflow_docs,
                "speedup": svc.speedup,
                "continue_rate": svc.continue_rate,
                "batches_fused": svc.batches_fused,
                "batches_staged": svc.batches_staged,
                "queries_exited": svc.queries_exited,
                "query_exit_rate": svc.query_exit_rate,
            },
            "warmup_seconds": (
                self.warmup_report.total_seconds if self.warmup_report else 0.0
            ),
            "n_devices": self.placement.n_devices,
        }

    def health(self) -> dict:
        """Liveness snapshot for operators and load balancers: supervisor
        state (``running``/``backoff``/``failed``/…), restart and crash
        counts, current queue depth, p50/p99 completion latency over the
        recent window, and — when a degradation ladder is configured —
        the current rung and its queue-delay EMA."""
        h = self.batcher.health()
        h["started"] = self._started
        if self.degradation is not None:
            h["degradation"] = self.degradation.snapshot()
        return h
