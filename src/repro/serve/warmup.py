"""AOT warmup: pay every compile before the first real request arrives.

Two cold-start costs stand between service start and steady-state latency:

1. **Tracing/compilation** — the progressive engine jits one step per
   static configuration (batch shape, capacities, mode). The first request
   at a new capacity bucket would eat that compile. :func:`warmup_service`
   drives one synthetic batch through every configured ``(Q, D)`` bucket so
   the step cache is hot; with ``execution_mode="auto"`` both ``lax.cond``
   branches are part of that single compiled step (the pick is a traced
   operand), and the warmup additionally *executes* both branches by
   seeding the survivor EMA at its two extremes.

2. **Capacity re-bucketing** — the compaction-capacity ratchet normally
   learns survivor peaks from traffic, which means batch 1 runs at the
   cold-start estimate and can both overflow (quality loss) and trigger a
   re-jit when the ratchet moves. Warmup seeds each bucket's peaks at
   ``seed_peak_frac × Q × D`` *before* the first trace: with the default
   ``1.0`` the capacities start at the physical maximum (every document
   survives), which cannot overflow and can only ratchet *down* never —
   the running max keeps them pinned, so the bucket compiles exactly once.

Across process restarts the same trace is a cache hit on disk:
:func:`enable_persistent_cache` points jax's persistent compilation cache
at a directory (default ``$REPRO_COMPILE_CACHE`` or a per-user temp dir),
so restart warmup replays compiled artifacts instead of re-invoking XLA.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time
import typing
from collections.abc import Sequence

import jax
import jax.numpy as jnp

from repro.serve.ranking_service import RankingService, ServiceStats

if typing.TYPE_CHECKING:  # annotation-only: avoids a serve-package cycle
    from repro.serve.placement import ServePlacement

DEFAULT_WARMUP_BUCKETS = ((1, 64), (4, 64), (8, 64))


def enable_persistent_cache(cache_dir: str | None = None) -> str | None:
    """Point jax's persistent compilation cache at ``cache_dir`` (created
    if needed). Thresholds are dropped to "cache everything" — serving
    steps are small but latency-critical. Returns the directory actually
    configured, or ``None`` if the runtime lacks the cache config (the
    service must start regardless)."""
    if cache_dir is None:
        cache_dir = os.environ.get("REPRO_COMPILE_CACHE") or os.path.join(
            tempfile.gettempdir(), f"repro-xla-cache-{os.getuid()}"
        )
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except (OSError, AttributeError, ValueError):
        return None
    return cache_dir


@dataclasses.dataclass
class WarmupReport:
    buckets: list[tuple[int, int]]
    seconds_per_bucket: dict[tuple[int, int], float]
    cache_dir: str | None = None
    rungs_warmed: int = 1   # degradation rungs compiled per bucket

    @property
    def total_seconds(self) -> float:
        return sum(self.seconds_per_bucket.values())


def warmup_service(
    service: RankingService,
    n_features: int,
    buckets: Sequence[tuple[int, int]] = DEFAULT_WARMUP_BUCKETS,
    *,
    seed_peak_frac: float = 1.0,
    run_both_branches: bool = True,
    warm_rungs: bool = True,
    placement: ServePlacement | None = None,
) -> WarmupReport:
    """Compile (and execute) every ``(Q, D)`` serving bucket up front.

    For each bucket: seed the per-bucket survivor peaks (stable capacities
    → exactly one trace, zero cold-start overflow), then run one synthetic
    batch. With mode ``"auto"`` and ``run_both_branches``, run a second
    batch with the EMA forced to the opposite extreme so both ``lax.cond``
    branches have executed, not just compiled. Afterwards the warmup's
    fingerprints are wiped — stats reset, EMAs cleared (real traffic
    starts with the honest cold-start fused default) — but the seeded
    peaks are KEPT: they are the no-overflow guarantee.

    Stage counts come from ``service.n_stages`` (ALL stages, dense gate
    included): a hybrid service's peaks/EMA carry the leading dense
    entry, and because the dense matmul is traced into the same jitted
    step as the tree launches, this one synthetic batch AOT-compiles the
    dense branch too — no separate dense warmup pass exists or is needed.

    With ``warm_rungs`` (default) and a degradation ladder installed
    (:meth:`RankingService.install_rungs`), every rung's step is compiled
    for every bucket — each rung's strategy closures / query-exit config
    are part of the engine's static cache key, so each is its own
    compile. This is what makes degrading under load jit-free: stepping
    the ladder at peak traffic swaps to a step that warmup already paid
    for. The service is left back at rung 0 (baseline).
    """
    n_stages = service.n_stages
    rung_levels: list[int | None] = [None]
    if warm_rungs and service.n_rungs > 1:
        rung_levels = list(range(service.n_rungs))
    report = WarmupReport(
        buckets=[], seconds_per_bucket={}, rungs_warmed=len(rung_levels)
    )
    for Q, D in buckets:
        t0 = time.perf_counter()
        state = service.bucket_state(Q, D)
        if state.peaks is None:
            seed = max(1, min(int(seed_peak_frac * Q * D), Q * D))
            state.peaks = [seed] * n_stages
        X = jnp.zeros((Q, D, n_features), jnp.float32)
        mask = jnp.ones((Q, D), bool)
        # Extreme EMAs steer the device pick to each branch in turn (the
        # cost model prices zero survivors as maximally staged-friendly
        # and full survival as fused-friendly).
        ema_probes = [[0.0] * n_stages]
        if (
            run_both_branches
            and service.execution_mode == "auto"
            and len(service.sentinels) > 1
        ):
            ema_probes.append([float(Q * D)] * n_stages)
        for level in rung_levels:
            if level is not None:
                service.set_rung(level)
            for ema in ema_probes:
                state.ema = ema
                service.rank_batch(X, mask, placement=placement)
        state.ema = None  # real traffic re-learns its own continue rates
        report.buckets.append((Q, D))
        report.seconds_per_bucket[(Q, D)] = time.perf_counter() - t0
    if rung_levels[-1] is not None:
        service.set_rung(0)  # hand real traffic the baseline rung
    # Warmup batches are not traffic: stats restart clean.
    service.stats = ServiceStats()
    return report
