from repro.train.optimizer import Optimizer, adamw, adafactor, adagrad_rowwise, get_optimizer
from repro.train.trainer import TrainState, make_train_step
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.train.distill import DistillResult, distill_dense_scorer, teacher_scores
from repro.train.elastic import remesh

__all__ = [
    "DistillResult",
    "distill_dense_scorer",
    "teacher_scores",
    "Optimizer",
    "adamw",
    "adafactor",
    "adagrad_rowwise",
    "get_optimizer",
    "TrainState",
    "make_train_step",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "remesh",
]
