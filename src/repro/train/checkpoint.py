"""Checkpoint/restart: pytree → flat .npz + JSON treedef, atomic, keep-N.

Fault-tolerance contract:
- writes are atomic (tmp file + ``os.replace``), so a job killed mid-save
  never corrupts the latest checkpoint;
- the data-pipeline cursor and the step counter are saved WITH the model
  state, so restart resumes the exact batch sequence;
- ``keep_last`` bounds disk usage; restore picks the newest complete step.

No orbax offline — this is a complete minimal implementation with the same
semantics a TPU job needs (per-host save of addressable shards would slot
in at ``_to_numpy``; on CPU all arrays are host-local).
"""

from __future__ import annotations

import contextlib
import json
import os
import re
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    extra: dict | None = None,
    keep_last: int = 3,
) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(directory, f".tmp_step_{step}.npz")
    final = os.path.join(directory, f"step_{step:010d}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    meta = {"step": step, "extra": extra or {}, "keys": sorted(flat)}
    tmp_meta = os.path.join(directory, f".tmp_step_{step}.json")
    with open(tmp_meta, "w") as f:
        json.dump(meta, f)
    os.replace(tmp_meta, os.path.join(directory, f"step_{step:010d}.json"))
    _gc(directory, keep_last)
    return final


def _steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.npz", name)
        if m and os.path.exists(os.path.join(directory, name.replace(".npz", ".json"))):
            out.append(int(m.group(1)))
    return sorted(out)


def _gc(directory: str, keep_last: int) -> None:
    steps = _steps(directory)
    for s in steps[:-keep_last] if keep_last else []:
        for ext in (".npz", ".json"):
            with contextlib.suppress(FileNotFoundError):
                os.remove(os.path.join(directory, f"step_{s:010d}{ext}"))


def latest_step(directory: str) -> int | None:
    steps = _steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, target: Any, step: int | None = None):
    """Restore into the structure of ``target`` (a template pytree).

    Returns (state, extra). Raises FileNotFoundError if no checkpoint.
    """
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(os.path.join(directory, f"step_{step:010d}.npz"))
    with open(os.path.join(directory, f"step_{step:010d}.json")) as f:
        meta = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["extra"]
