"""Distill the GBDT ensemble into the dense stage-0 scorer.

The teacher is the ensemble itself: its exact scores on training data
(:func:`repro.forest.scoring.score_bitvector` — the bit-exact reference
path, no kernel in the loop) supervise the tiny
:mod:`repro.models.dense_scorer` MLP. Two loss terms, following the
distillation line of related work (arXiv 2202.10728, 2305.08680):

- **MSE** on the RAW teacher score scale. This matters beyond
  conditioning: documents the dense gate exits keep the dense score as
  their *final* score, so the student's outputs must live on the
  ensemble's scale or the merged ranking (dense-exited docs vs
  tree-scored survivors) is garbage.
- **Pairwise logistic rank loss** within each query (all ordered pairs
  where the teacher separates the documents): the gate is rank-based
  (:func:`repro.core.strategies.dense_keep_fraction`), so what actually
  decides which documents survive is the student's per-query ORDER, not
  its absolute calibration. MSE alone underweights exactly the
  small-margin inversions that flip gate decisions.

Training whitens features internally (masked mean/std) for optimizer
conditioning, then FOLDS the whitening affine into the projection weights
and bias — the returned params/scorer consume raw ``[B, F]`` features,
which is what the engine hands a :class:`repro.core.stage.DenseStage`.

Full-batch AdamW (:func:`repro.train.optimizer.adamw` — the repo's own
pytree optimizer, no optax): the repro-scale ``[Q, D, F]`` blocks fit in
one jitted step, so the whole loop is ~`steps` device dispatches.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.forest.ensemble import TreeEnsemble
from repro.forest.scoring import score_bitvector
from repro.models.dense_scorer import (
    DENSE_HIDDEN,
    DENSE_N_VEC,
    DENSE_VEC_DIM,
    DenseParams,
    dense_score,
    init_dense_scorer,
    make_dense_scorer,
)
from repro.train.optimizer import adamw


@dataclasses.dataclass
class DistillResult:
    """Trained student + its teacher-fit diagnostics."""

    params: DenseParams
    scorer: Callable[[jax.Array], jax.Array]  # raw-feature [B, F] → [B];
    #   ONE closure per training run — its identity keys the engine's
    #   step cache through DenseStage
    history: list[dict]       # logged (step, loss, mse, rank) floats
    teacher_rmse: float       # masked RMSE vs ensemble scores, raw scale
    pair_accuracy: float      # teacher-ordered pairs the student orders
    #   the same way (the quantity the rank-based gate cares about)


def teacher_scores(ensemble: TreeEnsemble, X: jax.Array) -> jax.Array:
    """Exact ensemble scores for a ``[Q, D, F]`` block → ``[Q, D]``."""
    Q, D, F = X.shape
    return score_bitvector(ensemble, X.reshape(Q * D, F)).reshape(Q, D)


def _pair_terms(
    pred: jax.Array, teacher: jax.Array, m: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-query pairwise logistic loss and pair accuracy.

    Pairs are ordered by the TEACHER (``dt > 0`` picks each separated
    pair once, in teacher order); the student is pushed to agree via
    ``softplus(-ds)``. [Q, D, D] is fine at repro block sizes.
    """
    dt = teacher[:, :, None] - teacher[:, None, :]
    ds = pred[:, :, None] - pred[:, None, :]
    pair_m = (m[:, :, None] * m[:, None, :]) * (dt > 0)
    n_pairs = jnp.maximum(pair_m.sum(), 1.0)
    loss = (jax.nn.softplus(-ds) * pair_m).sum() / n_pairs
    acc = ((ds > 0) * pair_m).sum() / n_pairs
    return loss, acc


def distill_dense_scorer(
    ensemble: TreeEnsemble,
    X: jax.Array,
    mask: jax.Array,
    steps: int = 400,
    lr: float = 3e-3,
    rank_weight: float = 1.0,
    seed: int = 0,
    n_vec: int = DENSE_N_VEC,
    vec_dim: int = DENSE_VEC_DIM,
    hidden: int = DENSE_HIDDEN,
    log_every: int = 50,
) -> DistillResult:
    """Train the dense student against the ensemble teacher on one block.

    ``X`` is the padded ``[Q, D, F]`` training/validation block, ``mask``
    its ``[Q, D]`` validity mask (padding contributes to neither loss
    term nor the whitening statistics). Returns folded params — the
    scorer consumes raw features.
    """
    X = jnp.asarray(X, jnp.float32)
    mask = jnp.asarray(mask, bool)
    Q, D, F = X.shape
    teacher = teacher_scores(ensemble, X)
    m = mask.astype(jnp.float32)
    w = m.reshape(Q * D, 1)
    denom = jnp.maximum(w.sum(), 1.0)
    flat = X.reshape(Q * D, F)
    mu = (flat * w).sum(0) / denom
    sd = jnp.sqrt((jnp.square(flat - mu) * w).sum(0) / denom) + 1e-6
    Xn = (flat - mu) / sd

    params = init_dense_scorer(
        jax.random.PRNGKey(seed), F, n_vec=n_vec, vec_dim=vec_dim,
        hidden=hidden,
    )
    opt = adamw(lr=lr, weight_decay=1e-4)
    state = opt.init(params)

    def loss_fn(p: DenseParams) -> tuple[jax.Array, tuple]:
        pred = dense_score(p, Xn).reshape(Q, D)
        mse = (jnp.square(pred - teacher) * m).sum() / denom
        rank, acc = _pair_terms(pred, teacher, m)
        return mse + rank_weight * rank, (mse, rank, acc)

    @jax.jit
    def train_step(p: DenseParams, s: dict) -> tuple:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p, s = opt.update(grads, s, p)
        return p, s, loss, aux

    history = []
    for it in range(steps):
        params, state, loss, (mse, rank, acc) = train_step(params, state)
        if log_every and (it % log_every == 0 or it == steps - 1):
            history.append({
                "step": it, "loss": float(loss), "mse": float(mse),
                "rank": float(rank), "pair_accuracy": float(acc),
            })

    # Fold the whitening affine into the projection so the deployed
    # scorer consumes RAW features:
    #   einsum((x−μ)/σ, P) + b  ==  einsum(x, P/σ) + (b − einsum(μ/σ, P))
    folded = dict(params)
    folded["proj"] = params["proj"] / sd[:, None, None]
    folded["pb"] = params["pb"] - jnp.einsum(
        "f,fnd->nd", mu / sd, params["proj"]
    )
    pred = dense_score(folded, flat).reshape(Q, D)
    rmse = float(jnp.sqrt((jnp.square(pred - teacher) * m).sum() / denom))
    _, pair_acc = _pair_terms(pred, teacher, m)
    return DistillResult(
        params=folded,
        scorer=make_dense_scorer(folded),
        history=history,
        teacher_rmse=rmse,
        pair_accuracy=float(pair_acc),
    )
