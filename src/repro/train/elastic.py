"""Elastic re-meshing: resume the same logical program on a different mesh.

Because every placement in this framework is expressed through *logical*
axis rules (:mod:`repro.distributed.sharding`), surviving a node failure is:

1. restore the last checkpoint (host numpy),
2. build a new mesh from the surviving device count,
3. re-resolve the SAME logical specs against the new mesh,
4. ``jax.device_put`` the pytree with the new shardings, and re-jit.

``remesh`` implements steps 2–4. Shrinking the data axis is always legal
(batch re-divides); changing the model axis is validated against the
divisibility of every sharded dimension before committing.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import Rules


def validate_divisibility(tree, logical_tree, rules: Rules, mesh: Mesh):
    """Check every sharded dim divides its mesh-axis product."""
    problems = []

    def check(path, leaf, logical):
        spec = rules.resolve(*logical)
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            ways = 1
            for a in axes:
                ways *= mesh.shape[a]
            if dim % ways:
                problems.append((jax.tree_util.keystr(path), dim, ways))

    jax.tree_util.tree_map_with_path(
        check, tree, logical_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
    return problems


def remesh(tree, logical_tree, rules: Rules, mesh: Mesh):
    """Re-place a pytree onto ``mesh`` under ``rules``. Raises on bad divisors."""
    problems = validate_divisibility(tree, logical_tree, rules, mesh)
    if problems:
        raise ValueError(f"re-mesh would shard non-divisible dims: {problems[:5]}")

    def put(leaf, logical):
        return jax.device_put(leaf, NamedSharding(mesh, rules.resolve(*logical)))

    return jax.tree.map(
        put, tree, logical_tree, is_leaf=lambda x: hasattr(x, "shape")
    )
