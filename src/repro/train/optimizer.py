"""Optimizers as pure pytree transforms (no optax offline).

- ``adamw``      — bf16 params / f32 moments, decoupled weight decay.
- ``adafactor``  — factored second moment, no momentum (Shazeer & Stern):
  state is O(rows + cols) per matrix. Used for llama4-maverick (400B), where
  full AdamW state cannot fit the single-pod mesh.
- ``adagrad_rowwise`` — DLRM-style: embedding tables (first dim ≥ 2¹⁶) get
  one accumulator scalar per ROW; everything else dense Adagrad. This is
  the production optimizer for 10⁸-row tables.

Optimizer states mirror the param tree, so the same logical-axis sharding
rules apply (ZeRO-1 for free: stacked-layer moments inherit the L→"data"
sharding of their params).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            new_p = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
            return _cast_like(new_p, p), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        new = [leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        return (
            treedef.unflatten([t[0] for t in new]),
            {
                "m": treedef.unflatten([t[1] for t in new]),
                "v": treedef.unflatten([t[2] for t in new]),
                "count": count,
            },
        )

    return Optimizer(init=init, update=update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moment, no momentum; decay ∝ step^-0.8."""

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(leaf, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        decay = 1.0 - count.astype(jnp.float32) ** -0.8

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                u = g / jnp.sqrt(
                    (vr / jnp.maximum(denom, eps))[..., None]
                    * vc[..., None, :]
                    + eps
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            # Update clipping (RMS ≤ clip_threshold).
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr * u
            return _cast_like(new_p, p), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        new = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([t[0] for t in new])
        new_f = treedef.unflatten([t[1] for t in new])
        return new_params, {"f": new_f, "count": count}

    return Optimizer(init=init, update=update)


ROWWISE_MIN_ROWS = 1 << 16


def adagrad_rowwise(lr: float = 0.01, eps: float = 1e-8) -> Optimizer:
    """Row-wise Adagrad for big tables; dense Adagrad elsewhere."""

    def is_table(p):
        return p.ndim == 2 and p.shape[0] >= ROWWISE_MIN_ROWS

    def init(params):
        def leaf(p):
            if is_table(p):
                return jnp.zeros(p.shape[:1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        return {"acc": jax.tree.map(leaf, params)}

    def update(grads, state, params):
        def leaf(g, a, p):
            g = g.astype(jnp.float32)
            if is_table(p):
                a = a + (g * g).mean(axis=-1)
                step = g / (jnp.sqrt(a)[:, None] + eps)
            else:
                a = a + g * g
                step = g / (jnp.sqrt(a) + eps)
            return _cast_like(p.astype(jnp.float32) - lr * step, p), a

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_a = treedef.flatten_up_to(state["acc"])
        new = [leaf(g, a, p) for g, a, p in zip(flat_g, flat_a, flat_p)]
        return (
            treedef.unflatten([t[0] for t in new]),
            {"acc": treedef.unflatten([t[1] for t in new])},
        )

    return Optimizer(init=init, update=update)


def get_optimizer(name: str, lr: float = 1e-3) -> Optimizer:
    if name == "adamw":
        return adamw(lr)
    if name == "adafactor":
        return adafactor(lr)
    if name == "adagrad_rowwise":
        return adagrad_rowwise(lr)
    raise ValueError(f"unknown optimizer {name!r}")
