"""Train-step factory: grad accumulation, aux metrics, optional grad clip.

``make_train_step(loss_fn, optimizer, microbatch)`` returns a pure
``step(state, batch) → (state, metrics)``:

- microbatch > 0 splits the global batch on its leading axis and
  accumulates gradients with ``lax.scan`` (compute of microbatch *i+1*
  overlaps the DP all-reduce of microbatch *i*'s gradients under XLA's
  latency-hiding scheduler — the standard accumulation overlap).
- Gradient accumulation dtype is configurable (f32 default; bf16 for the
  400B config where the f32 accumulator alone would not fit).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.train.optimizer import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


def init_state(params, optimizer: Optimizer) -> TrainState:
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    loss_fn: Callable,            # (params, batch) -> scalar loss
    optimizer: Optimizer,
    microbatch: int = 0,
    grad_clip: float = 0.0,
    accum_dtype=jnp.float32,
):
    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state.params
        if microbatch:
            lead = jax.tree.leaves(batch)[0].shape[0]
            assert lead % microbatch == 0, (lead, microbatch)
            n_chunks = lead // microbatch
            chunked = jax.tree.map(
                lambda x: constrain(
                    x.reshape(n_chunks, microbatch, *x.shape[1:]),
                    None, "batch", *([None] * (x.ndim - 1)),
                ),
                batch,
            )

            def accum(carry, mb):
                loss_sum, gacc = carry
                loss, g = grads_of(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gacc, g
                )
                return (loss_sum + loss, gacc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (loss_sum, gsum), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zeros), chunked
            )
            loss = loss_sum / n_chunks
            grads = jax.tree.map(lambda g: g / n_chunks, gsum)
        else:
            loss, grads = grads_of(params, batch)

        gnorm = optax_global_norm(grads)
        if grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        new_params, new_opt = optimizer.update(grads, state.opt_state, params)
        new_state = TrainState(
            params=new_params, opt_state=new_opt, step=state.step + 1
        )
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step


def optax_global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
