"""Shape/dtype annotation support for the kernel entry points.

Two jobs:

1. **Import-safe jaxtyping aliases.**  ``Float32``/``Int32``/``UInt32``
   re-export jaxtyping when it is installed and degrade to plain
   ``jax.Array`` subscript shims when it is not — annotating a module
   with ``Float32[Array, "b f"]`` must never make it unimportable on a
   minimal box.
2. **A runtime-checked lane.**  :func:`shape_checked` wraps a function
   whose annotations are jaxtyping array types and validates argument
   and return shapes/dtypes at call time, with dim variables bound
   consistently ACROSS arguments (``"t n"`` on two operands means the
   same ``t`` and ``n``).  The tier-1 shape tests
   (``tests/test_shapes.py``) drive the kernel entry points through it;
   production call sites stay unwrapped — zero hot-path overhead.
"""

from __future__ import annotations

import functools
import inspect
import typing
from collections.abc import Callable
from typing import Any, TypeVar

import jax

Array = jax.Array

# Pallas kernel-body operands.  There is no stable public type for the
# mutable block references pallas passes to kernel bodies, so ``Ref`` is
# ``Any`` at runtime — but the NAME matters: the tracer-safety analyzer
# treats ``Ref``-annotated parameters as device values (tainted), so
# annotating a kernel body never weakens TS002/TS003 detection.
Ref = Any

try:
    from jaxtyping import AbstractArray as _AbstractArray
    from jaxtyping import Bool, Float32, Int32, UInt32, jaxtyped

    HAVE_JAXTYPING = True
except ImportError:  # pragma: no cover - exercised only on minimal boxes
    HAVE_JAXTYPING = False
    _AbstractArray = None  # type: ignore[assignment, misc]

    class _ArrayShim:
        """``Float32[Array, "b f"]`` → ``jax.Array`` when jaxtyping is
        absent: annotations keep their meaning for readers and stay
        valid at runtime, runtime checking is disabled."""

        def __class_getitem__(cls, item: object) -> type:
            return jax.Array

    Bool = Float32 = Int32 = UInt32 = _ArrayShim  # type: ignore[assignment, misc]

    def jaxtyped(*, typechecker: object = None) -> Callable:  # type: ignore[misc]
        def deco(fn: Callable) -> Callable:
            return fn

        return deco


F = TypeVar("F", bound=Callable)


def _is_array_hint(hint: object) -> bool:
    return (
        HAVE_JAXTYPING
        and isinstance(hint, type)
        and issubclass(hint, _AbstractArray)
    )


def _describe(value: object) -> str:
    shape = getattr(value, "shape", None)
    dtype = getattr(value, "dtype", None)
    if shape is None:
        return repr(type(value))
    return f"shape={tuple(shape)} dtype={dtype}"


def shape_checked(fn: Callable) -> Callable:
    """Wrap ``fn`` so its jaxtyping annotations are enforced per call.

    Works on jit-wrapped callables too (hints are read through
    ``__wrapped__``; the wrapped/compiled callable is still what runs).
    When jaxtyping is unavailable the function is returned unchanged.
    """
    if not HAVE_JAXTYPING:
        return fn
    target = inspect.unwrap(fn)
    hints = typing.get_type_hints(target)
    sig = inspect.signature(target)
    array_hints = {
        name: hint for name, hint in hints.items() if _is_array_hint(hint)
    }
    if not array_hints:
        return fn
    return_hint = array_hints.pop("return", None)

    @functools.wraps(fn)
    @jaxtyped(typechecker=None)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        bound = sig.bind(*args, **kwargs)
        for name, hint in array_hints.items():
            if name not in bound.arguments:
                continue
            value = bound.arguments[name]
            if not isinstance(value, hint):
                raise TypeError(
                    f"{target.__name__}: argument `{name}` "
                    f"({_describe(value)}) does not satisfy {hint} "
                    "(dim variables bind across arguments)"
                )
        out = fn(*args, **kwargs)
        if return_hint is not None and not isinstance(out, return_hint):
            raise TypeError(
                f"{target.__name__}: return value ({_describe(out)}) "
                f"does not satisfy {return_hint}"
            )
        return out

    return wrapper
