"""Instrumentation helpers shared by tests and benchmarks.

:func:`count_host_transfers` is the device-residency guard: it counts
device→host materializations of jax arrays while a block runs, split into
*explicit* reads (``jax.device_get`` — the sanctioned, fused stats read)
and *implicit* syncs (``float()`` / ``int()`` / ``bool()`` / ``np.asarray``
on a device array — the accidental kind that stalls the serving hot path).

Why not ``jax.transfer_guard``? On the CPU backend (this container)
device and host share memory, so jax's own guard never fires — it would
make the zero-transfer contract vacuously true. Instead we hook
``ArrayImpl._value``, the single Python chokepoint every host
materialization funnels through (``__array__``, ``__float__``,
``__int__``, ``__bool__``, ``device_get`` all read it), and attribute
hits inside a ``jax.device_get`` call to the explicit bucket.

Known blind spot: a raw buffer-protocol read (``memoryview``-style C
access that numpy *can* take on CPU zero-copy arrays) bypasses
``_value``. Serving code never does that; the guard is aimed at the
Python-level sync vectors that actually appear in hot paths.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax._src import array as _jax_array


@dataclasses.dataclass
class TransferCounts:
    """Mutable tally yielded by :func:`count_host_transfers`."""

    explicit_gets: int = 0   # jax.device_get calls
    implicit_syncs: int = 0  # host materializations outside device_get


@contextlib.contextmanager
def count_host_transfers():
    """Count device→host transfers in the ``with`` block.

    Yields a :class:`TransferCounts`; read it after the block. Not
    re-entrant and patches process-global hooks — test-scope only, never
    in serving code.
    """
    counts = TransferCounts()
    local = threading.local()

    real_get = jax.device_get
    real_value = _jax_array.ArrayImpl._value

    def counting_get(*args, **kwargs):
        counts.explicit_gets += 1
        local.in_get = True
        try:
            return real_get(*args, **kwargs)
        finally:
            local.in_get = False

    class CountingValue:
        def __get__(self, obj, objtype=None):
            if obj is not None and not getattr(local, "in_get", False):
                counts.implicit_syncs += 1
            return real_value.__get__(obj, objtype)

    jax.device_get = counting_get
    _jax_array.ArrayImpl._value = CountingValue()
    try:
        yield counts
    finally:
        jax.device_get = real_get
        _jax_array.ArrayImpl._value = real_value
