"""Fault-injection harness for the serving tier's chaos tests.

Everything here exists to make failure *deterministic and fast*:

- :class:`FakeClock` — virtual monotonic time behind the
  :class:`repro.serve.clock.Clock` protocol. ``now()`` reads virtual
  time; ``advance()`` moves it. Condition waits become short REAL polls
  (a few ms), so the worker loop re-reads the virtual clock often —
  deadline and backoff logic run against fake time while the test stays
  wall-clock fast.
- :class:`FakeService` — a numpy stand-in for :class:`RankingService`
  with the same ``rank_batch`` surface (deterministic per-document
  scores, neighbor-independent like the real masked engine), plus
  injectable engine failures and artificial per-call latency. Batcher
  semantics (admission, deadlines, supervision, scatter) get exercised
  without paying jax compiles.
- :class:`CrashTimes` — a ``BatcherHooks.on_flush`` payload that kills
  the worker thread a configured number of times (the supervisor's
  restart path), and :class:`PoisonOnce` — an ``on_result`` payload that
  poisons exactly one request's scatter.
- :func:`settle` — resolve a pile of futures into (results, errors)
  with a hard timeout: the "no future is ever left unresolved" assertion
  helper.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.ranking_service import ServiceStats

#: Real seconds a FakeClock condition-wait blocks per poll. Small enough
#: to keep chaos tests snappy, large enough not to busy-spin.
POLL_S = 0.002


class InjectedCrash(RuntimeError):
    """The fault the harness throws to kill a worker thread."""


class InjectedEngineError(RuntimeError):
    """The fault the harness throws from inside the (fake) engine."""


class FakeClock:
    """Virtual time with the :class:`repro.serve.clock.Clock` surface.

    ``wait``/``sleep`` do a short real wait regardless of the requested
    timeout — the waiter wakes frequently and re-reads ``now()``, so
    advancing virtual time is observed within a few milliseconds of real
    time without any coupling between the test thread and the waiter.
    """

    def __init__(self, start: float = 1000.0) -> None:
        self._now = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._now

    def advance(self, seconds: float) -> float:
        assert seconds >= 0.0, seconds
        with self._lock:
            self._now += float(seconds)
            return self._now

    def wait(self, cond: threading.Condition, timeout: float | None) -> bool:
        if timeout is not None and timeout <= 0.0:
            return False
        return cond.wait(timeout=POLL_S)

    def sleep(self, cond: threading.Condition, seconds: float) -> None:
        with cond:
            cond.wait(timeout=POLL_S)


class FakeService:
    """Engine stand-in: deterministic, fast, and failable on demand.

    Scores are ``features.sum(-1)`` masked to alive rows — per-document
    and independent of block neighbors, mirroring the bit-exactness
    property the real engine guarantees. ``fail_next(n)`` arms ``n``
    consecutive :class:`InjectedEngineError` raises; ``latency_s``
    simulates engine wall time (real sleep, keep it tiny).
    """

    def __init__(self, top_k: int = 5, latency_s: float = 0.0) -> None:
        self.top_k = int(top_k)
        self.stats = ServiceStats()
        self.calls = 0
        self.batch_shapes: list[tuple[int, int]] = []
        self.latency_s = float(latency_s)
        self._fail_remaining = 0
        self._lock = threading.Lock()
        # Degradation duck-surface (RankingService's rung API): records
        # every set_rung so tests can assert the controller really stepped.
        self.rungs_installed: tuple | None = None
        self.rung_level = 0
        self.rung_history: list[int] = []

    @property
    def n_rungs(self) -> int:
        if self.rungs_installed is None:
            return 0
        return len(self.rungs_installed) + 1  # + implicit baseline

    def install_rungs(self, rungs) -> None:
        assert self.rungs_installed is None
        self.rungs_installed = tuple(rungs)

    def set_rung(self, level: int) -> None:
        assert self.rungs_installed is not None
        assert 0 <= level < self.n_rungs, (level, self.n_rungs)
        self.rung_level = level
        self.rung_history.append(level)

    def fail_next(self, n: int = 1) -> None:
        with self._lock:
            self._fail_remaining = int(n)

    def rank_batch(
        self, X: object, mask: object, placement: object = None
    ) -> tuple[None, np.ndarray]:
        self.calls += 1
        x = np.asarray(X)
        m = np.asarray(mask)
        self.batch_shapes.append((x.shape[0], x.shape[1]))
        if self.latency_s > 0.0:
            time.sleep(self.latency_s)
        with self._lock:
            if self._fail_remaining > 0:
                self._fail_remaining -= 1
                raise InjectedEngineError("injected engine failure")
        scores = x.sum(axis=-1) * m
        return None, scores

    @staticmethod
    def expected_scores(features: np.ndarray) -> np.ndarray:
        """What ``rank_batch`` returns for one query's alive rows."""
        return np.asarray(features, np.float32).sum(axis=-1)


class CrashTimes:
    """``BatcherHooks.on_flush`` payload: kill the worker ``n`` times.

    Each call while armed raises :class:`InjectedCrash` — which escapes
    the worker loop and lands in the supervisor. ``fired`` counts kills.
    """

    def __init__(self, n: int = 1) -> None:
        self.remaining = int(n)
        self.fired = 0
        self._lock = threading.Lock()

    def __call__(self, doc_bucket: int, n_reqs: int) -> None:
        with self._lock:
            if self.remaining > 0:
                self.remaining -= 1
                self.fired += 1
                raise InjectedCrash("injected worker kill")


class PoisonOnce:
    """``BatcherHooks.on_result`` payload: poison exactly one scatter."""

    def __init__(self) -> None:
        self.armed = True

    def __call__(self, future: Future) -> None:
        if self.armed:
            self.armed = False
            raise InjectedEngineError("injected per-request poison")


def settle(
    futures: list[Future], timeout_s: float = 30.0
) -> tuple[list, list[BaseException]]:
    """Wait for EVERY future to resolve; raise if any is left hanging.

    Returns ``(results, errors)`` in submission order (each future lands
    in exactly one list). This is the chaos suite's core assertion: no
    interleaving of submit/crash/stop may strand a future.
    """
    deadline = time.monotonic() + timeout_s
    results, errors = [], []
    for fut in futures:
        remaining = deadline - time.monotonic()
        assert remaining > 0, "settle(): timed out with futures unresolved"
        try:
            results.append(fut.result(timeout=remaining))
        except BaseException as e:  # noqa: BLE001 — classification, not handling
            errors.append(e)
    return results, errors


def spike(batcher, n: int, features: np.ndarray, deadline_ms=None) -> list:
    """Fire ``n`` submits as fast as possible; collect futures AND
    synchronous rejections (Overloaded etc.) as pre-failed futures, so
    ``settle`` can account for every request in the spike."""
    futs: list[Future] = []
    for _ in range(n):
        try:
            futs.append(batcher.submit(features, deadline_ms=deadline_ms))
        except Exception as e:
            f: Future = Future()
            f.set_exception(e)
            futs.append(f)
    return futs
