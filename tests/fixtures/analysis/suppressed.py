"""Suppression fixture: both noqa placements silence a real finding."""

import jax
import numpy as np


@jax.jit
def step(x):
    return np.asarray(x)  # repro: noqa(TS001) -- fixture: deliberate waiver


@jax.jit
def step2(x):
    # repro: noqa(TS001, TS002) -- fixture: comment-line waiver applies
    # to the next code line (multi-line justifications welcome)
    return np.asarray(x)
