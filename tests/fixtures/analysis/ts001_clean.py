"""TS001 fixture (clean): shape math and host-side syncs are fine."""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x, scale: float):
    n = float(x.shape[0])  # shape access is trace-time
    return jnp.sum(x) * scale / n


def host_summary(batch):
    # never reachable from a jit root — host code may sync freely
    return float(np.asarray(batch).mean())
