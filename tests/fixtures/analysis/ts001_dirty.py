"""TS001 fixture: host syncs reachable from a jitted function."""

import jax
import jax.numpy as jnp
import numpy as np


def helper(x):
    # reached from `step` below — np.asarray forces a device→host copy
    return np.asarray(x)


@jax.jit
def step(x):
    total = jnp.sum(x)
    host = float(total)
    ready = total.item()
    return helper(x) + host + ready
