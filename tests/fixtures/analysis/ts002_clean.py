"""TS002 fixture (clean): branching on static config and shapes."""

import jax
import jax.numpy as jnp


@jax.jit
def normalize(x, method: str = "l2", eps: float = 1e-6):
    if method == "l2":  # annotated str parameter — static
        return x / (jnp.sqrt(jnp.sum(x * x)) + eps)
    if x.shape[0] > 1:  # shape — trace-time Python int
        return x / x.shape[0]
    return jnp.where(x > 0, x, 0.0)  # data dependence stays in ops
