"""TS002 fixture: Python control flow on traced values."""

import jax


@jax.jit
def clip_positive(x):
    if x.sum() > 0:
        return x
    while x.any():
        x = x - 1
    return -x
