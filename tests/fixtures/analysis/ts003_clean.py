"""TS003 fixture (clean): tree axis reduced through the sanctioned
pairwise halving."""

from jax.experimental import pallas as pl


def _pairwise_tree_sum(per_tree):
    n = per_tree.shape[1]
    while n > 1:
        half = n // 2
        per_tree = per_tree[:, :half] + per_tree[:, half : 2 * half]
        n = half
    return per_tree[:, 0]


def _kernel(x_ref, o_ref):
    o_ref[...] = _pairwise_tree_sum(x_ref[...])


def score(x, out_shape):
    return pl.pallas_call(_kernel, out_shape=out_shape)(x)


def prefix_residual(per_tree, order):
    # Reorder-path entry point: the permuted tree axis still reduces
    # through the sanctioned pairwise halving.
    return _pairwise_tree_sum(per_tree[:, order])
