"""TS003 fixture: reassociating reductions inside a Pallas kernel."""

import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    vals = x_ref[...]
    total = jnp.sum(vals, axis=1)  # bare sum over the tree axis
    acc = jnp.zeros_like(total)
    for t in range(4):
        acc += vals[:, t]  # += accumulation loop
    o_ref[...] = total + acc


def score(x, out_shape):
    return pl.pallas_call(
        functools.partial(_kernel),
        out_shape=out_shape,
    )(x)


def prefix_residual(per_tree, order):
    # Reorder-path entry point (TREE_SUM_EXTRA_ROOT_SUFFIXES): reduces
    # the PERMUTED tree axis with a bare sum — reassociation hazard even
    # though no pallas_call is in sight.
    permuted = per_tree[:, order]
    return permuted.sum(axis=1)
