"""TS004 fixture (clean): environment read once at module scope."""

import os

import jax

SCALE_K = int(os.environ.get("SCALE_K", "4"))


@jax.jit
def scale(x):
    return x * SCALE_K
