"""TS004 fixture: environment reads inside a jitted body."""

import os

import jax


@jax.jit
def scale(x):
    k = int(os.environ.get("SCALE_K", "4"))
    bias = int(os.getenv("BIAS", "0"))
    limit = int(os.environ["LIMIT"])
    return x * k + bias - limit
