"""TS005 fixture (clean): only the worker loop and the sanctioned
lifecycle methods touch the engine."""


def warmup_service(service):
    return service


class RankingService:
    def rank_batch(self, X, mask):
        return X, mask


class ContinuousBatcher:
    def __init__(self, service):
        self.service = service
        self.queue = []

    def submit(self, query):
        self.queue.append(query)  # enqueue only — the worker dequeues

    def _run(self):
        while self.queue:
            self._flush()

    def _flush(self):
        batch = self.queue.pop()
        return self.service.rank_batch(batch, None)


class ServingTier:
    def __init__(self, service):
        self.batcher = ContinuousBatcher(service)

    def start(self):
        warmup_service(self.batcher.service)
