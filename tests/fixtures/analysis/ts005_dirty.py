"""TS005 fixture: engine calls from client-facing serving methods."""


def warmup_service(service):
    return service


class RankingService:
    def rank_batch(self, X, mask):
        return X, mask


class ContinuousBatcher:
    def __init__(self, service):
        self.service = service

    def submit(self, query):
        # client thread touching the engine directly
        return self.service.rank_batch(query, None)

    def _run(self):
        pass


class ServingTier:
    def __init__(self, service):
        self.batcher = ContinuousBatcher(service)

    def stop(self):
        # warmup belongs in start(), before the worker exists
        warmup_service(self.batcher.service)
