"""TS006 fixture (clean): one fused device_get fetches everything."""

import jax


class RankingService:
    def rank_batch(self, X, mask):
        top, scores, stats = self._compute(X, mask)
        return jax.device_get((top, scores, stats))

    def _compute(self, X, mask):
        return X, X, mask
