"""TS006 fixture: two transfer sites reachable from rank_batch."""

import jax


class RankingService:
    def rank_batch(self, X, mask):
        out = self._compute(X, mask)
        stats = jax.device_get(out)
        return stats, self._peek(out)

    def _compute(self, X, mask):
        return X

    def _peek(self, out):
        return out.item()  # second transfer on the hot path
