"""TS007 clean fixture: bounded buffers, typed excepts, justified catch-all."""

import collections
import queue


class ContinuousBatcher:
    def __init__(self):
        # bounded buffers: the contract TS007 enforces
        self.latencies = collections.deque(maxlen=512)
        self.requests = queue.Queue(maxsize=64)
        self.history = collections.deque([], 128)  # positional maxlen

    def _run(self):
        batch = []
        while self.running():
            # bounded loop (not `while True`): growth is admission-gated
            batch.append(self.requests.get())
        return batch

    def running(self):
        return False

    def _flush(self, reqs):
        try:
            return len(reqs)
        except TypeError:
            # typed handler: lets real worker death propagate
            return 0


class WorkerSupervisor:
    def _guard_loop(self, target):
        try:
            target()
        except BaseException:  # repro: noqa(TS007) -- the supervisor IS the catch-all: crashes become restarts
            pass


class RequestLog:
    """Not a worker-loop class: the rule does not apply here."""

    def __init__(self):
        self.entries = collections.deque()

    def watch(self, source):
        while True:
            try:
                self.entries.append(source.get())
            except BaseException:
                return
