"""TS007 fixture: unbounded growth / blind excepts in worker-loop classes."""

import collections
import queue


class ContinuousBatcher:
    def __init__(self):
        # unbounded buffers in a serving class: overload becomes OOM
        self.latencies = collections.deque()
        self.requests = queue.Queue()

    def _run(self):
        while True:
            item = self.requests.get()
            # growing self-state forever inside the worker loop
            self.latencies.append(item)

    def _flush(self, reqs):
        try:
            return len(reqs)
        except BaseException:
            # swallows worker death the supervisor must observe
            return 0


class WorkerSupervisor:
    def _guard_loop(self, target):
        try:
            target()
        except:  # noqa: E722
            pass
