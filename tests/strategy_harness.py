"""Strategy conformance harness — shared oracle + contract helpers.

The query-exit, reorder, and hybrid suites all need the same scaffolding:
a deterministic problem generator, a from-scratch numpy replay of the
progressive cascade (prefixes from the ``partial_scores`` oracle, stage
decisions and query-level exit replayed on host), cross-mode
equivalence runs, and the launch-count contract table. Keeping them
here pins ONE definition of "conformant" that every engine
configuration ({fused, staged, auto} × query-exit on/off × reorder
on/off × dense-stage on/off) is held to.

Heterogeneous stages: passing ``dense=`` (a :class:`DenseStage`, see
:func:`make_dense_stage`) to :func:`run_mode` / :func:`run_all_modes` /
:func:`oracle_progressive` / :func:`assert_matches_oracle` /
:func:`measured_launches` prepends the dense gate as stage 0. The oracle
replays it exactly: the dense scorer and policy are pure functions of the
full ``[Q, D]`` grid, and the engine's tree strategies are mask-invariant,
so replaying them on full-grid prefixes (instead of the engine's
scatter-with-garbage-in-dead-slots grids) reproduces the masks bit-for-bit.

Not a test module: no ``test_`` functions live here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeRanker
from repro.core.stage import DenseStage, EngineConfig
from repro.core.strategies import (
    QueryExitConfig,
    dense_keep_fraction,
    ept_continue,
    query_converged,
)
from repro.forest.ensemble import TreeEnsemble, random_ensemble
from repro.forest.scoring import partial_scores
from repro.kernels import ops
from repro.models.dense_scorer import init_dense_scorer, make_dense_scorer

# One strategy family for the whole harness: EPT with a mid proximity
# threshold exercises partial-score-dependent exits without training.
STRATEGY_KWARGS = dict(k_s=5, p=0.5)

# Dense-gate keep fraction for hybrid conformance runs: aggressive enough
# that the tree stages visibly run on a pruned block, loose enough that
# later stages still have documents to exit.
DENSE_KEEP_FRAC = 0.5


def make_problem(seed: int, Q: int = 4, D: int = 24, F: int = 16,
                 n_trees: int = 60, depth: int = 4):
    """Deterministic (ensemble, X, mask) triple for conformance runs."""
    ens = random_ensemble(seed, n_trees=n_trees, depth=depth, n_features=F)
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.9)
    return ens, X, mask


def make_ranker(ens: TreeEnsemble, sentinel: int = 10) -> CascadeRanker:
    return CascadeRanker(
        ensemble=ens, sentinel=sentinel, strategy=ept_continue
    )


def make_dense_stage(n_features: int, seed: int = 0,
                     keep_frac: float = DENSE_KEEP_FRAC) -> DenseStage:
    """Deterministic (untrained) dense stage-0 gate for conformance runs.

    Conformance does not care whether the dense scorer is a *good* proxy,
    only that the engine routes its scores/decisions correctly — a
    freshly initialised scorer with a rank-based keep-fraction policy
    exercises exactly the same code paths as a distilled one. Build it
    ONCE per problem and reuse the returned object: stages hash their
    callables by identity, so a shared instance is what keeps the engine
    step cache hot across modes.
    """
    params = init_dense_scorer(jax.random.PRNGKey(seed), n_features)
    return DenseStage(
        scorer=make_dense_scorer(params),
        policy=functools.partial(dense_keep_fraction, keep_frac=keep_frac),
    )


def make_config(sentinels, mode: str = "fused",
                query_exit: QueryExitConfig | None = None,
                dense: DenseStage | None = None) -> EngineConfig:
    """The harness's one way of building an EngineConfig (never kwargs)."""
    if dense is None:
        return EngineConfig.trees(
            tuple(sentinels), mode=mode, query_exit=query_exit
        )
    return EngineConfig.hybrid(
        dense, tuple(sentinels), mode=mode, query_exit=query_exit
    )


def run_mode(ranker: CascadeRanker, X, mask, sentinels, mode: str,
             query_exit: QueryExitConfig | None = None,
             dense: DenseStage | None = None):
    """One engine run; auto mode gets a fixed survivor estimate."""
    kw = dict(STRATEGY_KWARGS)
    if mode == "auto":
        n_stages = len(sentinels) + (1 if dense is not None else 0)
        kw.update(
            stage_ema=jnp.linspace(0.6, 0.2, n_stages) * mask.size,
            have_ema=True,
        )
    config = make_config(sentinels, mode, query_exit, dense)
    return ranker.rank_progressive(X, mask, config, **kw)


def run_all_modes(ranker, X, mask, sentinels,
                  query_exit: QueryExitConfig | None = None,
                  dense: DenseStage | None = None) -> dict:
    """Run {fused, staged, auto}; assert they agree bit-for-bit.

    Cross-mode bit-exactness holds on non-overflow batches (the harness
    problems are sized so capacities never clip) — the engine's core
    conformance contract, with or without query-level exit, and with or
    without a dense stage 0 (both modes score the tree head on the SAME
    dense-compacted block, so the per-block kernel sums carry over).
    """
    results = {
        m: run_mode(ranker, X, mask, sentinels, m, query_exit, dense)
        for m in ("fused", "staged", "auto")
    }
    ref = results["fused"]
    for m in ("staged", "auto"):
        got = results[m]
        np.testing.assert_array_equal(
            np.asarray(ref.scores), np.asarray(got.scores),
            err_msg=f"mode={m} scores diverge from fused",
        )
        np.testing.assert_array_equal(
            np.asarray(ref.continue_mask), np.asarray(got.continue_mask),
            err_msg=f"mode={m} final alive mask diverges from fused",
        )
        if query_exit is not None:
            np.testing.assert_array_equal(
                np.asarray(ref.query_exited), np.asarray(got.query_exited),
                err_msg=f"mode={m} query_exited diverges from fused",
            )
    return results


def oracle_progressive(ens: TreeEnsemble, X, mask, sentinels,
                       query_exit: QueryExitConfig | None = None,
                       dense: DenseStage | None = None):
    """From-scratch numpy replay of the progressive cascade.

    Prefix scores come from the pure ``partial_scores`` oracle (NOT the
    engine's kernel), stage decisions and query-level exit are replayed
    on host with the same predicate functions the engine traces. With
    ``dense`` the gate is replayed first (scorer + policy on the full
    grid; query-exit stage indices shift by one so the dense gate is
    stage 0, matching the engine) and dense-exited documents keep the
    dense score as their final score. Returns
    ``(scores, stage_masks, exited)`` — ``stage_masks`` leads with the
    dense gate's mask when a dense stage is present. Scores agree with
    the engine up to reassociation (compare with allclose); masks and
    exit flags agree exactly.
    """
    Q, D, F = X.shape
    flat = X.reshape(Q * D, F)
    prefixes = [
        np.asarray(partial_scores(ens, flat, s)[0]).reshape(Q, D)
        for s in sentinels
    ]
    head, tail = partial_scores(ens, flat, sentinels[-1])
    full = np.asarray(head + tail).reshape(Q, D)

    alive = np.asarray(mask).copy()
    exited = np.zeros(Q, bool)
    stage_masks = []

    def exit_queries(stage_idx, prefix, alive, exited):
        if query_exit is None or stage_idx < query_exit.from_stage:
            return alive, exited
        conv = np.asarray(query_converged(
            jnp.asarray(prefix), jnp.asarray(alive),
            k=query_exit.k, margin=query_exit.margin,
        ))
        exited = exited | conv
        return alive & ~exited[:, None], exited

    if dense is not None:
        d_scores = np.asarray(dense.scorer(flat)).reshape(Q, D)
        keep = np.asarray(
            dense.policy(jnp.asarray(d_scores), jnp.asarray(alive))
        )
        alive = alive & keep
        alive, exited = exit_queries(0, d_scores, alive, exited)
        stage_masks.append(alive.copy())
        # Hybrid score-update order: a doc exited at tree stage k keeps
        # the stage-k prefix it was just scored with; dense-exited docs
        # keep the dense score as their final score.
        scores = d_scores.copy()
        for k in range(len(sentinels)):
            scores = np.where(alive, prefixes[k], scores)
            cont = np.asarray(ept_continue(
                jnp.asarray(prefixes[k]), jnp.asarray(alive),
                **STRATEGY_KWARGS,
            ))
            alive = alive & cont
            alive, exited = exit_queries(k + 1, prefixes[k], alive, exited)
            stage_masks.append(alive.copy())
    else:
        scores = prefixes[0].copy()
        for k in range(len(sentinels)):
            cont = np.asarray(ept_continue(
                jnp.asarray(prefixes[k]), jnp.asarray(alive),
                **STRATEGY_KWARGS,
            ))
            alive = alive & cont
            alive, exited = exit_queries(k, prefixes[k], alive, exited)
            stage_masks.append(alive.copy())
            if k + 1 < len(sentinels):
                scores = np.where(alive, prefixes[k + 1], scores)
    if sentinels[-1] < ens.n_trees:
        scores = np.where(alive, full, scores)
    return scores, stage_masks, exited


def assert_matches_oracle(result, ens, X, mask, sentinels,
                          query_exit: QueryExitConfig | None = None,
                          dense: DenseStage | None = None):
    """Engine result vs the numpy replay: masks/flags exact, scores close."""
    scores, stage_masks, exited = oracle_progressive(
        ens, X, mask, sentinels, query_exit, dense
    )
    assert len(result.stage_masks) == len(stage_masks), (
        len(result.stage_masks), len(stage_masks)
    )
    for k, m in enumerate(stage_masks):
        np.testing.assert_array_equal(
            m, np.asarray(result.stage_masks[k]),
            err_msg=f"stage {k} alive mask diverges from oracle",
        )
    if query_exit is not None:
        np.testing.assert_array_equal(exited, np.asarray(result.query_exited))
    np.testing.assert_allclose(
        np.asarray(result.scores), scores, rtol=1e-5, atol=1e-5
    )


def expected_launches(mode: str, S: int, has_tail: bool,
                      query_exit_on: bool) -> dict:
    """The trace-time launch-count contract for one configuration.

    ``S`` counts TREE stages only: the dense gate of a hybrid config is
    pure XLA (one matmul, no Pallas dispatch), so a hybrid cascade has
    exactly the same launch plan as the all-trees cascade over its tree
    stages. Without query exit the tail is unconditional; with it the
    tail launch sits behind a run-time ``lax.cond`` and counts as
    "gated". ``mode="auto"`` traces BOTH branch bodies into one program,
    so its plan is the sum of the fused and staged plans.
    """
    tail = 1 if has_tail else 0
    gated = tail if query_exit_on else 0
    plain_tail = 0 if query_exit_on else tail
    fused_seg = 1 if S > 1 else 0       # S=1 head degenerates to plain
    fused_plain = (0 if S > 1 else 1) + plain_tail
    staged_plain = S + plain_tail
    if mode == "fused":
        return {"segmented": fused_seg, "plain": fused_plain, "gated": gated}
    if mode == "staged":
        return {"segmented": 0, "plain": staged_plain, "gated": gated}
    return {
        "segmented": fused_seg,
        "plain": fused_plain + staged_plain,
        "gated": 2 * gated,
    }


def measured_launches(ranker, X, mask, sentinels, mode: str,
                      query_exit: QueryExitConfig | None = None,
                      dense: DenseStage | None = None) -> dict:
    """Trace-time launch counts staged by ONE fresh-step run."""
    ops.reset_launch_counts()
    run_mode(ranker, X, mask, sentinels, mode, query_exit, dense)
    return ops.launch_counts()
