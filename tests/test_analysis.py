"""Tests for the tracer-safety analyzer (repro.analysis).

Three layers: the fixture corpus (each rule has a known-dirty and a
known-clean file), the suppression syntax, and the contract that the
REAL ``src/repro`` tree is clean — that last test is what makes the
analyzer a regression gate rather than a demo.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run_paths

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
SRC_REPRO = Path(__file__).resolve().parent.parent / "src" / "repro"
ALL_CODES = ("TS001", "TS002", "TS003", "TS004", "TS005", "TS006", "TS007")

EXPECTED_DIRTY_COUNTS = {
    "TS001": 3,  # float(), .item(), np.asarray via helper
    "TS002": 2,  # if + while on traced values
    "TS003": 3,  # bare jnp.sum + "+=" loop + reorder-root bare .sum()
    "TS004": 3,  # os.environ.get, os.getenv, os.environ[...]
    "TS005": 2,  # batcher.submit engine call + tier.stop warmup
    "TS006": 1,  # the second transfer site
    "TS007": 5,  # deque()/Queue() unbounded, while-True append,
    #              except BaseException, bare except
}


def _codes(path: Path) -> set[str]:
    return {f.code for f in run_paths([path])}


@pytest.mark.parametrize("code", ALL_CODES)
def test_dirty_fixture_flags_its_rule_and_only_it(code: str):
    findings = run_paths([FIXTURES / f"{code.lower()}_dirty.py"])
    assert {f.code for f in findings} == {code}
    assert len(findings) == EXPECTED_DIRTY_COUNTS[code]
    for f in findings:
        assert f.line > 0
        assert f.hint  # every finding carries its one-line fix
        assert code in f.format()


@pytest.mark.parametrize("code", ALL_CODES)
def test_clean_fixture_is_clean(code: str):
    assert _codes(FIXTURES / f"{code.lower()}_clean.py") == set()


def test_suppression_comment_silences_findings():
    # suppressed.py is ts001-dirty twice over, with both noqa placements
    assert _codes(FIXTURES / "suppressed.py") == set()


def test_suppression_is_code_specific():
    # the same dirty file WITHOUT matching codes must still flag:
    # selecting a different rule set proves noqa(TS001) does not blanket
    findings = run_paths([FIXTURES / "ts001_dirty.py"], codes=["TS001"])
    assert findings, "unsuppressed dirty fixture must flag"
    findings = run_paths([FIXTURES / "suppressed.py"], codes=["TS001"])
    assert findings == []


def test_real_tree_is_clean():
    findings = run_paths([SRC_REPRO])
    assert findings == [], "\n".join(f.format() for f in findings)


def _run_cli(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    src = str(SRC_REPRO.parent)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, timeout=120,
    )


def test_cli_exit_codes_and_json():
    dirty = _run_cli(str(FIXTURES / "ts001_dirty.py"), "--format", "json")
    assert dirty.returncode == 1
    payload = json.loads(dirty.stdout)
    assert all(f["code"] == "TS001" for f in payload)

    clean = _run_cli(str(FIXTURES / "ts001_clean.py"))
    assert clean.returncode == 0

    rules = _run_cli("--list-rules")
    assert rules.returncode == 0
    for code in ALL_CODES:
        assert code in rules.stdout


def test_check_invariants_cli_entry():
    tool = SRC_REPRO.parent.parent / "tools" / "check_invariants.py"
    proc = subprocess.run(
        [sys.executable, str(tool), str(FIXTURES / "ts006_dirty.py")],
        capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 1
    assert "TS006" in proc.stdout


def test_select_filters_rules():
    findings = run_paths(
        [FIXTURES / "ts001_dirty.py"], codes=["TS004"]
    )
    assert findings == []
