"""Public API surface pinned against a checked-in snapshot.

``repro.core``, ``repro.serve``, and ``repro.forest`` are the packages
in-repo callers (benchmarks, examples, the serving tier) and the docs
treat as the public API. This test describes every ``__all__`` export —
function signatures, dataclass fields with defaults, class constructor
signatures and public attributes — and compares the result to
``tests/fixtures/api_surface.json``.

A mismatch means the public surface changed. If the change is
intentional, regenerate the snapshot and review the diff like any other
contract change:

    PYTHONPATH=src python tests/test_api_surface.py --update

The snapshot runs in the CI ``invariants`` job next to the tracer-safety
analyzer and the type lane: signature drift fails the gate, not a
downstream caller.
"""

from __future__ import annotations

import dataclasses
import importlib
import inspect
import json
import os
import typing

MODULES = ("repro.core", "repro.serve", "repro.forest")
SNAPSHOT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "fixtures",
    "api_surface.json",
)


def _default_repr(field: dataclasses.Field) -> str:
    if field.default_factory is not dataclasses.MISSING:  # type: ignore
        return "<factory>"
    if field.default is dataclasses.MISSING:
        return "<required>"
    return repr(field.default)


def _public_members(obj: type) -> list[str]:
    """Methods/properties/classmethods defined BY this class (not bases)."""
    return sorted(
        name for name, val in vars(obj).items()
        if not name.startswith("_")
        and (callable(val)
             or isinstance(val, (property, classmethod, staticmethod)))
    )


def _describe(obj: object) -> dict:
    if isinstance(obj, type) and dataclasses.is_dataclass(obj):
        return {
            "kind": "dataclass",
            "frozen": obj.__dataclass_params__.frozen,  # type: ignore
            "fields": [
                [f.name, _default_repr(f)] for f in dataclasses.fields(obj)
            ],
            "members": _public_members(obj),
        }
    if isinstance(obj, type):
        if typing.get_origin(obj) is None and getattr(
            obj, "_is_protocol", False
        ):
            return {"kind": "protocol", "members": _public_members(obj)}
        try:
            init = str(inspect.signature(obj.__init__))
        except (TypeError, ValueError):
            init = "<opaque>"
        return {"kind": "class", "init": init,
                "members": _public_members(obj)}
    if callable(obj):
        try:
            sig = str(inspect.signature(obj))
        except (TypeError, ValueError):
            sig = "<opaque>"
        return {"kind": "function", "signature": sig}
    return {"kind": type(obj).__name__, "repr": repr(obj)}


def describe_surface() -> dict:
    surface: dict = {}
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exports = sorted(mod.__all__)
        surface[modname] = {
            "__all__": exports,
            "exports": {
                name: _describe(getattr(mod, name)) for name in exports
            },
        }
    return surface


def test_api_surface_matches_snapshot():
    with open(SNAPSHOT) as f:
        pinned = json.load(f)
    current = describe_surface()
    for modname in MODULES:
        assert modname in pinned, f"snapshot missing {modname} — regenerate"
        assert current[modname]["__all__"] == pinned[modname]["__all__"], (
            f"{modname}.__all__ drifted; if intentional: "
            "PYTHONPATH=src python tests/test_api_surface.py --update"
        )
        for name, desc in current[modname]["exports"].items():
            assert desc == pinned[modname]["exports"][name], (
                f"{modname}.{name} changed shape; if intentional: "
                "PYTHONPATH=src python tests/test_api_surface.py --update\n"
                f"pinned:  {pinned[modname]['exports'][name]}\n"
                f"current: {desc}"
            )
    # No extra modules silently riding in the snapshot.
    assert sorted(pinned) == sorted(MODULES)


def test_every_export_resolves():
    """__all__ never names something the module doesn't define."""
    for modname in MODULES:
        mod = importlib.import_module(modname)
        for name in mod.__all__:
            assert hasattr(mod, name), (modname, name)


if __name__ == "__main__":
    import sys

    if "--update" in sys.argv:
        with open(SNAPSHOT, "w") as f:
            json.dump(describe_surface(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {SNAPSHOT}")
    else:
        print(__doc__)
