"""Smoke coverage for the bench entry points ``check_bench.py`` never runs.

``benchmarks/check_bench.py`` exercises ``bench_kernels`` and
``bench_serve`` tiny in CI, but ``bench_table1`` needs the fully trained
experiment and (deliberately) has no smoke-scale mode. This module pins
that state explicitly: the :func:`smoke` gate must raise
``NotImplementedError`` (the test then SKIPS, visibly, instead of the
bench silently never being imported), and the moment someone implements
it the same test starts enforcing the Table-1 row schema.

Also pins the ``check_bench`` validator itself on hand-built payloads —
the ``tradeoff`` section contract in particular — without paying for a
bench run.
"""

from __future__ import annotations

import importlib
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _bench_module(name: str):
    """Import ``benchmarks.<name>`` with the repo root importable."""
    root = str(REPO)
    if root not in sys.path:
        sys.path.insert(0, root)
    return importlib.import_module(f"benchmarks.{name}")


def test_bench_table1_smoke_gate():
    """bench_table1.smoke() is explicitly NotImplemented; if that ever
    changes, the returned rows must follow the Table-1 schema."""
    bt = _bench_module("bench_table1")
    try:
        rows = bt.smoke()
    except NotImplementedError as exc:
        assert "trained experiment" in str(exc)
        pytest.skip(f"bench_table1 smoke mode not implemented: {exc}")
    assert rows, "smoke() implemented but returned no rows"
    assert rows[0]["method"] == "Full"
    for row in rows:
        assert {"method", "ndcg@10", "delta_pct", "speedup"} <= row.keys()


def test_bench_table1_full_entry_points_exist():
    """The real entry points keep their signatures (the nightly lane and
    README instructions call them by name)."""
    bt = _bench_module("bench_table1")
    assert callable(bt.run) and callable(bt.main)


def _minimal_tradeoff_section() -> dict:
    config = {
        "name": "lear", "ndcg10": 0.9, "delta_pct": 0.0,
        "trees_traversed": 1000.0, "trees_vs_lear": 1.0,
        "wall_us": 10.0, "meets_ndcg_bar": True,
    }
    configs = [dict(config)]
    for name in ("lear+query_exit", "lear+reorder", "lear+query_exit+reorder"):
        configs.append({**config, "name": name, "trees_vs_lear": 0.8})
    return {"configs": configs, "ndcg_full": 0.91, "ndcg_bar_delta_pct": 0.5}


def test_check_bench_requires_tradeoff_section():
    cb = _bench_module("check_bench")
    assert "tradeoff" in cb.REQUIRED_SECTIONS
    problems = cb.validate({s: {} for s in cb.REQUIRED_SECTIONS if s != "tradeoff"})
    assert any("tradeoff" in p for p in problems)


@pytest.mark.parametrize(
    "mutate, fragment",
    [
        (lambda td: td["configs"].pop(), "missing config"),
        (lambda td: td["configs"][1].update(trees_vs_lear=1.2),
         "trees_vs_lear"),
        (lambda td: td["configs"][2].update(meets_ndcg_bar=False),
         "NDCG bar"),
        (lambda td: td["configs"][0].update(wall_us=float("nan")),
         "wall_us"),
        (lambda td: td["configs"][3].update(trees_traversed=0.0),
         "trees_traversed"),
    ],
)
def test_check_bench_tradeoff_contract_violations(mutate, fragment):
    """Each tradeoff-section contract violation produces a finding."""
    cb = _bench_module("check_bench")
    td = _minimal_tradeoff_section()
    mutate(td)
    problems = cb.validate_tradeoff(td)
    assert any(fragment in p for p in problems), problems


def test_check_bench_tradeoff_accepts_valid_section():
    cb = _bench_module("check_bench")
    assert cb.validate_tradeoff(_minimal_tradeoff_section()) == []
