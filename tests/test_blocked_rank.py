"""Blocked pairwise-count ranking ≡ direct ≡ stable-argsort, exactly.

The blocked compare tiles the D×D predicate but counts the SAME pairs with
the SAME float comparisons and index tie-break, so its ranks must equal
the direct path's and the stable-argsort oracle's integer-for-integer —
across the auto cutoff, on tie-heavy inputs, under masks, and for
non-tile-multiple candidate counts (where -inf padding must never beat a
real document).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.features import (
    RANK_BLOCK_D,
    RANK_BLOCKED_MIN_D,
    augment_features,
    query_ranks,
    query_ranks_blocked,
    query_ranks_direct,
)
from repro.metrics.ranking import rank_from_scores


def _assert_all_equal(s, m):
    oracle = np.asarray(rank_from_scores(s, m))
    direct = np.asarray(query_ranks_direct(s, m))
    blocked = np.asarray(query_ranks_blocked(s, m))
    auto = np.asarray(query_ranks(s, m))
    np.testing.assert_array_equal(direct, oracle)
    np.testing.assert_array_equal(blocked, oracle)
    np.testing.assert_array_equal(auto, oracle)


@pytest.mark.parametrize(
    "D", [8, 64, RANK_BLOCK_D, RANK_BLOCKED_MIN_D, RANK_BLOCKED_MIN_D + 1,
          300, 513]
)
def test_blocked_equals_argsort_across_cutoff(D):
    rng = np.random.default_rng(D)
    Q = 3
    s = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    m = jnp.asarray(rng.random((Q, D)) < 0.8)
    _assert_all_equal(s, m)


@pytest.mark.parametrize("D", [96, 257, 400])
def test_blocked_tie_heavy(D):
    """Scores on a tiny integer grid: masses of exact ties, resolved by
    the document-index tie-break — the semantics the blocked tiling must
    not perturb at tile borders."""
    rng = np.random.default_rng(1000 + D)
    Q = 4
    s = jnp.asarray(rng.integers(0, 3, size=(Q, D)).astype(np.float32))
    m = jnp.asarray(rng.random((Q, D)) < 0.9)
    _assert_all_equal(s, m)


def test_blocked_all_equal_scores_full_and_empty_mask():
    D = RANK_BLOCKED_MIN_D + 59   # non-multiple of the tile edge
    s = jnp.zeros((2, D), jnp.float32)
    _assert_all_equal(s, jnp.ones((2, D), bool))
    _assert_all_equal(s, jnp.zeros((2, D), bool))


def test_blocked_small_tiles_exercise_multi_block():
    """A tiny block_d forces many row/column tiles (including ragged last
    tiles) on a small D — the loop structure itself under test."""
    rng = np.random.default_rng(2)
    s = jnp.asarray(rng.integers(0, 4, size=(3, 45)).astype(np.float32))
    m = jnp.asarray(rng.random((3, 45)) < 0.7)
    got = np.asarray(query_ranks_blocked(s, m, block_d=16))
    np.testing.assert_array_equal(got, np.asarray(rank_from_scores(s, m)))


def test_query_ranks_dispatch():
    rng = np.random.default_rng(3)
    small = jnp.asarray(
        rng.normal(size=(2, RANK_BLOCKED_MIN_D)).astype(np.float32)
    )
    large = jnp.asarray(
        rng.normal(size=(2, RANK_BLOCKED_MIN_D + 1)).astype(np.float32)
    )
    m_small = jnp.ones(small.shape, bool)
    m_large = jnp.ones(large.shape, bool)
    # Explicit methods agree with auto on both sides of the cutoff.
    for s, m in ((small, m_small), (large, m_large)):
        np.testing.assert_array_equal(
            np.asarray(query_ranks(s, m)),
            np.asarray(query_ranks(s, m, method="direct")),
        )
        np.testing.assert_array_equal(
            np.asarray(query_ranks(s, m)),
            np.asarray(query_ranks(s, m, method="blocked")),
        )


def test_augment_features_identical_above_cutoff():
    """The device-resident feature build is unchanged by the blocked
    dispatch: augmented features above the cutoff equal a direct-ranked
    build exactly."""
    rng = np.random.default_rng(4)
    Q, D, F = 2, RANK_BLOCKED_MIN_D + 64, 5
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    partial = jnp.asarray(rng.integers(0, 5, size=(Q, D)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.85)
    aug = np.asarray(augment_features(X, partial, mask))
    # Rebuild the rank feature from the direct path: identical plane.
    ranks = np.asarray(
        query_ranks_direct(partial, mask)
    ).astype(np.float32)
    np.testing.assert_array_equal(
        aug[..., F + 1], np.where(np.asarray(mask), ranks, 0.0)
    )
