"""Launch-overhead calibration: probe arithmetic, process cache, auto wiring.

The probe itself is timed with a FAKE clock (a stand-in ``time`` module
injected into the calibration module's namespace) so the solved
``launch_overhead_trees`` is a deterministic function of the scripted
timings — the kernel still runs, only the measurement is scripted.
"""

import types

import numpy as np
import pytest

from repro.core.lear import LearClassifier
from repro.forest.ensemble import random_ensemble
from repro.serve import calibration
from repro.serve.ranking_service import RankingService, ServiceConfig

import jax
import jax.numpy as jnp

# Non-default probe shape: its cache key must never collide with the
# serving default (128, 64, 16) other tests may have populated.
PROBE = dict(n_docs=100, n_trees=32, block_t=8, iters=1)


@pytest.fixture(autouse=True)
def _isolated_cache():
    saved = dict(calibration._CALIBRATION_CACHE)
    calibration._CALIBRATION_CACHE.clear()
    yield
    calibration._CALIBRATION_CACHE.clear()
    calibration._CALIBRATION_CACHE.update(saved)


def _fake_clock(monkeypatch, times):
    """Script perf_counter readings (seconds). Patches only the calibration
    module's view of ``time`` — jax's own timers stay real."""
    seq = iter(times)
    fake = types.SimpleNamespace(perf_counter=lambda: next(seq))
    monkeypatch.setattr(calibration, "time", fake)


def test_probe_solves_scripted_timings(monkeypatch):
    """t_small=2000µs, t_full=5000µs at the PROBE shape solve to exactly
    800 doc·tree equivalents:
    per_doctree = 3000 / (100·24) = 1.25 µs;
    overhead = (2000 − 1.25·100·8) / 1.25 = 800."""
    _fake_clock(monkeypatch, [0.0, 2000e-6, 1.0, 1.0 + 5000e-6])
    got = calibration.calibrate_launch_overhead_trees(**PROBE)
    assert got == pytest.approx(800.0)
    report = calibration.last_calibration()
    assert report["launch_overhead_trees"] == pytest.approx(800.0)
    assert report["per_doctree_us"] == pytest.approx(1.25)


def test_degenerate_probe_falls_back_to_default(monkeypatch):
    """A noisy box where the small launch out-times the big one must not
    produce a negative/zero overhead — it falls back to the default."""
    _fake_clock(monkeypatch, [0.0, 5000e-6, 1.0, 1.0 + 5000e-6])
    got = calibration.calibrate_launch_overhead_trees(**PROBE)
    assert got == calibration.DEFAULT_LAUNCH_OVERHEAD_TREES


def test_calibration_cached_per_process(monkeypatch):
    _fake_clock(monkeypatch, [0.0, 2000e-6, 1.0, 1.0 + 5000e-6])
    first = calibration.calibrate_launch_overhead_trees(**PROBE)
    # Second call: any clock read would exhaust the scripted sequence and
    # raise StopIteration — a cache hit never touches the timer.
    second = calibration.calibrate_launch_overhead_trees(**PROBE)
    assert second == first
    assert len(calibration._CALIBRATION_CACHE) == 1
    # A different probe shape is a different key, not a stale hit.
    with pytest.raises(StopIteration):
        calibration.calibrate_launch_overhead_trees(
            n_docs=PROBE["n_docs"] + 1, n_trees=32, block_t=8, iters=1
        )


def test_record_path_merges_not_clobbers(monkeypatch, tmp_path):
    _fake_clock(monkeypatch, [0.0, 2000e-6, 1.0, 1.0 + 5000e-6])
    path = tmp_path / "BENCH.json"
    path.write_text('{"other_section": {"kept": true}}\n')
    calibration.calibrate_launch_overhead_trees(**PROBE, record_path=str(path))
    import json

    doc = json.loads(path.read_text())
    assert doc["other_section"] == {"kept": True}
    assert doc["launch_calibration"]["launch_overhead_trees"] == (
        pytest.approx(800.0)
    )


def test_auto_flows_into_service_and_device_cost_model():
    """``launch_overhead_trees="auto"`` resolves through the process cache
    into the service AND into the static config of the compiled step (the
    device cost model prices launches at exactly the calibrated value)."""
    key = (jax.default_backend(), 128, 64, 16)  # the serving default probe
    calibration._CALIBRATION_CACHE[key] = {"launch_overhead_trees": 777.0}

    ens = random_ensemble(0, n_trees=64, depth=3, n_features=8)
    clfs = [
        LearClassifier(
            forest=random_ensemble(50 + i, n_trees=4, depth=2, n_features=12),
            sentinel=s,
        )
        for i, s in enumerate((8, 28))
    ]
    svc = RankingService(
        ens, clfs[0],
        ServiceConfig(execution_mode="auto", launch_overhead_trees="auto"),
        extra_classifiers=clfs[1:],
    )
    assert svc.launch_overhead_trees == 777.0

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(1, 32, 8)).astype(np.float32))
    svc.rank_batch(X, jnp.ones((1, 32), bool))
    keys = list(svc.cascade._step_cache)
    assert keys, "no compiled step cached"
    assert any(777.0 in k for k in keys), keys
