"""Chaos suite: the fault-tolerance contracts, proven by injection.

Every test here drives the REAL batcher/supervisor/degradation machinery
with injected faults (tests/faults.py) and asserts the tentpole claims:

- a worker crash fails exactly the in-flight bucket, queued requests
  survive the restart, and the tier serves again;
- exhausting the restart budget fails everything TYPED — no future is
  ever left unresolved, before, during, or after the failure;
- a load spike is shed (:class:`Overloaded`) and degraded (rung ladder),
  never absorbed into unbounded queue growth;
- degraded responses are bit-exact with a standalone service configured
  as that rung — degradation changes WHICH configuration serves, not the
  numerics of serving it;
- every installed rung is AOT-warmed: stepping the ladder never jits.

Most tests use :class:`tests.faults.FakeService` (no jax, milliseconds);
the bit-exactness and warmup proofs use the real engine.
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp
import jax._src.test_util as jtu

from faults import (
    CrashTimes,
    FakeClock,
    FakeService,
    InjectedEngineError,
    PoisonOnce,
    settle,
    spike,
)
from repro.core.lear import LearClassifier
from repro.core.strategies import QueryExitConfig
from repro.forest.ensemble import random_ensemble
from repro.serve.batching import BatcherHooks, BucketPolicy, ContinuousBatcher
from repro.serve.degradation import (
    DegradationController,
    DegradationPolicy,
    ExitRung,
)
from repro.serve.errors import (
    BatcherStopped,
    Overloaded,
    WorkerCrashed,
    WorkerFailed,
)
from repro.serve.ranking_service import RankingService, ServiceConfig
from repro.serve.warmup import warmup_service

pytestmark = pytest.mark.chaos

F = 12


def _query(n_docs: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n_docs, F)).astype(np.float32)


def _batcher(svc, policy=None, **kw) -> ContinuousBatcher:
    b = ContinuousBatcher(svc, F, policy or BucketPolicy(), **kw)
    b.start()
    return b


def _assert_scores(scores: np.ndarray, q: np.ndarray) -> None:
    np.testing.assert_allclose(
        scores, FakeService.expected_scores(q), rtol=1e-6
    )


# -- supervision ----------------------------------------------------------


def test_worker_crash_restarts_and_serves_again():
    svc = FakeService()
    crash = CrashTimes(1)
    b = _batcher(
        svc,
        BucketPolicy(max_queries=1, max_wait_ms=1.0),
        hooks=BatcherHooks(on_flush=crash),
        backoff_base_s=0.002,
    )
    q = _query(16)
    with pytest.raises(WorkerCrashed):
        b.submit(q).result(timeout=30)
    assert crash.fired == 1

    # The supervisor restarted the worker: the tier serves again.
    _top, scores = b.submit(q).result(timeout=30)
    _assert_scores(scores, q)
    h = b.health()
    assert h["state"] == "running"
    assert h["crashes"] == 1 and h["restarts"] == 1
    assert "InjectedCrash" in h["last_error"]
    b.stop()
    assert b.stats.worker_crashes == 1
    assert b.stats.completed == 1 and b.stats.failed == 1


def test_queued_requests_survive_a_crash():
    """A crash fails exactly the in-flight bucket; requests still queued
    in OTHER buckets are served after the restart."""
    clock = FakeClock()
    svc = FakeService()
    crash = CrashTimes(1)
    b = _batcher(
        svc,
        BucketPolicy(max_queries=8, max_wait_ms=5.0),
        clock=clock,
        hooks=BatcherHooks(on_flush=crash),
        backoff_base_s=0.002,
    )
    # Queued survivor first (bucket 16; virtual timer frozen → it waits)...
    survivor_q = _query(16, seed=7)
    survivor = b.submit(survivor_q)
    # ...then a FULL bucket-8 flush, which the hook kills mid-air.
    doomed = [b.submit(_query(8, seed=i)) for i in range(8)]
    _, errors = settle(doomed)
    assert len(errors) == 8
    assert all(isinstance(e, WorkerCrashed) for e in errors)

    clock.advance(10.0)  # ripen the survivor's flush timer
    _top, scores = survivor.result(timeout=30)
    _assert_scores(scores, survivor_q)
    b.stop()
    assert b.stats.worker_crashes == 1
    assert b.stats.completed == 1 and b.stats.failed == 8


def test_restart_budget_exhaustion_fails_everything_typed():
    svc = FakeService()
    crash = CrashTimes(10)  # far more faults than the budget tolerates
    b = _batcher(
        svc,
        BucketPolicy(max_queries=1, max_wait_ms=1.0),
        hooks=BatcherHooks(on_flush=crash),
        max_restarts=1,
        backoff_base_s=0.002,
    )
    futs = spike(b, 4, _query(16))
    results, errors = settle(futs)
    assert results == [] and len(errors) == 4
    assert all(
        isinstance(e, (WorkerCrashed, WorkerFailed)) for e in errors
    )
    assert any(isinstance(e, WorkerFailed) for e in errors)

    # The batcher is failed, permanently and typed.
    with pytest.raises(WorkerFailed):
        b.submit(_query(16))
    assert b.health()["state"] == "failed"
    b.stop()
    assert b.health()["state"] == "failed"  # survives stop()


def test_engine_error_fails_bucket_and_loop_survives():
    svc = FakeService()
    b = _batcher(svc, BucketPolicy(max_queries=2, max_wait_ms=2.0))
    svc.fail_next(1)
    futs = [b.submit(_query(8, seed=i)) for i in range(2)]
    _, errors = settle(futs)
    assert len(errors) == 2
    assert all(isinstance(e, InjectedEngineError) for e in errors)

    # An engine error is contained: not a crash, and the next bucket works.
    q = _query(8, seed=9)
    _top, scores = b.submit(q).result(timeout=30)
    _assert_scores(scores, q)
    assert b.health()["crashes"] == 0
    b.stop()
    assert b.stats.worker_crashes == 0


def test_poisoned_batch_fails_one_request_only():
    svc = FakeService()
    b = _batcher(
        svc,
        BucketPolicy(max_queries=4, max_wait_ms=2.0),
        hooks=BatcherHooks(on_result=PoisonOnce()),
    )
    qs = [_query(16, seed=i) for i in range(4)]
    futs = [b.submit(q) for q in qs]
    results, errors = settle(futs)
    # Exactly one request is poisoned; its bucket-mates complete normally.
    assert len(errors) == 1 and isinstance(errors[0], InjectedEngineError)
    assert len(results) == 3
    assert svc.calls == 1  # one padded block served all four
    served = [i for i, f in enumerate(futs) if f.exception() is None]
    for i, (_top, scores) in zip(served, results):
        _assert_scores(scores, qs[i])
    b.stop()
    assert b.stats.completed == 3 and b.stats.failed == 1
    assert b.stats.worker_crashes == 0


# -- overload -------------------------------------------------------------


def test_load_spike_sheds_and_queue_stays_bounded():
    svc = FakeService(latency_s=0.002)
    b = _batcher(
        svc,
        BucketPolicy(max_queries=8, max_wait_ms=1.0, max_queue_depth=8),
    )
    q = _query(16)
    futs = spike(b, 300, q)
    results, errors = settle(futs, timeout_s=60)
    assert len(results) + len(errors) == 300
    assert all(isinstance(e, Overloaded) for e in errors)
    assert b.stats.shed_overload == len(errors) > 0
    # Admission control held: observed depth never exceeded the bound.
    assert b.stats.max_queue_depth <= 8
    assert 0.0 < b.stats.shed_rate < 1.0
    for _top, scores in results:
        _assert_scores(scores, q)
    b.stop()
    assert b.health()["queue_depth"] == 0


def test_load_spike_degrades_then_recovers():
    """Sustained queue delay walks the rung ladder down; calm traffic
    walks it back up. The controller only ever touches the service from
    the worker thread, with pointer swaps the FakeService records."""
    svc = FakeService(latency_s=0.003)
    policy = DegradationPolicy(
        rungs=(
            ExitRung("tight", threshold=0.9),
            ExitRung("tighter", threshold=0.95),
        ),
        degrade_above_ms=5.0,
        recover_below_ms=2.0,
        ema_alpha=0.5,
        dwell_flushes=1,
    )
    ctrl = DegradationController(svc, policy)
    ctrl.install()
    assert svc.n_rungs == 3  # baseline + 2 rungs
    b = _batcher(
        svc,
        BucketPolicy(max_queries=1, max_wait_ms=0.2, max_queue_depth=None),
        degradation=ctrl,
    )
    q = _query(16)
    futs = [b.submit(q) for _ in range(120)]
    settle(futs, timeout_s=60)
    snap = ctrl.snapshot()
    assert snap["degrade_steps"] >= 1
    assert max(svc.rung_history) >= 1

    # Calm trickle traffic: the delay EMA decays below the recovery
    # threshold and the ladder steps back to baseline.
    deadline = time.monotonic() + 30.0
    while ctrl.level != 0:
        assert time.monotonic() < deadline, ctrl.snapshot()
        b.submit(q).result(timeout=30)
    snap = ctrl.snapshot()
    assert snap["level"] == 0 and snap["rung"] == "baseline"
    assert snap["recover_steps"] >= 1
    b.stop()


# -- stop/submit races ----------------------------------------------------


def test_submit_during_drain_is_never_lost():
    """Race submits against stop(): every future either resolves with a
    result or raises a typed error — silently dropping a request into a
    dict nobody flushes is the bug this pins down."""
    for round_seed in range(5):
        svc = FakeService()
        b = _batcher(svc, BucketPolicy(max_queries=4, max_wait_ms=0.5))
        q = _query(16, seed=round_seed)
        futs: list = []
        stop_now = threading.Event()

        def hammer():
            while not stop_now.is_set():
                futs.extend(spike(b, 5, q))

        t = threading.Thread(target=hammer)
        t.start()
        time.sleep(0.02)
        stop_now.set()
        b.stop()
        t.join()
        results, errors = settle(futs, timeout_s=30)
        assert len(results) + len(errors) == len(futs)
        # Admitted requests were served; racing ones got a typed
        # rejection — stop (drain handoff) or shed (admission control).
        assert all(
            isinstance(e, (BatcherStopped, Overloaded)) for e in errors
        )
        for _top, scores in results:
            _assert_scores(scores, q)
        assert b.stats.completed == len(results)


def test_no_future_unresolved_across_random_interleavings():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    op = st.one_of(
        st.tuples(
            st.just("submit"),
            st.integers(min_value=1, max_value=40),
            st.sampled_from([None, 0.0, 5.0, 1000.0]),
        ),
        st.just(("crash",)),
        st.just(("engine_fail",)),
        st.just(("pause",)),
    )

    @hypothesis.settings(
        max_examples=15, deadline=None, derandomize=True,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    @hypothesis.given(ops=st.lists(op, min_size=1, max_size=30))
    def run(ops):
        svc = FakeService()
        crash = CrashTimes(0)  # armed per "crash" op below
        b = _batcher(
            svc,
            BucketPolicy(max_queries=2, max_wait_ms=0.5, max_queue_depth=16),
            hooks=BatcherHooks(on_flush=crash),
            max_restarts=3,
            backoff_base_s=0.001,
        )
        futs = []
        for item in ops:
            if item[0] == "submit":
                _, n_docs, deadline_ms = item
                futs.extend(spike(b, 1, _query(n_docs), deadline_ms))
            elif item[0] == "crash":
                with crash._lock:
                    crash.remaining += 1
            elif item[0] == "engine_fail":
                svc.fail_next(1)
            else:
                time.sleep(0.002)
        b.stop()
        results, errors = settle(futs, timeout_s=30)
        # THE invariant: every submitted request resolved, one way or the
        # other — no interleaving of submit/crash/fail/stop strands one.
        assert len(results) + len(errors) == len(futs)

    run()


# -- degraded-mode numerics (real engine) ---------------------------------


def _real_service(threshold=0.4, query_exit=None):
    ens = random_ensemble(0, n_trees=64, depth=4, n_features=F)
    clf = LearClassifier(
        forest=random_ensemble(100, n_trees=10, depth=3, n_features=16),
        sentinel=8,
    )
    return RankingService(
        ens, clf,
        ServiceConfig(
            threshold=threshold,
            execution_mode="fused",
            launch_overhead_trees=512.0,
            query_exit=query_exit,
        ),
    )


def test_degraded_rung_is_bitexact_with_standalone_config():
    """Serving at rung N is the SAME computation as a service built with
    that rung's knobs from scratch — degradation trades quality via the
    paper's exit knobs, never via approximate serving."""
    rung_qe = QueryExitConfig(k=5, margin=2.0)
    svc = _real_service(threshold=0.4)
    svc.install_rungs((
        ExitRung("tight", threshold=0.7),
        ExitRung("margin", threshold=0.7, query_exit=rung_qe),
    ))
    X = jnp.asarray(_query(32, seed=3)[None])
    mask = jnp.ones((1, 32), bool)

    svc.set_rung(1)
    top_1, sc_1 = svc.rank_batch(X, mask)
    svc.set_rung(2)
    top_2, sc_2 = svc.rank_batch(X, mask)
    svc.set_rung(0)

    ref_tight = _real_service(threshold=0.7)
    t_ref, s_ref = ref_tight.rank_batch(X, mask)
    np.testing.assert_array_equal(np.asarray(sc_1), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(top_1), np.asarray(t_ref))

    ref_margin = _real_service(threshold=0.7, query_exit=rung_qe)
    t_ref, s_ref = ref_margin.rank_batch(X, mask)
    np.testing.assert_array_equal(np.asarray(sc_2), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(top_2), np.asarray(t_ref))

    # Baseline numerics are untouched by the ladder having been installed.
    base = _real_service(threshold=0.4)
    t_ref, s_ref = base.rank_batch(X, mask)
    _topb, scb = svc.rank_batch(X, mask)
    np.testing.assert_array_equal(np.asarray(scb), np.asarray(s_ref))


def test_rung_warmup_leaves_zero_post_warmup_lowerings():
    """Every rung of the ladder is AOT-compiled by warmup: stepping the
    ladder afterwards — at peak load — never triggers a jit."""
    svc = _real_service(threshold=0.4)
    svc.install_rungs((
        ExitRung("tight", threshold=0.7),
        ExitRung("margin", threshold=0.9, query_exit=QueryExitConfig(k=5, margin=2.0)),
    ))
    report = warmup_service(svc, F, [(1, 32)])
    assert report.rungs_warmed == 3
    assert svc.rung_level == 0  # warmup hands traffic the baseline

    X = jnp.asarray(_query(32, seed=5)[None])
    mask = jnp.ones((1, 32), bool)
    with jtu.count_jit_and_pmap_lowerings() as count:
        for level in (0, 1, 2, 1, 0):
            svc.set_rung(level)
            svc.rank_batch(X, mask)
    assert count[0] == 0, f"{count[0]} recompiles while stepping rungs"
