"""Checkpoint/restart + elastic re-mesh (fault-tolerance substrate)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TokenPipeline
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.optimizer import adamw, adafactor, adagrad_rowwise
from repro.train.trainer import init_state, make_train_step


def _toy_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _toy_params(key):
    return {
        "w": jax.random.normal(key, (4, 2)),
        "b": jnp.zeros((2,)),
        "nested": [(jnp.ones((3,)), jnp.zeros((3,)))],
    }


def test_save_restore_roundtrip(tmp_path):
    opt = adamw(1e-2)
    state = init_state(_toy_params(jax.random.key(0)), opt)
    path = str(tmp_path)
    save_checkpoint(path, 7, state, extra={"pipeline": {"cursor": 3, "seed": 0}})
    assert latest_step(path) == 7
    restored, extra = restore_checkpoint(path, state)
    assert extra["pipeline"]["cursor"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_path):
    opt = adamw(1e-2)
    state = init_state(_toy_params(jax.random.key(0)), opt)
    for s in range(6):
        save_checkpoint(str(tmp_path), s, state, keep_last=2)
    assert latest_step(str(tmp_path)) == 5
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2


def test_training_resume_is_exact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    opt = adamw(1e-2)
    step = jax.jit(make_train_step(_toy_loss, opt))

    def batch_at(i):
        r = np.random.default_rng(100 + i)
        return {"x": jnp.asarray(r.normal(size=(8, 4)).astype(np.float32)),
                "y": jnp.asarray(r.normal(size=(8, 2)).astype(np.float32))}

    s1 = init_state(_toy_params(jax.random.key(1)), opt)
    for i in range(6):
        s1, _ = step(s1, batch_at(i))

    s2 = init_state(_toy_params(jax.random.key(1)), opt)
    for i in range(3):
        s2, _ = step(s2, batch_at(i))
    save_checkpoint(str(tmp_path), 3, s2, extra={"step": 3})
    s2r, extra = restore_checkpoint(str(tmp_path), s2)
    for i in range(int(extra["step"]), 6):
        s2r, _ = step(s2r, batch_at(i))

    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2r.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_pipeline_cursor_resume():
    p1 = TokenPipeline(vocab_size=100, batch_size=2, seq_len=8, seed=5)
    p1.next_batch()
    saved = p1.state()
    b1 = p1.next_batch()
    p2 = TokenPipeline(vocab_size=100, batch_size=2, seq_len=8, seed=5)
    p2.restore(saved)
    b2 = p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


@pytest.mark.parametrize("make_opt", [adamw, adafactor, adagrad_rowwise])
def test_optimizers_reduce_loss(make_opt):
    opt = make_opt(5e-2)
    step = jax.jit(make_train_step(_toy_loss, opt))
    state = init_state(_toy_params(jax.random.key(2)), opt)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(64, 4)).astype(np.float32))
    w_true = r.normal(size=(4, 2)).astype(np.float32)
    batch = {"x": x, "y": x @ w_true}
    first = None
    for _ in range(120):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < 0.5 * first


def test_microbatch_accumulation_matches_full_batch():
    opt = adamw(1e-2)
    full = jax.jit(make_train_step(_toy_loss, opt))
    micro = jax.jit(make_train_step(_toy_loss, opt, microbatch=4))
    r = np.random.default_rng(2)
    batch = {"x": jnp.asarray(r.normal(size=(16, 4)).astype(np.float32)),
             "y": jnp.asarray(r.normal(size=(16, 2)).astype(np.float32))}
    s0 = init_state(_toy_params(jax.random.key(3)), opt)
    s_full, m_full = full(s0, batch)
    s_micro, m_micro = micro(s0, batch)
    # Mean-of-chunk-losses == full-batch loss for equal chunk sizes.
    np.testing.assert_allclose(float(m_full["loss"]), float(m_micro["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
