"""Data substrate: synthetic LETOR calibration, pipelines, graph sampler."""

import numpy as np

from repro.data.graph_sampler import CSRGraph, sample_neighbors
from repro.data.pipeline import QueryBatcher, TokenPipeline
from repro.data.synthetic import PRESETS, make_letor_dataset


def test_label_distribution_calibration():
    for preset in ("msn1", "istella"):
        ds = make_letor_dataset(preset, n_queries=300, docs_scale=0.3, seed=0)
        labels = ds.labels[ds.mask]
        frac0 = float((labels == 0).mean())
        target = PRESETS[preset].label_probs[0]
        assert abs(frac0 - target) < 0.03, (preset, frac0, target)


def test_feature_count_and_splits():
    ds = make_letor_dataset("istella", n_queries=100, docs_scale=0.2)
    assert ds.X.shape[-1] == 220
    splits = ds.splits()
    total = sum(s.n_queries for s in splits.values())
    assert total == 100
    assert splits["train"].n_queries == 60


def test_informative_features_correlate():
    ds = make_letor_dataset("msn1", n_queries=200, docs_scale=0.3, seed=1)
    labels = ds.labels[ds.mask].astype(np.float64)
    # Mean |corr| over the informative block vs the trailing noise block
    # (individual features have randomized slopes/noise scales).
    n_inf = max(4, ds.X.shape[-1] * 3 // 10)
    c_inf = np.mean([abs(np.corrcoef(labels, ds.X[ds.mask][:, j])[0, 1])
                     for j in range(n_inf)])
    c_noise = np.mean([abs(np.corrcoef(labels, ds.X[ds.mask][:, -j])[0, 1])
                       for j in range(1, 11)])
    assert c_inf > 0.15 and c_noise < 0.05, (c_inf, c_noise)


def test_token_pipeline_determinism_and_sharding():
    a = TokenPipeline(vocab_size=1000, batch_size=2, seq_len=16, seed=1)
    b = TokenPipeline(vocab_size=1000, batch_size=2, seq_len=16, seed=1)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])
    # Different hosts draw different streams.
    c = TokenPipeline(vocab_size=1000, batch_size=2, seq_len=16, seed=1,
                      host_index=1, num_hosts=2)
    assert not np.array_equal(a.next_batch()["tokens"], c.next_batch()["tokens"])
    assert a.next_batch()["tokens"].max() < 1000


def test_query_batcher_wraps():
    qb = QueryBatcher(n_queries=10, batch_queries=4)
    seen = [qb.next_indices() for _ in range(3)]
    assert seen[2].max() < 10
    assert qb.state()["cursor"] == 2  # 12 mod 10


def test_neighbor_sampler_block_validity():
    g = CSRGraph.random(n_nodes=500, avg_degree=8, seed=0)
    seeds = np.arange(32)
    block = sample_neighbors(g, seeds, fanouts=(5, 3), seed=1)
    n = block["nodes"].shape[0]
    assert block["edge_src"].max() < n
    assert block["edge_dst"].max() < n
    assert int(block["n_seeds"]) == 32
    # All seed nodes come first.
    np.testing.assert_array_equal(np.sort(block["nodes"][:32]), seeds)
    # Edge count bounded by the fanout budget.
    assert block["edge_src"].shape[0] <= 32 * 5 + 32 * 5 * 3
