"""Distribution substrate on a local mesh + an 8-device subprocess check.

The full 512-device path is exercised by repro.launch.dryrun; here we test
the pieces that must hold on any mesh: rule resolution, constraint no-ops
without rules, elastic re-mesh divisibility validation, and a real 8-device
SPMD train step in a subprocess (XLA device count is process-global, so the
multi-device case cannot run in-process).
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    constrain,
    local_rules,
    multi_pod_rules,
    sharding_rules,
    single_pod_rules,
)
from repro.launch.mesh import make_local_mesh
from repro.train.elastic import remesh, validate_divisibility

# jax.sharding.set_mesh / AxisType landed after 0.4.x; tests that depend on
# the newer explicit-mesh API are capability-skipped on older runtimes.
requires_set_mesh = pytest.mark.skipif(
    not hasattr(jax.sharding, "set_mesh"),
    reason="jax.sharding.set_mesh not available in this jax version",
)


def test_rules_resolution():
    r = single_pod_rules()
    assert r.resolve("batch", None, "ff") == P(("data",), None, "model")
    m = multi_pod_rules()
    assert m.resolve("batch") == P(("pod", "data"))
    assert m.resolve("experts") == P("model")
    with pytest.raises(KeyError):
        r.resolve("nonexistent")


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)  # no rules context → identity
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@requires_set_mesh
def test_constrain_under_local_mesh():
    mesh = make_local_mesh()
    with sharding_rules(single_pod_rules()), jax.sharding.set_mesh(mesh):
        y = jax.jit(lambda v: constrain(v, "batch", "ff"))(jnp.ones((4, 4)))
    assert y.shape == (4, 4)


def test_validate_divisibility():
    mesh = make_local_mesh()  # 1×1 — everything divides
    tree = {"w": jnp.ones((6, 4))}
    logical = {"w": ("batch", "ff")}
    assert validate_divisibility(tree, logical, single_pod_rules(), mesh) == []


def test_remesh_roundtrip_local():
    mesh = make_local_mesh()
    tree = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    logical = {"w": (None, None), "b": (None,)}
    out = remesh(tree, logical, local_rules(), mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


_SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.sharding import sharding_rules, Rules
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.models.api import make_cell
from repro.models.synth import synthesize_inputs

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = Rules(table={
    "batch": ("data",), "groups": ("data",), "edges": ("data",),
    "seq": None, "embed": None, "ff": "model", "qkv": "model",
    "vocab": "model", "heads": None, "kv_seq": None, "layers": None,
    "experts": "model", "expert_ff": None, "rows": "model",
    "cands": ("data", "model"), "nodes": None, "dense": None,
})
cfg = get_smoke_config("deepseek-moe-16b")
shape = ShapeSpec(name="t", kind="train", seq_len=32, global_batch=8,
                  microbatch=4)
cell = make_cell(cfg, shape)
with sharding_rules(rules), jax.sharding.set_mesh(mesh):
    state = cell.init_state(jax.random.key(0))
    inputs = synthesize_inputs(cell, 0)
    new_state, metrics = jax.jit(cell.step)(state, inputs)
    loss = float(metrics["loss"])
assert np.isfinite(loss), loss
# Cross-check vs unsharded execution: same step on 1 logical program.
state2 = cell.init_state(jax.random.key(0))
_, m2 = jax.jit(cell.step)(state2, inputs)
assert abs(loss - float(m2["loss"])) < 1e-2, (loss, float(m2["loss"]))
print("SPMD_OK", loss)
"""


@requires_set_mesh
def test_8device_spmd_train_step():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SPMD_OK" in res.stdout, res.stdout + res.stderr
