"""Docs lane: keep docs/ and examples/ from drifting off the code.

Two guards, both cheap enough for tier-1:

- every ``repro.*`` dotted reference in ``docs/*.md`` (prose inline code
  AND fenced code blocks) must resolve to a real module/attribute, and
  every import statement inside a fenced python block must execute;
- ``examples/serve_progressive.py --smoke`` (the walkthrough
  ``docs/serving.md`` is built around) must run to completion.
"""

import importlib
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(ROOT, "docs")

# Dotted repro.* references; stop before trailing punctuation/parens.
_REF = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
_FENCE = re.compile(r"```(\w*)\n(.*?)```", re.S)
_IMPORT = re.compile(r"^(?:from\s+repro[.\w]*\s+import\s+.+|import\s+repro[.\w]*)$")


def _doc_files():
    assert os.path.isdir(DOCS), "docs/ directory missing"
    files = sorted(
        os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md")
    )
    assert files, "docs lane found no docs/*.md"
    return files


def _resolve(ref: str):
    """Import the longest module prefix of ``ref``, getattr the rest."""
    parts = ref.split(".")
    mod, idx = None, 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            idx = i
            break
        except ImportError:
            continue
    if mod is None:
        raise AssertionError(f"unresolvable module in reference {ref!r}")
    obj = mod
    for attr in parts[idx:]:
        obj = getattr(obj, attr)  # AttributeError = drifted doc
    return obj


@pytest.mark.parametrize("path", _doc_files(), ids=os.path.basename)
def test_doc_repro_references_resolve(path):
    """Doc-drift guard: every repro.* symbol a doc names still exists."""
    text = open(path).read()
    refs = sorted(set(_REF.findall(text)))
    assert refs, f"{path} references no repro.* symbols — wrong lane?"
    for ref in refs:
        try:
            _resolve(ref)
        except (AssertionError, AttributeError) as e:
            raise AssertionError(f"{os.path.basename(path)}: {ref}: {e}")


@pytest.mark.parametrize("path", _doc_files(), ids=os.path.basename)
def test_doc_code_block_imports_execute(path):
    """Import statements inside fenced python blocks must import cleanly."""
    text = open(path).read()
    for lang, body in _FENCE.findall(text):
        if lang not in ("python", "py"):
            continue
        for line in body.splitlines():
            line = line.strip()
            if _IMPORT.match(line):
                exec(line, {})  # noqa: S102 — doc-drift guard


def test_serve_progressive_example_smoke():
    """The serving walkthrough must run end to end (tiny sizes)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "examples", "serve_progressive.py"), "--smoke"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "calibrated launch_overhead_trees" in proc.stdout
    assert "speedup (trees)" in proc.stdout
