"""EngineConfig/ServiceConfig/TierConfig: the config objects and the
deprecation shims that retired the keyword-sprawl APIs.

Pins the api_redesign contract:

- ``EngineConfig`` is frozen, hashable, and structural: configs built
  independently from the same stage structure (same callables by
  identity) are EQUAL — and therefore hit the same compiled step in the
  ranker's LRU cache;
- the ``trees``/``hybrid`` constructors broadcast scalars and validate
  the parallel sequences;
- every legacy call form still works through the shim — keyword
  configuration on ``rank_progressive`` (and the positional-sentinels
  spelling), ``RankingService`` knob kwargs, ``ServingTier`` knob
  kwargs — each emitting ONE DeprecationWarning whose message starts
  with ``repro.`` (the prefix CI escalates to an error for in-repo
  callers), and each producing bit-identical results to the config
  spelling;
- mixing a config WITH legacy keywords is a ``TypeError`` for all three
  entry points.
"""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lear import LearClassifier
from repro.core.stage import DenseStage, EngineConfig, TreeStage
from repro.core.strategies import QueryExitConfig, ept_continue
from repro.forest.ensemble import random_ensemble
from repro.serve.ranking_service import RankingService, ServiceConfig
from repro.serve.tier import ServingTier, TierConfig
from strategy_harness import (
    STRATEGY_KWARGS,
    make_dense_stage,
    make_problem,
    make_ranker,
)

SENTINELS = (10, 20, 35)


# -- the config value itself -------------------------------------------------


def test_engine_config_is_frozen_and_hashable():
    cfg = EngineConfig.trees(SENTINELS, capacities=64)
    assert hash(cfg) is not None
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.mode = "staged"


def test_engine_config_structural_equality():
    """Same stage structure + same callables by identity ⇒ equal configs
    (the property that keeps the jit-step cache hot across calls)."""
    strat = ept_continue
    a = EngineConfig.trees(SENTINELS, strat, capacities=64, mode="staged")
    b = EngineConfig.trees(list(SENTINELS), strat, capacities=64,
                           mode="staged")
    assert a == b and hash(a) == hash(b)
    # A different callable object breaks equality even if behaviorally
    # identical — identity is the contract.
    other = lambda partial, alive, **kw: ept_continue(partial, alive, **kw)
    c = EngineConfig.trees(SENTINELS, other, capacities=64, mode="staged")
    assert a != c


def test_engine_config_validates():
    with pytest.raises(AssertionError):
        EngineConfig.trees(())                       # no tree stage
    with pytest.raises(AssertionError):
        EngineConfig.trees((20, 10))                 # not increasing
    with pytest.raises(AssertionError):
        EngineConfig.trees((10, 10))                 # duplicate sentinel
    with pytest.raises(AssertionError):
        EngineConfig.trees(SENTINELS, mode="eager")  # unknown mode
    with pytest.raises(AssertionError):
        # per-stage capacities must cover every stage
        EngineConfig.trees(SENTINELS, capacities=(64, 64))
    with pytest.raises(AssertionError):
        TreeStage(sentinel=0)
    with pytest.raises(AssertionError):
        DenseStage(scorer=lambda x: x, policy=lambda s, m: m, capacity=0)


def test_trees_constructor_broadcasts_scalars():
    strat = ept_continue
    cfg = EngineConfig.trees(SENTINELS, strat, classifier_trees=10,
                             capacities=128)
    assert cfg.sentinels == SENTINELS
    assert all(st.strategy is strat for st in cfg.tree_stages)
    assert all(st.classifier_trees == 10.0 for st in cfg.tree_stages)
    assert cfg.capacities == 128 and cfg.dense is None
    assert cfg.n_stages == len(SENTINELS)


def test_hybrid_constructor_prepends_dense_capacity():
    dense = make_dense_stage(8, seed=3)
    cfg = EngineConfig.hybrid(dense, SENTINELS, capacities=(64, 32, 16))
    assert cfg.dense is dense and cfg.n_stages == len(SENTINELS) + 1
    # dense.capacity=None rides on the last tree capacity
    assert cfg.capacities == (16, 64, 32, 16)
    bounded = dataclasses.replace(dense, capacity=48)
    cfg2 = EngineConfig.hybrid(bounded, SENTINELS, capacities=(64, 32, 16))
    assert cfg2.capacities == (48, 64, 32, 16)


def test_equal_configs_share_one_compiled_step():
    """Per-call config construction is free: equal configs (same strategy
    tuple) reuse the SAME cached step; a different mode compiles anew."""
    ens, X, mask = make_problem(30)
    r = make_ranker(ens)
    kw = dict(STRATEGY_KWARGS)
    r.rank_progressive(X, mask, EngineConfig.trees(SENTINELS), **kw)
    assert len(r._step_cache) == 1
    r.rank_progressive(X, mask, EngineConfig.trees(SENTINELS), **kw)
    assert len(r._step_cache) == 1          # structural hit, no retrace
    r.rank_progressive(
        X, mask, EngineConfig.trees(SENTINELS, mode="staged"), **kw
    )
    assert len(r._step_cache) == 2


# -- rank_progressive shim ---------------------------------------------------


def _legacy_engine_call(r, X, mask, positional=False):
    kw = dict(STRATEGY_KWARGS)
    if positional:
        # The legacy POSITIONAL spelling: sentinels in the config slot.
        return r.rank_progressive(X, mask, list(SENTINELS),
                                  capacities=64, **kw)
    return r.rank_progressive(
        X, mask, sentinels=list(SENTINELS), capacities=64, **kw
    )


@pytest.mark.parametrize("positional", [False, True],
                         ids=["keywords", "positional"])
def test_rank_progressive_legacy_kwargs_warn_and_match(positional):
    ens, X, mask = make_problem(31)
    r = make_ranker(ens)
    with pytest.warns(DeprecationWarning) as rec:
        legacy = _legacy_engine_call(r, X, mask, positional)
    assert len(rec) == 1
    assert str(rec[0].message).startswith("repro.")
    cfg = EngineConfig.trees(SENTINELS, capacities=64)
    modern = r.rank_progressive(X, mask, cfg, **STRATEGY_KWARGS)
    np.testing.assert_array_equal(
        np.asarray(legacy.scores), np.asarray(modern.scores)
    )
    for lm, mm in zip(legacy.stage_masks, modern.stage_masks):
        np.testing.assert_array_equal(np.asarray(lm), np.asarray(mm))


def test_rank_progressive_rejects_config_plus_legacy():
    ens, X, mask = make_problem(32)
    r = make_ranker(ens)
    cfg = EngineConfig.trees(SENTINELS, capacities=64)
    with pytest.raises(TypeError, match="not both"):
        r.rank_progressive(X, mask, cfg, mode="staged", **STRATEGY_KWARGS)
    with pytest.raises(TypeError, match="not both"):
        r.rank_progressive(
            X, mask, cfg, query_exit=QueryExitConfig(k=3), **STRATEGY_KWARGS
        )


def test_rank_progressive_requires_some_configuration():
    ens, X, mask = make_problem(33)
    r = make_ranker(ens)
    with pytest.raises(AssertionError, match="EngineConfig"):
        r.rank_progressive(X, mask)


# -- RankingService / ServingTier shims --------------------------------------


def _ens_and_clf(seed=0, n_features=12):
    ens = random_ensemble(seed, n_trees=64, depth=4, n_features=n_features)
    clf = LearClassifier(
        forest=random_ensemble(100, n_trees=10, depth=3,
                               n_features=n_features + 4),
        sentinel=8,
    )
    return ens, clf


def test_ranking_service_legacy_kwargs_warn_and_match():
    ens, clf = _ens_and_clf()
    with pytest.warns(DeprecationWarning) as rec:
        legacy = RankingService(ens, clf, threshold=0.4,
                                execution_mode="fused")
    assert len(rec) == 1
    assert str(rec[0].message).startswith("repro.")
    modern = RankingService(
        ens, clf, ServiceConfig(threshold=0.4, execution_mode="fused")
    )
    assert legacy.config == modern.config
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(2, 32, 12)), jnp.float32)
    m = jnp.ones((2, 32), bool)
    top_l, sc_l = legacy.rank_batch(X, m)
    top_m, sc_m = modern.rank_batch(X, m)
    np.testing.assert_array_equal(np.asarray(top_l), np.asarray(top_m))
    np.testing.assert_array_equal(np.asarray(sc_l), np.asarray(sc_m))


def test_ranking_service_default_config_is_silent():
    ens, clf = _ens_and_clf()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        svc = RankingService(ens, clf)
    assert svc.config == ServiceConfig()


def test_ranking_service_rejects_config_plus_legacy():
    ens, clf = _ens_and_clf()
    with pytest.raises(TypeError, match="not both"):
        RankingService(ens, clf, ServiceConfig(), threshold=0.4)


def test_serving_tier_legacy_kwargs_warn_and_match():
    ens, clf = _ens_and_clf()
    svc = RankingService(ens, clf, ServiceConfig(threshold=0.4))
    with pytest.warns(DeprecationWarning) as rec:
        tier = ServingTier(svc, 12, doc_counts=(32,), warmup=False,
                           persistent_cache=False)
    assert len(rec) == 1
    assert str(rec[0].message).startswith("repro.")
    assert tier.config == TierConfig(doc_counts=(32,), warmup=False,
                                     persistent_cache=False)


def test_serving_tier_rejects_config_plus_legacy():
    ens, clf = _ens_and_clf()
    svc = RankingService(ens, clf, ServiceConfig(threshold=0.4))
    with pytest.raises(TypeError, match="not both"):
        ServingTier(svc, 12, TierConfig(warmup=False), doc_counts=(32,))
