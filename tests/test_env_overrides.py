"""Environment-variable overrides of the engine tuning constants.

The deployment knobs (`PADDED_CACHE_MAX`, `LEAF_SELECT_MAX`,
`RANK_BLOCKED_MIN_D`, and the dense stage-0 sizing constants
`DENSE_N_VEC` / `DENSE_VEC_DIM` / `DENSE_HIDDEN` / `DENSE_COST_TREES`)
read the environment through the single :func:`repro.kernels.ops.env_int`
helper at import time. The helper's parsing contract is tested
in-process; the end-to-end override path (env → import → behavior change)
needs a fresh interpreter, so it runs in a subprocess — same idiom as the
multi-device check in test_distributed.
"""

import subprocess
import sys

import pytest

from repro.kernels.ops import env_int


def test_env_int_default_when_unset(monkeypatch):
    monkeypatch.delenv("REPRO_TEST_KNOB", raising=False)
    assert env_int("REPRO_TEST_KNOB", 42) == 42


def test_env_int_empty_means_default(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_KNOB", "   ")
    assert env_int("REPRO_TEST_KNOB", 42) == 42


def test_env_int_parses_override(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_KNOB", " 128 ")
    assert env_int("REPRO_TEST_KNOB", 42) == 128


def test_env_int_rejects_garbage(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_KNOB", "fast")
    with pytest.raises(ValueError, match="must be an integer"):
        env_int("REPRO_TEST_KNOB", 42)


def test_env_int_rejects_below_minimum(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_KNOB", "0")
    with pytest.raises(ValueError, match="must be >= 1"):
        env_int("REPRO_TEST_KNOB", 42)
    monkeypatch.setenv("REPRO_TEST_KNOB", "-3")
    with pytest.raises(ValueError):
        env_int("REPRO_TEST_KNOB", 42)


_OVERRIDE_PROG = r"""
import repro.kernels.ops as ops
import repro.core.features as features
from repro.forest.ensemble import random_ensemble

# The constants themselves picked up the environment.
assert ops.PADDED_CACHE_MAX == 2, ops.PADDED_CACHE_MAX
assert ops.LEAF_SELECT_MAX == 16, ops.LEAF_SELECT_MAX
assert features.RANK_BLOCKED_MIN_D == 32, features.RANK_BLOCKED_MIN_D

# ... and the behavior behind each constant moved with them.
# 1. Leaf-gather auto policy: the select/mxu crossover is now at 16 leaves.
assert ops.resolve_leaf_gather(16) == "select"
assert ops.resolve_leaf_gather(17) == "mxu"   # default would say "select"

# 2. Padded-buffer LRU: the per-ensemble cache evicts above 2 layouts.
ens = random_ensemble(0, n_trees=8, depth=2, n_features=4)
for bt in (1, 2, 4):
    ops.padded_forest(ens, block_t=bt)
assert len(ens._padded_cache) == 2, len(ens._padded_cache)

# 3. Blocked-rank auto policy: 33 candidates now pick the tiled compare
# (default cutoff 256 would go direct). Wrap the blocked entry point to
# observe the dispatch, and keep the result exact vs the direct form.
import numpy as np, jax.numpy as jnp
calls = []
real_blocked = features.query_ranks_blocked
features.query_ranks_blocked = (
    lambda *a, **k: calls.append(1) or real_blocked(*a, **k)
)
part = jnp.asarray(np.random.default_rng(0).normal(size=(2, 33)),
                   jnp.float32)
mask = jnp.ones((2, 33), bool)
auto = features.query_ranks(part, mask)   # auto → blocked above 32
assert calls, "auto dispatch did not pick the blocked path"
direct = features.query_ranks(part, mask, method="direct")
np.testing.assert_array_equal(np.asarray(auto), np.asarray(direct))
print("OVERRIDES_OK")
"""


def test_override_path_end_to_end():
    """Env → fresh import → constants AND the behavior they gate change."""
    res = subprocess.run(
        [sys.executable, "-c", _OVERRIDE_PROG],
        capture_output=True, text=True, timeout=300,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": "cpu",
            "REPRO_PADDED_CACHE_MAX": "2",
            "REPRO_LEAF_SELECT_MAX": "16",
            "REPRO_RANK_BLOCKED_MIN_D": "32",
        },
        cwd="/root/repo",
    )
    assert "OVERRIDES_OK" in res.stdout, res.stdout + res.stderr


_DENSE_OVERRIDE_PROG = r"""
import jax
import jax.numpy as jnp
import repro.models.dense_scorer as ds
from repro.core.stage import DenseStage

# The constants themselves picked up the environment.
assert ds.DENSE_N_VEC == 3, ds.DENSE_N_VEC
assert ds.DENSE_VEC_DIM == 8, ds.DENSE_VEC_DIM
assert ds.DENSE_HIDDEN == 12, ds.DENSE_HIDDEN
assert ds.DENSE_COST_TREES == 9, ds.DENSE_COST_TREES

# ... and the behavior behind them moved: the default-initialized scorer
# is shaped by the overridden constants end to end.
params = ds.init_dense_scorer(jax.random.PRNGKey(0), n_features=10)
assert params["proj"].shape == (10, 3, 8), params["proj"].shape
assert params["pb"].shape == (3, 8), params["pb"].shape
n_pairs = 3 * 2 // 2
assert params["w1"].shape == (3 * 8 + n_pairs, 12), params["w1"].shape
out = ds.dense_score(params, jnp.zeros((5, 10), jnp.float32))
assert out.shape == (5,), out.shape

# The accounting default of a DenseStage follows DENSE_COST_TREES.
stage = DenseStage(
    scorer=ds.make_dense_scorer(params), policy=lambda s, m: m
)
assert stage.stage_cost_trees == 9.0, stage.stage_cost_trees
print("DENSE_OVERRIDES_OK")
"""


def test_dense_override_path_end_to_end():
    """Env → fresh import → dense-scorer shapes and stage accounting move."""
    res = subprocess.run(
        [sys.executable, "-c", _DENSE_OVERRIDE_PROG],
        capture_output=True, text=True, timeout=300,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": "cpu",
            "REPRO_DENSE_N_VEC": "3",
            "REPRO_DENSE_VEC_DIM": "8",
            "REPRO_DENSE_HIDDEN": "12",
            "REPRO_DENSE_COST_TREES": "9",
        },
        cwd="/root/repo",
    )
    assert "DENSE_OVERRIDES_OK" in res.stdout, res.stdout + res.stderr


def test_dense_n_vec_minimum_enforced():
    """n_vec=1 has no pairwise interactions — rejected at import."""
    res = subprocess.run(
        [sys.executable, "-c", "import repro.models.dense_scorer"],
        capture_output=True, text=True, timeout=300,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": "cpu",
            "REPRO_DENSE_N_VEC": "1",
        },
        cwd="/root/repo",
    )
    assert res.returncode != 0
    assert "REPRO_DENSE_N_VEC must be >= 2" in res.stderr


def test_bad_override_fails_at_import():
    """A typo'd override must crash the first repro import, not be ignored."""
    res = subprocess.run(
        [sys.executable, "-c", "import repro.kernels.ops"],
        capture_output=True, text=True, timeout=300,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": "cpu",
            "REPRO_LEAF_SELECT_MAX": "sixty-four",
        },
        cwd="/root/repo",
    )
    assert res.returncode != 0
    assert "REPRO_LEAF_SELECT_MAX must be an integer" in res.stderr
