"""Edge contracts of the fault-tolerant serving path.

Fast, deterministic unit coverage riding below the chaos suite
(tests/test_chaos.py): deadline boundary cases (zero budget, budget
tighter than the flush window, in-queue expiry), admission control at
exactly the queue bound, the typed stop/submit handoff, the supervisor's
restart/backoff/budget state machine in isolation, and the degradation
controller's hysteresis — all off the real engine (FakeService/FakeClock
from tests/faults.py), so the whole file runs in milliseconds.
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from faults import FakeClock, FakeService, settle
from repro.serve.batching import BucketPolicy, ContinuousBatcher
from repro.serve.calibration import expected_engine_seconds
from repro.serve.clock import Clock, MonotonicClock, SYSTEM_CLOCK
from repro.serve.degradation import (
    DegradationController,
    DegradationPolicy,
    ExitRung,
)
from repro.serve.errors import (
    BatcherStopped,
    DeadlineExceeded,
    Overloaded,
    ServeError,
    WorkerCrashed,
    WorkerFailed,
)
from repro.serve.supervisor import (
    STATE_FAILED,
    STATE_RUNNING,
    STATE_STOPPED,
    WorkerSupervisor,
)

F = 12


def _query(n_docs: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n_docs, F)).astype(np.float32)


# -- typed errors ---------------------------------------------------------


def test_error_taxonomy():
    # One catchable root for every serving failure...
    for err in (Overloaded(3, 2), DeadlineExceeded(5.0, 9.0),
                BatcherStopped(), WorkerCrashed(), WorkerFailed()):
        assert isinstance(err, ServeError)
        assert isinstance(err, RuntimeError)
    # ...with machine-readable context on the load-control pair.
    o = Overloaded(1024, 1024)
    assert o.depth == 1024 and o.limit == 1024
    d = DeadlineExceeded(5.0, 9.25)
    assert d.deadline_ms == 5.0 and d.waited_ms == 9.25
    # Deadline misses also answer to the stdlib timeout idiom.
    assert isinstance(d, TimeoutError)


# -- deadlines ------------------------------------------------------------


def test_zero_deadline_is_dead_on_arrival():
    svc = FakeService()
    b = ContinuousBatcher(svc, F, BucketPolicy())
    b.start()
    fut = b.submit(_query(16), deadline_ms=0.0)
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5)
    b.stop()
    # Never enqueued, never scored: the engine was not asked.
    assert svc.calls == 0
    assert b.stats.shed_deadline == 1 and b.stats.failed == 1
    assert b.stats.deadline_miss_rate == 1.0


def test_deadline_tighter_than_flush_window_flushes_early():
    """max_wait_ms alone would stall a lone query for 10s; its 50ms
    deadline must pull the flush forward instead of expiring it."""
    svc = FakeService()
    b = ContinuousBatcher(
        svc, F, BucketPolicy(max_queries=8, max_wait_ms=10_000.0)
    )
    b.start()
    t0 = time.monotonic()
    _top, scores = b.submit(_query(16), deadline_ms=50.0).result(timeout=30)
    elapsed = time.monotonic() - t0
    b.stop()
    np.testing.assert_allclose(
        scores, FakeService.expected_scores(_query(16)), rtol=1e-6
    )
    assert elapsed < 5.0, elapsed  # nowhere near the 10s window
    assert b.stats.flushes_deadline == 1
    assert b.stats.expired_deadline == 0


def test_in_queue_expiry_never_launches_the_engine():
    clock = FakeClock()
    svc = FakeService()
    b = ContinuousBatcher(
        svc, F, BucketPolicy(max_queries=8, max_wait_ms=5.0), clock=clock
    )
    b.start()
    fut = b.submit(_query(16), deadline_ms=10.0)
    clock.advance(0.020)  # ripen the flush AND blow the budget
    with pytest.raises(DeadlineExceeded) as exc_info:
        fut.result(timeout=30)
    b.stop()
    assert svc.calls == 0  # the whole bucket was dead: no engine launch
    assert b.stats.expired_deadline == 1
    assert exc_info.value.deadline_ms == 10.0
    assert exc_info.value.waited_ms >= 10.0


def test_expired_request_does_not_drag_down_bucket_mates():
    clock = FakeClock()
    svc = FakeService()
    b = ContinuousBatcher(
        svc, F, BucketPolicy(max_queries=8, max_wait_ms=30.0), clock=clock
    )
    b.start()
    doomed = b.submit(_query(16, seed=1), deadline_ms=10.0)
    q = _query(16, seed=2)
    alive = b.submit(q)  # same bucket, no deadline
    clock.advance(0.020)  # doomed expires; the bucket still flushes
    _top, scores = alive.result(timeout=30)
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=30)
    b.stop()
    np.testing.assert_allclose(
        scores, FakeService.expected_scores(q), rtol=1e-6
    )
    assert svc.calls == 1
    assert b.stats.completed == 1 and b.stats.expired_deadline == 1


# -- admission control ----------------------------------------------------


def test_queue_at_exactly_max_depth_sheds_the_next_submit():
    clock = FakeClock()  # frozen: nothing flushes while we fill the queue
    svc = FakeService()
    b = ContinuousBatcher(
        svc, F,
        BucketPolicy(max_queries=64, max_wait_ms=1000.0, max_queue_depth=4),
        clock=clock,
    )
    b.start()
    futs = [b.submit(_query(16, seed=i)) for i in range(4)]  # fills to 4
    with pytest.raises(Overloaded) as exc_info:
        b.submit(_query(16, seed=99))
    assert exc_info.value.depth == 4 and exc_info.value.limit == 4
    assert b.stats.shed_overload == 1
    assert b.stats.max_queue_depth == 4
    b.stop()  # drain serves everything that was admitted
    results, errors = settle(futs, timeout_s=30)
    assert len(results) == 4 and errors == []
    assert b.stats.flushes_drain >= 1


def test_unbounded_policy_never_sheds():
    clock = FakeClock()
    b = ContinuousBatcher(
        FakeService(), F,
        BucketPolicy(max_queries=64, max_wait_ms=1000.0, max_queue_depth=None),
        clock=clock,
    )
    b.start()
    futs = [b.submit(_query(8, seed=i)) for i in range(64)]
    assert b.stats.shed_overload == 0
    b.stop()
    results, errors = settle(futs, timeout_s=30)
    assert len(results) == 64 and errors == []


# -- stop/submit handoff --------------------------------------------------


def test_submit_after_stop_raises_typed():
    b = ContinuousBatcher(FakeService(), F, BucketPolicy())
    with pytest.raises(BatcherStopped):
        b.submit(_query(8))  # never started
    b.start()
    b.stop()
    with pytest.raises(BatcherStopped):
        b.submit(_query(8))


def test_stop_drains_admitted_requests():
    clock = FakeClock()  # frozen: requests sit queued until the drain
    svc = FakeService()
    b = ContinuousBatcher(
        svc, F, BucketPolicy(max_queries=64, max_wait_ms=1000.0), clock=clock
    )
    b.start()
    qs = [_query(16, seed=i) for i in range(5)]
    futs = [b.submit(q) for q in qs]
    b.stop()
    results, errors = settle(futs, timeout_s=30)
    assert errors == [] and len(results) == 5
    for q, (_top, scores) in zip(qs, results):
        np.testing.assert_allclose(
            scores, FakeService.expected_scores(q), rtol=1e-6
        )


# -- supervisor state machine ---------------------------------------------


def test_supervisor_clean_exit_is_not_a_crash():
    ran = threading.Event()
    sup = WorkerSupervisor(ran.set, backoff_base_s=0.001)
    sup.start()
    assert ran.wait(timeout=5)
    sup.stop()
    h = sup.health()
    assert h.state == STATE_STOPPED
    assert h.restarts == 0 and h.crashes == 0 and h.last_error is None
    assert not h.healthy


def test_supervisor_restarts_until_budget_then_fails():
    runs = []
    failed = threading.Event()

    def target():
        runs.append(len(runs))
        raise RuntimeError(f"boom {len(runs)}")

    crashes = []
    sup = WorkerSupervisor(
        target,
        backoff_base_s=0.001,
        backoff_max_s=0.002,
        max_restarts=3,
        on_crash=crashes.append,
        on_failed=lambda exc: failed.set(),
    )
    sup.start()
    assert failed.wait(timeout=10)
    # initial run + 3 restarts = 4 executions, 4 crashes observed.
    assert len(runs) == 4
    assert len(crashes) == 4
    h = sup.health()
    assert h.state == STATE_FAILED and not h.healthy
    assert h.restarts == 3 and h.crashes == 4
    assert "boom 4" in h.last_error
    sup.stop()
    assert sup.health().state == STATE_FAILED  # failure is terminal


def test_supervisor_stop_interrupts_backoff_immediately():
    first = threading.Event()

    def target():
        if not first.is_set():
            first.set()
            raise RuntimeError("one crash, then a 60s backoff")

    sup = WorkerSupervisor(target, backoff_base_s=60.0, backoff_max_s=60.0)
    sup.start()
    assert first.wait(timeout=5)
    t0 = time.monotonic()
    sup.stop()  # must wake the sleeping guard, not wait out the minute
    assert time.monotonic() - t0 < 5.0
    assert sup.health().state == STATE_STOPPED


def test_supervisor_state_while_running():
    release = threading.Event()
    sup = WorkerSupervisor(lambda: release.wait(timeout=30))
    sup.start()
    assert sup.state == STATE_RUNNING
    assert sup.health().healthy
    release.set()
    sup.stop()


def test_broken_crash_callback_does_not_kill_the_guard():
    calls = []

    def target():
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("crash once")
        time.sleep(0.005)

    def bad_callback(exc):
        raise ValueError("observer bug")

    sup = WorkerSupervisor(
        target, backoff_base_s=0.001, on_crash=bad_callback
    )
    sup.start()
    deadline = time.monotonic() + 10
    while len(calls) < 2:
        assert time.monotonic() < deadline
        time.sleep(0.002)
    assert sup.health().state == STATE_RUNNING  # guard survived the observer
    sup.stop()


# -- degradation hysteresis ----------------------------------------------


def _controller(dwell=2):
    svc = FakeService()
    policy = DegradationPolicy(
        rungs=(ExitRung("a", threshold=0.8), ExitRung("b", threshold=0.9)),
        degrade_above_ms=10.0,
        recover_below_ms=2.0,
        ema_alpha=1.0,   # EMA == last observation: exact control
        dwell_flushes=dwell,
    )
    ctrl = DegradationController(svc, policy)
    ctrl.install()
    return svc, ctrl


def test_controller_steps_one_rung_per_dwell_window():
    svc, ctrl = _controller(dwell=2)
    assert ctrl.n_levels == 3
    assert ctrl.observe(0.050) == 1   # first move is free (fresh dwell)
    assert ctrl.observe(0.050) == 1   # dwell blocks an immediate second
    assert ctrl.observe(0.050) == 2   # window elapsed: next rung
    assert ctrl.observe(0.050) == 2   # ladder is capped at its last rung
    assert ctrl.observe(0.050) == 2
    assert svc.rung_history == [1, 2]  # set_rung only on actual moves


def test_controller_hysteresis_band_holds_level():
    svc, ctrl = _controller(dwell=1)
    assert ctrl.observe(0.050) == 1
    # In-band delay (2ms < 5ms < 10ms): neither degrade nor recover.
    for _ in range(5):
        assert ctrl.observe(0.005) == 1
    assert ctrl.observe(0.001) == 0   # below the band: recover
    assert ctrl.observe(0.001) == 0   # floor is the baseline
    snap = ctrl.snapshot()
    assert snap["degrade_steps"] == 1 and snap["recover_steps"] == 1
    assert snap["rung"] == "baseline"


def test_controller_snapshot_names_the_active_rung():
    _svc, ctrl = _controller(dwell=1)
    ctrl.observe(0.050)
    snap = ctrl.snapshot()
    assert snap["level"] == 1 and snap["rung"] == "a"
    assert snap["n_levels"] == 3
    assert snap["queue_delay_ema_ms"] == pytest.approx(50.0)
    assert snap["degrade_above_ms"] == 10.0
    assert snap["recover_below_ms"] == 2.0


def test_degradation_policy_validates_hysteresis_band():
    rungs = (ExitRung("a", threshold=0.8),)
    with pytest.raises(AssertionError):
        DegradationPolicy(
            rungs=rungs, degrade_above_ms=2.0, recover_below_ms=5.0
        )
    with pytest.raises(AssertionError):
        DegradationPolicy(rungs=())
    with pytest.raises(AssertionError):
        ExitRung("bad", threshold=1.5)
    with pytest.raises(AssertionError):
        ExitRung("bad", dense_keep_frac=0.0)


# -- clocks & cost prior --------------------------------------------------


def test_monotonic_clock_satisfies_protocol():
    assert isinstance(SYSTEM_CLOCK, Clock)
    assert isinstance(MonotonicClock(), Clock)
    assert isinstance(FakeClock(), Clock)  # the harness honors it too
    c = MonotonicClock()
    t0 = c.now()
    cond = threading.Condition()
    with cond:
        assert c.wait(cond, 0.005) is False  # timeout, not notify
    c.sleep(cond, 0.001)
    assert c.now() > t0


def test_expected_engine_seconds_prior_is_nonnegative():
    # Whether or not a calibration ran in this process, the prior must be
    # a finite non-negative number — it feeds a scheduling subtraction.
    est = expected_engine_seconds(8 * 64, 900)
    assert est >= 0.0 and np.isfinite(est)
    assert expected_engine_seconds(0, 0) >= 0.0
