"""Forest substrate tests: scorer equivalence, slicing, GBDT training."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.forest import (
    GBDTParams,
    score_bitvector,
    score_level,
    score_numpy_oracle,
    partial_scores,
    slice_trees,
    train_gbdt,
    train_lambdamart,
)
from repro.forest.ensemble import random_ensemble, from_arrays
from repro.metrics.ranking import mean_ndcg


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("depth", [1, 3, 6])
@pytest.mark.parametrize("n_trees", [1, 17])
def test_scorers_agree(rng, depth, n_trees):
    ens = random_ensemble(0, n_trees=n_trees, depth=depth, n_features=12)
    X = rng.normal(size=(64, 12)).astype(np.float32)
    ref = score_numpy_oracle(ens, X)
    bv = np.asarray(score_bitvector(ens, jnp.asarray(X)))
    lv = np.asarray(score_level(ens, jnp.asarray(X)))
    np.testing.assert_allclose(bv, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lv, ref, rtol=1e-5, atol=1e-5)


def test_irregular_tree_from_arrays(rng):
    # A lopsided 3-internal-node tree:      n0
    #                                     /    \
    #                                    n1    leafC
    #                                   /  \
    #                                leafA  n2
    #                                      /  \
    #                                   leafB leafD
    feats = [np.array([0, 1, 2])]
    thrs = [np.array([0.0, -1.0, 0.5], dtype=np.float32)]
    lefts = [np.array([1, -1, -2])]
    rights = [np.array([-3, 2, -4])]
    leaf_vals = [np.array([1.0, 2.0, 3.0, 4.0], dtype=np.float32)]
    ens = from_arrays(feats, thrs, lefts, rights, leaf_vals)
    X = rng.normal(size=(128, 3)).astype(np.float32)
    ref = score_numpy_oracle(ens, X)
    bv = np.asarray(score_bitvector(ens, jnp.asarray(X)))
    np.testing.assert_allclose(bv, ref, rtol=1e-5)


def test_partial_plus_tail_equals_full(rng):
    ens = random_ensemble(1, n_trees=40, depth=5, n_features=8)
    X = rng.normal(size=(32, 8)).astype(np.float32)
    head, tail = partial_scores(ens, jnp.asarray(X), sentinel=13)
    full = score_bitvector(ens, jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(head + tail), np.asarray(full), rtol=1e-4, atol=1e-5)


def test_slice_trees_matches_manual(rng):
    ens = random_ensemble(2, n_trees=20, depth=4, n_features=6)
    X = rng.normal(size=(16, 6)).astype(np.float32)
    head = score_bitvector(slice_trees(ens, 0, 7), jnp.asarray(X))
    tail = score_bitvector(slice_trees(ens, 7, 20), jnp.asarray(X))
    full = score_bitvector(ens, jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(head + tail), np.asarray(full), rtol=1e-4, atol=1e-5)


def test_gbdt_l2_fits_function(rng):
    X = rng.normal(size=(2000, 5)).astype(np.float32)
    y = (np.sin(X[:, 0]) + 0.5 * (X[:, 1] > 0) + 0.1 * X[:, 2]).astype(np.float32)
    params = GBDTParams(n_trees=40, depth=4, learning_rate=0.2)
    ens = train_gbdt(X, y, params, objective="l2")
    pred = np.asarray(score_bitvector(ens, jnp.asarray(X)))
    mse = float(np.mean((pred - y) ** 2))
    base = float(np.var(y))
    assert mse < 0.15 * base, f"GBDT failed to fit: mse={mse}, var={base}"


def test_gbdt_logistic_classifies(rng):
    X = rng.normal(size=(2000, 4)).astype(np.float32)
    y = (X[:, 0] + 0.5 * X[:, 1] > 0).astype(np.float32)
    w = np.where(y > 0, 2.0, 1.0).astype(np.float32)  # cost-sensitive path
    params = GBDTParams(n_trees=30, depth=4, learning_rate=0.3)
    ens = train_gbdt(X, y, params, objective="logistic", weights=w)
    logits = np.asarray(score_bitvector(ens, jnp.asarray(X)))
    acc = float(np.mean((logits > 0) == (y > 0.5)))
    assert acc > 0.9, f"accuracy {acc}"


def test_lambdamart_improves_ndcg(rng):
    Q, D, F = 60, 24, 6
    X = rng.normal(size=(Q, D, F)).astype(np.float32)
    # Relevance depends on two features → learnable ranking signal.
    util = X[..., 0] + 0.7 * X[..., 1] + 0.2 * rng.normal(size=(Q, D))
    labels = np.clip(np.digitize(util, [-0.5, 0.5, 1.2, 1.8]), 0, 4).astype(np.float32)
    mask = np.ones((Q, D), dtype=bool)
    mask[:, 20:] = rng.random((Q, 4)) > 0.5  # ragged queries
    params = GBDTParams(n_trees=30, depth=4, learning_rate=0.2)
    ens = train_lambdamart(X, labels, mask, params, k=10)
    flat = jnp.asarray(X.reshape(Q * D, F))
    scores = np.asarray(score_bitvector(ens, flat)).reshape(Q, D)
    ndcg = float(mean_ndcg(jnp.asarray(scores), jnp.asarray(labels), jnp.asarray(mask), k=10))
    rand = float(mean_ndcg(jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32)),
                           jnp.asarray(labels), jnp.asarray(mask), k=10))
    assert ndcg > rand + 0.15, f"lambdamart ndcg {ndcg} vs random {rand}"


def test_bitvector_bf16_thresholds_close(rng):
    ens = random_ensemble(3, n_trees=10, depth=4, n_features=4)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    full = np.asarray(score_bitvector(ens, jnp.asarray(X)))
    assert np.all(np.isfinite(full))
