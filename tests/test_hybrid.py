"""Hybrid neural/tree cascade: dense stage 0 conformance + distillation.

The tentpole's acceptance contract, as tests:

- a heterogeneous stage list (DenseStage + TreeStages) runs in all three
  execution modes and the modes agree bit-for-bit (the dense-compacted
  tree head block is identical across modes);
- the engine's masks/scores match the from-scratch numpy replay
  (``strategy_harness.oracle_progressive``) — dense gate included, with
  and without query-level exit;
- dense-exited documents keep the dense score as their final score (the
  distilled proxy stands in for the ensemble on the easy majority);
- the launch contract is UNCHANGED vs the all-trees cascade over the
  tree stages: the dense matmul is pure XLA and dispatches no Pallas
  kernel, for S=1 and S>1 tree stages alike;
- the hybrid accounting (dense spliced in as a zero-sentinel stage)
  stays a finite, lazy device scalar;
- ``distill_dense_scorer`` fits the ensemble's scores on a toy problem
  (teacher RMSE shrinks, pairwise order mostly preserved) and the
  resulting scorer drops into a DenseStage that passes the same
  cross-mode + oracle conformance as the untrained one.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stage import DenseStage, EngineConfig
from repro.core.strategies import QueryExitConfig
from strategy_harness import (
    assert_matches_oracle,
    expected_launches,
    make_dense_stage,
    make_problem,
    make_ranker,
    measured_launches,
    oracle_progressive,
    run_all_modes,
    run_mode,
)

SENTINELS = (10, 20, 35)
F = 16


@pytest.fixture(scope="module")
def problem():
    ens, X, mask = make_problem(40, F=F)
    return ens, X, mask, make_dense_stage(F, seed=40)


def test_hybrid_modes_agree_and_match_oracle(problem):
    ens, X, mask, dense = problem
    r = make_ranker(ens)
    results = run_all_modes(r, X, mask, SENTINELS, dense=dense)
    for res in results.values():
        assert_matches_oracle(res, ens, X, mask, SENTINELS, dense=dense)
        # Dense gate leads the stage-mask list: S_tree + 1 entries.
        assert len(res.stage_masks) == len(SENTINELS) + 1


def test_hybrid_dense_gate_prunes_and_scores(problem):
    """The gate's mask is the replayed policy decision, pruned docs keep
    the dense score, and tree survivors are a subset of gate survivors."""
    ens, X, mask, dense = problem
    r = make_ranker(ens)
    res = run_mode(r, X, mask, SENTINELS, "fused", dense=dense)
    Q, D, _ = X.shape
    d_scores = np.asarray(dense.scorer(X.reshape(Q * D, F))).reshape(Q, D)
    gate_alive = np.asarray(res.stage_masks[0])
    # keep_frac=0.5 on a ~90%-masked problem: a real prune, never empty.
    assert 0 < gate_alive.sum() < np.asarray(mask).sum()
    dense_exited = np.asarray(mask) & ~gate_alive
    # jit-vs-eager dense evaluation differs in float32 low bits — same
    # allclose convention as the harness's score comparison.
    np.testing.assert_allclose(
        np.asarray(res.scores)[dense_exited], d_scores[dense_exited],
        rtol=1e-5, atol=1e-5,
    )
    for m in res.stage_masks[1:]:
        assert not (np.asarray(m) & ~gate_alive).any()


def test_hybrid_with_query_exit(problem):
    ens, X, mask, dense = problem
    r = make_ranker(ens)
    qe = QueryExitConfig(k=3, margin=0.05, from_stage=1)
    results = run_all_modes(r, X, mask, SENTINELS, qe, dense=dense)
    assert_matches_oracle(
        results["fused"], ens, X, mask, SENTINELS, qe, dense=dense
    )
    # margin=inf is the EXACT regime: a query exits only once it has no
    # alive documents, so skipping its tail is score-preserving even
    # with the dense gate in front — bit-exact with the knob off.
    exact = QueryExitConfig(k=3, margin=float("inf"), from_stage=1)
    inf_run = run_mode(r, X, mask, SENTINELS, "fused", exact, dense=dense)
    base = run_mode(r, X, mask, SENTINELS, "fused", dense=dense)
    np.testing.assert_array_equal(
        np.asarray(inf_run.scores), np.asarray(base.scores)
    )


@pytest.mark.parametrize("mode", ["fused", "staged", "auto"])
@pytest.mark.parametrize("sentinels", [(10,), SENTINELS])
def test_hybrid_launch_contract(problem, mode, sentinels):
    """Dense stage adds ZERO Pallas launches: the hybrid launch plan equals
    the all-trees plan over the tree stages, including the S=1 degenerate
    head and the auto-mode both-branches trace."""
    ens, X, mask, dense = problem
    r = make_ranker(ens)
    if mode == "auto" and len(sentinels) == 1:
        # The dense gate does NOT count toward auto's ≥2-tree-stage
        # requirement: with one tree stage the modes are identical and
        # the engine rejects auto, hybrid or not.
        with pytest.raises(AssertionError, match="auto"):
            measured_launches(r, X, mask, sentinels, mode, dense=dense)
        return
    counts = measured_launches(r, X, mask, sentinels, mode, dense=dense)
    assert counts == expected_launches(
        mode, len(sentinels), has_tail=True, query_exit_on=False
    ), (mode, sentinels, counts)


def test_hybrid_speedup_is_lazy_and_finite(problem):
    ens, X, mask, dense = problem
    r = make_ranker(ens)
    res = run_mode(r, X, mask, SENTINELS, "fused", dense=dense)
    assert isinstance(res.speedup, jax.Array)  # lazy: no hidden host sync
    assert np.isfinite(float(res.speedup)) and float(res.speedup) > 0.0


def test_hybrid_rejects_dense_after_stage_zero(problem):
    _, _, _, dense = problem
    from repro.core.stage import TreeStage

    with pytest.raises(AssertionError):
        EngineConfig(stages=(TreeStage(sentinel=10), dense))


def test_distilled_scorer_conformant_end_to_end():
    """Distill against the real ensemble, then run the distilled stage
    through the full cross-mode + oracle conformance."""
    from repro.train.distill import distill_dense_scorer, teacher_scores

    ens, X, mask = make_problem(41, F=F)
    out = distill_dense_scorer(
        ens, X, mask, steps=150, lr=3e-3, seed=1, log_every=50
    )
    # The proxy learned the teacher: centered RMSE well under the score
    # spread, and pairwise order mostly preserved.
    t = np.asarray(teacher_scores(ens, X))[np.asarray(mask)]
    assert out.teacher_rmse < 0.5 * t.std(), (out.teacher_rmse, t.std())
    assert out.pair_accuracy > 0.8, out.pair_accuracy
    assert len(out.history) >= 2
    assert out.history[-1]["loss"] < out.history[0]["loss"]

    import functools

    from repro.core.strategies import dense_keep_fraction

    stage = DenseStage(
        scorer=out.scorer,
        policy=functools.partial(dense_keep_fraction, keep_frac=0.5),
    )
    r = make_ranker(ens)
    results = run_all_modes(r, X, mask, SENTINELS, dense=stage)
    assert_matches_oracle(
        results["staged"], ens, X, mask, SENTINELS, dense=stage
    )
