"""Pallas forest kernel: shape/dtype sweep vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import score_numpy_oracle
from repro.kernels.ops import forest_score
from repro.kernels.ref import forest_score_ref


@pytest.mark.parametrize(
    "n_docs,n_trees,depth,n_features",
    [
        (8, 1, 1, 3),
        (64, 16, 4, 16),
        (100, 30, 6, 136),    # MSN-1-like feature count, ragged doc count
        (256, 64, 5, 220),    # Istella-like feature count
        (33, 7, 3, 5),        # deliberately unaligned everything
    ],
)
def test_kernel_matches_oracle(n_docs, n_trees, depth, n_features):
    rng = np.random.default_rng(n_docs + n_trees)
    ens = random_ensemble(0, n_trees=n_trees, depth=depth, n_features=n_features)
    X = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    got = np.asarray(forest_score(ens, jnp.asarray(X), interpret=True))
    ref = score_numpy_oracle(ens, X)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_b,block_t", [(8, 1), (32, 4), (256, 16)])
def test_kernel_block_shapes(block_b, block_t):
    rng = np.random.default_rng(7)
    ens = random_ensemble(1, n_trees=48, depth=5, n_features=24)
    X = rng.normal(size=(96, 24)).astype(np.float32)
    got = np.asarray(
        forest_score(ens, jnp.asarray(X), block_b=block_b, block_t=block_t, interpret=True)
    )
    ref = score_numpy_oracle(ens, X)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_input_dtypes(dtype):
    rng = np.random.default_rng(11)
    ens = random_ensemble(2, n_trees=8, depth=4, n_features=10)
    X = rng.normal(size=(40, 10)).astype(np.float32)
    got = np.asarray(forest_score(ens, jnp.asarray(X, dtype=dtype), interpret=True))
    # bf16 inputs may flip predicates for values straddling thresholds; compare
    # against the oracle run at the same precision.
    ref = score_numpy_oracle(ens, np.asarray(jnp.asarray(X, dtype=dtype), np.float32))
    np.testing.assert_allclose(got, ref, rtol=1e-2, atol=1e-2)


def test_ref_matches_forest_scoring():
    rng = np.random.default_rng(3)
    ens = random_ensemble(4, n_trees=20, depth=6, n_features=50)
    X = rng.normal(size=(64, 50)).astype(np.float32)
    ref_kernel = np.asarray(
        forest_score_ref(
            jnp.asarray(X), ens.feature, ens.threshold, ens.mask_lo, ens.mask_hi, ens.leaf_value
        )
    )
    oracle = score_numpy_oracle(ens, X)
    np.testing.assert_allclose(ref_kernel + float(ens.base_score), oracle, rtol=1e-5, atol=1e-5)
