"""Correctness of the flash-style blockwise attention vs naive attention."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import blockwise_attention, decode_attention


def naive_attention(q, k, v, causal, q_offset=0):
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    qf = q.reshape(B, Sq, Hkv, G, Dh).astype(np.float32)
    scores = np.einsum("bqhgd,bkhd->bhgqk", qf, np.asarray(k, np.float32))
    scores /= np.sqrt(Dh)
    if causal:
        qpos = q_offset + np.arange(Sq)
        mask = qpos[:, None] >= np.arange(Skv)[None, :]
        scores = np.where(mask[None, None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v, np.float32))
    return out.reshape(B, Sq, H, Dh)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,q_block,kv_block",
    [
        (2, 64, 64, 4, 2, 16, 32),
        (3, 32, 32, 6, 1, 8, 8),     # B != n_blocks (regression: axis swap)
        (1, 128, 128, 2, 2, 128, 16),
        (2, 48, 48, 4, 4, 16, 48),
    ],
)
def test_blockwise_matches_naive(causal, B, Sq, Skv, H, Hkv, q_block, kv_block):
    rng = np.random.default_rng(B * Sq + H)
    Dh = 16
    q = rng.normal(size=(B, Sq, H, Dh)).astype(np.float32)
    k = rng.normal(size=(B, Skv, Hkv, Dh)).astype(np.float32)
    v = rng.normal(size=(B, Skv, Hkv, Dh)).astype(np.float32)
    got = np.asarray(
        blockwise_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=causal, q_block=q_block, kv_block=kv_block,
        )
    )
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_decode_matches_naive_last_token():
    rng = np.random.default_rng(0)
    B, S, H, Hkv, Dh = 2, 32, 4, 2, 16
    pos = 20
    q = rng.normal(size=(B, 1, H, Dh)).astype(np.float32)
    k_cache = rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32)
    v_cache = rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32)
    got = np.asarray(
        decode_attention(jnp.asarray(q), jnp.asarray(k_cache),
                         jnp.asarray(v_cache), jnp.int32(pos))
    )
    ref = naive_attention(
        q, k_cache[:, :pos], v_cache[:, :pos], causal=False
    )
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
