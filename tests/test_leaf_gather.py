"""Leaf-gather path parity: select tree ≡ MXU contraction ≡ one-hot, bit-exact.

The kernel's three leaf-value resolution paths move the same f32 values
(selects relocate them; the one-hot/MXU contractions sum one exact product
against zeros), and the shared tree-axis reduction is an explicit pairwise
add chain — so the paths must agree BIT-FOR-BIT, across leaf counts,
including non-power-of-two leaf axes (ragged ensembles) and leaf tables
wider than the reachable index range (the MXU-threshold regime).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.forest.ensemble import from_arrays, random_ensemble
from repro.forest.scoring import score_numpy_oracle
from repro.kernels.forest_score import (
    LEAF_GATHERS,
    forest_score_pallas,
)
from repro.kernels.ops import (
    LEAF_SELECT_MAX,
    forest_score,
    forest_score_segments,
    padded_forest,
    resolve_leaf_gather,
)
from repro.kernels.ref import leaf_values_ref


def _score_all_paths(ens, X):
    return {
        lg: np.asarray(forest_score(ens, jnp.asarray(X), leaf_gather=lg,
                                    interpret=True))
        for lg in LEAF_GATHERS
    }


@pytest.mark.parametrize("depth", [1, 3, 6])   # L = 2, 8, 64
def test_paths_bitexact_pow2_leaves(depth):
    rng = np.random.default_rng(depth)
    ens = random_ensemble(depth, n_trees=24, depth=depth, n_features=20)
    X = rng.normal(size=(100, 20)).astype(np.float32)
    got = _score_all_paths(ens, X)
    for lg in ("select", "mxu"):
        np.testing.assert_array_equal(got[lg], got["onehot"], err_msg=lg)
    np.testing.assert_allclose(
        got["onehot"], score_numpy_oracle(ens, X), rtol=1e-5, atol=1e-5
    )


def _random_ragged_trees(rng, leaf_counts, n_features):
    """Random binary trees with EXACT per-tree leaf counts (pre-order
    internal numbering, child < 0 encodes leaf slot -(i+1))."""
    feats, thrs, lefts, rights, leaves = [], [], [], [], []
    for n_leaves in leaf_counts:
        f, t, lt, rt = [], [], [], []
        leaf_ctr = [0]

        def rec(n):
            if n == 1:
                leaf_ctr[0] += 1
                return -leaf_ctr[0]
            idx = len(f)
            f.append(int(rng.integers(0, n_features)))
            t.append(float(rng.normal()))
            lt.append(0)
            rt.append(0)
            n_left = int(rng.integers(1, n))
            lt[idx] = rec(n_left)
            rt[idx] = rec(n - n_left)
            return idx

        rec(n_leaves)
        feats.append(np.asarray(f, np.int32))
        thrs.append(np.asarray(t, np.float32))
        lefts.append(np.asarray(lt, np.int32))
        rights.append(np.asarray(rt, np.int32))
        leaves.append(rng.normal(size=n_leaves).astype(np.float32) * 0.1)
    return from_arrays(feats, thrs, lefts, rights, leaves)


@pytest.mark.parametrize("leaf_counts", [(3, 5, 6, 4), (48, 33, 47, 21)])
def test_paths_bitexact_non_pow2_leaves(leaf_counts):
    """Ragged ensembles give a non-power-of-two leaf axis: the select path
    must pad it (padded_forest leaf_layout='pow2') and still agree
    bit-for-bit with the native-layout one-hot/MXU paths."""
    rng = np.random.default_rng(sum(leaf_counts))
    ens = _random_ragged_trees(rng, leaf_counts, n_features=12)
    assert ens.n_leaves & (ens.n_leaves - 1) != 0, ens.n_leaves
    X = rng.normal(size=(64, 12)).astype(np.float32)
    got = _score_all_paths(ens, X)
    for lg in ("select", "mxu"):
        np.testing.assert_array_equal(got[lg], got["onehot"], err_msg=lg)
    np.testing.assert_allclose(
        got["onehot"], score_numpy_oracle(ens, X), rtol=1e-5, atol=1e-5
    )
    pf = padded_forest(ens, leaf_gather="select")
    assert pf.leaf_layout == "pow2"
    assert pf.leaf_value.shape[1] == 1 << (ens.n_leaves - 1).bit_length()


def test_paths_bitexact_wide_leaf_table_L256():
    """L=256 (the MXU-threshold regime): widen a depth-3 forest's leaf
    table with junk columns — unreachable (every ctz leaf index < 8), so
    all three paths must still return identical scores."""
    rng = np.random.default_rng(7)
    ens = random_ensemble(7, n_trees=16, depth=3, n_features=16)
    pf = padded_forest(ens, leaf_gather="onehot")
    L = 256
    junk = jnp.asarray(
        rng.normal(size=(pf.leaf_value.shape[0], L - pf.leaf_value.shape[1]))
        .astype(np.float32)
    )
    wide_leaf = jnp.concatenate([pf.leaf_value, junk], axis=1)
    x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    got = {
        lg: np.asarray(forest_score_pallas(
            x, pf.feature, pf.threshold, pf.mask_lo, pf.mask_hi, wide_leaf,
            block_b=64, block_t=pf.block_t, leaf_gather=lg, interpret=True,
        ))
        for lg in LEAF_GATHERS
    }
    for lg in ("select", "mxu"):
        np.testing.assert_array_equal(got[lg], got["onehot"], err_msg=lg)
    assert resolve_leaf_gather(L) == "mxu"


def test_segmented_kernel_paths_bitexact():
    """The sentinel-segmented kernel shares _score_block: per-segment
    partials must be path-invariant bit-for-bit too."""
    rng = np.random.default_rng(11)
    ens = random_ensemble(11, n_trees=48, depth=6, n_features=32)
    X = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    segs = {}
    for lg in LEAF_GATHERS:
        pf = padded_forest(ens, boundaries=(10, 30, 48), leaf_gather=lg)
        segs[lg] = np.asarray(forest_score_segments(pf, X, n_segments=3))
    for lg in ("select", "mxu"):
        np.testing.assert_array_equal(segs[lg], segs["onehot"], err_msg=lg)


def test_resolve_and_layout_policy():
    """Auto policy: select tree up to LEAF_SELECT_MAX padded leaves, MXU
    above; the buffer cache keys on the resolved path (distinct layouts
    are distinct cached entries, same layout is shared)."""
    assert resolve_leaf_gather(2) == "select"
    assert resolve_leaf_gather(LEAF_SELECT_MAX) == "select"
    # Non-pow2 counts resolve on their padded width.
    assert resolve_leaf_gather(LEAF_SELECT_MAX - 1) == "select"
    assert resolve_leaf_gather(LEAF_SELECT_MAX + 1) == "mxu"
    assert resolve_leaf_gather(256) == "mxu"

    ens = random_ensemble(13, n_trees=8, depth=3, n_features=8)
    auto = padded_forest(ens)
    assert auto.leaf_gather == "select" and auto.leaf_layout == "pow2"
    assert padded_forest(ens, leaf_gather="select") is auto
    onehot = padded_forest(ens, leaf_gather="onehot")
    assert onehot is not auto and onehot.leaf_layout == "native"


def test_leaf_values_ref_is_the_gather_oracle():
    """The ref-layer gather oracle (take_along_axis) pins what every
    in-kernel path computes."""
    rng = np.random.default_rng(17)
    leaf_tab = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
    leaf = jnp.asarray(rng.integers(0, 8, size=(10, 6)).astype(np.int32))
    got = np.asarray(leaf_values_ref(leaf, leaf_tab))
    expect = np.asarray(leaf_tab)[np.arange(6)[None, :], np.asarray(leaf)]
    np.testing.assert_array_equal(got, expect)
