"""LEAR core tests: strategies, labels/weights, classifier, cascade engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CascadeRanker,
    augment_features,
    build_continue_labels,
    ept_continue,
    ert_continue,
    ideal_continue,
    instance_weights,
    train_lear,
)
from repro.data import make_letor_dataset
from repro.forest import GBDTParams, score_bitvector, train_lambdamart
from repro.metrics import mean_ndcg, precision_recall


@pytest.fixture(scope="module")
def small_ltr():
    ds = make_letor_dataset("msn1", n_queries=80, n_features=24, docs_scale=0.3, seed=1)
    params = GBDTParams(n_trees=40, depth=4, learning_rate=0.2)
    ens = train_lambdamart(ds.X, ds.labels.astype(np.float32), ds.mask, params, k=10)
    return ds, ens


def _scores(ens, ds):
    Q, D, F = ds.X.shape
    return np.asarray(
        score_bitvector(ens, jnp.asarray(ds.X.reshape(Q * D, F)))
    ).reshape(Q, D)


def test_ert_keeps_exactly_topk(small_ltr):
    ds, ens = small_ltr
    partial = jnp.asarray(_scores(ens, ds))
    mask = jnp.asarray(ds.mask)
    cont = ert_continue(partial, mask, k_s=15)
    per_q = np.asarray(cont.sum(axis=1))
    expect = np.minimum(np.asarray(mask.sum(axis=1)), 15)
    np.testing.assert_array_equal(per_q, expect)


def test_ept_monotone_in_p(small_ltr):
    ds, ens = small_ltr
    partial = jnp.asarray(_scores(ens, ds))
    mask = jnp.asarray(ds.mask)
    n_prev = -1
    for p in (0.0, 0.2, 0.5, 1.0):
        n = int(ept_continue(partial, mask, k_s=15, p=p).sum())
        assert n >= n_prev  # larger p ⇒ more conservative ⇒ more continues
        n_prev = n
    # p=0 keeps at least the top-k_s themselves.
    assert int(ept_continue(partial, mask, 15, 0.0).sum()) >= int(
        ert_continue(partial, mask, 15).sum()
    )


def test_ideal_preserves_ndcg(small_ltr):
    ds, ens = small_ltr
    Q, D, F = ds.X.shape
    flat = jnp.asarray(ds.X.reshape(Q * D, F))
    _, per_tree = score_bitvector(ens, flat, return_per_tree=True)
    sentinel = 10
    partial = np.asarray(per_tree[:, :sentinel].sum(axis=1)).reshape(Q, D)
    full = np.asarray(per_tree.sum(axis=1)).reshape(Q, D)
    mask = jnp.asarray(ds.mask)
    labels = jnp.asarray(ds.labels)
    cont, cut = ideal_continue(
        jnp.asarray(partial), jnp.asarray(full), labels, mask, k=10
    )
    ee_scores = jnp.where(cont, jnp.asarray(full), jnp.asarray(partial))
    ndcg_full = float(mean_ndcg(jnp.asarray(full), labels, mask, 10))
    ndcg_ee = float(mean_ndcg(ee_scores, labels, mask, 10))
    assert ndcg_ee >= ndcg_full - 1e-6, (ndcg_ee, ndcg_full)
    # Oracle cuts must be valid ranks.
    assert int(cut.min()) >= 0 and int(cut.max()) <= ds.X.shape[1]


def test_labels_and_weights(small_ltr):
    ds, ens = small_ltr
    full = jnp.asarray(_scores(ens, ds))
    mask = jnp.asarray(ds.mask)
    rel = jnp.asarray(ds.labels)
    cont = build_continue_labels(full, rel, mask, k=15)
    # Continue docs are relevant and ≤ 15 per query.
    assert int((cont & (rel == 0)).sum()) == 0
    assert int(cont.sum(axis=1).max()) <= 15
    w = instance_weights(cont, rel, mask)
    assert float(w[~np.asarray(mask)].sum() if (~np.asarray(mask)).any() else 0.0) == 0.0
    # Continue docs (minority) should get larger average weight than exits.
    w_np, c_np, m_np = np.asarray(w), np.asarray(cont), np.asarray(ds.mask)
    if c_np.any():
        assert w_np[c_np].mean() > w_np[m_np & ~c_np].mean()


def test_query_ranks_sort_free_matches_argsort():
    """The device feature pipeline's sort-free (pairwise-count) ranking is
    exactly the stable-argsort ranking — including score ties (broken by
    document index) and masked padding (ranked after every real doc)."""
    from repro.core.features import query_ranks
    from repro.metrics.ranking import rank_from_scores

    rng = np.random.default_rng(0)
    scores = rng.normal(size=(6, 40)).astype(np.float32)
    scores[0, :10] = 1.5          # exact ties within a query
    scores[1, :] = 0.0            # fully tied query
    mask = rng.random((6, 40)) < 0.8
    mask[2, :] = False            # fully masked query
    s, m = jnp.asarray(scores), jnp.asarray(mask)
    np.testing.assert_array_equal(
        np.asarray(query_ranks(s, m)), np.asarray(rank_from_scores(s, m))
    )


def test_augment_features_jits_and_matches_eager(small_ltr):
    """The augmented-feature build is device-resident: it traces cleanly
    under jit and the jitted result equals the eager one."""
    ds, ens = small_ltr
    partial = jnp.asarray(_scores(ens, ds))
    mask = jnp.asarray(ds.mask)
    X = jnp.asarray(ds.X)
    eager = augment_features(X, partial, mask)
    jitted = jax.jit(augment_features)(X, partial, mask)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_augment_features_shape_and_range(small_ltr):
    ds, ens = small_ltr
    partial = jnp.asarray(_scores(ens, ds))
    mask = jnp.asarray(ds.mask)
    aug = augment_features(jnp.asarray(ds.X), partial, mask)
    Q, D, F = ds.X.shape
    assert aug.shape == (Q, D, F + 4)
    norm = np.asarray(aug[..., F + 2])
    assert norm.min() >= 0.0 and norm.max() <= 1.0


def test_train_lear_recall(small_ltr):
    ds, ens = small_ltr
    sentinel = 10
    clf = train_lear(ds.X, ds.labels, ds.mask, ens, sentinel=sentinel, k=15)
    assert clf.n_trees == 10
    Q, D, F = ds.X.shape
    flat = jnp.asarray(ds.X.reshape(Q * D, F))
    _, per_tree = score_bitvector(ens, flat, return_per_tree=True)
    partial = (per_tree[:, :sentinel].sum(axis=1) + ens.base_score).reshape(Q, D)
    full = (per_tree.sum(axis=1) + ens.base_score).reshape(Q, D)
    mask = jnp.asarray(ds.mask)
    aug = augment_features(jnp.asarray(ds.X), partial, mask)
    cont_true = build_continue_labels(full, jnp.asarray(ds.labels), mask, k=15)
    cont_pred = clf.continue_mask(aug, mask, threshold=0.5)
    pr = precision_recall(cont_pred, cont_true, mask)
    # In-sample recall on Continue should be high (paper: 0.97/0.99 on test).
    assert pr["continue_recall"] > 0.85, pr


def test_cascade_compacted_matches_reference(small_ltr):
    ds, ens = small_ltr
    mask = jnp.asarray(ds.mask)
    cascade = CascadeRanker(
        ensemble=ens, sentinel=10,
        strategy=lambda partial, m: ert_continue(partial, m, k_s=12),
    )
    ref = cascade.rank(jnp.asarray(ds.X), mask)
    capacity = int(ref.continue_mask.sum()) + 8
    got = cascade.rank_compacted(jnp.asarray(ds.X), mask, capacity=capacity)
    assert got.overflow == 0
    np.testing.assert_allclose(
        np.asarray(got.scores), np.asarray(ref.scores), rtol=1e-4, atol=1e-5
    )
    assert got.speedup > 1.5  # k_s=12 of ~36 docs/query must cut work a lot
