"""Fused-vs-staged mode pick: host cost model vs the on-device mirror.

The serving contract: with ``execution_mode="auto"`` the pick happens ON
DEVICE (``lax.cond`` on ``progressive_cost_model_device``), and it must
choose the same branch the host-side reference
(``progressive_cost_model`` / ``RankingService._pick_mode``) would — the
host model is the documented, introspectable source of truth, the device
model is its traced mirror.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.metrics.speedup import (
    progressive_cost_model,
    progressive_cost_model_device,
)

SENTINELS = (32, 64, 96)
N_TREES = 192
N_DOCS = 1024


def _host_pick(ema, caps, loh):
    cost = {
        m: progressive_cost_model(
            N_DOCS, ema, SENTINELS, N_TREES, m,
            launch_overhead_trees=loh, stage_capacities=caps,
        )
        for m in ("fused", "staged")
    }
    return "staged" if cost["staged"] < cost["fused"] else "fused"


def _device_pick(ema, caps, loh):
    fused, staged = progressive_cost_model_device(
        N_DOCS, jnp.asarray(ema, jnp.float32), SENTINELS, N_TREES,
        launch_overhead_trees=loh, stage_capacities=caps,
    )
    return "staged" if bool(staged < fused) else "fused"


@pytest.mark.parametrize(
    "continue_rate", [0.02, 0.05, 0.15, 0.3, 0.5, 0.65, 0.8, 0.95, 1.0]
)
@pytest.mark.parametrize("loh", [0.0, 512.0, 4096.0, 20000.0])
def test_device_pick_matches_host_pick(continue_rate, loh):
    """Across the bench's continue-rate sweep (and beyond) and a wide
    launch-overhead range, the device pick chooses exactly the branch the
    host model chooses."""
    ema = [continue_rate * N_DOCS] * len(SENTINELS)
    caps = [512, 512, 512]
    assert _device_pick(ema, caps, loh) == _host_pick(ema, caps, loh)


def test_device_pick_matches_host_pick_shrinking_survivors():
    """Realistic nested-exit traffic: survivors shrink stage over stage,
    capacities bucketed per stage."""
    for rates in ([0.6, 0.3, 0.1], [0.9, 0.8, 0.7], [0.1, 0.05, 0.01]):
        ema = [r * N_DOCS for r in rates]
        caps = [1024, 512, 128]
        for loh in (0.0, 2048.0, 8192.0):
            assert _device_pick(ema, caps, loh) == _host_pick(ema, caps, loh)


def test_cost_model_prices_staged_at_min_capacity_peak():
    """Regression (sparse-traffic overestimate): staged stage work is
    priced at min(capacity, survivors). A capacity floor far above the
    observed survivor peak must not inflate staged cost — and survivors
    above capacity are still clipped at the block size."""
    ema = [10.0, 10.0, 10.0]          # sparse traffic
    caps = [512, 512, 512]            # cold-start-sized buckets
    sparse = progressive_cost_model(
        N_DOCS, ema, SENTINELS, N_TREES, "staged", stage_capacities=caps
    )
    # Stage work beyond stage 0 is priced at the 10-doc survivor estimate,
    # not the 512-doc block: head = n·s1 + 10·(s2−s1) + 10·(s3−s2).
    expect = N_DOCS * 32 + 10 * 32 + 10 * 32 + 10 * (N_TREES - 96)
    assert sparse == pytest.approx(expect)

    # Dense traffic: survivors exceed capacity → clipped at the block.
    dense = progressive_cost_model(
        N_DOCS, [800.0] * 3, SENTINELS, N_TREES, "staged",
        stage_capacities=caps,
    )
    expect_dense = N_DOCS * 32 + 512 * 32 + 512 * 32 + 800 * (N_TREES - 96)
    assert dense == pytest.approx(expect_dense)

    # The device mirror agrees on both regimes.
    for e in (ema, [800.0] * 3):
        fused_h = progressive_cost_model(
            N_DOCS, e, SENTINELS, N_TREES, "fused", stage_capacities=caps
        )
        staged_h = progressive_cost_model(
            N_DOCS, e, SENTINELS, N_TREES, "staged", stage_capacities=caps
        )
        fused_d, staged_d = progressive_cost_model_device(
            N_DOCS, jnp.asarray(e, jnp.float32), SENTINELS, N_TREES,
            stage_capacities=caps,
        )
        np.testing.assert_allclose(float(fused_d), fused_h, rtol=1e-6)
        np.testing.assert_allclose(float(staged_d), staged_h, rtol=1e-6)


def test_cost_model_block_rounded_survivor_pricing():
    """ROADMAP fix: staged stage work is priced at block_b-ROUNDED survivor
    counts clipped at capacity — a 3-survivor stage still costs one full
    kernel doc block, but never more than the capacity block."""
    ema = [3.0, 3.0, 3.0]
    caps = [512, 512, 512]
    priced = progressive_cost_model(
        N_DOCS, ema, SENTINELS, N_TREES, "staged",
        stage_capacities=caps, block_b=256,
    )
    # Stages 1..2 price ceil(3/256)*256 = 256 docs; the tail stays at the
    # raw survivor estimate (identical in both modes — cancels out).
    expect = N_DOCS * 32 + 256 * 32 + 256 * 32 + 3.0 * (N_TREES - 96)
    assert priced == pytest.approx(expect)

    # Tight bucket below block_b: the effective block shrinks with the
    # compacted row count (kernels.ops._prep_x), so cap=128 prices 128.
    tight = progressive_cost_model(
        N_DOCS, ema, SENTINELS, N_TREES, "staged",
        stage_capacities=[128] * 3, block_b=256,
    )
    expect_tight = N_DOCS * 32 + 128 * 32 + 128 * 32 + 3.0 * (N_TREES - 96)
    assert tight == pytest.approx(expect_tight)

    # Rounding clips at capacity for dense traffic.
    dense = progressive_cost_model(
        N_DOCS, [600.0] * 3, SENTINELS, N_TREES, "staged",
        stage_capacities=caps, block_b=256,
    )
    expect_dense = N_DOCS * 32 + 512 * 32 + 512 * 32 + 600.0 * (N_TREES - 96)
    assert dense == pytest.approx(expect_dense)

    # block_b=1 (the default) reproduces the bare min(capacity, survivors)
    # model — pre-existing callers see no change.
    bare = progressive_cost_model(
        N_DOCS, ema, SENTINELS, N_TREES, "staged", stage_capacities=caps
    )
    assert bare == pytest.approx(N_DOCS * 32 + 3 * 32 + 3 * 32
                                 + 3.0 * (N_TREES - 96))


@pytest.mark.parametrize("block_b", [1, 64, 256])
@pytest.mark.parametrize(
    "rates", [[0.6, 0.3, 0.1], [0.02, 0.01, 0.005], [0.9, 0.8, 0.7]]
)
def test_device_pick_matches_host_pick_block_rounded(block_b, rates):
    """Host/device pick agreement holds with block-rounded pricing — both
    models must be handed the same block_b (the serving stack passes
    ENGINE_BLOCK_B to both)."""
    ema = [r * N_DOCS for r in rates]
    caps = [1024, 512, 128]
    for loh in (0.0, 2048.0, 8192.0):
        host = {
            m: progressive_cost_model(
                N_DOCS, ema, SENTINELS, N_TREES, m,
                launch_overhead_trees=loh, stage_capacities=caps,
                block_b=block_b,
            )
            for m in ("fused", "staged")
        }
        fused_d, staged_d = progressive_cost_model_device(
            N_DOCS, jnp.asarray(ema, jnp.float32), SENTINELS, N_TREES,
            launch_overhead_trees=loh, stage_capacities=caps,
            block_b=block_b,
        )
        np.testing.assert_allclose(float(fused_d), host["fused"], rtol=1e-5)
        np.testing.assert_allclose(float(staged_d), host["staged"], rtol=1e-5)
        host_pick = "staged" if host["staged"] < host["fused"] else "fused"
        device_pick = "staged" if bool(staged_d < fused_d) else "fused"
        assert device_pick == host_pick, (block_b, rates, loh)


@pytest.mark.parametrize(
    "ema",
    [
        [0.0, 0.0, 0.0],                        # zero survivors everywhere
        [float("nan")] * 3,                     # poisoned stats pipeline
        [float("inf"), 100.0, float("-inf")],   # runaway estimates
        [-50.0, -1.0, 0.0],                     # negative (impossible) counts
    ],
)
@pytest.mark.parametrize("n_docs", [0, N_DOCS])
def test_cost_model_degenerate_ema_stays_finite(ema, n_docs):
    """Regression: zero-survivor, empty-batch, and non-finite EMA inputs
    must never produce NaN/inf costs — a NaN cost makes every comparison
    False and silently pins the pick to one branch."""
    import math

    caps = [512, 512, 512]
    for mode in ("fused", "staged"):
        cost = progressive_cost_model(
            n_docs, ema, SENTINELS, N_TREES, mode,
            launch_overhead_trees=4096.0, stage_capacities=caps, block_b=256,
        )
        assert math.isfinite(cost), (mode, ema, n_docs, cost)
        assert cost >= 0.0
    fused_d, staged_d = progressive_cost_model_device(
        n_docs, jnp.asarray(ema, jnp.float32), SENTINELS, N_TREES,
        launch_overhead_trees=4096.0, stage_capacities=caps, block_b=256,
    )
    assert np.isfinite(float(fused_d)) and np.isfinite(float(staged_d))
    # The pick is a real decision (one strict comparison of finite floats),
    # and host/device still agree on it.
    host = _host_pick_b256(ema, caps, 4096.0, n_docs)
    device = "staged" if bool(staged_d < fused_d) else "fused"
    assert device == host, (ema, n_docs, device, host)


def _host_pick_b256(ema, caps, loh, n_docs):
    cost = {
        m: progressive_cost_model(
            n_docs, ema, SENTINELS, N_TREES, m,
            launch_overhead_trees=loh, stage_capacities=caps, block_b=256,
        )
        for m in ("fused", "staged")
    }
    return "staged" if cost["staged"] < cost["fused"] else "fused"


def test_cost_model_sanitizes_like_clamped_input():
    """Sanitized non-finite estimates price exactly like their clamped
    finite equivalents (NaN → 0, +inf → n_docs, negative → 0)."""
    caps = [512, 512, 512]
    pairs = [
        ([float("nan")] * 3, [0.0] * 3),
        ([float("inf")] * 3, [float(N_DOCS)] * 3),
        ([-10.0, -1.0, -0.5], [0.0] * 3),
    ]
    for bad, clean in pairs:
        for mode in ("fused", "staged"):
            got = progressive_cost_model(
                N_DOCS, bad, SENTINELS, N_TREES, mode,
                stage_capacities=caps, block_b=256,
            )
            want = progressive_cost_model(
                N_DOCS, clean, SENTINELS, N_TREES, mode,
                stage_capacities=caps, block_b=256,
            )
            assert got == pytest.approx(want), (bad, mode)
        bad_d = progressive_cost_model_device(
            N_DOCS, jnp.asarray(bad, jnp.float32), SENTINELS, N_TREES,
            stage_capacities=caps, block_b=256,
        )
        clean_d = progressive_cost_model_device(
            N_DOCS, jnp.asarray(clean, jnp.float32), SENTINELS, N_TREES,
            stage_capacities=caps, block_b=256,
        )
        np.testing.assert_allclose(
            np.asarray([float(x) for x in bad_d]),
            np.asarray([float(x) for x in clean_d]), rtol=1e-6,
        )


def test_cost_model_no_tail_no_tail_launch_priced():
    """Sentinel at the ensemble end: no tail work, and fused prices a
    single launch (staged S launches)."""
    sent = (64, N_TREES)
    fused = progressive_cost_model(
        N_DOCS, [100.0, 50.0], sent, N_TREES, "fused",
        launch_overhead_trees=1000.0,
    )
    assert fused == pytest.approx(N_DOCS * N_TREES + 1000.0)
    staged = progressive_cost_model(
        N_DOCS, [100.0, 50.0], sent, N_TREES, "staged",
        launch_overhead_trees=1000.0,
    )
    assert staged == pytest.approx(N_DOCS * 64 + 100.0 * (N_TREES - 64) + 2000.0)
    fused_d, staged_d = progressive_cost_model_device(
        N_DOCS, jnp.asarray([100.0, 50.0], jnp.float32), sent, N_TREES,
        launch_overhead_trees=1000.0,
    )
    assert float(fused_d) == pytest.approx(fused)
    assert float(staged_d) == pytest.approx(staged)
