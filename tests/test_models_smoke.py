"""Per-architecture smoke tests: reduced config, one real step on CPU.

Spec requirement (f): every assigned architecture instantiates a REDUCED
same-family config and runs one forward/train step asserting output shapes
and the absence of NaNs. Full configs are only ever lowered abstractly by
the dry-run.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, ASSIGNED_ARCHS
from repro.configs.base import ShapeSpec
from repro.models.api import make_cell
from repro.models.synth import synthesize_inputs
from repro.train.trainer import TrainState

LM_ARCHS = [
    "qwen2.5-14b", "minitron-4b", "qwen3-4b",
    "deepseek-moe-16b", "llama4-maverick-400b-a17b",
]
RECSYS_ARCHS = ["bert4rec", "din", "deepfm", "dlrm-rm2"]

LM_TRAIN = ShapeSpec(name="smoke_train", kind="train", seq_len=32,
                     global_batch=4, microbatch=2)
LM_PREFILL = ShapeSpec(name="smoke_prefill", kind="prefill", seq_len=32,
                       global_batch=2)
LM_DECODE = ShapeSpec(name="smoke_decode", kind="decode", seq_len=32,
                      global_batch=2)


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), "non-finite values"


def _run_train(cell):
    state = cell.init_state(jax.random.key(0))
    inputs = synthesize_inputs(cell, seed=1)
    new_state, metrics = jax.jit(cell.step)(state, inputs)
    assert isinstance(new_state, TrainState)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    _finite(new_state.params)
    return float(metrics["loss"])


@pytest.mark.slow  # 5-12s per arch on CPU; prefill/decode covers the fwd path
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    cfg = get_smoke_config(arch)
    cell = make_cell(cfg, LM_TRAIN)
    loss = _run_train(cell)
    assert loss > 0  # CE over random tokens


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_and_decode(arch):
    cfg = get_smoke_config(arch)
    pre = make_cell(cfg, LM_PREFILL)
    params = pre.init_state(jax.random.key(0))
    logits, caches = jax.jit(pre.step)(params, synthesize_inputs(pre, 2))
    assert logits.shape == (LM_PREFILL.global_batch, cfg.vocab_size)
    _finite(logits)

    dec = make_cell(cfg, LM_DECODE)
    inputs = synthesize_inputs(dec, 3)
    logits2, new_caches = jax.jit(dec.step)(params, inputs)
    assert logits2.shape == (LM_DECODE.global_batch, cfg.vocab_size)
    _finite(logits2)
    # Cache must change at the written position.
    k_old = jax.tree.leaves(inputs["caches"])[0]
    k_new = jax.tree.leaves(new_caches)[0]
    assert k_old.shape == k_new.shape


@pytest.mark.slow  # heaviest single smoke (~14s); featured-graph stays tier-1
def test_nequip_molecule_train():
    cfg = get_smoke_config("nequip")
    shape = ShapeSpec(name="smoke_mol", kind="train", n_nodes=40, n_edges=120,
                      graph_batch=4)
    cell = make_cell(cfg, shape)
    _run_train(cell)


def test_nequip_featured_graph_train():
    cfg = get_smoke_config("nequip")
    shape = ShapeSpec(name="smoke_feat", kind="train", n_nodes=50, n_edges=160,
                      d_feat=24)
    cell = make_cell(cfg, shape)
    _run_train(cell)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_train_step(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeSpec(name="smoke_train", kind="train", batch=32)
    cell = make_cell(cfg, shape)
    _run_train(cell)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_serve(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeSpec(name="smoke_serve", kind="serve", batch=16)
    cell = make_cell(cfg, shape)
    params = cell.init_state(jax.random.key(0))
    scores = jax.jit(cell.step)(params, synthesize_inputs(cell, 5))
    assert scores.shape == (16,)
    _finite(scores)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_retrieval(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeSpec(name="smoke_retr", kind="serve", batch=1, n_candidates=512)
    cell = make_cell(cfg, shape)
    params = cell.init_state(jax.random.key(0))
    scores = jax.jit(cell.step)(params, synthesize_inputs(cell, 6))
    # candidate axis is padded to the 512-shard boundary
    assert scores.shape == (512,)
    _finite(scores)


def test_forest_cascade_serve():
    cfg = get_smoke_config("lear-msn1")
    shape = ShapeSpec(name="smoke_rank", kind="serve", batch=8)
    cell = make_cell(cfg, shape)
    params = cell.init_state(jax.random.key(0))
    scores, cont = jax.jit(cell.step)(params, synthesize_inputs(cell, 7))
    assert scores.shape == (8, cfg.max_docs)
    _finite(scores)


def test_all_assigned_archs_have_configs():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ASSIGNED_ARCHS:
        cfg = get_smoke_config(arch)
        assert cfg.name
