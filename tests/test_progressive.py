"""Multi-sentinel progressive cascade engine: kernel, compaction, semantics.

Covers the engine's contracts:
- segmented-prefix kernel vs the ``partial_scores`` oracle at every sentinel
  (including tree-block-unaligned sentinels);
- cumsum compaction ≡ argsort compaction (overflow / all-exit / all-continue),
  and the masked variant's within-capacity mask;
- ``rank_progressive`` with one sentinel is bit-exact vs ``rank_compacted``
  in BOTH execution modes;
- per-stage-tail (staged) mode is bit-exact with fused mode on non-overflow
  batches, and both agree with the ``rank()`` oracle;
- the launch contract under the end-to-end jit: a fused S=3 cascade stages
  exactly 1 segmented head + 1 tail launch, a staged one ≤ S+1 plain
  launches, and the TRACE-TIME counters do not move on cached
  re-executions of a compiled step;
- staged capacities are real kernel bounds: per-stage overflow is counted
  and clipped survivors retire with their stage prefix;
- nested exit masks: a document that exits at stage k keeps its stage-k
  prefix even if a later stage's strategy would have kept it;
- padded-buffer caching on the ensemble, LRU-bounded;
- overflow stays a lazy device scalar (no hidden host sync in the hot path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.stage import EngineConfig
from repro.core.compaction import (
    compact_indices_argsort,
    compact_indices_cumsum,
    compact_indices_cumsum_masked,
)
from repro.core.strategies import ert_continue
from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import partial_scores
from repro.kernels import ops


def _cascade(ens, k_s=8, sentinel=10):
    return CascadeRanker(
        ensemble=ens, sentinel=sentinel,
        strategy=lambda p, m: ert_continue(p, m, k_s=k_s),
    )


@pytest.mark.parametrize("sentinels", [(16,), (16, 32), (5, 19, 33)])
def test_segmented_prefixes_match_partial_scores(sentinels):
    """Every sentinel prefix from ONE launch matches the pure-jnp oracle —
    including sentinels that are not tree-block multiples."""
    rng = np.random.default_rng(3)
    ens = random_ensemble(3, n_trees=37, depth=4, n_features=21)
    X = jnp.asarray(rng.normal(size=(50, 21)).astype(np.float32))
    pf = ops.padded_forest(ens, boundaries=(*sentinels, ens.n_trees))
    seg = ops.forest_score_segments(pf, X, n_segments=len(sentinels))
    prefix = np.asarray(jnp.cumsum(seg, axis=1) + pf.base_score)
    for k, s in enumerate(sentinels):
        head, _ = partial_scores(ens, X, s)
        np.testing.assert_allclose(
            prefix[:, k], np.asarray(head), rtol=1e-5, atol=1e-5
        )


def test_forest_score_range_matches_tail_oracle():
    rng = np.random.default_rng(4)
    ens = random_ensemble(4, n_trees=37, depth=4, n_features=21)
    X = jnp.asarray(rng.normal(size=(40, 21)).astype(np.float32))
    pf = ops.padded_forest(ens, boundaries=(5, 19, 33, ens.n_trees))
    _, tail_ref = partial_scores(ens, X, 33)
    tail_got = ops.forest_score_range(pf, X, seg_lo=3)
    np.testing.assert_allclose(
        np.asarray(tail_got), np.asarray(tail_ref), rtol=1e-5, atol=1e-5
    )
    # Range starting at 0 over all segments = full scoring incl. base score.
    full_ref, _ = partial_scores(ens, X, ens.n_trees)
    full_got = ops.forest_score_range(pf, X)
    np.testing.assert_allclose(
        np.asarray(full_got), np.asarray(full_ref), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize(
    "cont_rate,capacity",
    [
        (0.3, 64),     # ample capacity
        (0.9, 32),     # overflow
        (0.0, 16),     # all-exit
        (1.0, 128),    # all-continue (capacity == n)
    ],
)
def test_cumsum_compaction_equals_argsort(cont_rate, capacity):
    rng = np.random.default_rng(int(cont_rate * 10) + capacity)
    n = 128
    if cont_rate == 0.0:
        cont = np.zeros(n, bool)
    elif cont_rate == 1.0:
        cont = np.ones(n, bool)
    else:
        cont = rng.random(n) < cont_rate
    cj = jnp.asarray(cont)
    sel_c, n_c = compact_indices_cumsum(cj, capacity)
    sel_a, n_a = compact_indices_argsort(cj, capacity)
    assert int(n_c) == int(n_a) == int(cont.sum())
    valid = min(int(n_c), capacity)
    # Valid slots agree exactly (stable: ascending survivor indices).
    np.testing.assert_array_equal(
        np.asarray(sel_c)[:valid], np.asarray(sel_a)[:valid]
    )
    np.testing.assert_array_equal(
        np.asarray(sel_c)[:valid], np.flatnonzero(cont)[:valid]
    )


@pytest.mark.parametrize("mode", ["fused", "staged"])
def test_progressive_single_sentinel_bitexact_vs_compacted(mode):
    rng = np.random.default_rng(5)
    ens = random_ensemble(5, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.9)
    cascade = _cascade(ens)
    ref = cascade.rank_compacted(X, mask, capacity=64)
    got = cascade.rank_progressive(
        X, mask, EngineConfig.trees([10], capacities=[64], mode=mode)
    )
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))
    np.testing.assert_array_equal(
        np.asarray(ref.continue_mask), np.asarray(got.continue_mask)
    )
    assert ref.speedup == float(got.speedup)  # progressive speedup is lazy
    assert int(ref.overflow) == int(got.overflow) == 0


def test_progressive_single_sentinel_bitexact_under_overflow():
    rng = np.random.default_rng(6)
    ens = random_ensemble(6, n_trees=40, depth=3, n_features=8)
    Q, D, F = 4, 32, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens, k_s=16)  # 64 survivors
    ref = cascade.rank_compacted(X, mask, capacity=16)  # overflow 48
    got = cascade.rank_progressive(
        X, mask, EngineConfig.trees([10], capacities=[16])
    )
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))
    assert int(ref.overflow) == int(got.overflow) == 48


def test_progressive_s3_launch_budget():
    """The acceptance contract, asserted via trace-time counters under the
    end-to-end jit: a fused S=3 cascade stages exactly 1 segmented head
    launch + 1 tail launch; a staged one stages S+1 = 4 plain launches and
    no segmented launch; and cached re-executions of a compiled step move
    NO counters (the launch plan is a property of the computation, not of
    the call)."""
    rng = np.random.default_rng(7)
    ens = random_ensemble(7, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (16, 10, 6)
    ]

    def run(mode):
        result = cascade.rank_progressive(
            X, mask, EngineConfig.trees(
                [10, 20, 35], tuple(strategies), capacities=128, mode=mode
            ),
        )
        jax.block_until_ready(result.scores)
        return result

    ops.reset_launch_counts()
    run("fused")
    counts = ops.launch_counts()
    assert counts["segmented"] == 1, counts
    # Exactly ONE tail launch — a regression to per-stage tails (the S-launch
    # pattern fused mode replaces) must fail here, not sneak under a <= S.
    assert counts["plain"] == 1, counts
    # Cached re-execution: the compiled step stages no new launches.
    run("fused")
    assert ops.launch_counts() == counts, ops.launch_counts()

    ops.reset_launch_counts()
    run("staged")
    staged_counts = ops.launch_counts()
    # Stage-0 head + per-stage tails for stages 1..S-1 + final tail = S+1.
    assert staged_counts == {"plain": 4, "segmented": 0, "gated": 0}, staged_counts
    run("staged")
    assert ops.launch_counts() == staged_counts, ops.launch_counts()


def test_progressive_nested_exit_semantics():
    """A doc that exits at stage 1 keeps its stage-1 prefix even when the
    stage-2 strategy alone would have continued it."""
    rng = np.random.default_rng(8)
    ens = random_ensemble(8, n_trees=60, depth=4, n_features=16)
    Q, D, F = 4, 16, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    strategies = [
        lambda p, m: ert_continue(p, m, k_s=4),    # aggressive stage 1
        lambda p, m: m,                            # stage 2 would keep all
    ]
    result = cascade.rank_progressive(
        X, mask, EngineConfig.trees([10, 30], tuple(strategies), capacities=64)
    )
    alive1 = np.asarray(result.stage_masks[0])
    alive2 = np.asarray(result.stage_masks[1])
    np.testing.assert_array_equal(alive2, alive1)   # nested: no resurrection
    prefix = np.asarray(result.partials)
    exited = np.asarray(mask) & ~alive1
    np.testing.assert_allclose(
        np.asarray(result.scores)[exited], prefix[..., 0][exited],
        rtol=0, atol=0,
    )
    # Survivors got strictly more trees than their stage-2 prefix.
    full, _ = partial_scores(ens, X.reshape(Q * D, F), ens.n_trees)
    np.testing.assert_allclose(
        np.asarray(result.scores)[alive2],
        np.asarray(full).reshape(Q, D)[alive2],
        rtol=1e-5, atol=1e-5,
    )


def test_progressive_sentinel_at_ensemble_end():
    """sS == n_trees: no tail trees remain, no tail launch is issued."""
    rng = np.random.default_rng(9)
    ens = random_ensemble(9, n_trees=32, depth=3, n_features=8)
    Q, D, F = 3, 16, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    ops.reset_launch_counts()
    result = cascade.rank_progressive(
        X, mask, EngineConfig.trees([16, 32], capacities=64)
    )
    jax.block_until_ready(result.scores)
    counts = ops.launch_counts()
    assert counts == {"plain": 0, "segmented": 1, "gated": 0}, counts
    full, _ = partial_scores(ens, X.reshape(Q * D, F), ens.n_trees)
    survivors = np.asarray(result.continue_mask)
    np.testing.assert_allclose(
        np.asarray(result.scores)[survivors],
        np.asarray(full).reshape(Q, D)[survivors],
        rtol=1e-5, atol=1e-5,
    )


def test_padded_forest_cached_on_ensemble():
    ens = random_ensemble(10, n_trees=24, depth=3, n_features=8)
    pf1 = ops.padded_forest(ens, boundaries=(10, 24))
    pf2 = ops.padded_forest(ens, boundaries=(10, 24))
    assert pf1 is pf2
    assert ops.padded_forest(ens) is ops.padded_forest(ens)
    assert ops.padded_forest(ens) is not pf1  # distinct layout, distinct entry


def test_head_tail_slices_cached():
    ens = random_ensemble(11, n_trees=24, depth=3, n_features=8)
    cascade = _cascade(ens)
    assert cascade._head_tail() is cascade._head_tail()


def test_overflow_is_lazy_device_scalar():
    rng = np.random.default_rng(12)
    ens = random_ensemble(12, n_trees=40, depth=3, n_features=8)
    Q, D, F = 4, 16, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    for result in (
        cascade.rank_compacted(X, mask, capacity=16),
        cascade.rank_progressive(
            X, mask, EngineConfig.trees([10], capacities=16)
        ),
    ):
        assert isinstance(result.overflow, jax.Array)  # not a host int
        assert int(result.overflow) >= 0               # stats-path read works
    # Progressive speedup is also lazy (the reference paths return floats).
    prog = cascade.rank_progressive(
        X, mask, EngineConfig.trees([10], capacities=16)
    )
    assert isinstance(prog.speedup, jax.Array)
    assert float(prog.speedup) > 1.0


def test_lear_classifier_kernel_path_matches_bitvector():
    """prob_continue(use_kernel=True) routes through the Pallas kernel and
    agrees with the pure-XLA bitvector path."""
    from repro.core.lear import LearClassifier

    rng = np.random.default_rng(13)
    clf = LearClassifier(
        forest=random_ensemble(13, n_trees=10, depth=4, n_features=12),
        sentinel=10,
    )
    X_aug = jnp.asarray(rng.normal(size=(3, 20, 12)).astype(np.float32))
    p_xla = clf.prob_continue(X_aug, use_kernel=False)
    p_pallas = clf.prob_continue(X_aug, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(p_pallas), np.asarray(p_xla), rtol=1e-5, atol=1e-6
    )


def test_bucket_capacity_policy():
    assert bucket_capacity(1, 10_000) == 64        # floor
    assert bucket_capacity(100, 10_000) == 128     # next power of two
    assert bucket_capacity(128, 10_000) == 128     # exact power stays
    assert bucket_capacity(5_000, 4_096) == 4_096  # clipped to limit


def test_compaction_masked_within_capacity():
    rng = np.random.default_rng(20)
    cont = jnp.asarray(rng.random(96) < 0.5)
    sel, n_cont, within = compact_indices_cumsum_masked(cont, 16)
    sel_ref, n_ref = compact_indices_cumsum(cont, 16)
    assert int(n_cont) == int(n_ref)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(sel_ref))
    # ``within`` is exactly the first ``capacity`` survivors, in index order.
    idx = np.flatnonzero(np.asarray(cont))
    expect = np.zeros(96, bool)
    expect[idx[:16]] = True
    np.testing.assert_array_equal(np.asarray(within), expect)


def test_staged_matches_fused_and_oracle():
    """Per-stage-tail mode vs fused mode vs the ``rank()`` oracle.

    On a non-overflow batch the two modes are BIT-exact (same per-block
    kernel sums, same left-to-right prefix association); the reference
    ``rank()`` path scores through a different (pure-XLA) kernel, so it is
    compared to numerical tolerance.
    """
    rng = np.random.default_rng(21)
    ens = random_ensemble(21, n_trees=60, depth=4, n_features=16)
    Q, D, F = 5, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.9)
    cascade = _cascade(ens)
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (16, 10, 6)
    ]
    def config(mode):
        return EngineConfig.trees(
            [10, 20, 35], tuple(strategies), capacities=128, mode=mode
        )

    fused = cascade.rank_progressive(X, mask, config("fused"))
    staged = cascade.rank_progressive(X, mask, config("staged"))
    assert int(fused.overflow) == int(staged.overflow) == 0
    np.testing.assert_array_equal(
        np.asarray(fused.scores), np.asarray(staged.scores)
    )
    for mf, ms in zip(fused.stage_masks, staged.stage_masks):
        np.testing.assert_array_equal(np.asarray(mf), np.asarray(ms))
    assert float(fused.speedup) == float(staged.speedup)

    # Single-sentinel oracle: both modes vs the full-compute rank() path.
    for mode in ("fused", "staged"):
        got = cascade.rank_progressive(
            X, mask, EngineConfig.trees([10], capacities=[Q * D], mode=mode)
        )
        ref = cascade.rank(X, mask)
        np.testing.assert_array_equal(
            np.asarray(ref.continue_mask), np.asarray(got.continue_mask)
        )
        np.testing.assert_allclose(
            np.asarray(got.scores), np.asarray(ref.scores),
            rtol=1e-5, atol=1e-5,
        )


def test_staged_capacity_is_real_bound_with_overflow():
    """Staged capacities clip the survivor block: clipped docs retire with
    their stage prefix, per-stage overflow is counted, and later stages
    never see the clipped docs."""
    rng = np.random.default_rng(22)
    ens = random_ensemble(22, n_trees=40, depth=3, n_features=8)
    Q, D, F = 4, 32, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens, k_s=16)  # 64 stage-0 survivors
    res = cascade.rank_progressive(
        X, mask,
        EngineConfig.trees([10, 20], capacities=[16, 128], mode="staged"),
    )
    assert int(res.overflow) == 48          # 64 survivors, stage-0 cap 16
    alive0 = np.asarray(res.stage_masks[0])
    assert alive0.sum() == 16               # clipped to capacity
    # Clipped docs keep their stage-0 prefix (the compacted survivors are
    # the first 16 in index order; later survivors retired).
    prefix0 = np.asarray(res.partials[..., 0])
    decided = np.asarray(
        ert_continue(jnp.asarray(prefix0), mask, k_s=16)
    )
    clipped = decided & ~alive0
    assert clipped.sum() == 48
    np.testing.assert_array_equal(
        np.asarray(res.scores)[clipped], prefix0[clipped]
    )


def test_padded_forest_cache_lru_eviction():
    """The per-ensemble padded-buffer cache is LRU-bounded: sweeping
    sentinel layouts cannot grow device memory without bound, and the
    most-recently-used layout survives eviction pressure."""
    ens = random_ensemble(23, n_trees=24, depth=3, n_features=8)
    pf0 = ops.padded_forest(ens, boundaries=(10, 24))
    for i in range(ops.PADDED_CACHE_MAX - 1):
        ops.padded_forest(ens, boundaries=(i + 1, 24))
    # Cache is now full; pf0 is the LRU entry. Touch it, then insert one
    # more layout: the touched entry must survive, the oldest untouched go.
    assert ops.padded_forest(ens, boundaries=(10, 24)) is pf0
    ops.padded_forest(ens, boundaries=(20, 24))
    cache = ens._padded_cache
    assert len(cache) == ops.PADDED_CACHE_MAX
    assert ops.padded_forest(ens, boundaries=(10, 24)) is pf0  # still cached
    # The evicted layout is rebuilt fresh on re-request — and re-cached.
    rebuilt = ops.padded_forest(ens, boundaries=(1, 24))
    assert ops.padded_forest(ens, boundaries=(1, 24)) is rebuilt


def test_auto_mode_launch_counters_stable_under_cond():
    """mode="auto" compiles BOTH branches under one lax.cond: tracing the
    combined S=3 program stages 1 segmented launch (fused branch) plus
    S+2=5 plain launches (fused tail + staged head/stage-tails/tail), each
    accounted ONCE — and re-executions, including ones that flip the
    executed branch, move no counters."""
    rng = np.random.default_rng(30)
    ens = random_ensemble(30, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (16, 10, 6)
    ]
    # Low launch overhead: the cost model picks staged whenever the EMA is
    # trusted (at this toy scale the block-rounded survivor pricing
    # saturates at the capacity block, so the flip comes from the traced
    # have_ema operand, not the EMA magnitude).
    config = EngineConfig.trees(
        [10, 20, 35], tuple(strategies), capacities=128, mode="auto",
        launch_overhead_trees=100.0,
    )

    ops.reset_launch_counts()
    res = cascade.rank_progressive(
        X, mask, config, stage_ema=jnp.asarray([4.0, 4.0, 4.0])
    )
    jax.block_until_ready(res.scores)
    counts = ops.launch_counts()
    assert counts == {"segmented": 1, "plain": 5, "gated": 0}, counts
    # Branch flip on the cached step (have_ema=False forces the fused
    # cold-start branch — a traced operand): no re-trace, no counter move.
    res2 = cascade.rank_progressive(
        X, mask, config, have_ema=False,
        stage_ema=jnp.asarray([4.0, 4.0, 4.0]),
    )
    jax.block_until_ready(res2.scores)
    assert ops.launch_counts() == counts, ops.launch_counts()
    assert bool(res.picked_staged) and not bool(res2.picked_staged)


def test_auto_mode_bitexact_with_picked_branch():
    """The combined program's output is bit-exact with running the picked
    branch directly, for both pick outcomes; have_ema=False forces the
    fused cold-start branch regardless of the estimate."""
    rng = np.random.default_rng(31)
    ens = random_ensemble(31, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.9)
    cascade = _cascade(ens)
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (16, 10, 6)
    ]
    def config(mode, loh=0.0):
        return EngineConfig.trees(
            [10, 20, 35], tuple(strategies), capacities=128, mode=mode,
            launch_overhead_trees=loh,
        )

    fixed = {
        m: cascade.rank_progressive(X, mask, config(m))
        for m in ("fused", "staged")
    }
    # Block-rounded pricing: at this scale staged stage work saturates at
    # the capacity block, so launch overhead decides — cheap launches pick
    # staged, expensive launches pick fused. Both cond branches execute.
    for loh, expect in ((100.0, "staged"), (5000.0, "fused")):
        got = cascade.rank_progressive(
            X, mask, config("auto", loh), stage_ema=jnp.asarray([4.0] * 3)
        )
        assert ("staged" if bool(got.picked_staged) else "fused") == expect
        np.testing.assert_array_equal(
            np.asarray(got.scores), np.asarray(fixed[expect].scores)
        )
        np.testing.assert_array_equal(
            np.asarray(got.continue_mask),
            np.asarray(fixed[expect].continue_mask),
        )
    cold = cascade.rank_progressive(
        X, mask, config("auto", 512.0), stage_ema=jnp.asarray([4.0] * 3),
        have_ema=False,
    )
    assert not bool(cold.picked_staged)
    np.testing.assert_array_equal(
        np.asarray(cold.scores), np.asarray(fixed["fused"].scores)
    )


def test_strategies_clamp_small_query_block():
    """k_s larger than the padded candidate count must not crash (top_k
    rejects k > axis size) — every masked doc continues instead."""
    from repro.core.strategies import ept_continue

    rng = np.random.default_rng(24)
    partial = jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32))
    mask = jnp.asarray(rng.random((3, 5)) < 0.8)
    for cont in (
        ert_continue(partial, mask, k_s=50),
        ept_continue(partial, mask, k_s=50, p=1e9),
    ):
        np.testing.assert_array_equal(np.asarray(cont), np.asarray(mask))
