"""Multi-sentinel progressive cascade engine: kernel, compaction, semantics.

Covers the engine's contracts:
- segmented-prefix kernel vs the ``partial_scores`` oracle at every sentinel
  (including tree-block-unaligned sentinels);
- cumsum compaction ≡ argsort compaction (overflow / all-exit / all-continue);
- ``rank_progressive`` with one sentinel is bit-exact vs ``rank_compacted``;
- an S=3 cascade issues exactly 1 segmented head launch and ≤ S tail
  launches (launch counters in :mod:`repro.kernels.ops`);
- nested exit masks: a document that exits at stage k keeps its stage-k
  prefix even if a later stage's strategy would have kept it;
- padded-buffer caching on the ensemble;
- overflow stays a lazy device scalar (no hidden host sync in the hot path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeRanker, bucket_capacity
from repro.core.compaction import compact_indices_argsort, compact_indices_cumsum
from repro.core.strategies import ert_continue
from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import partial_scores
from repro.kernels import ops


def _cascade(ens, k_s=8, sentinel=10):
    return CascadeRanker(
        ensemble=ens, sentinel=sentinel,
        strategy=lambda p, m: ert_continue(p, m, k_s=k_s),
    )


@pytest.mark.parametrize("sentinels", [(16,), (16, 32), (5, 19, 33)])
def test_segmented_prefixes_match_partial_scores(sentinels):
    """Every sentinel prefix from ONE launch matches the pure-jnp oracle —
    including sentinels that are not tree-block multiples."""
    rng = np.random.default_rng(3)
    ens = random_ensemble(3, n_trees=37, depth=4, n_features=21)
    X = jnp.asarray(rng.normal(size=(50, 21)).astype(np.float32))
    pf = ops.padded_forest(ens, boundaries=(*sentinels, ens.n_trees))
    seg = ops.forest_score_segments(pf, X, n_segments=len(sentinels))
    prefix = np.asarray(jnp.cumsum(seg, axis=1) + pf.base_score)
    for k, s in enumerate(sentinels):
        head, _ = partial_scores(ens, X, s)
        np.testing.assert_allclose(
            prefix[:, k], np.asarray(head), rtol=1e-5, atol=1e-5
        )


def test_forest_score_range_matches_tail_oracle():
    rng = np.random.default_rng(4)
    ens = random_ensemble(4, n_trees=37, depth=4, n_features=21)
    X = jnp.asarray(rng.normal(size=(40, 21)).astype(np.float32))
    pf = ops.padded_forest(ens, boundaries=(5, 19, 33, ens.n_trees))
    _, tail_ref = partial_scores(ens, X, 33)
    tail_got = ops.forest_score_range(pf, X, seg_lo=3)
    np.testing.assert_allclose(
        np.asarray(tail_got), np.asarray(tail_ref), rtol=1e-5, atol=1e-5
    )
    # Range starting at 0 over all segments = full scoring incl. base score.
    full_ref, _ = partial_scores(ens, X, ens.n_trees)
    full_got = ops.forest_score_range(pf, X)
    np.testing.assert_allclose(
        np.asarray(full_got), np.asarray(full_ref), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize(
    "cont_rate,capacity",
    [
        (0.3, 64),     # ample capacity
        (0.9, 32),     # overflow
        (0.0, 16),     # all-exit
        (1.0, 128),    # all-continue (capacity == n)
    ],
)
def test_cumsum_compaction_equals_argsort(cont_rate, capacity):
    rng = np.random.default_rng(int(cont_rate * 10) + capacity)
    n = 128
    if cont_rate == 0.0:
        cont = np.zeros(n, bool)
    elif cont_rate == 1.0:
        cont = np.ones(n, bool)
    else:
        cont = rng.random(n) < cont_rate
    cj = jnp.asarray(cont)
    sel_c, n_c = compact_indices_cumsum(cj, capacity)
    sel_a, n_a = compact_indices_argsort(cj, capacity)
    assert int(n_c) == int(n_a) == int(cont.sum())
    valid = min(int(n_c), capacity)
    # Valid slots agree exactly (stable: ascending survivor indices).
    np.testing.assert_array_equal(
        np.asarray(sel_c)[:valid], np.asarray(sel_a)[:valid]
    )
    np.testing.assert_array_equal(
        np.asarray(sel_c)[:valid], np.flatnonzero(cont)[:valid]
    )


def test_progressive_single_sentinel_bitexact_vs_compacted():
    rng = np.random.default_rng(5)
    ens = random_ensemble(5, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < 0.9)
    cascade = _cascade(ens)
    ref = cascade.rank_compacted(X, mask, capacity=64)
    got = cascade.rank_progressive(X, mask, sentinels=[10], capacities=[64])
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))
    np.testing.assert_array_equal(
        np.asarray(ref.continue_mask), np.asarray(got.continue_mask)
    )
    assert ref.speedup == float(got.speedup)  # progressive speedup is lazy
    assert int(ref.overflow) == int(got.overflow) == 0


def test_progressive_single_sentinel_bitexact_under_overflow():
    rng = np.random.default_rng(6)
    ens = random_ensemble(6, n_trees=40, depth=3, n_features=8)
    Q, D, F = 4, 32, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens, k_s=16)  # 64 survivors
    ref = cascade.rank_compacted(X, mask, capacity=16)  # overflow 48
    got = cascade.rank_progressive(X, mask, sentinels=[10], capacities=[16])
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))
    assert int(ref.overflow) == int(got.overflow) == 48


def test_progressive_s3_launch_budget():
    """The acceptance contract: exactly 1 segmented head launch, ≤ S plain
    (tail) launches for an S=3 cascade."""
    rng = np.random.default_rng(7)
    ens = random_ensemble(7, n_trees=60, depth=4, n_features=16)
    Q, D, F = 6, 24, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    strategies = [
        (lambda p, m, k=k: ert_continue(p, m, k_s=k)) for k in (16, 10, 6)
    ]
    ops.reset_launch_counts()
    result = cascade.rank_progressive(
        X, mask, sentinels=[10, 20, 35], capacities=128, strategies=strategies
    )
    jax.block_until_ready(result.scores)
    counts = ops.launch_counts()
    assert counts["segmented"] == 1, counts
    # Exactly ONE tail launch — a regression to per-stage tails (the S-launch
    # pattern this engine replaces) must fail here, not sneak under a <= S.
    assert counts["plain"] == 1, counts


def test_progressive_nested_exit_semantics():
    """A doc that exits at stage 1 keeps its stage-1 prefix even when the
    stage-2 strategy alone would have continued it."""
    rng = np.random.default_rng(8)
    ens = random_ensemble(8, n_trees=60, depth=4, n_features=16)
    Q, D, F = 4, 16, 16
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    strategies = [
        lambda p, m: ert_continue(p, m, k_s=4),    # aggressive stage 1
        lambda p, m: m,                            # stage 2 would keep all
    ]
    result = cascade.rank_progressive(
        X, mask, sentinels=[10, 30], capacities=64, strategies=strategies
    )
    alive1 = np.asarray(result.stage_masks[0])
    alive2 = np.asarray(result.stage_masks[1])
    np.testing.assert_array_equal(alive2, alive1)   # nested: no resurrection
    prefix = np.asarray(result.partials)
    exited = np.asarray(mask) & ~alive1
    np.testing.assert_allclose(
        np.asarray(result.scores)[exited], prefix[..., 0][exited],
        rtol=0, atol=0,
    )
    # Survivors got strictly more trees than their stage-2 prefix.
    full, _ = partial_scores(ens, X.reshape(Q * D, F), ens.n_trees)
    np.testing.assert_allclose(
        np.asarray(result.scores)[alive2],
        np.asarray(full).reshape(Q, D)[alive2],
        rtol=1e-5, atol=1e-5,
    )


def test_progressive_sentinel_at_ensemble_end():
    """sS == n_trees: no tail trees remain, no tail launch is issued."""
    rng = np.random.default_rng(9)
    ens = random_ensemble(9, n_trees=32, depth=3, n_features=8)
    Q, D, F = 3, 16, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    ops.reset_launch_counts()
    result = cascade.rank_progressive(X, mask, sentinels=[16, 32], capacities=64)
    jax.block_until_ready(result.scores)
    counts = ops.launch_counts()
    assert counts == {"plain": 0, "segmented": 1}, counts
    full, _ = partial_scores(ens, X.reshape(Q * D, F), ens.n_trees)
    survivors = np.asarray(result.continue_mask)
    np.testing.assert_allclose(
        np.asarray(result.scores)[survivors],
        np.asarray(full).reshape(Q, D)[survivors],
        rtol=1e-5, atol=1e-5,
    )


def test_padded_forest_cached_on_ensemble():
    ens = random_ensemble(10, n_trees=24, depth=3, n_features=8)
    pf1 = ops.padded_forest(ens, boundaries=(10, 24))
    pf2 = ops.padded_forest(ens, boundaries=(10, 24))
    assert pf1 is pf2
    assert ops.padded_forest(ens) is ops.padded_forest(ens)
    assert ops.padded_forest(ens) is not pf1  # distinct layout, distinct entry


def test_head_tail_slices_cached():
    ens = random_ensemble(11, n_trees=24, depth=3, n_features=8)
    cascade = _cascade(ens)
    assert cascade._head_tail() is cascade._head_tail()


def test_overflow_is_lazy_device_scalar():
    rng = np.random.default_rng(12)
    ens = random_ensemble(12, n_trees=40, depth=3, n_features=8)
    Q, D, F = 4, 16, 8
    X = jnp.asarray(rng.normal(size=(Q, D, F)).astype(np.float32))
    mask = jnp.ones((Q, D), bool)
    cascade = _cascade(ens)
    for result in (
        cascade.rank_compacted(X, mask, capacity=16),
        cascade.rank_progressive(X, mask, sentinels=[10], capacities=16),
    ):
        assert isinstance(result.overflow, jax.Array)  # not a host int
        assert int(result.overflow) >= 0               # stats-path read works
    # Progressive speedup is also lazy (the reference paths return floats).
    prog = cascade.rank_progressive(X, mask, sentinels=[10], capacities=16)
    assert isinstance(prog.speedup, jax.Array)
    assert float(prog.speedup) > 1.0


def test_lear_classifier_kernel_path_matches_bitvector():
    """prob_continue(use_kernel=True) routes through the Pallas kernel and
    agrees with the pure-XLA bitvector path."""
    from repro.core.lear import LearClassifier

    rng = np.random.default_rng(13)
    clf = LearClassifier(
        forest=random_ensemble(13, n_trees=10, depth=4, n_features=12),
        sentinel=10,
    )
    X_aug = jnp.asarray(rng.normal(size=(3, 20, 12)).astype(np.float32))
    p_xla = clf.prob_continue(X_aug, use_kernel=False)
    p_pallas = clf.prob_continue(X_aug, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(p_pallas), np.asarray(p_xla), rtol=1e-5, atol=1e-6
    )


def test_bucket_capacity_policy():
    assert bucket_capacity(1, 10_000) == 64        # floor
    assert bucket_capacity(100, 10_000) == 128     # next power of two
    assert bucket_capacity(128, 10_000) == 128     # exact power stays
    assert bucket_capacity(5_000, 4_096) == 4_096  # clipped to limit
