"""Property-based tests (hypothesis) for system invariants.

Invariants:
1. Kernel/oracle agreement on arbitrary ensembles and inputs.
2. Early-exit strategy sanity: ERT keeps exactly min(k_s, n_docs); EPT is
   monotone in p and always ⊇ ERT(k_s).
3. Head+tail decomposition equals full scoring at any sentinel.
4. NDCG invariance under score-order-preserving transforms.
5. NequIP rotation equivariance: energies invariant, forces covariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.strategies import ept_continue, ert_continue
from repro.forest.ensemble import random_ensemble
from repro.forest.scoring import partial_scores, score_bitvector, score_numpy_oracle
from repro.kernels.ops import forest_score
from repro.metrics.ranking import ndcg_at_k

SETTINGS = dict(max_examples=20, deadline=None)


@given(
    n_trees=st.integers(1, 24),
    depth=st.integers(1, 6),
    n_feat=st.integers(1, 40),
    n_docs=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_kernel_matches_oracle_property(n_trees, depth, n_feat, n_docs, seed):
    rng = np.random.default_rng(seed)
    ens = random_ensemble(seed, n_trees=n_trees, depth=depth, n_features=n_feat)
    X = rng.normal(size=(n_docs, n_feat)).astype(np.float32)
    got = np.asarray(forest_score(ens, jnp.asarray(X), interpret=True))
    ref = score_numpy_oracle(ens, X)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@given(
    sentinel=st.integers(0, 20),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_head_tail_decomposition(sentinel, seed):
    rng = np.random.default_rng(seed)
    ens = random_ensemble(seed, n_trees=20, depth=4, n_features=6)
    X = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    head, tail = partial_scores(ens, X, sentinel)
    full = score_bitvector(ens, X)
    np.testing.assert_allclose(np.asarray(head + tail), np.asarray(full),
                               rtol=1e-4, atol=1e-5)


@given(
    k_s=st.integers(1, 30),
    n_docs=st.integers(2, 64),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ert_counts(k_s, n_docs, seed):
    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.normal(size=(4, n_docs)).astype(np.float32))
    mask = jnp.asarray(rng.random((4, n_docs)) < 0.8)
    cont = ert_continue(partial, mask, k_s=k_s)
    per_q = np.asarray(cont.sum(axis=1))
    expect = np.minimum(np.asarray(mask.sum(axis=1)), k_s)
    np.testing.assert_array_equal(per_q, expect)


@given(
    p1=st.floats(0.0, 1.0),
    p2=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ept_monotone_and_superset(p1, p2, seed):
    lo, hi = min(p1, p2), max(p1, p2)
    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.normal(size=(3, 40)).astype(np.float32))
    mask = jnp.ones((3, 40), bool)
    c_lo = ept_continue(partial, mask, k_s=10, p=lo)
    c_hi = ept_continue(partial, mask, k_s=10, p=hi)
    assert bool((~c_lo | c_hi).all())            # monotone: lo ⊆ hi
    c_ert = ert_continue(partial, mask, k_s=10)
    assert bool((~c_ert | c_lo).all())           # EPT ⊇ ERT at any p ≥ 0


@given(
    scale=st.floats(0.1, 10.0),
    shift=st.floats(-5.0, 5.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ndcg_invariant_to_monotone_transform(scale, shift, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(5, 30)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 5, size=(5, 30)))
    mask = jnp.asarray(rng.random((5, 30)) < 0.9)
    a = ndcg_at_k(scores, labels, mask, 10)
    b = ndcg_at_k(scores * scale + shift, labels, mask, 10)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**10))
def test_nequip_rotation_equivariance(seed):
    from repro.configs import get_smoke_config
    from repro.models import nequip as nq
    from repro.models.so3 import _random_rotation

    cfg = get_smoke_config("nequip")
    rng = np.random.default_rng(seed)
    N, E = 12, 30
    params = nq.init(cfg, jax.random.key(seed))
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    species = rng.integers(0, cfg.n_species, size=N).astype(np.int32)
    src = rng.integers(0, N, size=E).astype(np.int32)
    dst = rng.integers(0, N, size=E).astype(np.int32)

    def energy(p):
        return nq.forward_energy(
            cfg, params, jnp.asarray(p), jnp.asarray(species),
            jnp.asarray(src), jnp.asarray(dst),
        )[0]

    R = _random_rotation(rng).astype(np.float32)
    e1 = float(energy(pos))
    e2 = float(energy(pos @ R.T))
    np.testing.assert_allclose(e1, e2, rtol=2e-4, atol=2e-5)

    f1 = np.asarray(jax.grad(lambda p: energy(p))(jnp.asarray(pos)))
    f2 = np.asarray(jax.grad(lambda p: energy(p))(jnp.asarray(pos @ R.T)))
    np.testing.assert_allclose(f1 @ R.T, f2, rtol=2e-3, atol=2e-4)
