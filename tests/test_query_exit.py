"""Query-level early exit: conformance, contracts, and properties.

Pinned by the strategy conformance harness (tests/strategy_harness.py):

- ``margin=inf`` (exact regime) is SCORE-PRESERVING: bit-exact with
  ``query_exit=None`` in every execution mode;
- finite margin (approximate regime): queries that did NOT exit stay
  bit-exact with the query-exit-off run, exited queries keep partials;
- the engine agrees with a from-scratch numpy replay of the cascade
  (stage masks and exit flags exactly, scores to reassociation);
- fused ≡ staged ≡ auto with query exit on, off, and per margin regime;
- the launch-count contract: the tail launch moves under the run-time
  gate (counted "gated") exactly when query exit is enabled, and cached
  step re-executions move no counters;
- ``query_converged`` edge semantics: the no-challenger rule, tie
  conservatism, k clamped to D, and the ``margin=inf`` ⇔ zero-alive
  equivalence (randomized hypothesis sweeps of the same properties
  live in tests/test_strategies_property.py);
- the serving tier: ``RankingService(query_exit=...)`` keeps margin=inf
  responses bit-exact, counts exited queries, and feeds the tail-skip
  EMA into the mode-pick cost model.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import QueryExitConfig, query_converged
from strategy_harness import (
    assert_matches_oracle,
    expected_launches,
    make_problem,
    make_ranker,
    measured_launches,
    run_all_modes,
    run_mode,
)

SENTINELS = (10, 20, 30)


def test_query_exit_config_validates():
    with pytest.raises(AssertionError):
        QueryExitConfig(k=0)
    with pytest.raises(AssertionError):
        QueryExitConfig(margin=-1.0)
    with pytest.raises(AssertionError):
        QueryExitConfig(from_stage=-1)
    assert QueryExitConfig() == QueryExitConfig(k=10, margin=math.inf)
    assert hash(QueryExitConfig(k=3)) is not None  # static cache key


@pytest.mark.parametrize("mode", ["fused", "staged", "auto"])
def test_margin_inf_is_score_preserving(mode):
    """Exact regime: only zero-alive queries exit, so skipping their tail
    work cannot change any score — bit-exact with the knob off."""
    ens, X, mask = make_problem(11)
    r = make_ranker(ens)
    base = run_mode(r, X, mask, SENTINELS, mode)
    qe = run_mode(r, X, mask, SENTINELS, mode,
                  query_exit=QueryExitConfig(k=3))
    np.testing.assert_array_equal(
        np.asarray(base.scores), np.asarray(qe.scores)
    )
    np.testing.assert_array_equal(
        np.asarray(base.continue_mask), np.asarray(qe.continue_mask)
    )
    assert base.query_exited is None
    assert qe.query_exited.shape == (X.shape[0],)


@pytest.mark.parametrize(
    "query_exit",
    [None, QueryExitConfig(k=3), QueryExitConfig(k=3, margin=0.1),
     QueryExitConfig(k=3, margin=0.1, from_stage=1)],
    ids=["off", "inf", "margin0.1", "from_stage1"],
)
def test_all_modes_agree(query_exit):
    """fused ≡ staged ≡ auto, for every query-exit regime."""
    ens, X, mask = make_problem(12)
    run_all_modes(make_ranker(ens), X, mask, SENTINELS, query_exit)


@pytest.mark.parametrize(
    "query_exit",
    [None, QueryExitConfig(k=3), QueryExitConfig(k=3, margin=0.1),
     QueryExitConfig(k=3, margin=0.1, from_stage=1)],
    ids=["off", "inf", "margin0.1", "from_stage1"],
)
def test_engine_matches_numpy_replay(query_exit):
    """Stage masks and exit flags agree EXACTLY with the from-scratch
    oracle; scores agree to reassociation."""
    ens, X, mask = make_problem(13)
    r = make_ranker(ens)
    result = run_mode(r, X, mask, SENTINELS, "fused", query_exit)
    assert_matches_oracle(result, ens, X, mask, SENTINELS, query_exit)


def test_finite_margin_nonexited_queries_bitexact():
    """Approximate regime damage is CONTAINED: a query that did not take
    the query-level exit scores bit-exactly as with the knob off."""
    ens, X, mask = make_problem(14)
    r = make_ranker(ens)
    base = run_mode(r, X, mask, SENTINELS, "fused")
    qe = run_mode(r, X, mask, SENTINELS, "fused",
                  query_exit=QueryExitConfig(k=3, margin=0.05))
    exited = np.asarray(qe.query_exited)
    kept = ~exited
    assert kept.any(), "problem must leave some queries un-exited"
    np.testing.assert_array_equal(
        np.asarray(base.scores)[kept], np.asarray(qe.scores)[kept]
    )


def test_exited_query_docs_leave_alive_mask():
    """From its exit stage on, an exited query contributes no alive docs
    (its remaining work is actually skipped, not just flagged)."""
    ens, X, mask = make_problem(15)
    r = make_ranker(ens)
    qe = run_mode(r, X, mask, SENTINELS, "fused",
                  query_exit=QueryExitConfig(k=3, margin=0.1))
    exited = np.asarray(qe.query_exited)
    assert exited.any(), "problem must exit at least one query"
    final = np.asarray(qe.stage_masks[-1])
    assert not final[exited].any()


def test_degenerate_margin_exits_everything_after_stage0():
    """k ≥ D with finite margin: no challenger can exist, every query
    converges at stage 0 and ALL scores stay at the first prefix — the
    run-time tail gate demonstrably skipped the tail computation."""
    ens, X, mask = make_problem(16)
    D = X.shape[1]
    r = make_ranker(ens)
    qe = run_mode(r, X, mask, SENTINELS, "fused",
                  query_exit=QueryExitConfig(k=D, margin=0.0))
    assert np.asarray(qe.query_exited).all()
    from repro.forest.scoring import partial_scores
    Q, _, F = X.shape
    prefix0 = np.asarray(
        partial_scores(ens, X.reshape(Q * D, F), SENTINELS[0])[0]
    ).reshape(Q, D)
    np.testing.assert_allclose(
        np.asarray(qe.scores), prefix0, rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("mode", ["fused", "staged", "auto"])
@pytest.mark.parametrize("qe_on", [False, True], ids=["qe_off", "qe_on"])
def test_launch_contract(mode, qe_on):
    """Trace-time plan: the tail counts "gated" exactly when query exit
    is on; auto's plan is the sum of both branch plans. Re-running the
    cached step moves NO counters."""
    ens, X, mask = make_problem(17)
    r = make_ranker(ens)  # fresh ranker: empty step cache
    query_exit = QueryExitConfig(k=3, margin=0.1) if qe_on else None
    counts = measured_launches(r, X, mask, SENTINELS, mode, query_exit)
    assert counts == expected_launches(
        mode, S=len(SENTINELS), has_tail=True, query_exit_on=qe_on
    ), (mode, qe_on, counts)
    again = measured_launches(r, X, mask, SENTINELS, mode, query_exit)
    assert again == {"plain": 0, "segmented": 0, "gated": 0}, again


def test_no_tail_configuration_has_no_gate():
    """Sentinel at T: nothing to gate — no gated launch even with query
    exit enabled, and scores still match the off run bit-for-bit."""
    ens, X, mask = make_problem(18)
    sentinels = (10, 20, ens.n_trees)
    r = make_ranker(ens)
    counts = measured_launches(
        r, X, mask, sentinels, "fused", QueryExitConfig(k=3, margin=0.1)
    )
    assert counts == expected_launches(
        "fused", S=3, has_tail=False, query_exit_on=True
    ), counts
    assert counts["gated"] == 0


def test_query_exit_is_part_of_step_cache_key():
    """Toggling the knob on one ranker compiles distinct steps — results
    for the off-config stay correct after the on-config ran."""
    ens, X, mask = make_problem(19)
    r = make_ranker(ens)
    before = run_mode(r, X, mask, SENTINELS, "fused")
    run_mode(r, X, mask, SENTINELS, "fused", QueryExitConfig(k=2, margin=0.0))
    after = run_mode(r, X, mask, SENTINELS, "fused")
    np.testing.assert_array_equal(
        np.asarray(before.scores), np.asarray(after.scores)
    )
    assert after.query_exited is None


# --- query_converged unit properties (deterministic edges) -------------


def test_converged_inf_margin_is_zero_alive():
    partial = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    alive = jnp.asarray([[False, False], [True, False]])
    got = query_converged(partial, alive, k=1, margin=math.inf)
    np.testing.assert_array_equal(np.asarray(got), [True, False])


def test_converged_no_challenger_rule():
    # n_alive <= k: vacuously converged under any finite margin.
    partial = jnp.asarray([[5.0, 1.0, 0.0]])
    alive = jnp.asarray([[True, True, False]])
    assert bool(query_converged(partial, alive, k=2, margin=1e9)[0])


def test_converged_tie_is_conservative():
    # kth == challenger: difference 0 is never > margin — not converged.
    partial = jnp.asarray([[2.0, 2.0, 2.0]])
    alive = jnp.ones((1, 3), bool)
    assert not bool(query_converged(partial, alive, k=1, margin=0.0)[0])
    # A strict gap larger than the margin converges.
    partial = jnp.asarray([[2.0, 0.5, 0.4]])
    assert bool(query_converged(partial, alive, k=1, margin=1.0)[0])


def test_converged_k_clamped_to_d():
    partial = jnp.asarray([[1.0, 2.0]])
    alive = jnp.ones((1, 2), bool)
    assert bool(query_converged(partial, alive, k=7, margin=0.0)[0])
    assert not bool(query_converged(partial, alive, k=7, margin=math.inf)[0])


# Hypothesis-based properties (ragged masks, ties, k ≥ D sweeps) live in
# tests/test_strategies_property.py so this module still runs where
# hypothesis is not installed.

# --- serving tier ------------------------------------------------------


def _service(query_exit=None, execution_mode="auto"):
    from repro.core.lear import LearClassifier
    from repro.forest.ensemble import random_ensemble
    from repro.serve.ranking_service import RankingService, ServiceConfig

    ens = random_ensemble(0, n_trees=64, depth=4, n_features=12)
    clfs = [
        LearClassifier(
            forest=random_ensemble(100 + i, n_trees=10, depth=3,
                                   n_features=16),
            sentinel=s,
        )
        for i, s in enumerate((8, 28))
    ]
    svc = RankingService(
        ens, clfs[0],
        ServiceConfig(
            threshold=0.4, execution_mode=execution_mode,
            launch_overhead_trees=50.0, query_exit=query_exit,
        ),
        extra_classifiers=clfs[1:],
    )
    gate = lambda p, m, features=None: m & (features[..., 0] > 0.0)
    svc.stage_strategies = [gate] * len(svc.sentinels)
    return svc


def _gated_batch(rng, Q, D, F, survive_frac):
    X = rng.normal(size=(Q, D, F)).astype(np.float32)
    flags = np.zeros((Q, D), np.float32) - 1.0
    flags[:, : int(round(survive_frac * D))] = 1.0
    X[..., 0] = flags
    return jnp.asarray(X), jnp.ones((Q, D), bool)


def test_service_query_exit_margin_inf_bitexact_and_counted():
    """Service-level conformance: margin=inf responses are bit-exact with
    the knob off; an all-exit batch is counted in the stats and drives
    the tail-skip EMA the cost model reads."""
    rng = np.random.default_rng(2)
    base = _service()
    qe = _service(query_exit=QueryExitConfig(k=5))
    Q, D, F = 2, 64, 12
    batches = [_gated_batch(rng, Q, D, F, f) for f in (0.5, 0.0, 0.3)]
    for X, m in batches:
        _, s0 = base.rank_batch(X, m)
        _, s1 = qe.rank_batch(X, m)
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert base.stats.queries_exited == 0
    assert qe.stats.queries_exited == Q          # the all-exit batch
    assert qe.stats.query_exit_rate == pytest.approx(Q / (3 * Q))
    assert 0.0 < qe._active_state().tail_skip < 1.0
    assert base._query_exit_rate_estimate() == 0.0
    assert qe._query_exit_rate_estimate() == qe._active_state().tail_skip
    qe._pick_mode(Q * D)  # host mirror prices with the rate — must not raise


def test_tier_stats_expose_query_exit():
    from repro.serve.tier import ServingTier, TierConfig

    svc = _service(query_exit=QueryExitConfig(k=5))
    tier = ServingTier(
        svc, n_features=12,
        config=TierConfig(warmup=False, persistent_cache=False),
    )
    got = tier.stats()["service"]
    assert got["queries_exited"] == 0
    assert got["query_exit_rate"] == 0.0
