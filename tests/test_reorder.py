"""Learned tree reordering: invariance, ordering quality, and cascade
conformance on the permuted ensemble.

Pinned by the strategy conformance harness (tests/strategy_harness.py):

- identity permutation is BIT-exact through every scoring path;
- arbitrary permutations agree with the source ensemble up to
  reassociation of the tree-axis reduction (the ``_pairwise_tree_sum``
  tolerance), full-traversal and kernel paths alike;
- ``reorder_trees`` validates its permutation and never mutates the
  source ensemble (its padded-buffer cache stays independent);
- greedy residual-fit order beats boosting order on prefix convergence
  (fixed seed), and both learned orders are true permutations;
- the progressive engine is conformant ON the reordered ensemble:
  fused ≡ staged ≡ auto, oracle replay agreement, and the combined
  configuration (reorder + query-level exit) stays score-preserving at
  ``margin=inf``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import QueryExitConfig
from repro.forest.ensemble import random_ensemble
from repro.forest.reorder import (
    full_from_contributions,
    greedy_order,
    learn_order,
    per_tree_contributions,
    prefix_residual,
    reorder_trees,
    reordered_ensemble,
    variance_order,
)
from repro.forest.scoring import score_bitvector, score_numpy_oracle
from repro.kernels import ops
from strategy_harness import (
    assert_matches_oracle,
    make_problem,
    make_ranker,
    run_all_modes,
    run_mode,
)

SENTINELS = (10, 20, 30)


def _fixture(seed=3, B=200, T=64, F=16):
    ens = random_ensemble(seed, n_trees=T, depth=5, n_features=F)
    rng = np.random.default_rng(seed)
    Xv = jnp.asarray(rng.standard_normal((B, F)).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((80, F)).astype(np.float32))
    return ens, Xv, X


def test_identity_reorder_is_bitexact():
    ens, _, X = _fixture()
    same = reorder_trees(ens, np.arange(ens.n_trees))
    np.testing.assert_array_equal(
        np.asarray(score_bitvector(ens, X)),
        np.asarray(score_bitvector(same, X)),
    )
    np.testing.assert_array_equal(
        np.asarray(ops.forest_score(ens, X, interpret=True)),
        np.asarray(ops.forest_score(same, X, interpret=True)),
    )


@pytest.mark.parametrize("perm_seed", [0, 1])
def test_arbitrary_permutation_within_tree_sum_tolerance(perm_seed):
    """Permutation invariance of the additive model: any tree order
    scores the same documents to reassociation tolerance — on the pure
    path, the kernel path, and the numpy oracle."""
    ens, _, X = _fixture()
    perm = np.random.default_rng(perm_seed).permutation(ens.n_trees)
    permuted = reorder_trees(ens, perm)
    ref = np.asarray(score_bitvector(ens, X))
    np.testing.assert_allclose(
        np.asarray(score_bitvector(permuted, X)), ref,
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.forest_score(permuted, X, interpret=True)), ref,
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        score_numpy_oracle(permuted, np.asarray(X)), ref,
        rtol=1e-5, atol=1e-5,
    )


def test_contributions_compose_to_full_score():
    """per_tree_contributions + the sanctioned reducer reproduce the
    reference score — the decomposition the order learner fits."""
    ens, Xv, _ = _fixture()
    contrib = per_tree_contributions(ens, Xv)
    assert contrib.shape == (Xv.shape[0], ens.n_trees)
    np.testing.assert_allclose(
        np.asarray(full_from_contributions(ens, contrib)),
        np.asarray(score_bitvector(ens, Xv)),
        rtol=1e-5, atol=1e-5,
    )


def test_reorder_rejects_non_permutations():
    ens, _, _ = _fixture()
    with pytest.raises(AssertionError):
        reorder_trees(ens, np.zeros(ens.n_trees, np.int64))  # repeats
    with pytest.raises(AssertionError):
        reorder_trees(ens, np.arange(ens.n_trees - 1))       # wrong length


def test_reorder_does_not_mutate_source():
    """The permuted ensemble is a NEW instance; the source (and its
    padded-buffer cache identity) is untouched."""
    ens, _, X = _fixture()
    before = np.asarray(score_bitvector(ens, X)).copy()
    pf_before = ops.padded_forest(ens, boundaries=(10, ens.n_trees))
    permuted = reorder_trees(
        ens, np.random.default_rng(0).permutation(ens.n_trees)
    )
    assert permuted is not ens
    np.testing.assert_array_equal(
        np.asarray(score_bitvector(ens, X)), before
    )
    # Same boundaries, same instance → the source's cache still serves;
    # the permuted instance pads its own layout.
    assert ops.padded_forest(ens, boundaries=(10, ens.n_trees)) is pf_before
    pf_perm = ops.padded_forest(permuted, boundaries=(10, ens.n_trees))
    assert pf_perm is not pf_before


def test_greedy_beats_boosting_order_on_prefix_convergence():
    """The point of the whole exercise: after the same number of trees,
    the greedy order's partial sum is closer to the full score than
    boosting order — at every quartile prefix."""
    ens, Xv, _ = _fixture()
    contrib = np.asarray(per_tree_contributions(ens, Xv))
    T = ens.n_trees
    identity = np.arange(T)
    greedy = greedy_order(contrib)
    r_id = prefix_residual(contrib, identity)
    r_gr = prefix_residual(contrib, greedy)
    for frac in (0.25, 0.5, 0.75):
        m = int(T * frac)
        assert r_gr[m] <= r_id[m], (frac, r_gr[m], r_id[m])
    # Both residual curves end at zero (the full sum is order-free).
    assert r_gr[-1] == pytest.approx(0.0, abs=1e-9)
    assert r_id[-1] == pytest.approx(0.0, abs=1e-9)


def test_learned_orders_are_permutations():
    ens, Xv, _ = _fixture()
    contrib = np.asarray(per_tree_contributions(ens, Xv))
    T = ens.n_trees
    for order in (
        greedy_order(contrib),
        variance_order(contrib),
        learn_order(ens, Xv, method="greedy"),
        learn_order(ens, Xv, method="variance"),
        learn_order(ens, Xv, method="identity"),
        learn_order(ens, Xv, method="greedy", max_docs=50),  # subsample
    ):
        np.testing.assert_array_equal(np.sort(order), np.arange(T))
    with pytest.raises(AssertionError):
        learn_order(ens, Xv, method="nope")


def test_learn_order_is_deterministic():
    ens, Xv, _ = _fixture()
    a = learn_order(ens, Xv, method="greedy")
    b = learn_order(ens, Xv, method="greedy")
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize(
    "query_exit", [None, QueryExitConfig(k=3), QueryExitConfig(k=3, margin=0.1)],
    ids=["off", "inf", "margin0.1"],
)
def test_cascade_conformance_on_reordered_ensemble(query_exit):
    """The engine treats a permuted ensemble like any other: all three
    modes agree bit-for-bit and the numpy replay (run on the permuted
    ensemble) matches — with query exit off, exact, and approximate."""
    ens, X, mask = make_problem(21)
    Q, D, F = X.shape
    permuted, order = reordered_ensemble(
        ens, X.reshape(Q * D, F), method="greedy"
    )
    r = make_ranker(permuted)
    results = run_all_modes(r, X, mask, SENTINELS, query_exit)
    assert_matches_oracle(
        results["fused"], permuted, X, mask, SENTINELS, query_exit
    )


def test_reorder_plus_query_exit_margin_inf_is_score_preserving():
    """The combined configuration: on the SAME permuted ensemble,
    enabling exact query exit changes no score."""
    ens, X, mask = make_problem(22)
    Q, D, F = X.shape
    permuted, _ = reordered_ensemble(ens, X.reshape(Q * D, F))
    r = make_ranker(permuted)
    base = run_mode(r, X, mask, SENTINELS, "fused")
    qe = run_mode(r, X, mask, SENTINELS, "fused",
                  query_exit=QueryExitConfig(k=3))
    np.testing.assert_array_equal(
        np.asarray(base.scores), np.asarray(qe.scores)
    )
