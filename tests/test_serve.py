"""RankingService serving-path contracts: capacity policy, sync discipline,
adaptive execution mode, and small-query edges.

These tests drive the service with deterministic feature-keyed stage
strategies (continue ⇔ ``features[..., 0] > 0``) so survivor counts are
controlled exactly per batch without training a classifier.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lear import LearClassifier
from repro.forest.ensemble import random_ensemble
from repro.serve.ranking_service import RankingService, ServiceConfig


def _service(seed=0, n_trees=64, sentinels=(8, 28), **knobs):
    ens = random_ensemble(seed, n_trees=n_trees, depth=4, n_features=12)
    clfs = [
        LearClassifier(
            forest=random_ensemble(100 + i, n_trees=10, depth=3, n_features=16),
            sentinel=s,
        )
        for i, s in enumerate(sentinels)
    ]
    svc = RankingService(
        ens, clfs[0], ServiceConfig(threshold=0.4, **knobs),
        extra_classifiers=clfs[1:],
    )
    # Deterministic stage gate: continue ⇔ feature 0 positive. Replacing the
    # strategy list BEFORE the first batch keeps the jitted-step cache to
    # one entry per mode.
    gate = lambda p, m, features=None: m & (features[..., 0] > 0.0)
    svc.stage_strategies = [gate] * len(svc.sentinels)
    return svc


def _batch(rng, Q, D, F, survive_frac):
    """A [Q, D, F] batch whose gate-survivor count is survive_frac exactly."""
    X = rng.normal(size=(Q, D, F)).astype(np.float32)
    flags = np.zeros((Q, D), np.float32) - 1.0
    n = int(round(survive_frac * D))
    flags[:, :n] = 1.0
    X[..., 0] = flags
    return jnp.asarray(X), jnp.ones((Q, D), bool)


def test_capacity_never_shrinks_below_observed_peak():
    """Regression: one sparse batch must not shrink a stage's bucket under
    already-observed traffic — oscillating survivor counts cause zero
    overflow after the warmup batch."""
    rng = np.random.default_rng(1)
    svc = _service(execution_mode="fused")
    Q, D, F = 2, 64, 12
    dense = _batch(rng, Q, D, F, survive_frac=0.8)   # 102 survivors
    sparse = _batch(rng, Q, D, F, survive_frac=0.05)  # 6 survivors
    svc.rank_batch(*dense)                 # warmup: cold-start bucket (64)
    warmup_overflow = svc.stats.overflow_docs
    assert warmup_overflow > 0             # proves the scenario bites
    for _ in range(3):                     # oscillate: sparse then dense
        svc.rank_batch(*sparse)
        svc.rank_batch(*dense)
    assert svc.stats.overflow_docs == warmup_overflow  # zero after warmup
    # The bucket ratcheted up and the cold-start floor still holds.
    caps = svc._pick_capacities(Q * D)
    assert all(c >= 128 for c in caps), caps


def test_rank_batch_single_fused_device_read(monkeypatch):
    """The whole stats path is ONE jax.device_get — folding mask.sum() into
    the fused read removed the extra per-batch host syncs."""
    rng = np.random.default_rng(2)
    svc = _service(execution_mode="fused")
    X, mask = _batch(rng, 2, 32, 12, survive_frac=0.3)
    svc.rank_batch(X, mask)  # compile outside the counted window
    calls = []
    real = jax.device_get
    monkeypatch.setattr(
        jax, "device_get", lambda *a, **k: calls.append(1) or real(*a, **k)
    )
    svc.rank_batch(X, mask)
    assert len(calls) == 1, len(calls)


def test_top_k_clamped_to_candidate_count():
    """A query block smaller than top_k returns all D candidates instead of
    crashing jax.lax.top_k."""
    rng = np.random.default_rng(3)
    svc = _service(execution_mode="fused", top_k=10)
    X, mask = _batch(rng, 2, 4, 12, survive_frac=0.5)
    top_idx, scores = svc.rank_batch(X, mask)
    assert top_idx.shape == (2, 4)
    assert scores.shape == (2, 4)


def test_adaptive_mode_tracks_continue_rate():
    """The service picks per-stage tails when survivors shrink fast (big
    head-work saving) and the fused head when survivors stay large, from
    its OBSERVED continue rates; the first batch defaults to fused."""
    rng = np.random.default_rng(4)
    Q, D, F = 2, 64, 12
    # survivor_ema=1.0: track the last batch exactly (keeps the arithmetic
    # of the crossover deterministic in the test).
    svc = _service(
        execution_mode="auto", launch_overhead_trees=512.0, survivor_ema=1.0
    )
    lo = _batch(rng, Q, D, F, survive_frac=0.05)
    hi = _batch(rng, Q, D, F, survive_frac=0.95)

    svc.rank_batch(*lo)                      # cold start: no observed rates
    assert svc.stats.batches_fused == 1
    svc.rank_batch(*lo)                      # observed 5% continue → staged
    assert svc.stats.batches_staged == 1
    for _ in range(4):                       # EMA converges to 95% → fused
        svc.rank_batch(*hi)
    assert svc._pick_mode(Q * D) == "fused"
    assert svc.stats.batches_fused > 1

    # Forced modes bypass the cost model entirely.
    forced = _service(execution_mode="staged", launch_overhead_trees=512.0)
    forced.rank_batch(*hi)
    assert forced.stats.batches_staged == 1


def test_rank_batch_zero_host_transfers_with_lear_classifier():
    """The device-residency acceptance contract: with a REAL LEAR
    classifier in the loop (kernel-scored, device-built augmented
    features), a steady-state rank_batch performs ZERO implicit
    device→host transfers — the single fused jax.device_get at the end is
    the only read."""
    from repro.utils import count_host_transfers

    rng = np.random.default_rng(6)
    ens = random_ensemble(60, n_trees=64, depth=4, n_features=12)
    clfs = [
        LearClassifier(
            forest=random_ensemble(160 + i, n_trees=10, depth=3,
                                   n_features=16),
            sentinel=s,
        )
        for i, s in enumerate((8, 28))
    ]
    svc = RankingService(
        ens, clfs[0],
        ServiceConfig(
            threshold=0.4, execution_mode="auto",
            launch_overhead_trees=512.0,
        ),
        extra_classifiers=clfs[1:],
    )
    X = jnp.asarray(rng.normal(size=(2, 32, 12)).astype(np.float32))
    mask = jnp.ones((2, 32), bool)
    # Warm up both the cold-start trace and the steady-state trace (the
    # capacity ratchet may re-bucket after batch 1).
    svc.rank_batch(X, mask)
    svc.rank_batch(X, mask)
    with count_host_transfers() as counts:
        svc.rank_batch(X, mask)
    assert counts.explicit_gets == 1, counts
    assert counts.implicit_syncs == 0, counts


def test_service_device_pick_matches_host_reference():
    """Acceptance: the in-program (lax.cond) pick chooses exactly the
    branch the host-side reference `_pick_mode` predicts, across a
    continue-rate sweep injected as the survivor EMA."""
    rng = np.random.default_rng(7)
    Q, D, F = 2, 64, 12
    svc = _service(
        execution_mode="auto", launch_overhead_trees=512.0, survivor_ema=1.0
    )
    X, mask = _batch(rng, Q, D, F, survive_frac=0.5)
    svc.rank_batch(X, mask)  # warm up; establishes peaks/EMA
    for rate in (0.02, 0.05, 0.15, 0.3, 0.5, 0.8, 0.95):
        svc._stage_ema = [rate * Q * D] * len(svc.sentinels)
        host_pick = svc._pick_mode(Q * D)
        before = (svc.stats.batches_fused, svc.stats.batches_staged)
        svc.rank_batch(X, mask)
        df = svc.stats.batches_fused - before[0]
        ds = svc.stats.batches_staged - before[1]
        device_pick = "staged" if ds else "fused"
        assert (df, ds) in ((1, 0), (0, 1))
        assert device_pick == host_pick, (rate, device_pick, host_pick)


def test_adaptive_state_is_per_batch_shape():
    """Survivor peaks and the continue-rate EMA are keyed by the padded
    batch shape: a sparse trickle at one shape must not shrink capacities
    or skew the mode pick of another shape's bucket."""
    rng = np.random.default_rng(8)
    svc = _service(execution_mode="fused")
    dense = _batch(rng, 2, 64, 12, survive_frac=0.8)
    tiny = _batch(rng, 1, 8, 12, survive_frac=0.0)
    svc.rank_batch(*dense)
    svc.rank_batch(*dense)
    big = svc.bucket_state(2, 64)
    peaks_before = list(big.peaks)
    ema_before = list(big.ema)
    for _ in range(3):
        svc.rank_batch(*tiny)
    # The tiny bucket adapted independently...
    small = svc.bucket_state(1, 8)
    assert small.peaks is not None and small.peaks != peaks_before
    # ...and the bulk bucket's state is untouched by the trickle.
    assert big.peaks == peaks_before
    assert big.ema == ema_before
    # The introspection surface follows the most recently served shape.
    assert svc._stage_ema == small.ema
    svc.rank_batch(*dense)
    assert svc._stage_ema == big.ema


def test_modes_serve_identical_scores():
    """Fused and staged services return identical responses on a
    non-overflow batch (the engine's bit-exactness surfaces end to end)."""
    rng = np.random.default_rng(5)
    X, mask = _batch(rng, 2, 32, 12, survive_frac=0.25)
    out = {}
    for mode in ("fused", "staged"):
        svc = _service(execution_mode=mode)
        out[mode] = svc.rank_batch(X, mask)
        assert svc.stats.overflow_docs == 0
    np.testing.assert_array_equal(out["fused"][0], out["staged"][0])
    np.testing.assert_array_equal(out["fused"][1], out["staged"][1])
